"""§Perf hillclimbing runner: three chosen (arch x shape) pairs, iterating
on the dominant roofline term.  Writes results/hillclimb.json.

Run:  PYTHONPATH=src python scripts/hillclimb.py [pair ...]
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import dryrun as D  # noqa: E402  (sets 512 devices)

# (pair-name, arch, shape, iteration-name, run_one kwargs)
EXPERIMENTS = [
    # ---- H1: deepseek train_4k — collective-dominant (GShard dispatch) ---
    ("ds_train", "deepseek-v3-671b", "train_4k", "baseline_gshard", {}),
    ("ds_train", "deepseek-v3-671b", "train_4k", "it1_expert_parallel",
     {"cfg_overrides": {"moe_impl": "ep"}}),
    ("ds_train", "deepseek-v3-671b", "train_4k", "it2_ep_cap1.0",
     {"cfg_overrides": {"moe_impl": "ep", "moe_capacity_factor": 1.0}}),
    ("ds_train", "deepseek-v3-671b", "train_4k", "it3_gshard_cap1.0",
     {"cfg_overrides": {"moe_capacity_factor": 1.0}}),

    # ---- H2: qwen2.5 train_4k — memory-dominant (attention probs, remat) -
    ("qw_train", "qwen2.5-14b", "train_4k", "baseline_no_seqpar",
     {"seq_parallel": False}),
    ("qw_train", "qwen2.5-14b", "train_4k", "it1_seq_parallel", {}),
    ("qw_train", "qwen2.5-14b", "train_4k", "it2_no_remat",
     {"remat": False}),
    ("qw_train", "qwen2.5-14b", "train_4k", "it3_no_remat_no_seqpar",
     {"remat": False, "seq_parallel": False}),
    ("qw_train", "qwen2.5-14b", "train_4k", "it4_qchunk_512",
     {"cfg_overrides": {"attn_q_chunk": 512}}),
    ("qw_train", "qwen2.5-14b", "train_4k", "it5_qchunk_4096",
     {"cfg_overrides": {"attn_q_chunk": 4096}}),

    # ---- H3: deepseek decode_32k — worst fit (242 GiB/dev baseline) ------
    ("ds_decode", "deepseek-v3-671b", "decode_32k", "baseline_tp_only", {}),
    ("ds_decode", "deepseek-v3-671b", "decode_32k", "it1_2d_weight_shard",
     {"serve_fsdp": True}),
    ("ds_decode", "deepseek-v3-671b", "decode_32k", "it2_2d_plus_ep",
     {"serve_fsdp": True, "cfg_overrides": {"moe_impl": "ep"}}),
    ("ds_decode", "deepseek-v3-671b", "decode_32k", "it3_2d_fp8_cache",
     {"serve_fsdp": True,
      "cfg_overrides": {"cache_dtype": "float8_e4m3fn"}}),

    # ---- H4 (bonus): zamba2 train_4k — SSD chunk-size blocking knob ------
    ("zb_train", "zamba2-2.7b", "train_4k", "baseline_chunk256", {}),
    ("zb_train", "zamba2-2.7b", "train_4k", "it1_chunk128",
     {"cfg_overrides": {"ssm_chunk": 128}}),
    ("zb_train", "zamba2-2.7b", "train_4k", "it2_chunk64",
     {"cfg_overrides": {"ssm_chunk": 64}}),
    ("zb_train", "zamba2-2.7b", "train_4k", "it3_chunk512",
     {"cfg_overrides": {"ssm_chunk": 512}}),
]


def main():
    only = set(sys.argv[1:])
    out_path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "hillclimb.json")
    results = []
    if os.path.exists(out_path):
        results = json.load(open(out_path))
    done = {(r["pair"], r["iteration"]) for r in results}
    for pair, arch, shape, itname, kw in EXPERIMENTS:
        if only and pair not in only:
            continue
        if (pair, itname) in done:
            print(f"skip {pair}/{itname} (cached)")
            continue
        print(f"=== {pair}/{itname} ===", flush=True)
        try:
            r = D.run_one(arch, shape, multi_pod=False, **kw)
            r["pair"], r["iteration"] = pair, itname
            rf = r["roofline"]
            print(f"  mem={r['bytes_per_device'] / 2**30:.2f}GiB "
                  f"C={rf['compute_s']:.3f} M={rf['memory_s']:.3f} "
                  f"X={rf['collective_s']:.3f} dom={rf['dominant']} "
                  f"useful={r['useful_ratio']}", flush=True)
        except Exception as e:  # noqa: BLE001
            r = {"pair": pair, "iteration": itname, "status": "FAIL",
                 "error": f"{type(e).__name__}: {e}"[:500]}
            print(f"  FAIL {r['error'][:200]}", flush=True)
        results.append(r)
        json.dump(results, open(out_path, "w"), indent=1)
    print("hillclimb done")


if __name__ == "__main__":
    main()
