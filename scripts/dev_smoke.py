"""Dev harness: forward + train + prefill/decode for every reduced config,
plus the GNN serving / distributed-training / docs stages.

Run all stages with no arguments, or name a subset::

    PYTHONPATH=src python scripts/dev_smoke.py
    PYTHONPATH=src python scripts/dev_smoke.py gemma_7b serve_gnn
    PYTHONPATH=src python scripts/dev_smoke.py --help     # list stages
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.data.pipeline import input_specs
from repro.models.transformer import model as M
from repro.optim import AdamW

EXTRA_STAGES = {
    "serve_gnn": "online GNN inference serving smoke (repro.serving)",
    "dist_gnn": "2-device mini-batch gradient-equivalence subprocess",
    "kernels": "Pallas-kernel grad-equivalence subprocesses (interpret "
               "mode): 2-device fused aggregation, one-pass fused GAT, "
               "and a --reorder bfs --use-kernel launcher run",
    "comm": "2-device int8 wire-codec full-graph subprocess (finite "
            "losses, compressed bytes/step)",
    "docs": "markdown links + public-API docstrings (scripts/check_docs.py)",
    "lint": "static analysis: repro.analysis over src/ + tests/ (the "
            "repo's own bug-class rules, exit code is the gate)",
    "obs": "telemetry plane: short serve+train launcher runs with "
           "--metrics-out/--trace-out, Prometheus + JSONL validated",
    "replicas": "elastic serving: 2-replica launcher run with one rolling "
                "hot-swap, plus a forced autoscale scale-up, replica "
                "telemetry validated from --metrics-out",
    "dynamic": "dynamic graphs: synthesize a JSONL update stream, fold it "
               "through both launchers via --update-stream, update/"
               "invalidation telemetry validated from --metrics-out",
}

if any(a in ("-h", "--help") for a in sys.argv[1:]):
    print(__doc__.strip())
    print("\nstages (default: all):")
    for a in ARCH_IDS:
        print(f"  {a:24s} reduced-config forward/train/prefill/decode")
    for name, desc in EXTRA_STAGES.items():
        print(f"  {name:24s} {desc}")
    sys.exit(0)

ONLY = sys.argv[1:] if len(sys.argv) > 1 else None
RUN_SERVING = ONLY is None or "serve_gnn" in ONLY
RUN_DIST = ONLY is None or "dist_gnn" in ONLY
RUN_KERNELS = ONLY is None or "kernels" in ONLY
RUN_COMM = ONLY is None or "comm" in ONLY
RUN_DOCS = ONLY is None or "docs" in ONLY
RUN_LINT = ONLY is None or "lint" in ONLY
RUN_OBS = ONLY is None or "obs" in ONLY
RUN_REPLICAS = ONLY is None or "replicas" in ONLY
RUN_DYNAMIC = ONLY is None or "dynamic" in ONLY
ARCHES = [a for a in (ONLY or ARCH_IDS) if a not in EXTRA_STAGES]


def concrete_batch(cfg, B, S, kind, key):
    fam = cfg.family
    batch = {}
    if kind in ("train", "prefill"):
        if fam == "vlm":
            batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                                jnp.float32)
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
        elif fam == "encdec":
            batch["enc_embeds"] = jax.random.normal(
                key, (B, S, cfg.d_model), jnp.float32)
            batch["tokens"] = jax.random.randint(key, (B, S), 0,
                                                 cfg.vocab_size)
        else:
            batch["tokens"] = jax.random.randint(key, (B, S), 0,
                                                 cfg.vocab_size)
        if kind == "train":
            batch["labels"] = jax.random.randint(key, (B, S), 0,
                                                 cfg.vocab_size)
    else:
        if fam == "vlm":
            batch["embeds"] = jax.random.normal(key, (B, 1, cfg.d_model),
                                                jnp.float32)
        else:
            batch["token"] = jax.random.randint(key, (B, 1), 0,
                                                cfg.vocab_size)
        batch["pos"] = jnp.asarray(S // 2, jnp.int32)
    return batch


for arch in ARCHES:
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    B, S = 2, 32
    params = M.init_params(cfg, key, max_seq=S)
    n = M.param_count(params)

    batch = concrete_batch(cfg, B, S, "train", key)
    logits = jax.jit(lambda p, b: M.forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab), logits.shape
    assert not np.any(np.isnan(np.asarray(logits, np.float32))), "NaN fwd"

    opt = AdamW(lr=1e-3)
    ostate = opt.init(params)
    ts = jax.jit(M.make_train_step(cfg, opt))
    params2, ostate, metrics = ts(params, ostate, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), loss

    # prefill + decode
    pb = concrete_batch(cfg, B, S, "prefill", key)
    lg, cache = jax.jit(lambda p, b: M.prefill(cfg, p, b))(params, pb)
    assert lg.shape == (B, cfg.padded_vocab)
    db = concrete_batch(cfg, B, S, "decode", key)
    if cfg.family == "encdec":
        db["pos"] = jnp.asarray(S - 1, jnp.int32)  # reuse prefill cache
    else:
        cache = M.init_cache(cfg, B, S)
        db["pos"] = jnp.asarray(S // 2, jnp.int32)
    lg2, cache = jax.jit(lambda p, c, b: M.decode_step(cfg, p, c, b))(
        params, cache, db)
    assert lg2.shape == (B, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(lg2, np.float32))), "NaN decode"
    print(f"OK {arch:24s} params={n:9d} loss={loss:.3f}")

if RUN_SERVING:
    # online GNN serving path: tiny graph, 32 requests, must report
    # nonzero throughput and a cache hit rate
    from repro.graph import generators as G
    from repro.models.gnn import model as GM
    from repro.models.gnn.model import GNNConfig
    from repro.serving import GNNInferenceServer, poisson_workload

    g = G.featurize(G.sbm(128, 4, p_in=0.9, p_out=0.02, seed=0), 16,
                    seed=0, class_sep=1.5)
    scfg = GNNConfig(arch="sage", feat_dim=16, hidden=32,
                     num_classes=g.num_classes)
    srv = GNNInferenceServer(
        g, scfg, GM.init_gnn(scfg, jax.random.PRNGKey(0)),
        fanouts=(3, 3), buckets=(1, 4, 8), cache_policy="degree",
        cache_capacity=g.num_nodes // 4, seed=0)
    srv.warmup()
    srv.run(poisson_workload(32, np.arange(g.num_nodes), 2000.0, seed=1))
    s = srv.summary()
    assert s["served"] == 32, s
    assert s["throughput_rps"] > 0, s
    assert 0.0 <= s["embedding_hit_ratio"] <= 1.0, s
    assert s["jit_entries"] <= len(srv.batcher.buckets), s
    print(f"OK {'serve_gnn':24s} rps={s['throughput_rps']:.0f} "
          f"p99={s['p99_ms']:.2f}ms hit={s['embedding_hit_ratio']:.2%}")

def run_subprocess_check(label, script, args, marker):
    """Run a tests/*_check.py equivalence script in a clean subprocess
    (device count is fixed at jax import, so forced multi-host
    topologies cannot run in this process) and assert its PASS marker."""
    import os
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(root, "tests", script), *args],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert marker in r.stdout, r.stdout
    print(f"OK {label:24s} {r.stdout.strip().splitlines()[-1]}")


if RUN_DIST:
    # distributed mini-batch path: 2-device gradient equivalence
    run_subprocess_check("dist_gnn", "distributed_train_check.py",
                         ["2", "hash", "sage"], "PASS dist-equivalence")

if RUN_KERNELS:
    # differentiable Pallas aggregation: jax.grad through the fused
    # kernel (interpret mode) must reproduce the jax.ops reference step
    # for step on a forced 2-device mesh — CPU-only CI exercises the
    # kernel bodies + custom VJPs every run
    run_subprocess_check("kernels", "kernel_train_check.py",
                         ["2", "hash"], "PASS kernel-equivalence")
    # one-pass fused GAT: training through the online-softmax kernel's
    # composed custom VJP must match the XLA reference path
    run_subprocess_check("kernels_gat", "gat_train_check.py",
                         ["1"], "PASS gat-fused-equivalence")

    # locality reordering end-to-end on the kernel path: the launcher
    # must reorder, print the locality report, dispatch the fused GAT
    # kernel, and train to a finite loss
    import os
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train_gnn",
         "--nodes", "96", "--feat-dim", "8", "--hidden", "16",
         "--epochs", "2", "--arch", "gat", "--use-kernel",
         "--reorder", "bfs"],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "reorder=bfs" in r.stdout, r.stdout
    assert "nan" not in r.stdout.lower(), r.stdout
    print(f"OK {'kernels_reorder':24s} "
          f"{[l for l in r.stdout.splitlines() if 'reorder=' in l][0]}")

if RUN_COMM:
    # communication plane: an int8-wire full-graph run on 2 forced
    # devices must train without NaNs (error-feedback residuals intact)
    # and report codec-compressed bytes/step
    run_subprocess_check("comm", "comm_train_check.py",
                         ["2", "int8"], "PASS comm-train")

if RUN_OBS:
    # telemetry plane end-to-end: both launchers run with
    # --metrics-out/--trace-out; the Prometheus text must parse, carry
    # the expected series, and the JSONL traces must validate
    import os
    import subprocess
    import tempfile

    from repro.core.telemetry import parse_prometheus, validate_trace_jsonl

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    with tempfile.TemporaryDirectory() as td:
        runs = {
            "serve": ["-m", "repro.launch.serve_gnn", "--nodes", "96",
                      "--feat-dim", "8", "--hidden", "16", "--requests",
                      "24", "--fanouts", "3", "3", "--buckets", "1", "4"],
            "train": ["-m", "repro.launch.train_gnn", "--minibatch",
                      "--nodes", "96", "--feat-dim", "8", "--hidden",
                      "16", "--epochs", "1", "--batch", "24"],
        }
        want_series = {"serve": "serving_request_latency_seconds_count",
                       "train": "train_step_seconds_count"}
        for name, argv in runs.items():
            prom = os.path.join(td, f"{name}.prom")
            trace = os.path.join(td, f"{name}.jsonl")
            r = subprocess.run(
                [sys.executable, *argv, "--metrics-out", prom,
                 "--trace-out", trace],
                capture_output=True, text=True, timeout=600, env=env)
            assert r.returncode == 0, r.stdout + r.stderr
            parsed = parse_prometheus(open(prom).read())
            assert want_series[name] in parsed, (name, sorted(parsed))
            n_ev = validate_trace_jsonl(trace)
            assert n_ev > 0, (name, trace)
            print(f"OK {'obs_' + name:24s} series={len(parsed)} "
                  f"trace_events={n_ev}")

if RUN_REPLICAS:
    # elastic serving plane end-to-end through the launcher: a 2-replica
    # run with one rolling hot-swap, then a 1-replica autoscale run under
    # a burst that forces a scale-up — both validated from --metrics-out
    import os
    import subprocess
    import tempfile

    from repro.core.telemetry import parse_prometheus

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    common = ["-m", "repro.launch.serve_gnn", "--nodes", "128",
              "--feat-dim", "8", "--hidden", "16", "--fanouts", "3", "3",
              "--buckets", "1", "4", "8"]
    with tempfile.TemporaryDirectory() as td:
        # 2 replicas + one rolling hot-swap: zero drops/torn (asserted
        # inside the router), >= 1 completed swap, both replicas visible
        prom = os.path.join(td, "swap.prom")
        r = subprocess.run(
            [sys.executable, *common, "--replicas", "2", "--requests",
             "64", "--hot-swap-every", "32", "--metrics-out", prom],
            capture_output=True, text=True, timeout=600, env=env)
        assert r.returncode == 0, r.stdout + r.stderr
        parsed = parse_prometheus(open(prom).read())
        assert parsed["serving_replicas"][()] == 2, parsed["serving_replicas"]
        swaps = parsed["serving_hot_swaps_total"][()]
        assert swaps >= 1, r.stdout
        dispatch = parsed["serving_router_dispatch_total"]
        assert len(dispatch) == 2 and sum(dispatch.values()) == 64, dispatch
        print(f"OK {'replicas_swap':24s} replicas=2 hot_swaps={swaps:.0f}")

        # autoscale: 1 replica under an 8000 req/s burst must scale up
        prom = os.path.join(td, "scale.prom")
        r = subprocess.run(
            [sys.executable, *common, "--replicas", "1", "--autoscale",
             "--max-replicas", "4", "--rate", "8000", "--requests", "192",
             "--metrics-out", prom],
            capture_output=True, text=True, timeout=600, env=env)
        assert r.returncode == 0, r.stdout + r.stderr
        parsed = parse_prometheus(open(prom).read())
        ups = parsed["serving_scale_events_total"][(("direction", "up"),)]
        assert ups >= 1, r.stdout
        assert parsed["serving_replicas"][()] >= 2, parsed["serving_replicas"]
        print(f"OK {'replicas_scale':24s} scale_ups={ups:.0f} "
              f"replicas={parsed['serving_replicas'][()]:.0f}")

if RUN_DYNAMIC:
    # dynamic-graph plane end-to-end: synthesize an update stream to
    # JSONL, fold it through the serving launcher (incremental frontier
    # invalidation between request chunks) and the full-graph trainer
    # (fold between epochs); the exported metrics must show the stream
    # consumed, rows invalidated, and zero staleness violations
    import os
    import subprocess
    import tempfile

    from repro.core.telemetry import parse_prometheus
    from repro.core.updates import synthesize_updates
    from repro.graph import generators as G

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    with tempfile.TemporaryDirectory() as td:
        stream = os.path.join(td, "events.jsonl")
        sg = G.featurize(G.sbm(96, 4, p_in=0.9, p_out=0.02, seed=0), 8,
                         seed=0, class_sep=1.5)
        synthesize_updates(sg, 12, seed=3).to_jsonl(stream)

        prom = os.path.join(td, "serve.prom")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve_gnn", "--nodes",
             "96", "--feat-dim", "8", "--hidden", "16", "--requests",
             "24", "--fanouts", "3", "3", "--buckets", "1", "4",
             "--update-stream", stream, "--metrics-out", prom],
            capture_output=True, text=True, timeout=600, env=env)
        assert r.returncode == 0, r.stdout + r.stderr
        parsed = parse_prometheus(open(prom).read())
        # each serve pass (baseline + cached) loads its own copy of the
        # stream, so appended events arrive in multiples of the stream size
        n_up = sum(parsed.get("graph_updates_total", {}).values())
        assert n_up > 0 and n_up % 12 == 0, (n_up, r.stdout)
        n_inv = sum(parsed.get("cache_invalidated_rows_total", {}).values())
        assert n_inv > 0, r.stdout
        print(f"OK {'dynamic_serve':24s} updates={n_up:.0f} "
              f"invalidated_rows={n_inv:.0f}")

        prom = os.path.join(td, "train.prom")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.train_gnn", "--fullgraph",
             "--nodes", "96", "--feat-dim", "8", "--hidden", "16",
             "--epochs", "3", "--staleness", "1",
             "--update-stream", stream, "--metrics-out", prom],
            capture_output=True, text=True, timeout=600, env=env)
        assert r.returncode == 0, r.stdout + r.stderr
        parsed = parse_prometheus(open(prom).read())
        n_up = sum(parsed.get("graph_updates_total", {}).values())
        assert n_up == 12, (n_up, r.stdout)
        viol = parsed.get("halo_staleness_violations_total", {(): 0.0})
        assert sum(viol.values()) == 0, r.stdout
        n_ref = sum(parsed.get("delta_refresh_rows_total", {}).values())
        print(f"OK {'dynamic_train':24s} updates={n_up:.0f} "
              f"ghost_rows_invalidated={n_ref:.0f} violations=0")

if RUN_DOCS:
    # docs tier: intra-repo markdown links resolve and every exported
    # repro.distributed / repro.serving / core symbol has a docstring
    import os
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "check_docs.py")],
        capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    print(f"OK {'docs':24s} {r.stdout.strip().splitlines()[-1]}")

if RUN_LINT:
    # lint stage: the merged tree must be clean under the repo's own
    # AST invariant rules (docs/analysis.md) — findings fail the smoke
    import os
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "tests"],
        cwd=root, env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    print(f"OK {'lint':24s} {r.stdout.strip().splitlines()[-1]}")
print("ALL OK")
