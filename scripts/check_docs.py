"""Docs tier: fail on broken intra-repo markdown links and on exported
public-API symbols missing docstrings.

Stdlib-only so it can run anywhere the repo checks out:

* **links** — every relative ``[text](target)`` in a tracked ``*.md``
  must resolve to an existing file/directory (http(s)/mailto and pure
  ``#anchor`` links are skipped; ``path#fragment`` checks the path part);
* **docstrings** — every name in ``repro.distributed.__all__`` and
  ``repro.serving.__all__``, plus every public top-level class/function
  defined in ``repro.core.{halo,caching,comm,propagation}``, must carry
  a non-trivial docstring (public dataclasses whose semantics live in
  the module docstring still need at least a summary line).

Run directly or via ``scripts/run_tests.sh docs``.
"""
from __future__ import annotations

import inspect
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}

EXPORT_MODULES = ["repro.distributed", "repro.serving", "repro.analysis"]
CORE_MODULES = ["repro.core.halo", "repro.core.caching",
                "repro.core.comm", "repro.core.propagation",
                "repro.core.telemetry", "repro.core.updates"]


def markdown_files() -> list:
    out = []
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        out.extend(os.path.join(dirpath, f) for f in filenames
                   if f.endswith(".md"))
    return sorted(out)


def check_links() -> list:
    problems = []
    for md in markdown_files():
        with open(md, encoding="utf-8") as f:
            text = f.read()
        # fenced code blocks may contain bracketed pseudo-links; drop them
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:                       # pure in-page anchor
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(md), path))
            if not os.path.exists(resolved):
                problems.append(f"{os.path.relpath(md, ROOT)}: broken "
                                f"link -> {target}")
    return problems


def _has_doc(obj) -> bool:
    doc = inspect.getdoc(obj)
    if not doc or not doc.strip():
        return False
    # dataclasses synthesize "Name(field: type, ...)" — that is a
    # signature, not documentation
    name = getattr(obj, "__name__", None)
    if name and doc.startswith(f"{name}(") and doc.endswith(")"):
        return False
    return True


def check_docstrings() -> list:
    import importlib

    problems = []
    for name in EXPORT_MODULES:
        mod = importlib.import_module(name)
        if not _has_doc(mod):
            problems.append(f"{name}: module missing docstring")
        for sym in getattr(mod, "__all__", []):
            obj = getattr(mod, sym)
            if not _has_doc(obj):
                problems.append(f"{name}.{sym}: exported symbol missing "
                                f"docstring")
    for name in CORE_MODULES:
        mod = importlib.import_module(name)
        if not _has_doc(mod):
            problems.append(f"{name}: module missing docstring")
        for sym, obj in vars(mod).items():
            if sym.startswith("_"):
                continue
            if not (inspect.isclass(obj) or inspect.isfunction(obj)):
                continue
            if getattr(obj, "__module__", None) != name:
                continue                       # re-exported, checked at home
            if not _has_doc(obj):
                problems.append(f"{name}.{sym}: public symbol missing "
                                f"docstring")
    return problems


def main() -> int:
    problems = check_links() + check_docstrings()
    for p in problems:
        print(f"DOCS FAIL {p}")
    n_md = len(markdown_files())
    if problems:
        print(f"check_docs: {len(problems)} problem(s) across {n_md} "
              f"markdown files + {len(EXPORT_MODULES + CORE_MODULES)} "
              f"modules")
        return 1
    print(f"check_docs OK: {n_md} markdown files, "
          f"{len(EXPORT_MODULES + CORE_MODULES)} modules")
    return 0


if __name__ == "__main__":
    sys.exit(main())
