"""Renders EXPERIMENTS.md from results/dryrun_all.json, results/
hillclimb.json and the benchmark CSV (results/bench.csv if present).

  PYTHONPATH=src python scripts/make_experiments.py
"""
import json
import os

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
R = lambda *p: os.path.join(ROOT, *p)

MODEL_FLOPS_NOTE = {
    "compute": "closest to roofline; larger per-chip batch or fewer "
               "recompute passes would push MFU up",
    "memory": "dominant HBM traffic; see per-row note",
    "collective": "dominant interconnect traffic; see per-row note",
}


def gib(b):
    return f"{b / 2**30:.2f}"


def move_note(r):
    """One sentence on what would move the dominant term down."""
    arch, shape, rf = r["arch"], r["shape"], r["roofline"]
    dom = rf["dominant"]
    fam_moe = "deepseek" in arch or "granite" in arch
    if dom == "collective":
        if fam_moe and shape == "train_4k":
            return ("kill the GShard dispatch/combine all-to-alls with "
                    "explicit shard_map expert parallelism (§Perf H1)")
        return ("overlap gradient reduce-scatter with the backward scan "
                "and widen the FSDP shard to cut all-gather volume")
    if dom == "memory":
        if shape in ("prefill_32k", "train_4k") and "mamba" not in arch \
                and "zamba" not in arch:
            return ("materialized attention probabilities dominate HBM "
                    "traffic; the Pallas flash-attention kernel keeps them "
                    "in VMEM (§Perf H2)")
        if "mamba" in arch or "zamba" in arch:
            return ("SSD intra-chunk score tiles dominate; the ssd_chunk "
                    "Pallas kernel fuses decay*CB*x in VMEM")
        if shape in ("decode_32k", "long_500k"):
            return ("decode is weight+cache bandwidth-bound (useful ratio "
                    "is intrinsically low at batch " +
                    str({"decode_32k": 128, "long_500k": 1}[shape]) +
                    "); larger decode batch or cache quantization")
        return "fuse residual/norm reads and shrink fp32 intermediates"
    return "increase per-chip arithmetic intensity (larger local batch)"


def section_dryrun(rows):
    out = ["## §Dry-run — every (architecture × shape × mesh) lowers and "
           "compiles\n"]
    out.append("512 forced host devices; meshes 16×16 (`data`,`model`) and "
               "2×16×16 (`pod`,`data`,`model`).  `lower().compile()` "
               "succeeded for **78/80** combos; the 2 skips are "
               "whisper-tiny × long_500k (documented in DESIGN.md — "
               "enc-dec cross-attention has no sub-quadratic variant).\n")
    out.append("| arch | shape | mesh | status | mem/device | arg bytes | "
               "collective bytes/step/device |")
    out.append("|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        if r["status"] == "OK":
            coll = int(r["collective_bytes_per_device"].get("total", 0))
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
                f"{gib(r['bytes_per_device'])} GiB | "
                f"{gib(r.get('arg_bytes', 0))} GiB | {coll:,} |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['status']} | — | — | — |")
    out.append("")
    out.append(
        "Memory-analysis and cost-analysis numbers come from the compiled "
        "artifact.  XLA cost analysis visits `while` bodies once, so all "
        "FLOP/byte/collective totals are **structurally extrapolated**: "
        "1-and-2-layer fully-unrolled variants of each stack are compiled "
        "and the exactly-determined linear model `c0 + Σ nᵢ·bodyᵢ` is "
        "solved per metric (see `dryrun.py`).  Multi-pod rows prove the "
        "`pod` axis shards (no extrapolation; roofline is single-pod per "
        "the brief).\n")
    return "\n".join(out)


def section_roofline(rows):
    out = ["## §Roofline — single-pod (256 × TPU v5e: 197 TF/s bf16, "
           "819 GB/s HBM, ~50 GB/s ICI)\n"]
    out.append("Terms are seconds per step per chip: compute = FLOPs/peak, "
               "memory = HBM bytes/bw, collective = collective bytes/link "
               "bw.  `useful` = MODEL_FLOPS (6·N·D, active params for MoE) "
               "/ extrapolated HLO FLOPs — values < 1 expose remat "
               "recompute + attention/dispatch overhead; decode shapes are "
               "intrinsically tiny (1 token).\n")
    out.append("| arch | shape | compute s | memory s | collective s | "
               "dominant | useful | mem GiB | what moves the dominant "
               "term |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if r["mesh"] != "16x16":
            continue
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | "
                       f"— | — | {r['reason'][:70]} |")
            continue
        if r["status"] != "OK":
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"**{rf['dominant']}** | {r['useful_ratio']} | "
            f"{gib(r['bytes_per_device'])} | {move_note(r)} |")
    out.append("")
    return "\n".join(out)


def section_perf(hc):
    out = ["## §Perf — hillclimbing the three chosen pairs\n"]
    out.append("Methodology: hypothesis → change → re-lower/re-analyse → "
               "confirm/refute, iterating on the dominant roofline term "
               "(full narrative below each table).  Baselines are the "
               "paper-era configurations; beyond-paper changes are "
               "recorded separately, per the brief.\n")
    pairs = {}
    for r in hc:
        pairs.setdefault(r["pair"], []).append(r)
    titles = {
        "ds_train": "H1 — deepseek-v3-671b × train_4k (most collective-"
                    "bound; most representative of expert parallelism)",
        "qw_train": "H2 — qwen2.5-14b × train_4k (memory-bound dense "
                    "mainstream)",
        "ds_decode": "H3 — deepseek-v3-671b × decode_32k (worst fit: "
                     "baseline does not fit HBM)",
        "zb_train": "H4 (bonus) — zamba2-2.7b × train_4k (SSD chunk-size "
                    "blocking knob; ties to the ssd_chunk kernel)",
    }
    for pair, rows in pairs.items():
        out.append(f"### {titles.get(pair, pair)}\n")
        out.append("| iteration | compute s | memory s | collective s | "
                   "dominant | useful | mem GiB |")
        out.append("|---|---|---|---|---|---|---|")
        for r in rows:
            if r.get("status") != "OK":
                out.append(f"| {r['iteration']} | FAIL | | | | | |")
                continue
            rf = r["roofline"]
            out.append(
                f"| {r['iteration']} | {rf['compute_s']:.4f} | "
                f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
                f"{rf['dominant']} | {r['useful_ratio']} | "
                f"{gib(r['bytes_per_device'])} |")
        out.append("")
    return "\n".join(out)


def main():
    rows = json.load(open(R("results", "dryrun_all.json")))
    parts = [open(R("EXPERIMENTS.head.md")).read()]
    parts.append(section_dryrun(rows))
    parts.append(section_roofline(rows))
    hc_path = R("results", "hillclimb.json")
    if os.path.exists(hc_path):
        parts.append(section_perf(json.load(open(hc_path))))
    parts.append(open(R("EXPERIMENTS.tail.md")).read())
    with open(R("EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(parts))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
