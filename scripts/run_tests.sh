#!/usr/bin/env bash
# Tiered test runner — one command locally and in CI.
#
#   scripts/run_tests.sh            # tier1: the default fast suite
#   scripts/run_tests.sh tier2      # slow + distributed matrix (subprocess,
#                                   # forced multi-device)
#   scripts/run_tests.sh kernels    # Pallas-kernel grad-equivalence checks
#                                   # in interpret mode (CPU-only CI runs
#                                   # the kernel bodies + custom VJPs)
#   scripts/run_tests.sh comm       # communication-plane tier: codec units
#                                   # + 2-device int8 full-graph subprocess
#                                   # (finite losses, compressed bytes)
#   scripts/run_tests.sh docs       # intra-repo markdown links + public-API
#                                   # docstrings (scripts/check_docs.py)
#   scripts/run_tests.sh obs        # telemetry-plane tier: registry/tracer
#                                   # units + the 2-device serve+train
#                                   # snapshot cross-check subprocess
#   scripts/run_tests.sh replicas   # elastic serving tier: router/autoscale/
#                                   # hot-swap units + crash-safe checkpoint
#                                   # resume tests
#   scripts/run_tests.sh dynamic    # dynamic-graph tier: update-log units +
#                                   # delta-vs-rebuild equivalence subprocess
#                                   # matrix ({1,2} devices x {hash,ldg})
#   scripts/run_tests.sh lint       # static analysis: repro.analysis over
#                                   # src/ + tests/ (exit code is the gate)
#                                   # + the linter's own test suite
#   scripts/run_tests.sh all        # everything
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier="${1:-tier1}"
shift || true
case "$tier" in
  tier1) exec python -m pytest -q -m "not slow and not distributed" "$@" ;;
  tier2) exec python -m pytest -q -m "slow or distributed" "$@" ;;
  kernels)
    python tests/kernel_train_check.py 1 hash "$@"
    python tests/kernel_train_check.py 2 hash "$@"
    python tests/gat_train_check.py 1
    exec python tests/gat_train_check.py 2 ;;
  comm)
    python -m pytest -q -m "not distributed" tests/test_comm.py "$@"
    exec python tests/comm_train_check.py 2 int8 ;;
  docs)  exec python scripts/check_docs.py "$@" ;;
  obs)
    python -m pytest -q -m "not distributed" tests/test_telemetry.py "$@"
    exec python tests/telemetry_check.py ;;
  replicas)
    exec python -m pytest -q -m "not distributed" \
      tests/test_replica_serving.py tests/test_checkpoint.py "$@" ;;
  dynamic)
    python -m pytest -q -m "not distributed" tests/test_dynamic_graph.py "$@"
    python tests/dynamic_train_check.py 1 hash
    python tests/dynamic_train_check.py 1 ldg
    python tests/dynamic_train_check.py 2 hash
    exec python tests/dynamic_train_check.py 2 ldg ;;
  lint)
    python -m repro.analysis src tests
    exec python -m pytest -q -m "not distributed" tests/test_analysis.py "$@" ;;
  all)   exec python -m pytest -q "$@" ;;
  *) echo "usage: $0 [tier1|tier2|kernels|comm|docs|obs|replicas|dynamic|lint|all] [pytest args...]" >&2
     exit 2 ;;
esac
