"""Survey Tables 1 & 3 (§2.2.2/§3.2.1): partitioning strategies compared on
edge-cut fraction, replication factor, balance and runtime — on both a
uniform (ER) and a skewed power-law (BA) graph."""
import time

import numpy as np

from benchmarks.common import emit
from repro.core import partitioning as P
from repro.graph import generators as G


def main():
    graphs = {
        "er": G.erdos_renyi(1500, 8.0, seed=0, directed=False),
        "powerlaw": G.barabasi_albert(1500, 4, seed=0),
    }
    n_parts = 8
    rows = {}
    for gname, g in graphs.items():
        for method in ("hash", "ldg", "fennel", "hdrf", "hybrid", "grid",
                       "2ps"):
            if method == "grid" and int(np.sqrt(n_parts)) ** 2 != n_parts:
                continue
            t0 = time.perf_counter()
            try:
                p = P.partition(g, n_parts if method != "grid" else 4, method)
            except AssertionError:
                continue
            dt = (time.perf_counter() - t0) * 1e6
            rf = p.replication_factor(g)
            bal = p.balance()
            cut = (p.edge_cut_fraction(g)
                   if isinstance(p, P.EdgeCutPartition) else float("nan"))
            rows[(gname, method)] = rf
            emit(f"partitioning/{gname}/{method}", dt,
                 f"rf={rf:.3f};balance={bal:.3f};edgecut={cut:.3f}")
    # survey-claim checks
    claim1 = rows[("powerlaw", "hdrf")] < rows[("powerlaw", "hash")]
    emit("partitioning/claim_vertexcut_beats_edgecut_on_powerlaw", 0.0,
         f"holds={claim1}")

    # EASE-style automatic selection (§2.2.2)
    for gname, g in graphs.items():
        emit(f"partitioning/ease_select/{gname}", 0.0,
             f"choice={P.select_partitioner(g, n_parts)}")


if __name__ == "__main__":
    main()
