"""Survey Tables 2 & 7, §3.2.5–§3.2.9: distributed GNN benchmarks (push vs
pull, data-parallel vs P3 hybrid, BSP vs stale sync, all-reduce vs PS) —
runs the payload in a subprocess with 8 forced host devices."""
import os
import subprocess
import sys

from benchmarks.common import ROOT, SRC


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "spmd_bench.py")],
        capture_output=True, text=True, timeout=900, env=env)
    if "SPMD_BENCH_DONE" not in r.stdout:
        print(f"distributed/SUBPROCESS_FAILED,0.0,"
              f"err={r.stderr[-200:].replace(chr(10), ' ')}")
        return
    for line in r.stdout.splitlines():
        if "," in line and not line.startswith("SPMD"):
            print(line, flush=True)


if __name__ == "__main__":
    main()
