"""Survey Tables 2 & 7, §3.2.5–§3.2.9: distributed GNN benchmarks (push vs
pull, data-parallel vs P3 hybrid, BSP vs stale sync, all-reduce vs PS) —
runs the payload in a subprocess with 8 forced host devices — plus the
partition-aware mini-batch pipeline's cross-partition traffic with and
without the halo cache (PaGraph claim, host-side accounting)."""
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import ROOT, SRC, emit


def _halo_traffic():
    """Cross-partition fetched bytes on the reddit-like graph, halo cache
    (degree policy, capacity = 10% of nodes) vs no cache."""
    from repro.distributed import DistributedMinibatchSampler
    from repro.graph.datasets import load

    g = load("reddit-like").graph
    n = g.num_nodes
    bytes_by_policy = {}
    for policy in ("none", "degree"):
        s = DistributedMinibatchSampler(
            g, 4, [5, 5], 64, partitioner="hash", cache_policy=policy,
            cache_capacity=n // 10, seed=0)
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()     # time sampling only, not setup
        for _ in range(8):
            s.sample_global(rng.choice(n, 64, replace=False))
        st = s.stats()
        bytes_by_policy[policy] = st["cross_partition_bytes"]
        emit(f"distributed/minibatch_xpart_{policy}",
             (time.perf_counter() - t0) * 1e6 / 8,
             f"bytes={st['cross_partition_bytes']}"
             f";hit={st['halo_hit_ratio']:.3f}")
    saving = 1.0 - bytes_by_policy["degree"] / max(bytes_by_policy["none"], 1)
    emit("distributed/halo_cache_saving", 0.0, f"saving={saving:.1%}")


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "spmd_bench.py")],
        capture_output=True, text=True, timeout=900, env=env)
    if "SPMD_BENCH_DONE" not in r.stdout:
        print(f"distributed/SUBPROCESS_FAILED,0.0,"
              f"err={r.stderr[-200:].replace(chr(10), ' ')}")
        return
    for line in r.stdout.splitlines():
        if "," in line and not line.startswith("SPMD"):
            print(line, flush=True)
    _halo_traffic()


if __name__ == "__main__":
    main()
