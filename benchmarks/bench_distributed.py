"""Survey Tables 2 & 7, §3.2.5–§3.2.9: distributed GNN benchmarks (push vs
pull, data-parallel vs P3 hybrid, BSP vs stale sync, all-reduce vs PS) —
runs the payload in a subprocess with 8 forced host devices — plus the
partition-aware mini-batch pipeline's cross-partition traffic with and
without the halo cache (PaGraph claim, host-side accounting)."""
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import ROOT, SRC, emit


def _halo_traffic():
    """Cross-partition fetched bytes on the reddit-like graph along two
    axes: halo cache (degree policy, capacity = 10% of nodes) vs no
    cache, and wire codec (fp32 vs int8 — the communication-plane
    compression claim: int8 must cut remote feature bytes ~4x on the
    SAME sampled batches)."""
    from repro.distributed import DistributedMinibatchSampler
    from repro.graph.datasets import load

    g = load("reddit-like").graph
    n = g.num_nodes
    bytes_by = {}
    for policy in ("none", "degree"):
        for codec in ("fp32", "int8"):
            s = DistributedMinibatchSampler(
                g, 4, [5, 5], 64, partitioner="hash", cache_policy=policy,
                cache_capacity=n // 10, wire_codec=codec, seed=0)
            rng = np.random.default_rng(0)
            t0 = time.perf_counter()     # time sampling only, not setup
            for _ in range(8):
                s.sample_global(rng.choice(n, 64, replace=False))
            st = s.stats()
            bytes_by[policy, codec] = st["cross_partition_bytes"]
            emit(f"distributed/minibatch_xpart_{policy}_{codec}",
                 (time.perf_counter() - t0) * 1e6 / 8,
                 f"bytes={st['cross_partition_bytes']}"
                 f";hit={st['halo_hit_ratio']:.3f}")
    saving = 1.0 - bytes_by["degree", "fp32"] / max(bytes_by["none", "fp32"],
                                                    1)
    emit("distributed/halo_cache_saving", 0.0, f"saving={saving:.1%}")
    # compression claim (sampling is deterministic per seed, so both
    # codecs fetched exactly the same remote rows)
    ratio = bytes_by["none", "int8"] / max(bytes_by["none", "fp32"], 1)
    assert ratio <= 0.30, f"int8/fp32 cross-partition ratio {ratio:.3f}"
    emit("distributed/wire_codec_int8_ratio", 0.0, f"ratio={ratio:.1%}")


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "spmd_bench.py")],
        capture_output=True, text=True, timeout=900, env=env)
    if "SPMD_BENCH_DONE" not in r.stdout:
        print(f"distributed/SUBPROCESS_FAILED,0.0,"
              f"err={r.stderr[-200:].replace(chr(10), ' ')}")
        return
    for line in r.stdout.splitlines():
        if "," in line and not line.startswith("SPMD"):
            print(line, flush=True)
    _halo_traffic()


if __name__ == "__main__":
    main()
