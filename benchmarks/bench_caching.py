"""Survey Table 6 (§3.2.4): caching policies — hit ratio and transferred
bytes under neighbor-sampled access streams (PaGraph/AliGraph claims)."""
import numpy as np

from benchmarks.common import emit
from repro.core import caching as CA
from repro.core.sampling import NeighborSampler
from repro.graph import generators as G


def main():
    g = G.featurize(G.barabasi_albert(3000, 4, seed=0), 64, seed=0)
    rng = np.random.default_rng(0)
    s = NeighborSampler(g, [5, 5], seed=0)
    batches = [s.sample(rng.choice(g.num_nodes, 32, replace=False)
                        ).input_nodes for _ in range(30)]
    results = {}
    for policy in ("none", "random", "importance", "degree"):
        for frac in (0.05, 0.2):
            cap = int(g.num_nodes * frac)
            r = CA.measure_cache(g, policy, cap, batches)
            results[(policy, frac)] = r
            emit(f"caching/{policy}/cap{int(frac * 100)}pct", 0.0,
                 f"hit={r['hit_ratio']:.3f};mb={r['transferred_mb']:.2f}")
    claim = (results[("degree", 0.2)]["hit_ratio"]
             > results[("random", 0.2)]["hit_ratio"])
    emit("caching/claim_pagraph_degree_beats_random", 0.0, f"holds={claim}")

    # GNNAdvisor/ZIPPER vertex reordering (also Table 6, §3.2.4).
    # Honest finding: BFS locality reordering helps community-structured
    # graphs (ER/SBM) but NOT hub-dominated power-law graphs, where hubs
    # touch every id band regardless of ordering.
    from repro.core import reordering as RO
    graphs = {"powerlaw": g,
              "er": G.erdos_renyi(2000, 8.0, seed=0, directed=False)}
    for gname, gg in graphs.items():
        base = RO.edge_locality(gg, window=128)
        for name in ("degree", "bfs_locality"):
            perm = RO.REORDERINGS[name](gg)
            loc = RO.edge_locality(RO.apply_order(gg, perm), window=128)
            emit(f"caching/reorder_{name}/{gname}", 0.0,
                 f"edge_locality={loc:.3f};baseline={base:.3f};"
                 f"improves={loc > base}")


if __name__ == "__main__":
    main()
