"""Survey Table 4 (§3.2.2): sampling strategies — sample time, input-node
counts (neighborhood-explosion containment), subgraph sizes."""
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import sampling as S
from repro.graph import generators as G


def main():
    g = G.featurize(G.barabasi_albert(2000, 5, seed=0), 32, seed=0)
    rng = np.random.default_rng(0)
    seeds = rng.choice(g.num_nodes, 64, replace=False)

    full = S.neighborhood_growth(g, seeds, hops=2)
    emit("sampling/full_2hop_neighborhood", 0.0, f"nodes={full[-1]}")

    samplers = {
        "neighbor": S.NeighborSampler(g, [5, 5], seed=0),
        "importance": S.ImportanceSampler(g, [5, 5], seed=0),
        "fastgcn": S.LayerWiseSampler(g, [256, 256], dependent=False, seed=0),
        "ladies": S.LayerWiseSampler(g, [256, 256], dependent=True, seed=0),
    }
    for name, s in samplers.items():
        mb_holder = {}

        def run():
            mb_holder["mb"] = s.sample(seeds)

        us = timeit(run, warmup=1, iters=3)
        mb = mb_holder["mb"]
        n_in = int((mb.blocks[0].src_nodes >= 0).sum())
        emit(f"sampling/{name}", us,
             f"input_nodes={n_in};containment={n_in / max(full[-1], 1):.3f}")

    cs = S.ClusterSampler(g, 32, 4, seed=0)
    us = timeit(lambda: cs.sample_subgraph(), iters=3)
    nodes, sub = cs.sample_subgraph()
    emit("sampling/cluster", us, f"sub_nodes={sub.num_nodes};"
         f"sub_edges={sub.num_edges}")
    rw = S.SaintRWSampler(g, 64, 4, seed=0)
    us = timeit(lambda: rw.sample_subgraph(), iters=3)
    nodes, sub = rw.sample_subgraph()
    emit("sampling/saint_rw", us, f"sub_nodes={sub.num_nodes};"
         f"sub_edges={sub.num_edges}")


if __name__ == "__main__":
    main()
