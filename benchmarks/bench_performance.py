"""Survey §3.2.12 (performance assessment): end-to-end epoch times of
system-style configurations on the SAME dataset/hardware — the controlled
comparison the survey says the literature lacks.

Configurations (lineage):
  neugraph-like : full-batch, no sampling, grid-ish layout      [117]
  distdgl-like  : neighbor sampling + distributed-KVStore-ish
                  feature store, degree cache                    [213]
  pagraph-like  : neighbor sampling + degree cache, pipelined    [111]
  fastgcn-like  : layer-wise importance sampling                 [19]
  clustergcn-like: cluster subgraph batches                      [24]
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import caching as CA
from repro.core import sampling as SA
from repro.core.abstraction import DeviceGraph
from repro.core.scheduling import PipelinedLoader
from repro.graph import generators as G
from repro.models.gnn import model as GM
from repro.models.gnn.model import GNNConfig
from repro.optim import AdamW


def main():
    g = G.sbm(1024, 4, p_in=0.9, p_out=0.02, seed=0)
    g = G.featurize(g, 32, seed=0, class_sep=1.5)
    cfg = GNNConfig(arch="gcn", feat_dim=32, hidden=64, num_classes=4)
    rng = np.random.default_rng(0)
    y_all = jnp.asarray(g.labels)

    def fresh():
        params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-2, weight_decay=0.0)
        return params, opt, opt.init(params)

    def final_acc(params):
        dg = DeviceGraph.from_graph(g)
        logits = GM.forward_full(cfg, params, dg, jnp.asarray(g.features))
        return float(GM.accuracy(logits, y_all))

    # --- neugraph-like: full batch --------------------------------------
    params, opt, ostate = fresh()
    dg = DeviceGraph.from_graph(g)
    step = jax.jit(GM.make_fullgraph_train_step(cfg, opt))
    x = jnp.asarray(g.features)
    mask = jnp.ones_like(y_all, jnp.float32)
    step(params, ostate, dg, x, y_all, mask)  # compile
    t0 = time.perf_counter()
    for _ in range(5):
        params, ostate, loss = step(params, ostate, dg, x, y_all, mask)
    jax.block_until_ready(loss)
    emit("performance/neugraph_like_fullbatch",
         (time.perf_counter() - t0) / 5 * 1e6,
         f"loss={float(loss):.3f};acc={final_acc(params):.3f}")

    # --- sampled variants -------------------------------------------------
    def run_sampled(name, sampler, cache_policy, pipelined):
        params, opt, ostate = fresh()
        step = jax.jit(GM.make_minibatch_train_step(cfg, opt))
        cache_ids = CA.CACHE_POLICIES[cache_policy](g, g.num_nodes // 10)
        store = CA.FeatureStore(g, cache_ids)

        def make_batch():
            seeds = rng.choice(g.num_nodes, 64, replace=False)
            return sampler.sample(seeds), seeds

        it = None
        if pipelined:
            it = PipelinedLoader(make_batch, depth=4, n_workers=2)

        n_steps = 16
        # warm the jit with one batch
        mb, seeds = make_batch()
        blocks = [DeviceGraph.from_block(b) for b in mb.blocks]
        x_in = jnp.asarray(g.features[np.maximum(mb.blocks[0].src_nodes, 0)])
        step(params, ostate, blocks, x_in, jnp.asarray(g.labels[seeds]),
             jnp.ones(len(seeds), jnp.float32))
        t0 = time.perf_counter()
        loss = None
        for _ in range(n_steps):
            mb, seeds = next(it) if pipelined else make_batch()
            store.fetch(mb.input_nodes)
            blocks = [DeviceGraph.from_block(b) for b in mb.blocks]
            x_in = jnp.asarray(
                g.features[np.maximum(mb.blocks[0].src_nodes, 0)])
            params, ostate, loss = step(
                params, ostate, blocks, x_in, jnp.asarray(g.labels[seeds]),
                jnp.ones(len(seeds), jnp.float32))
        jax.block_until_ready(loss)
        dt = (time.perf_counter() - t0) / n_steps * 1e6
        if it:
            it.close()
        emit(f"performance/{name}", dt,
             f"loss={float(loss):.3f};hit={store.hit_ratio:.2f};"
             f"acc={final_acc(params):.3f}")

    run_sampled("distdgl_like_neighbor",
                SA.NeighborSampler(g, [5, 5], seed=0), "degree", False)
    run_sampled("pagraph_like_pipelined",
                SA.NeighborSampler(g, [5, 5], seed=0), "degree", True)
    run_sampled("fastgcn_like_layerwise",
                SA.LayerWiseSampler(g, [128, 128], dependent=False, seed=0),
                "none", False)

    # clustergcn-like: subgraph batches (per-subgraph jit reuse via padding
    # is out of scope; report per-batch python+jit-amortized time)
    params, opt, ostate = fresh()
    cs = SA.ClusterSampler(g, 16, 2, seed=0)
    opt_step = jax.jit(GM.make_fullgraph_train_step(cfg, opt))
    t0 = time.perf_counter()
    loss = None
    for _ in range(8):
        nodes, sub = cs.sample_subgraph()
        dgs = DeviceGraph.from_graph(sub)
        params, ostate, loss = opt_step(
            params, ostate, dgs, jnp.asarray(sub.features),
            jnp.asarray(sub.labels),
            jnp.ones(sub.num_nodes, jnp.float32))
    jax.block_until_ready(loss)
    emit("performance/clustergcn_like_subgraph",
         (time.perf_counter() - t0) / 8 * 1e6,
         f"loss={float(loss):.3f};acc={final_acc(params):.3f}")


if __name__ == "__main__":
    main()
