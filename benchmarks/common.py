"""Shared benchmark utilities."""
from __future__ import annotations

import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def build_graph(name: str, *, seed: int = 0):
    """The shared er / sbm / reddit-like benchmark graph suite (same
    shapes as tests/conftest.py), so cross-bench numbers stay
    apples-to-apples.  Requires ``repro`` on the path."""
    from repro.graph import generators as G
    if name == "er":
        g = G.erdos_renyi(256, 8.0, seed=seed, directed=False)
        return G.featurize(g, 16, seed=seed, num_classes=4)
    if name == "sbm":
        g = G.sbm(256, 4, p_in=0.9, p_out=0.02, seed=seed)
        return G.featurize(g, 16, seed=seed, class_sep=1.5)
    if name == "reddit-like":
        from repro.graph.datasets import load
        return load("reddit-like", seed=seed, scale=800 / 233_000).graph
    raise KeyError(f"unknown benchmark graph family {name!r}")


def timeit(fn, *, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def timeit_min(fn, *, warmup: int = 2, iters: int = 10) -> float:
    """Best-of-N wall time in microseconds.  The min (not median) is the
    right statistic when the quantity of interest is the code's inherent
    speed under a data-layout change — scheduler noise and cache-warming
    only ever add time, so the min converges on the true cost."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)


def run_subprocess_py(code: str, *, devices: int = 8, timeout: int = 600
                      ) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stdout[-1000:] + r.stderr[-1000:])
    return r.stdout
