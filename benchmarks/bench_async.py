"""Staleness-bounded asynchronous full-graph training bench (survey
§3.2.7: "the zero-/delayed-communication strategies are fastest with
slight accuracy fluctuation") with a wire-codec axis (the survey's
communication-reduction chapter: quantized ghost transfers à la
Dorylus/SANCUS).

Sweeps the staleness bound S ∈ {0, 1, 2} × wire codec ∈ {fp32, bf16,
int8} on er / sbm / reddit-like graphs (2 forced host devices,
subprocess so the device count can be set before jax initializes) and
records, per (graph, codec, S):

* ``step_ms``        — mean wall time per training step (post-warmup);
* ``bytes_per_step`` — cross-partition ghost-refresh traffic (payload at
  the codec's per-row wire size + per-RPC headers, consumed-plan
  accounting);
* ``accuracy`` / ``accuracy_gap`` — final full-graph accuracy and its
  gap vs the same codec's S=0 run from the same init;
* ``accuracy_gap_vs_fp32`` / ``bytes_vs_fp32`` — gap and byte ratio vs
  the fp32 codec at the *same* S (the compression claims);
* ``comm_savings``   — fraction of the same-codec synchronous volume
  saved by staleness.

Results land in ``BENCH_async.json`` at the repo root (see
docs/benchmarks.md for the field glossary) and are also emitted as the
usual ``name,us,derived`` CSV lines.  The acceptance invariants are
asserted here, not just reported:

* bytes/step strictly decreasing in S on the reddit-like graph, for
  EVERY codec (RefreshPlan estimates are codec-aware);
* int8 bytes/step ≤ 30% of fp32 at the same (graph, S);
* |accuracy(int8) − accuracy(fp32)| ≤ 0.02 at the same (graph, S).
"""
import json
import os
import subprocess
import sys

from benchmarks.common import ROOT, SRC, emit

GRAPHS = ("er", "sbm", "reddit-like")
STALENESS = (0, 1, 2)
CODECS = ("fp32", "bf16", "int8")
DEVICES = 2
EPOCHS = 12
HIDDEN = 64
REFRESH_FRAC = 0.05
INT8_BYTES_FRAC = 0.30
INT8_ACC_GAP = 0.02


def _payload() -> None:
    """Runs inside the forced-device subprocess; prints one JSON blob."""
    import numpy as np

    from benchmarks.common import build_graph
    from repro.distributed import AsyncFullGraphTrainer
    from repro.models.gnn import model as GM
    from repro.models.gnn.model import GNNConfig
    from repro.optim import AdamW

    import jax

    out = {}
    for name in GRAPHS:
        g = build_graph(name)
        opt = AdamW(lr=1e-2, weight_decay=0.0)
        by_codec = {}
        for codec in CODECS:
            cfg = GNNConfig(arch="gcn", feat_dim=g.features.shape[1],
                            hidden=HIDDEN, num_classes=g.num_classes,
                            wire_codec=codec)
            # same init for every (codec, S) cell of this graph
            params0 = GM.init_gnn(cfg, jax.random.PRNGKey(0))
            rows = {}
            for s in STALENESS:
                tr = AsyncFullGraphTrainer(g, cfg, opt, DEVICES,
                                           partitioner="hash", staleness=s,
                                           refresh_frac=REFRESH_FRAC)
                p, _, loss = tr.run(params0, opt.init(params0), EPOCHS)
                st = tr.stats()
                # drop the compile step from timing
                times = tr.step_times_s[1:] or tr.step_times_s
                rows[str(s)] = {
                    "loss": loss,
                    "accuracy": tr.accuracy(p),
                    "step_ms": 1e3 * sum(times) / len(times),
                    "bytes_per_step": st["bytes_per_step"],
                    "sync_bytes_per_step": st["sync_bytes_per_step"],
                    "comm_savings": st["comm_savings"],
                    "ghost_rows": st["ghost_rows"],
                }
            acc0 = rows["0"]["accuracy"]
            for s in STALENESS:
                rows[str(s)]["accuracy_gap"] = \
                    acc0 - rows[str(s)]["accuracy"]
            by_codec[codec] = rows
            assert np.isfinite([r["loss"] for r in rows.values()]).all()
        # cross-codec claims at the same S
        for codec in CODECS:
            for s in STALENESS:
                row = by_codec[codec][str(s)]
                ref = by_codec["fp32"][str(s)]
                row["bytes_vs_fp32"] = (row["bytes_per_step"]
                                        / max(ref["bytes_per_step"], 1))
                row["accuracy_gap_vs_fp32"] = (ref["accuracy"]
                                               - row["accuracy"])
        out[name] = by_codec
        for s in STALENESS:
            r8 = by_codec["int8"][str(s)]
            assert r8["bytes_vs_fp32"] <= INT8_BYTES_FRAC, \
                (name, s, r8["bytes_vs_fp32"])
            assert abs(r8["accuracy_gap_vs_fp32"]) <= INT8_ACC_GAP, \
                (name, s, r8["accuracy_gap_vs_fp32"])
    for codec in CODECS:
        b = [out["reddit-like"][codec][str(s)]["bytes_per_step"]
             for s in STALENESS]
        assert b[0] > b[1] > b[2], \
            f"{codec}: bytes/step not strictly decreasing: {b}"
    print("ASYNC_JSON " + json.dumps(out))


def main() -> None:
    env = dict(os.environ)
    # the payload re-imports this module, so it needs ROOT (for
    # ``benchmarks.common``) as well as SRC on the path
    env["PYTHONPATH"] = SRC + os.pathsep + ROOT
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={DEVICES}")
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--payload"],
        capture_output=True, text=True, timeout=1200, env=env)
    blob = next((ln for ln in r.stdout.splitlines()
                 if ln.startswith("ASYNC_JSON ")), None)
    if r.returncode != 0 or blob is None:
        print(f"async/SUBPROCESS_FAILED,0.0,"
              f"err={r.stderr[-200:].replace(chr(10), ' ')}")
        return
    results = json.loads(blob[len("ASYNC_JSON "):])
    path = os.path.join(ROOT, "BENCH_async.json")
    with open(path, "w") as f:
        json.dump({"devices": DEVICES, "epochs": EPOCHS, "hidden": HIDDEN,
                   "refresh_frac": REFRESH_FRAC, "codecs": list(CODECS),
                   "results": results},
                  f, indent=2, sort_keys=True)
    for name, by_codec in results.items():
        for codec, rows in by_codec.items():
            for s, row in sorted(rows.items()):
                emit(f"async/{name}_{codec}_S{s}", row["step_ms"] * 1e3,
                     f"bytes_step={row['bytes_per_step']:.0f}"
                     f";acc={row['accuracy']:.3f}"
                     f";acc_gap={row['accuracy_gap']:.3f}"
                     f";bytes_vs_fp32={row['bytes_vs_fp32']:.2f}"
                     f";saved={row['comm_savings']:.1%}")
    print(f"async/BENCH_async_json,0.0,path={os.path.relpath(path, ROOT)}")


if __name__ == "__main__":
    if "--payload" in sys.argv:
        _payload()
    else:
        main()
