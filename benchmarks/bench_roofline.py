"""Deliverable (g): roofline table from the dry-run artifacts.

Reads results/dryrun_all.json (written by `python -m repro.launch.dryrun
--all --json ...`) and prints per (arch x shape) the three roofline terms,
the dominant bottleneck, and the MODEL_FLOPS/HLO_FLOPs useful ratio.
If the sweep artifact is missing it emits a pointer instead of failing.

A second, artifact-free section maps the locality-reordering policies
(survey §3.2.4) onto the blocked kernels' tile geometry: per graph x
policy it emits the VMEM-residency / tile-density metrics
(``repro.kernels.segment_sum.edge_tile_density``) next to the static
locality numbers — the roofline-side explanation for the wall-clock
``reorder_speedup`` measured in bench_kernels.
"""
import json
import os

from benchmarks.common import ROOT, build_graph, emit

SWEEP = os.path.join(ROOT, "results", "dryrun_all.json")


def reorder_density():
    """Tile-density roofline axis: how each reorder policy changes the
    (dst-tile, edge-tile) grid occupancy and the per-tile source
    working set the blocked kernels sweep."""
    from repro.core.reordering import locality_report
    from repro.kernels.segment_sum import edge_tile_density
    for name in ("er", "sbm", "reddit-like"):
        g = build_graph(name)
        for policy in ("none", "degree", "bfs", "rcm"):
            gp, perm, inv = g.reordered(policy)
            e = gp.edges()
            td = edge_tile_density(e[:, 0], e[:, 1], gp.num_nodes)
            rep = locality_report(gp)
            emit(f"roofline/tile_density/{name}/{policy}", 0.0,
                 f"active_tile_frac={td['active_tile_frac']:.3f};"
                 f"src_rows_per_edge_tile="
                 f"{td['src_rows_per_edge_tile']:.1f};"
                 f"gather_stride={rep['avg_gather_stride']:.1f};"
                 f"reuse_hit={rep['reuse_hit_rate']:.3f}")


def main():
    reorder_density()
    if not os.path.exists(SWEEP):
        emit("roofline/missing", 0.0,
             "run: python -m repro.launch.dryrun --all --json "
             "results/dryrun_all.json")
        return
    rows = json.load(open(SWEEP))
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        if r["mesh"] != "16x16":
            continue           # roofline table is single-pod (per brief)
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] == "SKIP":
            emit(name, 0.0, f"SKIP:{r['reason'][:60]}")
            continue
        if r["status"] != "OK":
            emit(name, 0.0, "FAIL")
            continue
        rf = r["roofline"]
        step_s = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        emit(name, step_s * 1e6,
             f"C={rf['compute_s']:.4f}s;M={rf['memory_s']:.4f}s;"
             f"X={rf['collective_s']:.4f}s;dom={rf['dominant']};"
             f"useful={r['useful_ratio']};"
             f"mem_gib={r['bytes_per_device'] / 2**30:.2f}")
    n_multi = sum(1 for r in rows if r["mesh"] == "2x16x16"
                  and r["status"] == "OK")
    emit("roofline/multi_pod_lowered", 0.0, f"combos_ok={n_multi}")


if __name__ == "__main__":
    main()
