"""Benchmark harness — one module per survey table/figure.

Prints ``name,us_per_call,derived`` CSV.  Module map:
  bench_partitioning  — Tables 1 & 3 (§2.2.2 / §3.2.1)
  bench_sampling      — Table 4  (§3.2.2)
  bench_abstraction   — Table 5  (§3.2.3)
  bench_caching       — Table 6  (§3.2.4)
  bench_distributed   — Tables 2 & 7 (§3.2.5–§3.2.9: parallelism,
                        propagation, sync, coordination; 8-device payload)
  bench_scheduling    — Table 8  (§3.2.8)
  bench_datasets      — Table 9  (§3.2.10)
  bench_performance   — §3.2.12 system-lineage comparison
  bench_kernels       — Pallas kernels vs oracles
  bench_roofline      — deliverable (g): roofline terms from the dry-run
  bench_serving       — online inference: cache hierarchy vs no-cache
  bench_async         — §3.2.7 staleness-bounded async full-graph training
                        (writes BENCH_async.json)
  bench_dynamic       — dynamic graphs: incremental delta invalidation vs
                        full-flush rebuild (writes BENCH_dynamic.json)
"""
import sys
import traceback

from benchmarks import (bench_abstraction, bench_async, bench_caching,
                        bench_datasets, bench_distributed, bench_dynamic,
                        bench_kernels, bench_partitioning,
                        bench_performance, bench_roofline, bench_sampling,
                        bench_scheduling, bench_serving)

MODULES = [
    ("partitioning", bench_partitioning),
    ("sampling", bench_sampling),
    ("abstraction", bench_abstraction),
    ("caching", bench_caching),
    ("scheduling", bench_scheduling),
    ("datasets", bench_datasets),
    ("performance", bench_performance),
    ("kernels", bench_kernels),
    ("distributed", bench_distributed),
    ("roofline", bench_roofline),
    ("serving", bench_serving),
    ("async", bench_async),
    ("dynamic", bench_dynamic),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = 0
    only = set(sys.argv[1:])
    for name, mod in MODULES:
        if only and name not in only:
            continue
        try:
            mod.main()
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{name}/BENCH_FAILED,0.0,", flush=True)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
