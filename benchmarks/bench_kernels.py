"""Kernel microbench: oracle-path timing on CPU + interpret-mode
correctness of the Pallas kernels (TPU timing is hardware-gated; the
kernels' roofline effect is analysed in EXPERIMENTS.md §Perf)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.segment_sum import segment_sum_pallas
from repro.kernels.ssd_chunk import ssd_chunk_state_pallas


def main():
    rng = np.random.default_rng(0)

    # segment sum
    E, F, N = 20000, 128, 2048
    msgs = jnp.asarray(rng.normal(size=(E, F)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, N, E), jnp.int32)
    oracle = jax.jit(lambda m: ref.segment_sum(m, ids, N))
    jax.block_until_ready(oracle(msgs))
    emit("kernels/segment_sum/oracle_xla",
         timeit(lambda: jax.block_until_ready(oracle(msgs))), f"E={E};F={F}")
    err = float(jnp.max(jnp.abs(
        segment_sum_pallas(msgs[:512], ids[:512], N)
        - ref.segment_sum(msgs[:512], ids[:512], N))))
    emit("kernels/segment_sum/pallas_interpret", 0.0, f"maxerr={err:.2e}")

    # flash attention
    B, H, K, S, hd = 1, 8, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, K, S, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, K, S, hd)), jnp.float32)
    oracle = jax.jit(lambda a, b, c: ref.flash_attention(a, b, c))
    jax.block_until_ready(oracle(q, k, v))
    emit("kernels/flash_attention/oracle_xla",
         timeit(lambda: jax.block_until_ready(oracle(q, k, v))),
         f"S={S};H={H}")
    got = flash_attention_pallas(q[:, :, :128], k, v, bq=64, bk=64)
    want = ref.flash_attention(q[:, :, :128], k, v)
    emit("kernels/flash_attention/pallas_interpret", 0.0,
         f"maxerr={float(jnp.max(jnp.abs(got - want))):.2e}")

    # ssd chunk state
    B, L, H, P, G, N2 = 2, 256, 24, 64, 1, 128
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.random((B, L, H)), jnp.float32)
    A = -jnp.asarray(rng.random(H) + 0.1, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, G, N2)), jnp.float32)
    oracle = jax.jit(lambda *a: ref.ssd_chunk_state(*a))
    jax.block_until_ready(oracle(x, dt, A, Bm))
    emit("kernels/ssd_chunk/oracle_xla",
         timeit(lambda: jax.block_until_ready(oracle(x, dt, A, Bm))),
         f"L={L};H={H}")
    got = ssd_chunk_state_pallas(x[:1, :64], dt[:1, :64], A, Bm[:1, :64])
    want = ref.ssd_chunk_state(x[:1, :64], dt[:1, :64], A, Bm[:1, :64])
    emit("kernels/ssd_chunk/pallas_interpret", 0.0,
         f"maxerr={float(jnp.max(jnp.abs(got - want))):.2e}")


if __name__ == "__main__":
    main()
