"""Kernel bench: differentiable Pallas aggregation (fused vs unfused vs
``jax.ops``) on the shared test graphs, plus the flash-attention / SSD
interpret-mode correctness probes.

For each of er / sbm / reddit-like, the aggregation hot spot
``out[d] = sum coef_e * h[src_e]`` is timed through a full
``value_and_grad`` step (fwd + bwd) on three paths:

* ``jax_ops``   — XLA ``jnp.take`` + ``jax.ops.segment_sum`` (oracle);
* ``unfused``   — XLA gather+scale, then the blocked Pallas scatter
  kernel (``segment_sum_pallas``) with its gather-kernel VJP;
* ``fused``     — ``gather_scale_segment_sum_pallas``, one kernel, no
  (E, F) message tensor in HBM, VJP = swapped fused kernel + edge-dot.

Each path also gets its *modeled* HBM traffic from the analytic models
in :mod:`repro.kernels.segment_sum` — the roofline quantity the blocked
tiling is designed around.  Off-TPU the kernels run in interpret mode,
so ``step_ms`` measures the reference XLA path honestly but the kernel
paths only relatively; the byte model is backend-independent.  The
acceptance invariant — fused modeled bytes strictly below unfused on
every graph — is asserted here, not just reported.

Results land in ``BENCH_kernels.json`` at the repo root (field glossary
in docs/benchmarks.md) and as the usual ``name,us,derived`` CSV lines.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ROOT, build_graph, emit, timeit
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.segment_sum import (gather_scale_segment_sum_pallas,
                                       hbm_bytes_fused_kernel,
                                       hbm_bytes_jax_ops,
                                       hbm_bytes_unfused_kernel,
                                       segment_sum_pallas)
from repro.kernels.ssd_chunk import ssd_chunk_state_pallas

GRAPHS = ("er", "sbm", "reddit-like")
FEAT_DIM = 64


def _interpret() -> bool:
    """Resolve per run, like repro.kernels.ops: real kernels on TPU,
    interpreter elsewhere (recorded in the JSON so readers can tell)."""
    return jax.default_backend() != "tpu"


def _agg_inputs(g, rng):
    """GCN-normalized aggregation inputs over the full graph."""
    e = g.edges()
    src = jnp.asarray(e[:, 0], jnp.int32)
    dst = jnp.asarray(e[:, 1], jnp.int32)
    indeg = np.maximum(g.in_degree(), 1).astype(np.float32)
    outdeg = np.maximum(g.out_degree(), 1).astype(np.float32)
    coef = jnp.asarray((1 / np.sqrt(outdeg))[e[:, 0]]
                       * (1 / np.sqrt(indeg))[e[:, 1]])
    h = jnp.asarray(rng.normal(size=(g.num_nodes, FEAT_DIM)), jnp.float32)
    return h, src, dst, coef


def bench_aggregation() -> dict:
    rng = np.random.default_rng(0)
    results = {}
    for name in GRAPHS:
        g = build_graph(name)
        N, E = g.num_nodes, g.num_edges
        h, src, dst, coef = _agg_inputs(g, rng)
        w = jnp.asarray(rng.normal(size=(N, FEAT_DIM)), jnp.float32)

        def agg_jax_ops(h_):
            msgs = jnp.take(h_, src, axis=0) * coef[:, None]
            return jax.ops.segment_sum(msgs, dst, N)

        def agg_unfused(h_):
            msgs = jnp.take(h_, src, axis=0) * coef[:, None]
            return segment_sum_pallas(msgs, dst, N,
                                      interpret=_interpret())

        def agg_fused(h_):
            return gather_scale_segment_sum_pallas(h_, src, dst, coef, N,
                                                   interpret=_interpret())

        paths = {
            "jax_ops": (agg_jax_ops, hbm_bytes_jax_ops(E, FEAT_DIM, N)),
            "unfused": (agg_unfused,
                        hbm_bytes_unfused_kernel(E, FEAT_DIM, N)),
            "fused": (agg_fused,
                      hbm_bytes_fused_kernel(E, FEAT_DIM, N, N)),
        }

        row = {"num_nodes": N, "num_edges": E, "paths": {}}
        ref_out = agg_jax_ops(h)
        for pname, (fn, bytes_model) in paths.items():
            step = jax.jit(jax.value_and_grad(
                lambda h_, fn=fn: jnp.sum(fn(h_) * w)))
            jax.block_until_ready(step(h))          # compile outside timer
            us = timeit(lambda: jax.block_until_ready(step(h)), iters=3)
            maxerr = float(jnp.max(jnp.abs(fn(h) - ref_out)))
            row["paths"][pname] = {
                "fwd_bwd_ms": us / 1e3,
                "hbm_bytes_fwd": bytes_model["fwd"],
                "hbm_bytes_bwd": bytes_model["bwd"],
                "hbm_bytes": bytes_model["total"],
                "max_err_vs_jax_ops": maxerr,
            }
            emit(f"kernels/agg_{name}_{pname}", us,
                 f"E={E};F={FEAT_DIM};hbm_model_bytes="
                 f"{bytes_model['total']};maxerr={maxerr:.2e}")
        fused_b = row["paths"]["fused"]["hbm_bytes"]
        unfused_b = row["paths"]["unfused"]["hbm_bytes"]
        assert fused_b < unfused_b, (
            f"{name}: fused modeled HBM bytes {fused_b} not below "
            f"unfused {unfused_b}")
        row["fused_traffic_saving"] = 1.0 - fused_b / unfused_b
        emit(f"kernels/agg_{name}_fused_saving", 0.0,
             f"saving={row['fused_traffic_saving']:.2%}")
        results[name] = row
    return results


def main():
    rng = np.random.default_rng(0)

    results = bench_aggregation()
    path = os.path.join(ROOT, "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump({"feat_dim": FEAT_DIM,
                   "backend": jax.default_backend(),
                   "interpret": _interpret(),
                   "results": results},
                  f, indent=2, sort_keys=True)

    # flash attention
    B, H, K, S, hd = 1, 8, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, K, S, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, K, S, hd)), jnp.float32)
    oracle = jax.jit(lambda a, b, c: ref.flash_attention(a, b, c))
    jax.block_until_ready(oracle(q, k, v))
    emit("kernels/flash_attention/oracle_xla",
         timeit(lambda: jax.block_until_ready(oracle(q, k, v))),
         f"S={S};H={H}")
    got = flash_attention_pallas(q[:, :, :128], k, v, bq=64, bk=64)
    want = ref.flash_attention(q[:, :, :128], k, v)
    emit("kernels/flash_attention/pallas_interpret", 0.0,
         f"maxerr={float(jnp.max(jnp.abs(got - want))):.2e}")

    # ssd chunk state
    B, L, H, P, G, N2 = 2, 256, 24, 64, 1, 128
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.random((B, L, H)), jnp.float32)
    A = -jnp.asarray(rng.random(H) + 0.1, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, G, N2)), jnp.float32)
    oracle = jax.jit(lambda *a: ref.ssd_chunk_state(*a))
    jax.block_until_ready(oracle(x, dt, A, Bm))
    emit("kernels/ssd_chunk/oracle_xla",
         timeit(lambda: jax.block_until_ready(oracle(x, dt, A, Bm))),
         f"L={L};H={H}")
    got = ssd_chunk_state_pallas(x[:1, :64], dt[:1, :64], A, Bm[:1, :64])
    want = ref.ssd_chunk_state(x[:1, :64], dt[:1, :64], A, Bm[:1, :64])
    emit("kernels/ssd_chunk/pallas_interpret", 0.0,
         f"maxerr={float(jnp.max(jnp.abs(got - want))):.2e}")


if __name__ == "__main__":
    main()
