"""Kernel bench: differentiable Pallas aggregation (fused vs unfused vs
``jax.ops``) on the shared test graphs, plus the flash-attention / SSD
interpret-mode correctness probes.

For each of er / sbm / reddit-like, the aggregation hot spot
``out[d] = sum coef_e * h[src_e]`` is timed through a full
``value_and_grad`` step (fwd + bwd) on three paths:

* ``jax_ops``   — XLA ``jnp.take`` + ``jax.ops.segment_sum`` (oracle);
* ``unfused``   — XLA gather+scale, then the blocked Pallas scatter
  kernel (``segment_sum_pallas``) with its gather-kernel VJP;
* ``fused``     — ``gather_scale_segment_sum_pallas``, one kernel, no
  (E, F) message tensor in HBM, VJP = swapped fused kernel + edge-dot.

Each path also gets its *modeled* HBM traffic from the analytic models
in :mod:`repro.kernels.segment_sum` — the roofline quantity the blocked
tiling is designed around.  Off-TPU the kernels run in interpret mode,
so ``step_ms`` measures the reference XLA path honestly but the kernel
paths only relatively; the byte model is backend-independent.  The
acceptance invariant — fused modeled bytes strictly below unfused on
every graph — is asserted here, not just reported.

Three further sections ride the same JSON (PR 10 raw-speed campaign):

* **reorder** — the locality-reordering axis.  Policy wall-clock is
  measured on 4k-node instances (a 256-node graph's whole working set
  fits in cache, so locality is invisible there): min-of-N XLA gather
  per policy, ``reorder_speedup = t_none / best policy`` (``none`` is in
  the candidate set, so the speedup is the autotune pick and never below
  1.0 — per-policy numbers are reported unclamped).  The Pallas
  fused/unfused paths are timed per policy on the small shared suite
  (interpret off-TPU: relative numbers, recorded as such).
* **gat** — one-pass fused online-softmax GAT vs the multi-pass
  kernel path, plus both modeled byte totals; the fused < multipass
  bytes invariant is asserted on every graph.
* **int8_in** — wire-format int8 rows aggregated directly by the
  quantized fused kernel vs decode-then-fp32, with the modeled decode
  round-trip traffic the direct path avoids.

Results land in ``BENCH_kernels.json`` at the repo root (field glossary
in docs/benchmarks.md) and as the usual ``name,us,derived`` CSV lines.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ROOT, build_graph, emit, timeit, timeit_min
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gat_fused import (gat_fused_attention_pallas,
                                     hbm_bytes_gat_fused,
                                     hbm_bytes_gat_multipass)
from repro.kernels.segment_sum import (edge_tile_density,
                                       gather_scale_segment_sum_pallas,
                                       gather_scale_segment_sum_q_pallas,
                                       hbm_bytes_fused_kernel,
                                       hbm_bytes_fused_q_kernel,
                                       hbm_bytes_jax_ops,
                                       hbm_bytes_unfused_kernel,
                                       segment_sum_pallas)
from repro.kernels.ssd_chunk import ssd_chunk_state_pallas

GRAPHS = ("er", "sbm", "reddit-like")
FEAT_DIM = 64
POLICIES = ("none", "degree", "bfs", "rcm")


def _interpret() -> bool:
    """Resolve per run, like repro.kernels.ops: real kernels on TPU,
    interpreter elsewhere (recorded in the JSON so readers can tell)."""
    return jax.default_backend() != "tpu"


def _agg_inputs(g, rng):
    """GCN-normalized aggregation inputs over the full graph."""
    e = g.edges()
    src = jnp.asarray(e[:, 0], jnp.int32)
    dst = jnp.asarray(e[:, 1], jnp.int32)
    indeg = np.maximum(g.in_degree(), 1).astype(np.float32)
    outdeg = np.maximum(g.out_degree(), 1).astype(np.float32)
    coef = jnp.asarray((1 / np.sqrt(outdeg))[e[:, 0]]
                       * (1 / np.sqrt(indeg))[e[:, 1]])
    h = jnp.asarray(rng.normal(size=(g.num_nodes, FEAT_DIM)), jnp.float32)
    return h, src, dst, coef


def bench_aggregation() -> dict:
    rng = np.random.default_rng(0)
    results = {}
    for name in GRAPHS:
        g = build_graph(name)
        N, E = g.num_nodes, g.num_edges
        h, src, dst, coef = _agg_inputs(g, rng)
        w = jnp.asarray(rng.normal(size=(N, FEAT_DIM)), jnp.float32)

        def agg_jax_ops(h_):
            msgs = jnp.take(h_, src, axis=0) * coef[:, None]
            return jax.ops.segment_sum(msgs, dst, N)

        def agg_unfused(h_):
            msgs = jnp.take(h_, src, axis=0) * coef[:, None]
            return segment_sum_pallas(msgs, dst, N,
                                      interpret=_interpret())

        def agg_fused(h_):
            return gather_scale_segment_sum_pallas(h_, src, dst, coef, N,
                                                   interpret=_interpret())

        paths = {
            "jax_ops": (agg_jax_ops, hbm_bytes_jax_ops(E, FEAT_DIM, N)),
            "unfused": (agg_unfused,
                        hbm_bytes_unfused_kernel(E, FEAT_DIM, N)),
            "fused": (agg_fused,
                      hbm_bytes_fused_kernel(E, FEAT_DIM, N, N)),
        }

        row = {"num_nodes": N, "num_edges": E, "paths": {}}
        ref_out = agg_jax_ops(h)
        for pname, (fn, bytes_model) in paths.items():
            step = jax.jit(jax.value_and_grad(
                lambda h_, fn=fn: jnp.sum(fn(h_) * w)))
            jax.block_until_ready(step(h))          # compile outside timer
            us = timeit(lambda: jax.block_until_ready(step(h)), iters=3)
            maxerr = float(jnp.max(jnp.abs(fn(h) - ref_out)))
            row["paths"][pname] = {
                "fwd_bwd_ms": us / 1e3,
                "hbm_bytes_fwd": bytes_model["fwd"],
                "hbm_bytes_bwd": bytes_model["bwd"],
                "hbm_bytes": bytes_model["total"],
                "max_err_vs_jax_ops": maxerr,
            }
            emit(f"kernels/agg_{name}_{pname}", us,
                 f"E={E};F={FEAT_DIM};hbm_model_bytes="
                 f"{bytes_model['total']};maxerr={maxerr:.2e}")
        fused_b = row["paths"]["fused"]["hbm_bytes"]
        unfused_b = row["paths"]["unfused"]["hbm_bytes"]
        assert fused_b < unfused_b, (
            f"{name}: fused modeled HBM bytes {fused_b} not below "
            f"unfused {unfused_b}")
        row["fused_traffic_saving"] = 1.0 - fused_b / unfused_b
        emit(f"kernels/agg_{name}_fused_saving", 0.0,
             f"saving={row['fused_traffic_saving']:.2%}")
        results[name] = row
    return results


def _big_graph(name: str):
    """4k-node instances for the locality axis — large enough that the
    feature matrix (4096 x 64 fp32 = 1 MiB) and the edge gather stream
    overflow L1/L2, so ordering actually moves wall-clock."""
    from repro.graph import generators as G
    if name == "er-4k":
        return G.erdos_renyi(4096, 8.0, seed=0, directed=False)
    if name == "sbm-4k":
        return G.sbm(4096, 4, p_in=0.9, p_out=0.02, seed=0)
    if name == "reddit-4k":
        from repro.graph.datasets import load
        return load("reddit-like", seed=0, scale=4000 / 233_000).graph
    raise KeyError(name)


def bench_reorder() -> dict:
    """Locality-reordering axis: measured min-of-N wall-clock per policy
    on the 4k instances (XLA gather — honest on any backend), plus the
    Pallas fused/unfused paths per policy on the small shared suite, and
    the static locality / tile-density metrics for every combination."""
    from repro.core.reordering import locality_report
    rng = np.random.default_rng(0)
    out = {"big": {}, "kernel_paths": {}}

    for name in ("er-4k", "sbm-4k", "reddit-4k"):
        g = _big_graph(name)
        N, E = g.num_nodes, g.num_edges
        h0 = rng.normal(size=(N, FEAT_DIM)).astype(np.float32)

        @jax.jit
        def xla_fwd(h_, src_, dst_, coef_):
            msgs = jnp.take(h_, src_, axis=0) * coef_[:, None]
            return jax.ops.segment_sum(msgs, dst_, N)

        row = {"num_nodes": N, "num_edges": E, "policies": {}}
        for policy in POLICIES:
            gp, perm, inv = g.reordered(policy)
            e = gp.edges()
            hp, src, dst, coef = _agg_inputs(gp, rng)
            hp = jnp.asarray(h0[np.asarray(perm)])     # same rows, relabeled
            jax.block_until_ready(xla_fwd(hp, src, dst, coef))
            us = timeit_min(
                lambda: jax.block_until_ready(xla_fwd(hp, src, dst, coef)),
                warmup=2, iters=20)
            rep = locality_report(gp)
            td = edge_tile_density(e[:, 0], e[:, 1], N)
            row["policies"][policy] = {
                "xla_gather_us": us, "locality": rep, "tile_density": td}
            emit(f"kernels/reorder_{name}_{policy}", us,
                 f"stride={rep['avg_gather_stride']:.1f};"
                 f"reuse_hit={rep['reuse_hit_rate']:.3f};"
                 f"active_tiles={td['active_tile_frac']:.3f}")
        t_none = row["policies"]["none"]["xla_gather_us"]
        best = min(POLICIES,
                   key=lambda p: row["policies"][p]["xla_gather_us"])
        row["best_policy"] = best
        row["reorder_speedup"] = (
            t_none / row["policies"][best]["xla_gather_us"])
        for policy in POLICIES:     # unclamped per-policy numbers too
            row["policies"][policy]["speedup_vs_none"] = (
                t_none / row["policies"][policy]["xla_gather_us"])
        assert row["reorder_speedup"] >= 1.0     # none is a candidate
        emit(f"kernels/reorder_{name}_speedup", 0.0,
             f"best={best};speedup={row['reorder_speedup']:.3f}")
        out["big"][name] = row

    # Pallas paths per policy on the small suite: one jit per path,
    # reused across policies (same shapes, different id/coef data)
    for name in GRAPHS:
        g = build_graph(name)
        N = g.num_nodes
        fused_fn = jax.jit(lambda h_, s_, d_, c_: (
            gather_scale_segment_sum_pallas(h_, s_, d_, c_, N,
                                            interpret=_interpret())))

        def unfused(h_, s_, d_, c_):
            msgs = jnp.take(h_, s_, axis=0) * c_[:, None]
            return segment_sum_pallas(msgs, d_, N, interpret=_interpret())
        unfused_fn = jax.jit(unfused)

        prow = {}
        for policy in POLICIES:
            gp, perm, inv = g.reordered(policy)
            hp, src, dst, coef = _agg_inputs(gp, rng)
            jax.block_until_ready(fused_fn(hp, src, dst, coef))
            jax.block_until_ready(unfused_fn(hp, src, dst, coef))
            prow[policy] = {
                "fused_us": timeit_min(lambda: jax.block_until_ready(
                    fused_fn(hp, src, dst, coef)), warmup=1, iters=3),
                "unfused_us": timeit_min(lambda: jax.block_until_ready(
                    unfused_fn(hp, src, dst, coef)), warmup=1, iters=3),
            }
            emit(f"kernels/reorder_{name}_{policy}_pallas",
                 prow[policy]["fused_us"],
                 f"unfused_us={prow[policy]['unfused_us']:.1f}")
        out["kernel_paths"][name] = prow
    return out


def bench_gat() -> dict:
    """One-pass fused GAT vs the multi-pass kernel path: wall-clock
    (interpret off-TPU — relative numbers) and the modeled HBM bytes,
    with the fused < multipass invariant asserted per graph."""
    rng = np.random.default_rng(0)
    heads, hd = 4, FEAT_DIM // 4
    out = {}
    for name in GRAPHS:
        g = build_graph(name)
        N, E = g.num_nodes, g.num_edges
        e = g.edges()
        src = jnp.asarray(e[:, 0], jnp.int32)
        dst = jnp.asarray(e[:, 1], jnp.int32)
        mask = jnp.ones((E,), bool)
        hs = jnp.asarray(rng.normal(size=(N, heads * hd)), jnp.float32)
        es = jnp.asarray(rng.normal(size=(N, heads)), jnp.float32) * 0.1
        ed = jnp.asarray(rng.normal(size=(N, heads)), jnp.float32) * 0.1

        fused = jax.jit(lambda a, b, c: gat_fused_attention_pallas(
            a, b, c, src, dst, mask, N, heads=heads,
            interpret=_interpret()))

        def multipass(a, b, c):
            maskf = mask.astype(jnp.float32)
            logits = jax.nn.leaky_relu(
                jnp.take(b, src, axis=0) + jnp.take(c, dst, axis=0), 0.2)
            logits = jnp.where(maskf[:, None] > 0, logits, -1e30)
            mx = jax.ops.segment_max(logits, dst, N)
            mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
            ex = jnp.exp(logits - mx[dst]) * maskf[:, None]
            den = segment_sum_pallas(ex, dst, N, interpret=_interpret())
            alpha = ex / (jnp.take(den, dst, axis=0) + 1e-9)
            msgs = (jnp.take(a.reshape(-1, heads, hd), src, axis=0)
                    * alpha[..., None])
            return segment_sum_pallas(msgs.reshape(-1, heads * hd), dst,
                                      N, interpret=_interpret())
        multipass_fn = jax.jit(multipass)

        jax.block_until_ready(fused(hs, es, ed))
        jax.block_until_ready(multipass_fn(hs, es, ed))
        maxerr = float(jnp.max(jnp.abs(fused(hs, es, ed)
                                       - multipass_fn(hs, es, ed))))
        t_fused = timeit_min(lambda: jax.block_until_ready(
            fused(hs, es, ed)), warmup=1, iters=3)
        t_multi = timeit_min(lambda: jax.block_until_ready(
            multipass_fn(hs, es, ed)), warmup=1, iters=3)
        b_fused = hbm_bytes_gat_fused(E, heads, hd, N, N)
        b_multi = hbm_bytes_gat_multipass(E, heads, hd, N, N)
        assert b_fused["total"] < b_multi["total"], (
            f"{name}: fused GAT modeled bytes {b_fused['total']} not "
            f"below multipass {b_multi['total']}")
        out[name] = {
            "fused_us": t_fused, "multipass_us": t_multi,
            "gat_fused_speedup": t_multi / t_fused,
            "hbm_bytes_fused": b_fused["total"],
            "hbm_bytes_multipass": b_multi["total"],
            "bytes_saving": 1.0 - b_fused["total"] / b_multi["total"],
            "max_err_vs_multipass": maxerr,
        }
        emit(f"kernels/gat_{name}_fused", t_fused,
             f"multipass_us={t_multi:.1f};"
             f"speedup={t_multi / t_fused:.2f};"
             f"bytes_saving={out[name]['bytes_saving']:.2%};"
             f"maxerr={maxerr:.2e}")
    return out


def bench_int8_in() -> dict:
    """int8-in/fp32-accumulate aggregation: the quantized fused kernel
    consumes wire rows + (min, scale) directly vs decoding to fp32 rows
    first.  The two must agree to ~fp32 roundoff (the kernel performs
    the same affine per source slab); the modeled traffic shows what the
    skipped decode round-trip saves."""
    rng = np.random.default_rng(0)
    out = {}
    for name in GRAPHS:
        g = build_graph(name)
        N, E = g.num_nodes, g.num_edges
        h, src, dst, coef = _agg_inputs(g, rng)
        hn = np.asarray(h)
        mn = hn.min(axis=1, keepdims=True)
        scale = np.maximum((hn.max(axis=1, keepdims=True) - mn) / 255.0,
                           1e-12)
        q = np.rint((hn - mn) / scale).astype(np.uint8)
        qj, mnj, scj = jnp.asarray(q), jnp.asarray(mn), jnp.asarray(scale)

        q_fn = jax.jit(lambda q_, m_, s_: gather_scale_segment_sum_q_pallas(
            q_, m_, s_, src, dst, coef, N, interpret=_interpret()))
        decode_fn = jax.jit(lambda q_, m_, s_: (
            gather_scale_segment_sum_pallas(
                m_ + q_.astype(jnp.float32) * s_, src, dst, coef, N,
                interpret=_interpret())))

        jax.block_until_ready(q_fn(qj, mnj, scj))
        jax.block_until_ready(decode_fn(qj, mnj, scj))
        maxdiff = float(jnp.max(jnp.abs(q_fn(qj, mnj, scj)
                                        - decode_fn(qj, mnj, scj))))
        t_q = timeit_min(lambda: jax.block_until_ready(
            q_fn(qj, mnj, scj)), warmup=1, iters=3)
        t_dec = timeit_min(lambda: jax.block_until_ready(
            decode_fn(qj, mnj, scj)), warmup=1, iters=3)
        bq = hbm_bytes_fused_q_kernel(E, FEAT_DIM, N, N)
        bf = hbm_bytes_fused_kernel(E, FEAT_DIM, N, N)
        out[name] = {
            "int8_in_us": t_q, "decode_then_fp32_us": t_dec,
            "max_diff_vs_decode": maxdiff,
            "hbm_bytes_fwd_int8_in": bq["fwd"],
            "hbm_bytes_fwd_fp32": bf["fwd"],
            "decode_roundtrip_bytes_avoided": bq[
                "decode_roundtrip_avoided"],
        }
        assert bq["fwd"] < bf["fwd"], (
            f"{name}: int8-in fwd bytes {bq['fwd']} not below fp32 "
            f"{bf['fwd']}")
        emit(f"kernels/int8_in_{name}", t_q,
             f"decode_us={t_dec:.1f};maxdiff={maxdiff:.2e};"
             f"bytes_avoided={bq['decode_roundtrip_avoided']}")
    return out


def main():
    rng = np.random.default_rng(0)

    results = bench_aggregation()
    reorder = bench_reorder()
    gat = bench_gat()
    int8_in = bench_int8_in()
    path = os.path.join(ROOT, "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump({"feat_dim": FEAT_DIM,
                   "backend": jax.default_backend(),
                   "interpret": _interpret(),
                   "results": results,
                   "reorder": reorder,
                   "gat": gat,
                   "int8_in": int8_in},
                  f, indent=2, sort_keys=True)

    # flash attention
    B, H, K, S, hd = 1, 8, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, K, S, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, K, S, hd)), jnp.float32)
    oracle = jax.jit(lambda a, b, c: ref.flash_attention(a, b, c))
    jax.block_until_ready(oracle(q, k, v))
    emit("kernels/flash_attention/oracle_xla",
         timeit(lambda: jax.block_until_ready(oracle(q, k, v))),
         f"S={S};H={H}")
    got = flash_attention_pallas(q[:, :, :128], k, v, bq=64, bk=64)
    want = ref.flash_attention(q[:, :, :128], k, v)
    emit("kernels/flash_attention/pallas_interpret", 0.0,
         f"maxerr={float(jnp.max(jnp.abs(got - want))):.2e}")

    # ssd chunk state
    B, L, H, P, G, N2 = 2, 256, 24, 64, 1, 128
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.random((B, L, H)), jnp.float32)
    A = -jnp.asarray(rng.random(H) + 0.1, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, G, N2)), jnp.float32)
    oracle = jax.jit(lambda *a: ref.ssd_chunk_state(*a))
    jax.block_until_ready(oracle(x, dt, A, Bm))
    emit("kernels/ssd_chunk/oracle_xla",
         timeit(lambda: jax.block_until_ready(oracle(x, dt, A, Bm))),
         f"L={L};H={H}")
    got = ssd_chunk_state_pallas(x[:1, :64], dt[:1, :64], A, Bm[:1, :64])
    want = ref.ssd_chunk_state(x[:1, :64], dt[:1, :64], A, Bm[:1, :64])
    emit("kernels/ssd_chunk/pallas_interpret", 0.0,
         f"maxerr={float(jnp.max(jnp.abs(got - want))):.2e}")


if __name__ == "__main__":
    main()
