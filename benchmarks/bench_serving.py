"""Serving-path benchmark: the embedding/feature cache hierarchy vs a
no-cache baseline on a reddit-like (power-law, hot-hub) synthetic graph
under a Zipf-skewed request stream — the regime where historical-embedding
caching pays (§3.2.4 applied at inference time)."""
import copy

import jax
import numpy as np

from benchmarks.common import emit
from repro.graph.datasets import load
from repro.models.gnn import model as GM
from repro.models.gnn.model import GNNConfig
from repro.serving import GNNInferenceServer, poisson_workload

REQUESTS = 192
BUCKETS = (1, 4, 16, 32)
FANOUTS = (5, 5)


def _serve(g, cfg, params, policy, staleness=0, tick_every_s=0.0):
    srv = GNNInferenceServer(
        g, cfg, params, fanouts=FANOUTS, buckets=BUCKETS,
        cache_policy=policy, cache_capacity=int(g.num_nodes * 0.2),
        max_staleness=staleness, seed=0)
    srv.warmup()
    wl = poisson_workload(REQUESTS, np.arange(g.num_nodes), 4000.0, seed=1)
    srv.run(copy.deepcopy(wl), tick_every_s=tick_every_s)
    return srv.summary()


def main():
    ds = load("reddit-like", seed=0, scale=0.01)    # ~2.3k nodes, power-law
    g = ds.graph
    cfg = GNNConfig(arch="sage", feat_dim=g.features.shape[1], hidden=64,
                    num_classes=g.num_classes, num_layers=len(FANOUTS))
    params = GM.init_gnn(cfg, jax.random.PRNGKey(0))

    results = {}
    for policy in ("none", "degree", "importance"):
        r = _serve(g, cfg, params, policy)
        results[policy] = r
        per_req = r["feature_bytes"] / REQUESTS
        emit(f"serving/{policy}",
             1e6 / max(r["throughput_rps"], 1e-9),
             f"rps={r['throughput_rps']:.0f};p50ms={r['p50_ms']:.2f};"
             f"p99ms={r['p99_ms']:.2f};emb_hit={r['embedding_hit_ratio']:.3f};"
             f"bytes_per_req={per_req:.0f}")

    base = results["none"]["feature_bytes"]
    for policy in ("degree", "importance"):
        cached = results[policy]["feature_bytes"]
        emit(f"serving/claim_cache_cuts_traffic_{policy}", 0.0,
             f"holds={cached < base};saved_frac={1 - cached / max(base, 1):.3f}")

    # bounded staleness trades freshness for hit rate under feature-refresh
    # epochs (cache clock ticks every 10ms of virtual time)
    for s in (0, 4):
        r = _serve(g, cfg, params, "degree", staleness=s,
                   tick_every_s=0.010)
        emit(f"serving/staleness{s}", 0.0,
             f"emb_hit={r['embedding_hit_ratio']:.3f};"
             f"bytes={r['feature_bytes']}")


if __name__ == "__main__":
    main()
