"""Serving-path benchmark: the embedding/feature cache hierarchy vs a
no-cache baseline on a reddit-like (power-law, hot-hub) synthetic graph
under a Zipf-skewed request stream — the regime where historical-embedding
caching pays (§3.2.4 applied at inference time).

The numbers now flow through the telemetry plane
(:mod:`repro.core.telemetry`): each policy run is measured from a fresh
``MetricsRegistry.snapshot()``, cross-checked against the legacy instance
counters, and written to ``BENCH_serving.json`` at the repo root with
asserted SLOs (p99 latency ceiling, embedding hit-rate floor for the
cached policies) plus the telemetry overhead guard: enabling the plane
must change serve wall time by <= ``OVERHEAD_TOL`` (min-of-3 runs each
way).  A replicated-mode row runs the same stream through a 2-replica
:class:`~repro.serving.router.ReplicaRouter` with one rolling weight
hot-swap mid-run and asserts zero drops, zero version-torn batches, and
the same p99 ceiling.  Field glossary in ``docs/benchmarks.md``.
"""
import copy
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import ROOT, emit
from repro.core import telemetry
from repro.graph.datasets import load
from repro.models.gnn import model as GM
from repro.models.gnn.model import GNNConfig
from repro.serving import (GNNInferenceServer, ReplicaRouter,
                           poisson_workload)

REQUESTS = 192
BUCKETS = (1, 4, 16, 32)
FANOUTS = (5, 5)

# SLOs asserted into BENCH_serving.json.  Generous: the CPU interpret-mode
# container is ~100x a real accelerator, and the p99 includes simulated
# queueing delay at 4000 req/s offered load.
SLO_P99_MS = 500.0           # virtual-clock p99 ceiling, cached policies
                             # (measured ~25 ms here: ~20x headroom)
SLO_EMB_HIT = 0.20           # embedding hit-rate floor, cached policies
OVERHEAD_TOL = 0.05          # telemetry on/off wall-time ratio bound
OVERHEAD_ABS_S = 0.010       # absolute slack so tiny walls can't flake


def _serve(g, cfg, params, policy, staleness=0, tick_every_s=0.0,
           n_requests=REQUESTS):
    srv = GNNInferenceServer(
        g, cfg, params, fanouts=FANOUTS, buckets=BUCKETS,
        cache_policy=policy, cache_capacity=int(g.num_nodes * 0.2),
        max_staleness=staleness, seed=0)
    srv.warmup()
    wl = poisson_workload(n_requests, np.arange(g.num_nodes), 4000.0,
                          seed=1)
    t0 = time.perf_counter()
    srv.run(copy.deepcopy(wl), tick_every_s=tick_every_s)
    wall = time.perf_counter() - t0
    out = srv.summary()
    out["wall_s"] = wall
    return out, srv


def _snapshot_row(reg, summary, srv) -> dict:
    """One BENCH row built FROM the registry snapshot, with every value
    cross-checked against the legacy instance counters it must equal."""
    snap = reg.snapshot()
    lat = snap["serving_request_latency_seconds"]["series"][""]
    hits = reg.value("cache_lookups_total",
                     cache="serving.embedding", result="hit")
    misses = reg.value("cache_lookups_total",
                       cache="serving.embedding", result="miss")
    feature_bytes = reg.total("comm_bytes_total", path="serving.features")
    fill_bytes = reg.total("comm_bytes_total", path="serving.fill")
    # the snapshot must agree with the subsystem counters exactly
    assert int(hits) == srv.cache.hits, (hits, srv.cache.hits)
    assert int(misses) == srv.cache.misses, (misses, srv.cache.misses)
    assert int(feature_bytes) == srv.cache.features.transport.total_bytes
    assert int(fill_bytes) == sum(t.total_bytes
                                  for t in srv.cache.fill.values())
    assert lat["count"] == summary["served"]
    emb_hit = hits / (hits + misses) if hits + misses else 0.0
    assert abs(emb_hit - summary["embedding_hit_ratio"]) < 1e-9
    return {
        "served": int(lat["count"]),
        "p50_ms": lat["p50"] * 1e3,
        "p99_ms": lat["p99"] * 1e3,
        "throughput_rps": summary["throughput_rps"],
        "embedding_hit_ratio": emb_hit,
        "feature_bytes": int(feature_bytes),
        "fill_bytes": int(fill_bytes),
        "wire_bytes": int(feature_bytes + fill_bytes),
        "batches": int(reg.value("serving_batches_total")),
    }


def _serve_replicated(g, cfg, params, *, n_replicas=2,
                      hot_swap_every=0) -> dict:
    """One replicated-mode row: N replicas behind the router under the
    same Zipf/Poisson stream, with an optional rolling hot-swap mid-run.
    Zero drops and zero version-torn batches are part of the row (and
    asserted into the SLO block)."""
    router = ReplicaRouter(
        g, cfg, params, n_replicas=n_replicas, policy="least_queue",
        shared_cache=True, cache_policy="degree",
        cache_capacity=int(g.num_nodes * 0.2),
        fanouts=FANOUTS, buckets=BUCKETS, seed=0)
    wl = poisson_workload(REQUESTS, np.arange(g.num_nodes), 4000.0, seed=1)

    def fresh(version):
        return GM.init_gnn(cfg, jax.random.PRNGKey(version))

    stats = router.run(copy.deepcopy(wl), hot_swap_every=hot_swap_every,
                       new_params_fn=fresh if hot_swap_every else None)
    out = router.summary()
    return {
        "served": out["served"],
        "dropped": out["dropped"],
        "torn_batches": out["torn_batches"],
        "p50_ms": out["p50_ms"],
        "p99_ms": out["p99_ms"],
        "throughput_rps": out["throughput_rps"],
        "embedding_hit_ratio": out["embedding_hit_ratio"],
        "wire_bytes": out["wire_bytes"],
        "replicas": n_replicas,
        "replicas_peak": stats.replicas_peak,
        "hot_swaps": out["hot_swaps"],
        "version_counts": out["version_counts"],
    }


def _overhead_guard(g, cfg, params) -> dict:
    """Min-of-3 serve wall time with telemetry off vs on: the plane's
    whole point is that it is cheap enough to leave on.  Uses a 3x
    workload so the serve loop (not warmup jitter) dominates the wall
    and the relative bound is the binding one."""
    walls = {}
    for on in (False, True):
        prev = telemetry.set_enabled(on)
        try:
            walls[on] = min(
                _serve(g, cfg, params, "degree",
                       n_requests=3 * REQUESTS)[0]["wall_s"]
                for _ in range(3))
        finally:
            telemetry.set_enabled(prev)
    bound = walls[False] * (1.0 + OVERHEAD_TOL) + OVERHEAD_ABS_S
    return {
        "wall_s_disabled": walls[False],
        "wall_s_enabled": walls[True],
        "overhead_frac": walls[True] / walls[False] - 1.0,
        "tolerance_frac": OVERHEAD_TOL,
        "holds": walls[True] <= bound,
    }


def main():
    ds = load("reddit-like", seed=0, scale=0.01)    # ~2.3k nodes, power-law
    g = ds.graph
    cfg = GNNConfig(arch="sage", feat_dim=g.features.shape[1], hidden=64,
                    num_classes=g.num_classes, num_layers=len(FANOUTS))
    params = GM.init_gnn(cfg, jax.random.PRNGKey(0))

    reg = telemetry.get_registry()
    prev_enabled = telemetry.set_enabled(True)
    results = {}
    for policy in ("none", "degree", "importance"):
        reg.reset()           # one clean snapshot per policy run
        summary, srv = _serve(g, cfg, params, policy)
        r = _snapshot_row(reg, summary, srv)
        results[policy] = r
        per_req = r["feature_bytes"] / REQUESTS
        emit(f"serving/{policy}",
             1e6 / max(r["throughput_rps"], 1e-9),
             f"rps={r['throughput_rps']:.0f};p50ms={r['p50_ms']:.2f};"
             f"p99ms={r['p99_ms']:.2f};emb_hit={r['embedding_hit_ratio']:.3f};"
             f"bytes_per_req={per_req:.0f}")

    base = results["none"]["feature_bytes"]
    for policy in ("degree", "importance"):
        cached = results[policy]["feature_bytes"]
        emit(f"serving/claim_cache_cuts_traffic_{policy}", 0.0,
             f"holds={cached < base};saved_frac={1 - cached / max(base, 1):.3f}")

    # bounded staleness trades freshness for hit rate under feature-refresh
    # epochs (cache clock ticks every 10ms of virtual time)
    staleness = {}
    for s in (0, 4):
        reg.reset()
        summary, srv = _serve(g, cfg, params, "degree", staleness=s,
                              tick_every_s=0.010)
        staleness[str(s)] = _snapshot_row(reg, summary, srv)
        emit(f"serving/staleness{s}", 0.0,
             f"emb_hit={staleness[str(s)]['embedding_hit_ratio']:.3f};"
             f"bytes={staleness[str(s)]['feature_bytes']}")

    # replicated mode: 2 replicas + one rolling hot-swap under the same
    # stream — zero drops and zero torn batches are asserted below
    reg.reset()
    replicated = _serve_replicated(g, cfg, params, n_replicas=2,
                                   hot_swap_every=REQUESTS // 2)
    emit("serving/replicated2",
         1e6 / max(replicated["throughput_rps"], 1e-9),
         f"rps={replicated['throughput_rps']:.0f};"
         f"p99ms={replicated['p99_ms']:.2f};"
         f"dropped={replicated['dropped']};"
         f"torn={replicated['torn_batches']};"
         f"swaps={replicated['hot_swaps']}")

    telemetry.set_enabled(prev_enabled)
    overhead = _overhead_guard(g, cfg, params)
    emit("serving/claim_telemetry_overhead_le_5pct", 0.0,
         f"holds={overhead['holds']};"
         f"frac={overhead['overhead_frac']:.3f}")

    slo = {
        "p99_ms_max": SLO_P99_MS,
        "embedding_hit_min": SLO_EMB_HIT,
        "p99_holds": all(results[p]["p99_ms"] <= SLO_P99_MS
                         for p in ("degree", "importance")),
        "hit_holds": all(results[p]["embedding_hit_ratio"] >= SLO_EMB_HIT
                         for p in ("degree", "importance")),
        "replicated_p99_holds": replicated["p99_ms"] <= SLO_P99_MS,
        "replicated_zero_dropped": replicated["dropped"] == 0,
        "replicated_zero_torn": replicated["torn_batches"] == 0,
    }
    path = os.path.join(ROOT, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump({"requests": REQUESTS, "buckets": list(BUCKETS),
                   "fanouts": list(FANOUTS), "results": results,
                   "staleness": staleness, "replicated": replicated,
                   "slo": slo, "telemetry_overhead": overhead},
                  f, indent=2, sort_keys=True)
    emit("serving/BENCH_serving_json", 0.0,
         f"path={os.path.relpath(path, ROOT)}")

    # the SLOs are assertions, not just fields: a regression fails the bench
    assert slo["p99_holds"], f"p99 SLO violated: {results}"
    assert slo["hit_holds"], f"hit-rate SLO violated: {results}"
    assert slo["replicated_p99_holds"], f"replicated p99: {replicated}"
    assert slo["replicated_zero_dropped"], f"replicated drops: {replicated}"
    assert slo["replicated_zero_torn"], f"torn batches: {replicated}"
    assert overhead["holds"], f"telemetry overhead guard: {overhead}"


if __name__ == "__main__":
    main()
