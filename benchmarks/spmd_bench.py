"""Multi-device (8 forced host devices) benchmark payload — executed in a
subprocess by bench_distributed.py.  Prints CSV rows directly.

Covers:
  Table 2/7 (§3.2.5): data-parallel pull vs P3 hybrid — step time +
    per-step collective bytes from the compiled HLO;
  §3.2.6: push vs pull aggregation collective bytes;
  Table 2 / §3.2.7: BSP vs stale (DistGNN) — per-epoch time + comm saved;
  §3.2.9: decentralized all-reduce vs parameter-server bytes.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8")

import time                                            # noqa: E402

import jax                                             # noqa: E402
import jax.numpy as jnp                                # noqa: E402
import numpy as np                                     # noqa: E402
from jax.experimental.shard_map import shard_map       # noqa: E402
from jax.sharding import PartitionSpec as P            # noqa: E402

from repro.core import coordination as C               # noqa: E402
from repro.core import parallel as PL                  # noqa: E402
from repro.core import propagation as PR               # noqa: E402
from repro.graph import generators as G                # noqa: E402
from repro.launch.hlo_analysis import collective_bytes  # noqa: E402
from repro.models.gnn import model as GM               # noqa: E402
from repro.models.gnn.model import GNNConfig           # noqa: E402
from repro.optim import AdamW, Sgd                     # noqa: E402

N_DEV = 8


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def coll_of(jitted, *args):
    return collective_bytes(jitted.lower(*args).compile().as_text())


def timeit(fn, iters=5):
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


g = G.sbm(1024, 4, p_in=0.9, p_out=0.02, seed=0)
g = G.featurize(g, 64, seed=0, class_sep=1.5)
cfg = GNNConfig(arch="gcn", feat_dim=64, hidden=128, num_classes=4)
params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
opt = AdamW(lr=1e-2, weight_decay=0.0)
sg = PR.shard_graph(g, N_DEV, method="hash")

# ---- pull (data-parallel full graph, BSP) ---------------------------------
mesh, pstep = PR.make_distributed_gcn_step(opt, N_DEV, mode="pull")
ostate = opt.init(params)


def run_pull():
    p2, o2, loss = pstep(params, ostate, sg)
    jax.block_until_ready(loss)


us_pull = timeit(run_pull)
emit("parallelism/data_parallel_pull_step", us_pull,
     f"nodes={g.num_nodes};edges={g.num_edges}")

# ---- P3 hybrid -------------------------------------------------------------
e = g.edges()
perm = sg.perm
es_g = perm[e[:, 0]].astype(np.int32)
ed_g = perm[e[:, 1]].astype(np.int32)
indeg, outdeg = np.asarray(sg.in_deg), np.asarray(sg.out_deg)
coef = (1 / np.sqrt(outdeg[es_g]) / np.sqrt(indeg[ed_g])).astype(np.float32)
p3_params = [dict(params[0]), dict(params[1])]
p3_opt = AdamW(lr=1e-2, weight_decay=0.0)
p3_state = p3_opt.init(p3_params)
mesh3, p3step = PL.make_p3_train_step(p3_opt, N_DEV)
jp3 = jax.jit(p3step)
args3 = (p3_params, p3_state, sg.x, jnp.asarray(es_g), jnp.asarray(ed_g),
         jnp.ones(len(e), jnp.float32), jnp.asarray(coef), sg.labels,
         sg.label_mask)


def run_p3():
    p2, o2, loss = jp3(*args3)
    jax.block_until_ready(loss)


us_p3 = timeit(run_p3)
c3 = coll_of(jp3, *args3)
emit("parallelism/p3_hybrid_step", us_p3,
     f"coll_bytes={c3.get('total', 0)};"
     f"rs={c3.get('reduce-scatter', 0)};ag={c3.get('all-gather', 0)}")

# ---- push vs pull aggregation collective bytes -----------------------------
F = 64
h_loc_spec = P(PR.AXIS, None)
push_layout = PR.push_layout(sg, g)


def pull_once(h, es, ed, em):
    return PR.pull_aggregate(h, es, ed, em, sg.n_local)


def push_once(h, es, ed, em):
    return PR.push_aggregate(h, es, ed, em, sg.n_local * N_DEV)


x = jnp.asarray(np.random.default_rng(0).normal(
    size=(sg.n_local * N_DEV, F)), jnp.float32)
pull_j = jax.jit(shard_map(
    pull_once, mesh=mesh,
    in_specs=(h_loc_spec, P(PR.AXIS), P(PR.AXIS), P(PR.AXIS)),
    out_specs=h_loc_spec, check_rep=False))
push_j = jax.jit(shard_map(
    push_once, mesh=mesh,
    in_specs=(h_loc_spec, P(PR.AXIS), P(PR.AXIS), P(PR.AXIS)),
    out_specs=h_loc_spec, check_rep=False))
cb_pull = coll_of(pull_j, x, sg.edge_src_g, sg.edge_dst_l, sg.edge_mask)
cb_push = coll_of(push_j, x, push_layout["edge_src_l"],
                  push_layout["edge_dst_g"], push_layout["edge_mask"])
us_pl = timeit(lambda: jax.block_until_ready(
    pull_j(x, sg.edge_src_g, sg.edge_dst_l, sg.edge_mask)))
us_ps = timeit(lambda: jax.block_until_ready(
    push_j(x, push_layout["edge_src_l"], push_layout["edge_dst_g"],
           push_layout["edge_mask"])))
emit("propagation/pull_all_gather", us_pl,
     f"coll_bytes={cb_pull.get('total', 0)}")
emit("propagation/push_reduce_scatter", us_ps,
     f"coll_bytes={cb_push.get('total', 0)}")

# correctness cross-check: push == pull aggregation
a = pull_j(x, sg.edge_src_g, sg.edge_dst_l, sg.edge_mask)
b = push_j(x, push_layout["edge_src_l"], push_layout["edge_dst_g"],
           push_layout["edge_mask"])
err = float(jnp.max(jnp.abs(a - b)))
emit("propagation/push_eq_pull", 0.0, f"maxerr={err:.2e}")

# ---- sync: BSP vs stale ----------------------------------------------------
mesh, sstep = PR.make_distributed_gcn_step(opt, N_DEV, mode="stale")
for staleness in (1, 4, 8):
    p2 = [dict(l) for l in params]
    o2 = opt.init(p2)
    t0 = time.perf_counter()
    losses = []
    for it in range(12):
        # refresh costs one extra device round-trip of the full features
        halo = sg.x if it % staleness == 0 else halo  # noqa: F821
        p2, o2, loss = sstep(p2, o2, sg, halo_cache=halo)
        losses.append(float(loss))
    dt = (time.perf_counter() - t0) * 1e6 / 12
    emit(f"sync/stale_s{staleness}", dt,
         f"loss0={losses[0]:.3f};loss11={losses[-1]:.4f};"
         f"halo_exchanges_saved={(1 - 1 / staleness):.0%}")

# ---- coordination: all-reduce vs parameter server --------------------------
sgd = Sgd(lr=0.1)
w0 = {"w": jnp.ones((256, 256))}
s0 = sgd.init(w0)


def make(coord):
    def body(w, s, gseed):
        grads = {"w": gseed * jnp.ones((256, 256))}
        return C.COORDINATORS[coord](sgd, w, grads, s)

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(P(), P(), P(PR.AXIS)),
                             out_specs=(P(), P()), check_rep=False))


gseed = jnp.arange(N_DEV, dtype=jnp.float32)
for coord in ("decentralized", "parameter_server"):
    f = make(coord)
    cb = coll_of(f, w0, s0, gseed)
    us = timeit(lambda: jax.block_until_ready(f(w0, s0, gseed)))
    emit(f"coordination/{coord}", us, f"coll_bytes={cb.get('total', 0)}")

print("SPMD_BENCH_DONE")
