"""Survey Table 5 (§3.2.3): programming-abstraction overhead — per-layer
forward time of each GNN architecture through the SAGA-NN abstraction, and
the Pallas-kernel aggregation path vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.abstraction import DeviceGraph
from repro.graph import generators as G
from repro.models.gnn import model as GM
from repro.models.gnn.model import GNNConfig


def main():
    g = G.featurize(G.erdos_renyi(2000, 10.0, seed=0, directed=False), 64,
                    seed=0, num_classes=8)
    dg = DeviceGraph.from_graph(g)
    x = jnp.asarray(g.features)

    for arch in ("gcn", "sage", "gat", "gin"):
        cfg = GNNConfig(arch=arch, feat_dim=64, hidden=128, num_classes=8)
        params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
        fwd = jax.jit(lambda p, gg, xx: GM.forward_full(cfg, p, gg, xx))
        out = fwd(params, dg, x)
        us = timeit(lambda: jax.block_until_ready(fwd(params, dg, x)),
                    iters=5)
        emit(f"abstraction/forward/{arch}", us,
             f"nodes={g.num_nodes};edges={g.num_edges}")

    # aggregation path: jnp segment_sum vs Pallas kernel (interpret)
    msgs = jnp.asarray(np.random.default_rng(0).normal(
        size=(g.num_edges, 64)), jnp.float32)
    ids = dg.edge_dst
    ref = jax.jit(lambda m: jax.ops.segment_sum(m, ids, g.num_nodes))
    jax.block_until_ready(ref(msgs))
    us_ref = timeit(lambda: jax.block_until_ready(ref(msgs)), iters=5)
    emit("abstraction/aggregate/jnp_oracle", us_ref, "path=xla")
    from repro.kernels.segment_sum import segment_sum_pallas
    got = segment_sum_pallas(msgs, ids, g.num_nodes)
    want = ref(msgs)
    err = float(jnp.max(jnp.abs(got - want)))
    emit("abstraction/aggregate/pallas_interpret", 0.0,
         f"allclose_maxerr={err:.2e};timing=TPU-only")


if __name__ == "__main__":
    main()
