"""Survey Table 8 (§3.2.8): scheduling — AGL-style pipelined loading vs
sequential, GraphTheta work stealing, FlexGraph cost-balanced assignment."""
import time

import numpy as np

from benchmarks.common import emit
from repro.core import scheduling as SC


def main():
    def slow_sample():
        time.sleep(0.004)
        return np.zeros(8)

    def train_step(_):
        time.sleep(0.004)

    n = 30
    t0 = time.perf_counter()
    for _ in range(n):
        train_step(slow_sample())
    seq = time.perf_counter() - t0

    loader = SC.PipelinedLoader(slow_sample, depth=4, n_workers=2)
    t0 = time.perf_counter()
    for _ in range(n):
        train_step(next(loader))
    pipe = time.perf_counter() - t0
    loader.close()
    emit("scheduling/sequential", seq * 1e6, "")
    emit("scheduling/pipelined_agl", pipe * 1e6,
         f"speedup={seq / pipe:.2f}x;idle_s={loader.idle_s:.3f}")

    # work stealing: one worker overloaded
    tasks = [[lambda: time.sleep(0.002)] * 24] + [[] for _ in range(3)]
    out = SC.WorkStealingPool(tasks).run()
    emit("scheduling/work_stealing", out["wall_s"] * 1e6,
         f"stolen={out['stolen']}/{out['done']}")

    # FlexGraph cost-balanced assignment vs naive round-robin
    rng = np.random.default_rng(0)
    nv = rng.integers(100, 2000, 32)
    ne = rng.integers(500, 20000, 32)
    costs = SC.predict_partition_cost(nv, ne, 64, 128)
    lpt = SC.cost_balanced_assignment(costs, 8)
    rr = np.arange(32) % 8
    def maxload(assign):
        loads = np.zeros(8)
        for c, a in zip(costs, assign):
            loads[a] += c
        return loads.max() / loads.mean()
    emit("scheduling/flexgraph_lpt_vs_roundrobin", 0.0,
         f"lpt_imbalance={maxload(lpt):.3f};rr_imbalance={maxload(rr):.3f}")


if __name__ == "__main__":
    main()
