"""Dynamic-graph bench: incremental (delta-frontier) invalidation vs a
full-flush rebuild-on-schedule baseline, swept over update rates.

Per update rate r ∈ {0%, 1%, 5%} (stream events as a fraction of graph
nodes), two identical servers serve the SAME workload over the sparse
``er`` benchmark graph, folding the SAME synthetic update stream in 4
chunks on the same cadence:

* **incremental** — :meth:`GNNInferenceServer.apply_graph_update`
  invalidates only the (L-1)-hop frontier the delta reaches (memoized
  sampler picks keep untouched neighborhoods bit-identical);
* **flush** — the delta-blind baseline (``flush=True``): every fold
  wholesale-invalidates every admitted row — including zero-event folds,
  since a system without delta tracking cannot know nothing changed.

Recorded per (rate, strategy): embedding hit rate, invalidated
(re-refreshed) rows, cache-fill bytes, p50/p99 latency.  Asserted here,
not just reported:

* incremental hit-rate >= flush hit-rate at EVERY rate;
* incremental refreshes STRICTLY fewer rows than flush at every rate;
* a per-rate 2-device continual-training fold (S=1, hash) finishes with
  ``halo_staleness_violations_total == 0`` and a finite loss.

Results land in ``BENCH_dynamic.json`` at the repo root and as the usual
``name,us,derived`` CSV lines.
"""
import json
import os
import subprocess
import sys

from benchmarks.common import ROOT, SRC, emit

RATES = (0.0, 0.01, 0.05)
REQUESTS = 128
CHUNKS = 4
DEVICES = 2
EPOCHS = 2          # per side of the continual-training fold
STALENESS = 1
TIMEOUT_S = 2400


def _payload() -> None:
    """Runs inside the forced-device subprocess; prints one JSON blob."""
    import copy

    import jax
    import numpy as np

    from benchmarks.common import build_graph
    from repro.core import telemetry
    from repro.core.updates import GraphUpdateLog, synthesize_updates
    from repro.distributed import AsyncFullGraphTrainer
    from repro.models.gnn import model as GM
    from repro.models.gnn.model import GNNConfig
    from repro.optim import AdamW
    from repro.serving import GNNInferenceServer, poisson_workload

    telemetry.set_enabled(True)
    reg = telemetry.get_registry()
    telemetry.counter("halo_staleness_violations_total").reset()

    g0 = build_graph("er")
    cfg = GNNConfig(arch="sage", feat_dim=16, hidden=32,
                    num_classes=g0.num_classes)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
    # all sweep cells share one jitted forward: bucket shapes are static,
    # so each (bucket, block-shape) compiles once for the whole sweep
    # instead of once per server instance
    fwd = jax.jit(lambda p, inner, outer, x, ch, fm:
                  GM.forward_blocks_cached(cfg, p, inner, outer, x, ch, fm))
    cfg_t = GNNConfig(arch="gcn", feat_dim=16, hidden=32,
                      num_classes=g0.num_classes)
    params_t = GM.init_gnn(cfg_t, jax.random.PRNGKey(1))
    opt = AdamW(lr=1e-2, weight_decay=0.0)

    out = {}
    for rate in RATES:
        n_ev = int(round(rate * g0.num_nodes))
        rows = {}
        for mode in ("incremental", "flush"):
            g = copy.deepcopy(g0)
            log = (synthesize_updates(g, n_ev, seed=7) if n_ev
                   else GraphUpdateLog())
            srv = GNNInferenceServer(g, cfg, params, fanouts=[3, 3],
                                     buckets=(1, 4, 16), max_staleness=8,
                                     cache_policy="degree", seed=0,
                                     forward_fn=fwd)
            srv.warmup()
            wl = poisson_workload(REQUESTS, np.arange(g.num_nodes),
                                  4000.0, seed=1)
            per = -(-len(wl) // CHUNKS)
            per_ev = -(-log.last_seq // CHUNKS) if log.last_seq else 0
            for c in range(CHUNKS):
                chunk = wl[c * per:(c + 1) * per]
                if chunk:
                    srv.run(list(chunk))
                upto = min((c + 1) * per_ev, log.last_seq)
                srv.apply_graph_update(log, upto, flush=(mode == "flush"))
            s = srv.summary()
            assert s["served"] == REQUESTS, s["served"]
            assert srv._update_seq == log.last_seq
            print(f"payload: rate={rate} mode={mode} done", file=sys.stderr)
            rows[mode] = {
                "hit_ratio": s["embedding_hit_ratio"],
                "invalidated_rows": s["invalidated_rows"],
                "fill_bytes": s["fill_bytes"],
                "wire_bytes": s["wire_bytes"],
                "p50_ms": s["p50_ms"],
                "p99_ms": s["p99_ms"],
                "events": log.last_seq,
            }
        inc, fl = rows["incremental"], rows["flush"]
        assert inc["hit_ratio"] >= fl["hit_ratio"], (rate, rows)
        assert inc["invalidated_rows"] < fl["invalidated_rows"], (rate, rows)

        # continual training through the same rate: fold mid-run at S=1,
        # the staleness guarantee must survive the delta invalidation
        g = copy.deepcopy(g0)
        log = (synthesize_updates(g, n_ev, seed=7) if n_ev
               else GraphUpdateLog())
        tr = AsyncFullGraphTrainer(g, cfg_t, opt, DEVICES,
                                   partitioner="hash", staleness=STALENESS)
        p, o, _ = tr.run(params_t, opt.init(params_t), EPOCHS)
        fold = tr.fold_updates(log)
        p, o, loss = tr.run(p, o, EPOCHS)
        viol = reg.value("halo_staleness_violations_total")
        assert viol == 0.0, viol
        assert np.isfinite(loss), loss
        rows["train"] = {
            "loss": float(loss),
            "events": fold["events"],
            "ghost_rows_invalidated": fold["invalidated_rows"],
            "staleness_violations": int(viol),
        }
        out[f"{rate:.2f}"] = rows
        print(f"payload: rate={rate} train done", file=sys.stderr)
    print("DYNAMIC_JSON " + json.dumps(out))


def main() -> None:
    env = dict(os.environ)
    # the payload re-imports this module, so it needs ROOT (for
    # ``benchmarks.common``) as well as SRC on the path
    env["PYTHONPATH"] = SRC + os.pathsep + ROOT
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={DEVICES}")
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--payload"],
        capture_output=True, text=True, timeout=TIMEOUT_S, env=env)
    blob = next((ln for ln in r.stdout.splitlines()
                 if ln.startswith("DYNAMIC_JSON ")), None)
    if r.returncode != 0 or blob is None:
        print(f"dynamic/SUBPROCESS_FAILED,0.0,"
              f"err={r.stderr[-200:].replace(chr(10), ' ')}")
        return
    results = json.loads(blob[len("DYNAMIC_JSON "):])
    path = os.path.join(ROOT, "BENCH_dynamic.json")
    with open(path, "w") as f:
        json.dump({"devices": DEVICES, "requests": REQUESTS,
                   "chunks": CHUNKS, "rates": list(RATES),
                   "staleness": STALENESS, "results": results},
                  f, indent=2, sort_keys=True)
    for rate, rows in sorted(results.items()):
        for mode in ("incremental", "flush"):
            row = rows[mode]
            emit(f"dynamic/{mode}_rate{rate}", row["p50_ms"] * 1e3,
                 f"hit={row['hit_ratio']:.2%}"
                 f";invalidated={row['invalidated_rows']}"
                 f";fill_kib={row['fill_bytes'] / 1024:.1f}"
                 f";events={row['events']}")
        t = rows["train"]
        emit(f"dynamic/train_rate{rate}", 0.0,
             f"loss={t['loss']:.3f};events={t['events']}"
             f";ghost_inv={t['ghost_rows_invalidated']}"
             f";violations={t['staleness_violations']}")
    print(f"dynamic/BENCH_dynamic_json,0.0,"
          f"path={os.path.relpath(path, ROOT)}")


if __name__ == "__main__":
    if "--payload" in sys.argv:
        _payload()
    else:
        main()
