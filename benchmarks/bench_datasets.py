"""Survey Table 9 (§3.2.10): dataset substrate — synthetic graph generation
scaling and the LM corpus generator throughput."""
import time

from benchmarks.common import emit
from repro.data.pipeline import SyntheticLMDataset
from repro.graph import generators as G


def main():
    for n in (1000, 5000, 20000):
        t0 = time.perf_counter()
        g = G.erdos_renyi(n, 8.0, seed=0, directed=False)
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"datasets/er_{n}", dt, f"edges={g.num_edges}")
    t0 = time.perf_counter()
    g = G.barabasi_albert(5000, 4, seed=0)
    emit("datasets/ba_5000", (time.perf_counter() - t0) * 1e6,
         f"edges={g.num_edges};max_deg={int(g.out_degree().max())}")
    t0 = time.perf_counter()
    g = G.sbm(5000, 8, 0.9, 0.01, seed=0)
    emit("datasets/sbm_5000", (time.perf_counter() - t0) * 1e6,
         f"edges={g.num_edges};classes={g.num_classes}")

    ds = SyntheticLMDataset(1024, 256, seed=0)
    t0 = time.perf_counter()
    ds.sample(32)
    emit("datasets/lm_corpus_32x256", (time.perf_counter() - t0) * 1e6,
         "planted=bigram")


if __name__ == "__main__":
    main()
