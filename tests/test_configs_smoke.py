"""Per-architecture smoke tests (deliverable f): each assigned arch is
instantiated as a REDUCED variant of the same family (2 layers,
d_model <= 512, <= 4 experts) and runs one forward + one train step on CPU,
asserting output shapes and the absence of NaNs.  The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ARCH_ALIASES, ARCH_IDS, INPUT_SHAPES,
                                get_config)
from repro.models.transformer import model as M
from repro.optim import AdamW

B, S = 2, 32


def _batch(cfg, key, kind="train"):
    fam = cfg.family
    batch = {}
    if fam == "vlm":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    elif fam == "encdec":
        batch["enc_embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                                jnp.float32)
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if kind == "train":
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCH_ALIASES))
def test_reduced_config_bounds(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    # the full config retains the published numbers
    full = get_config(arch)
    assert full.citation


@pytest.mark.parametrize("arch", sorted(ARCH_ALIASES))
def test_forward_shapes_and_no_nans(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, max_seq=S)
    logits = jax.jit(lambda p, b: M.forward(cfg, p, b))(
        params, _batch(cfg, key, "prefill"))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", sorted(ARCH_ALIASES))
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, max_seq=S)
    opt = AdamW(lr=1e-3)
    ostate = opt.init(params)
    step = jax.jit(M.make_train_step(cfg, opt))
    params2, ostate, metrics = step(params, ostate, _batch(cfg, key))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2)))
    assert moved
    # no NaNs anywhere in the updated tree
    for leaf in jax.tree_util.tree_leaves(params2):
        assert not np.any(np.isnan(np.asarray(leaf, np.float32)))


def test_all_ten_archs_present():
    assert len(ARCH_ALIASES) == 10
    assert len(set(ARCH_IDS)) == 10
    fams = {get_config(a).family for a in ARCH_ALIASES}
    assert fams == {"vlm", "mla_moe", "ssm", "dense", "encdec", "hybrid",
                    "moe"}


def test_exact_published_numbers():
    c = get_config("deepseek-v3-671b")
    assert (c.num_layers, c.d_model, c.num_heads, c.vocab_size,
            c.num_experts, c.experts_per_token) == (61, 7168, 128, 129280,
                                                    256, 8)
    c = get_config("qwen2-vl-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (28, 3584, 28, 4, 18944, 152064)
    c = get_config("mamba2-780m")
    assert (c.num_layers, c.d_model, c.vocab_size, c.ssm_state) == (
        48, 1536, 50280, 128)
    c = get_config("qwen2.5-14b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (48, 5120, 40, 8, 13824, 152064)
    c = get_config("whisper-tiny")
    assert (c.num_layers, c.encoder_layers, c.d_model, c.num_heads, c.d_ff,
            c.vocab_size) == (4, 4, 384, 6, 1536, 51865)
    c = get_config("zamba2-2.7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size,
            c.ssm_state, c.attn_every) == (54, 2560, 32, 10240, 32000, 64, 6)
    c = get_config("phi3-mini-3.8b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (32, 3072, 32, 32, 8192, 32064)
    c = get_config("glm4-9b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (40, 4096, 32, 2, 13696, 151552)
    c = get_config("gemma-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.resolved_head_dim) == (28, 3072, 16, 16, 24576,
                                                   256000, 256)
    c = get_config("granite-moe-1b-a400m")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.moe_d_ff, c.vocab_size, c.num_experts,
            c.experts_per_token) == (24, 1024, 16, 8, 512, 49155, 32, 8)


def test_vocab_padding_divides_model_axis():
    for arch in ARCH_ALIASES:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 16 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
