"""SPMD GNN check — run in a subprocess with 8 forced host devices.

Validates:
  1. distributed pull-mode full-graph GCN == single-device reference
     (numerical equivalence of loss trajectories);
  2. stale mode (DistGNN) trains with bounded loss divergence;
  3. P3 hybrid step runs and learns;
  4. PS coordination == all-reduce coordination (same params).
Prints PASS lines; the pytest wrapper asserts on them.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.core import propagation as PR            # noqa: E402
from repro.core import parallel as PL               # noqa: E402
from repro.core.abstraction import DeviceGraph      # noqa: E402
from repro.graph import generators as G             # noqa: E402
from repro.models.gnn import model as GM            # noqa: E402
from repro.models.gnn.model import GNNConfig        # noqa: E402
from repro.optim import AdamW, Sgd                  # noqa: E402

assert jax.device_count() == 8, jax.device_count()

g = G.sbm(192, 4, p_in=0.9, p_out=0.02, seed=0)
g = G.featurize(g, 16, seed=0, class_sep=1.5)
N_DEV = 8

cfg = GNNConfig(arch="gcn", feat_dim=16, hidden=32, num_classes=4)
key = jax.random.PRNGKey(0)
params0 = GM.init_gnn(cfg, key)
opt = AdamW(lr=1e-2, weight_decay=0.0)

# --- single-device reference on the SAME permuted/padded layout ----------
sg = PR.shard_graph(g, N_DEV, method="hash")
dg_edges_src = np.asarray(sg.edge_src_g)
dg_edges_dst_local = np.asarray(sg.edge_dst_l)
n_local = sg.n_local
# reconstruct global edge list from the sharded layout
dev_of = np.repeat(np.arange(N_DEV), sg.e_local)
dst_g = dg_edges_dst_local + dev_of * n_local
mask = np.asarray(sg.edge_mask)

x_full = np.asarray(sg.x)
labels_full = np.asarray(sg.labels)
lmask_full = np.asarray(sg.label_mask)
indeg = np.asarray(sg.in_deg)
outdeg = np.asarray(sg.out_deg)


def ref_loss(params, x):
    h = jnp.asarray(x)
    for i, p in enumerate(params):
        hw = h @ p["w"]
        coef = (1 / np.sqrt(outdeg[dg_edges_src])
                * 1 / np.sqrt(indeg[dst_g]) * mask)
        feat = hw[dg_edges_src] * jnp.asarray(coef)[:, None]
        agg = jax.ops.segment_sum(feat, jnp.asarray(dst_g), len(x))
        h = agg + p["b"]
        if i + 1 < len(params):
            h = jax.nn.relu(h)
    logz = jax.nn.logsumexp(h, axis=-1)
    gold = jnp.take_along_axis(h, jnp.asarray(labels_full)[:, None],
                               axis=-1)[:, 0]
    return jnp.sum((logz - gold) * lmask_full) / lmask_full.sum()


def ref_train(n_steps):
    params = jax.tree.map(lambda a: a, params0)
    ostate = opt.init(params)
    losses = []

    @jax.jit
    def step(params, ostate):
        loss, grads = jax.value_and_grad(
            lambda p: ref_loss(p, x_full))(params)
        params, ostate = opt.apply(params, grads, ostate)
        return params, ostate, loss

    for _ in range(n_steps):
        params, ostate, loss = step(params, ostate)
        losses.append(float(loss))
    return params, losses


mesh, dstep = PR.make_distributed_gcn_step(opt, N_DEV, mode="pull")
params = jax.tree.map(lambda a: a, params0)
ostate = opt.init(params)
dlosses = []
for _ in range(10):
    params, ostate, loss = dstep(params, ostate, sg)
    dlosses.append(float(loss))

rparams, rlosses = ref_train(10)
# fp32 reduction-order differences compound through AdamW: demand tight
# agreement early, relative agreement late.
early = max(abs(a - b) for a, b in zip(dlosses[:4], rlosses[:4]))
late = abs(dlosses[-1] - rlosses[-1]) / rlosses[-1]
assert early < 1e-4, (dlosses, rlosses)
assert late < 0.01, (dlosses, rlosses)
# parameter-level equivalence after 10 steps: the guard for gradient
# scaling bugs (e.g. psum inside loss_fn under check_rep=False multiplies
# grads by n_dev) that Adam's scale-invariance + clipping hide from the
# EARLY loss trajectory entirely and leave late_rel at only ~0.04
pdiff = max(
    float(jnp.max(jnp.abs(a - b)))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(rparams)))
assert pdiff < 1e-4, pdiff
print(f"PASS pull-equivalence early={early:.2e} late_rel={late:.3f} "
      f"pdiff={pdiff:.2e}")

# --- stale mode: refresh halo every 3 steps -------------------------------
mesh, sstep = PR.make_distributed_gcn_step(opt, N_DEV, mode="stale")
params = jax.tree.map(lambda a: a, params0)
ostate = opt.init(params)
halo = sg.x
slosses = []
for it in range(12):
    if it % 3 == 0:
        halo = sg.x * 0 + np.asarray(sg.x)  # emulate refresh from store
    params, ostate, loss = sstep(params, ostate, sg, halo_cache=halo)
    slosses.append(float(loss))
assert slosses[-1] < slosses[0], slosses
print(f"PASS stale-mode loss {slosses[0]:.3f}->{slosses[-1]:.3f}")

# --- push mode: reduce-scatter partial aggregates --------------------------
push_arrays = PR.push_layout(sg, g)
mesh, pushstep = PR.make_distributed_gcn_step(opt, N_DEV, mode="push")
params = jax.tree.map(lambda a: a, params0)
ostate = opt.init(params)
plosses = []
for _ in range(10):
    params, ostate, loss = pushstep(params, ostate, sg,
                                    push_arrays=push_arrays)
    plosses.append(float(loss))
err_push = max(abs(a - b) for a, b in zip(plosses[:4], rlosses[:4]))
assert err_push < 1e-3, (plosses, rlosses)
print(f"PASS push-equivalence early={err_push:.2e}")

# --- P3 hybrid -------------------------------------------------------------
e = g.edges()
perm = sg.perm
es_g = perm[e[:, 0]].astype(np.int32)
ed_g = perm[e[:, 1]].astype(np.int32)
coef = (1 / np.sqrt(outdeg[es_g]) / np.sqrt(indeg[ed_g])).astype(np.float32)
emask = np.ones(len(e), np.float32)

p3_params = [dict(params0[0]), dict(params0[1])]
p3_opt = AdamW(lr=1e-2, weight_decay=0.0)
p3_state = p3_opt.init(p3_params)
mesh3, p3step = PL.make_p3_train_step(p3_opt, N_DEV)
jp3 = jax.jit(p3step)
p3_losses = []
for _ in range(10):
    p3_params, p3_state, loss = jp3(
        p3_params, p3_state, jnp.asarray(x_full), jnp.asarray(es_g),
        jnp.asarray(ed_g), jnp.asarray(emask), jnp.asarray(coef),
        jnp.asarray(labels_full), jnp.asarray(lmask_full))
    p3_losses.append(float(loss))
err3 = max(abs(a - b) for a, b in zip(p3_losses, rlosses))
assert err3 < 1e-2, (p3_losses, rlosses[:10])
print(f"PASS p3-hybrid maxerr={err3:.2e}")

# --- coordination: PS == all-reduce ---------------------------------------
from jax.experimental.shard_map import shard_map      # noqa: E402
from jax.sharding import PartitionSpec as P           # noqa: E402
from repro.core import coordination as C              # noqa: E402

sgd = Sgd(lr=0.1)
w0 = {"w": jnp.ones((4, 4))}
s0 = sgd.init(w0)


def grad_for(i):
    return {"w": jnp.full((4, 4), float(i))}


def run(coord):
    def body(w, s, gseed):
        grads = {"w": gseed * jnp.ones((4, 4))}
        return C.COORDINATORS[coord](sgd, w, grads, s)

    f = shard_map(body, mesh=mesh,
                  in_specs=(P(), P(), P(PR.AXIS)),
                  out_specs=(P(), P()), check_rep=False)
    gseed = jnp.arange(8, dtype=jnp.float32).reshape(8)
    return jax.jit(f)(w0, s0, gseed)


wa, _ = run("decentralized")
wb, _ = run("parameter_server")
np.testing.assert_allclose(np.asarray(wa["w"]), np.asarray(wb["w"]),
                           atol=1e-5)
print("PASS coordination ps==allreduce")
print("ALL SPMD CHECKS PASS")
