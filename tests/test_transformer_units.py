"""Transformer substrate unit tests: attention equivalences, RoPE
properties, MoE dispatch equivalence, SSD vs naive recurrence, decode
consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.transformer import attention as A
from repro.models.transformer import layers as L
from repro.models.transformer import model as M
from repro.models.transformer import moe as MoE
from repro.models.transformer import ssm as S

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# chunked attention == dense reference
# ---------------------------------------------------------------------------

def _dense_attn(q, k, v, causal, window=0, q_offset=0):
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd).astype(jnp.float32) / np.sqrt(hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd)


@pytest.mark.parametrize("q_chunk", [8, 16, 1024])
@pytest.mark.parametrize("window", [0, 8])
def test_attention_chunking_equivalence(q_chunk, window):
    B, S, H, K, hd = 2, 48, 4, 2, 16
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, K, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, K, hd)), jnp.float32)
    got = L.attention(q, k, v, causal=True, q_offset=0, window=window,
                      q_chunk=q_chunk)
    want = _dense_attn(q, k, v, True, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               atol=1e-5, rtol=1e-5)


def test_rope_preserves_norm_and_relativity():
    hd, S = 32, 16
    x = jnp.asarray(RNG.normal(size=(1, S, 2, hd)), jnp.float32)
    pos = jnp.arange(S)[None]
    y = L.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> independent of p
    q = jnp.asarray(RNG.normal(size=(1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 1, 1, hd)), jnp.float32)
    dots = []
    for p in (0, 5, 11):
        qr = L.apply_rope(q, jnp.asarray([[p]]), 10_000.0)
        kr = L.apply_rope(k, jnp.asarray([[p + 3]]), 10_000.0)
        dots.append(float(jnp.sum(qr * kr)))
    assert abs(dots[0] - dots[1]) < 1e-4 and abs(dots[0] - dots[2]) < 1e-4


def test_mrope_sections_match_standard_when_positions_equal():
    cfg = get_config("qwen2-vl-7b").reduced()
    hd = cfg.resolved_head_dim
    x = jnp.asarray(RNG.normal(size=(2, 8, 2, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    y_m = L.apply_rope(x, pos3, cfg.rope_theta,
                       mrope_sections=cfg.mrope_sections)
    y_s = L.apply_rope(x, pos, cfg.rope_theta)
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_s), atol=1e-5)


# ---------------------------------------------------------------------------
# MoE: GShard dense dispatch == gather dispatch
# ---------------------------------------------------------------------------

def test_moe_dispatch_equivalence():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    key = jax.random.PRNGKey(0)
    p = MoE.init_moe(cfg, key, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    # generous capacity so neither path drops tokens
    y1 = MoE.moe_block(cfg, p, x, capacity_factor=8.0, group_size=32)
    y2 = MoE.moe_block_gathered(cfg, p, x, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    p = MoE.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(1, 32, cfg.d_model)), jnp.float32)
    y_tight = MoE.moe_block(cfg, p, x, capacity_factor=0.25)
    y_loose = MoE.moe_block(cfg, p, x, capacity_factor=8.0)
    assert float(jnp.max(jnp.abs(y_tight - y_loose))) > 1e-6


# ---------------------------------------------------------------------------
# SSD == naive recurrence
# ---------------------------------------------------------------------------

def _naive_ssd(x, dt, A, Bm, Cm):
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = np.repeat(np.asarray(Bm), rep, axis=2)
    Ch = np.repeat(np.asarray(Cm), rep, axis=2)
    xn, dtn = np.asarray(x), np.asarray(dt)
    An = np.asarray(A)
    h = np.zeros((B, H, P, N))
    ys = np.zeros((B, S, H, P))
    for t in range(S):
        dA = np.exp(dtn[:, t] * An)                       # (B,H)
        h = dA[:, :, None, None] * h + np.einsum(
            "bh,bhp,bhn->bhpn", dtn[:, t], xn[:, t], Bh[:, t])
        ys[:, t] = np.einsum("bhpn,bhn->bhp", h, Ch[:, t])
    return ys, h


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_recurrence(chunk):
    B, S, H, P, G, N = 2, 32, 4, 8, 1, 16
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.random((B, S, H)) * 0.5, jnp.float32)
    A = -jnp.asarray(RNG.random(H) + 0.2, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, G, N)), jnp.float32)
    y, hfin = S_ssd(x, dt, A, Bm, Cm, chunk)
    y_ref, h_ref = _naive_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hfin), h_ref, atol=1e-4, rtol=1e-4)


def S_ssd(x, dt, A, Bm, Cm, chunk):
    return S.ssd_chunked(x, dt, A, Bm, Cm, chunk, return_final_state=True)


# ---------------------------------------------------------------------------
# decode == forward (incremental consistency) per family
# ---------------------------------------------------------------------------

def _concrete_batch(cfg, B, S, key):
    fam = cfg.family
    if fam == "vlm":
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32),
                "positions": jnp.broadcast_to(
                    jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)}
    if fam == "encdec":
        return {"enc_embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                                jnp.float32),
                "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "glm4-9b", "gemma-7b",
                                  "granite-moe-1b-a400m", "mamba2-780m",
                                  "zamba2-2.7b", "whisper-tiny",
                                  "deepseek-v3-671b", "qwen2-vl-7b"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.num_experts:
        # avoid capacity-drop divergence between the two paths
        pass
    key = jax.random.PRNGKey(1)
    B, S = 2, 16
    params = M.init_params(cfg, key, max_seq=S + 1)
    batch = _concrete_batch(cfg, B, S, key)

    logits_full = M.forward(cfg, params, batch)           # (B, S, V)

    prefix = {k: (v[..., :S - 1, :] if v.ndim == 3 and k != "positions"
                  else v[..., :S - 1] if k in ("tokens",)
                  else v[:, :, :S - 1] if k == "positions"
                  else v)
              for k, v in batch.items()}
    if cfg.family == "encdec":
        prefix["enc_embeds"] = batch["enc_embeds"]        # full audio ctx
    lg_prefill, cache = M.prefill(cfg, params, prefix)

    np.testing.assert_allclose(np.asarray(lg_prefill),
                               np.asarray(logits_full[:, S - 2]),
                               atol=2e-3, rtol=2e-3)

    db = {"pos": jnp.asarray(S - 1, jnp.int32)}
    if cfg.family == "vlm":
        db["embeds"] = batch["embeds"][:, S - 1:]
    else:
        db["token"] = batch["tokens"][:, S - 1:]

    if cfg.family in ("dense", "vlm", "moe", "mla_moe", "encdec", "hybrid"):
        # grow kv caches by one slot along the cache-sequence axis (axis 2)
        def pad_seq(a):
            pads = [(0, 0)] * a.ndim
            pads[2] = (0, 1)
            return jnp.pad(a, pads)

        def pad_kv(tree):
            out = {}
            for k_, v_ in tree.items():
                if k_ == "cross":           # encoder context: fixed length
                    out[k_] = v_
                elif isinstance(v_, dict):
                    out[k_] = pad_kv(v_)
                elif k_ in ("k", "v", "c", "kr"):
                    out[k_] = pad_seq(v_)
                else:
                    out[k_] = v_
            return out

        cache = pad_kv(cache)

    logits_dec, _ = M.decode_step(cfg, params, cache, db)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, S - 1]),
                               atol=2e-3, rtol=2e-3)


def test_mla_decode_matches_mla_forward():
    cfg = get_config("deepseek-v3-671b").reduced()
    key = jax.random.PRNGKey(3)
    p = A.init_mla(cfg, key, jnp.float32)
    B, S = 2, 12
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_full, (c_n, kr) = A.mla_forward(cfg, p, x, pos, return_cache=True)

    cache_c = jnp.pad(c_n[:, :S - 1], ((0, 0), (0, 1), (0, 0)))
    cache_kr = jnp.pad(kr[:, :S - 1], ((0, 0), (0, 1), (0, 0)))
    out_dec, _, _ = A.mla_decode(cfg, p, x[:, S - 1:], cache_c, cache_kr,
                                 jnp.asarray(S - 1))
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(out_full[:, -1]), atol=1e-3,
                               rtol=1e-3)


def test_fp8_kv_cache_decode_close_to_bf16():
    """FP8 KV cache (beyond-paper decode optimization) stays close to the
    full-precision decode — and the cache pytree is genuinely fp8."""
    cfg = get_config("qwen2.5-14b").reduced()
    key = jax.random.PRNGKey(7)
    B, S = 2, 16
    params = M.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_full = M.forward(cfg, params, {"tokens": tokens})

    cfg8 = cfg.replace(cache_dtype="float8_e4m3fn")
    _, cache = M.prefill(cfg8, params, {"tokens": tokens[:, :S - 1]})
    assert cache["k"].dtype == jnp.float8_e4m3fn
    cache = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)]),
        cache)
    lg, _ = M.decode_step(cfg8, params, cache,
                          {"token": tokens[:, S - 1:],
                           "pos": jnp.asarray(S - 1, jnp.int32)})
    ref_probs = jax.nn.softmax(logits_full[:, S - 1], -1)
    fp8_probs = jax.nn.softmax(lg, -1)
    # distributional agreement (fp8 quantization noise is bounded)
    tv = 0.5 * float(jnp.abs(ref_probs - fp8_probs).sum(-1).max())
    assert tv < 0.15, tv


def test_sliding_window_ring_cache_decode():
    cfg = get_config("qwen2.5-14b").reduced().replace(sliding_window=8)
    key = jax.random.PRNGKey(5)
    B, S = 1, 24
    params = M.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_full = M.forward(cfg, params, {"tokens": tokens},
                            window=cfg.sliding_window)

    lg, cache = M.prefill(cfg, params, {"tokens": tokens[:, :S - 1]})
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits_full[:, S - 2]), atol=2e-3,
                               rtol=2e-3)
    logits_dec, _ = M.decode_step(
        cfg, params, cache,
        {"token": tokens[:, S - 1:], "pos": jnp.asarray(S - 1, jnp.int32)})
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, S - 1]), atol=2e-3,
                               rtol=2e-3)
