"""Cross-layer equivalence matrix for the distributed mini-batch pipeline.

The gradient-equivalence tests run ``tests/distributed_train_check.py`` in
a subprocess with ``--xla_force_host_platform_device_count={2,4}`` and
demand the partition-parallel shard_map step reproduce the single-device
reference step to <= 1e-5 per parameter, over
``partitioner ∈ {hash, ldg} × arch ∈ {gcn, sage}``.

The in-process tests cover the host-side layers on one device: halo
ownership, partition-aware traffic accounting, collate shape stability,
prefetcher overlap, and the n_dev=1 degenerate step.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_check(n_dev, partitioner, arch, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "distributed_train_check.py"),
         str(n_dev), partitioner, arch],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.mark.distributed
@pytest.mark.parametrize("arch", ["gcn", "sage"])
@pytest.mark.parametrize("partitioner", ["hash", "ldg"])
@pytest.mark.parametrize("n_dev", [2, 4])
def test_gradient_equivalence_matrix(n_dev, partitioner, arch):
    r = _run_check(n_dev, partitioner, arch)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PASS dist-equivalence" in r.stdout, r.stdout


# ---------------------------------------------------------------------------
# in-process host-side layers (single device)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def graph(graph):
    return graph("sbm", 200)


@pytest.fixture(scope="module")
def dist_sampler(graph):
    from repro.distributed import DistributedMinibatchSampler
    return DistributedMinibatchSampler(
        graph, 2, [3, 3], 16, partitioner="hash", cache_policy="degree",
        cache_capacity=graph.num_nodes // 10, seed=0)


def test_halo_layout_covers_every_endpoint(graph):
    from repro.core.halo import build_halo
    from repro.core.partitioning import partition
    part = partition(graph, 3, "hash")
    lay = build_halo(graph, part)
    e = graph.edges()
    for p in range(3):
        present = np.zeros(graph.num_nodes, bool)
        present[lay.owned[p]] = True
        present[lay.halo[p]] = True
        touches = (lay.owner[e[:, 0]] == p) | (lay.owner[e[:, 1]] == p)
        assert present[e[touches]].all()
        # ghost and owned sets are disjoint
        assert not np.intersect1d(lay.owned[p], lay.halo[p]).size


def test_owned_seeds_split_exactly(graph, dist_sampler):
    rng = np.random.default_rng(0)
    seeds = rng.choice(graph.num_nodes, 16, replace=False)
    batches = dist_sampler.sample_global(seeds)
    got = np.concatenate([b.seeds[b.seeds >= 0] for b in batches])
    assert sorted(got.tolist()) == sorted(seeds.tolist())
    for b in batches:
        own = dist_sampler.layout.owner[b.seeds[b.seeds >= 0]]
        assert (own == b.part).all()
        assert b.label_mask.sum() == (b.seeds >= 0).sum()


def test_partition_store_accounting(graph, dist_sampler):
    """Owned rows are free local reads; remote rows are traffic unless
    halo-cached; total = local + hits + misses covers every needed row."""
    from repro.distributed import DistributedMinibatchSampler
    rng = np.random.default_rng(1)
    seeds = rng.choice(graph.num_nodes, 16, replace=False)
    dist_sampler.sample_global(seeds)
    st = dist_sampler.stats()
    assert st["cross_partition_bytes"] > 0
    assert st["local_rows"] > 0
    # an uncached sampler on the same seeds moves strictly more bytes
    nocache = DistributedMinibatchSampler(
        graph, 2, [3, 3], 16, partitioner="hash", cache_policy="none",
        seed=0)
    nocache.sample_global(seeds)
    assert (nocache.stats()["cross_partition_bytes"]
            > st["cross_partition_bytes"] * 0.5)
    assert nocache.stats()["halo_hit_ratio"] == 0.0


def test_collate_shapes_static_across_batches(graph, dist_sampler):
    from repro.distributed import collate
    rng = np.random.default_rng(2)
    shapes = []
    for _ in range(3):
        seeds = rng.choice(graph.num_nodes, 16, replace=False)
        arrays = collate(dist_sampler.sample_global(seeds),
                         dist_sampler.out_deg)
        shapes.append(tuple(a.shape for part in ("es", "ed", "em", "sdeg")
                            for a in arrays[part])
                      + (arrays["x"].shape, arrays["y"].shape))
        caps = dist_sampler.block_shapes()
        for l, (dcap, scap, ecap) in enumerate(caps):
            assert arrays["es"][l].shape == (2, ecap)
            assert arrays["sdeg"][l].shape == (2, scap)
    assert len(set(shapes)) == 1         # one jit entry forever


def test_single_device_step_matches_reference(graph):
    """n_dev=1 distributed step == plain mini-batch step (in-process)."""
    import jax
    import jax.numpy as jnp

    from repro.distributed import (DistributedMinibatchSampler, collate,
                                   device_blocks,
                                   make_distributed_minibatch_step)
    from repro.models.gnn import model as GM
    from repro.models.gnn.model import GNNConfig
    from repro.optim import AdamW

    cfg = GNNConfig(arch="sage", feat_dim=16, hidden=32,
                    num_classes=graph.num_classes)
    params0 = GM.init_gnn(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    ds = DistributedMinibatchSampler(graph, 1, [3, 3], 12,
                                     partitioner="hash",
                                     cache_policy="none", seed=0)
    mesh, dstep = make_distributed_minibatch_step(cfg, opt, 1,
                                                  ds.block_shapes())
    ref_step = jax.jit(GM.make_minibatch_train_step(cfg, opt))
    pd, od = params0, opt.init(params0)
    pr, orr = jax.tree.map(lambda a: a, params0), opt.init(params0)
    rng = np.random.default_rng(3)
    for _ in range(2):
        seeds = rng.choice(graph.num_nodes, 12, replace=False)
        batches = ds.sample_global(seeds)
        pd, od, loss_d = dstep(pd, od, collate(batches, ds.out_deg))
        b = batches[0]
        pr, orr, loss_r = ref_step(
            pr, orr, device_blocks(b, ds.out_deg), jnp.asarray(b.x_in),
            jnp.asarray(b.labels), jnp.asarray(b.label_mask))
        assert abs(float(loss_d) - float(loss_r)) < 1e-6
    diffs = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                         pd, pr)
    assert max(jax.tree_util.tree_leaves(diffs)) < 1e-6


def test_prefetcher_overlaps_and_preserves_order():
    import time

    from repro.distributed import HostPrefetcher

    counter = {"n": 0}

    def make_batch():
        time.sleep(0.005)
        counter["n"] += 1
        return counter["n"]

    pf = HostPrefetcher(make_batch)
    got = []
    for _ in range(8):
        got.append(next(pf))
        time.sleep(0.01)          # "device step" the sampling hides behind
    pf.close()
    assert got == list(range(1, 9))          # in order, none dropped
    assert pf.produced >= 8
    # nearly all sampling time was hidden behind the consumer's work
    assert pf.overlap_ratio() > 0.3, (pf.sample_s, pf.wait_s)
