"""Dynamic-graph invariants: the streaming update log and the incremental
invalidation it drives through caches, halos, and samplers.

The subprocess matrix (``tests/dynamic_train_check.py``, forced
multi-device over {1,2} devices x {hash,ldg}) proves the headline
equivalence — continual-training params and post-update serving logits
match a cold rebuild on the mutated graph to <= 1e-5.  The in-process
tests here cover the host-side mechanics: log append/fold/composition
semantics, frontier expansion, surgical cache invalidation (touched rows
age to NEVER, untouched stay hot), delta-aware halo refresh plans with
zero staleness violations, and sampler pick memoization across deltas.
"""
import copy
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def graph(graph):
    return graph("sbm", 144)


@pytest.fixture()
def log_g(graph):
    """A private mutable copy of the shared graph plus a 16-event log."""
    from repro.core.updates import synthesize_updates
    g = copy.deepcopy(graph)
    return g, synthesize_updates(g, 16, seed=2)


# ---------------------------------------------------------------------------
# GraphUpdateLog semantics
# ---------------------------------------------------------------------------

def test_log_append_sequencing_and_clock_stamps():
    from repro.core.caching import VersionClock
    from repro.core.updates import GraphUpdateLog

    clock = VersionClock()
    log = GraphUpdateLog(clock=clock)
    e1 = log.add_edge(0, 1)
    clock.tick(3)
    e2 = log.remove_edge(0, 1)
    e3 = log.update_features(2, np.ones(4))
    assert (e1.seq, e2.seq, e3.seq) == (1, 2, 3)
    assert e1.clock == 0 and e2.clock == 3 and e3.clock == 3
    assert log.last_seq == 3
    assert log.counts == {"add_edge": 1, "remove_edge": 1,
                          "update_features": 1}
    assert e3.x.dtype == np.float32


def test_apply_composition_is_bitwise(log_g):
    g, log = log_g
    for split in (0, 5, 9, 16):
        g1 = log.apply(g, split)
        g2 = log.apply(g1, 16, from_seq=split)
        ref = log.apply(g, 16)
        assert np.array_equal(g2.row_ptr, ref.row_ptr)
        assert np.array_equal(g2.col_idx, ref.col_idx)
        assert np.array_equal(g2.features, ref.features)


def test_apply_never_mutates_the_input(log_g):
    g, log = log_g
    rp, ci = g.row_ptr.copy(), g.col_idx.copy()
    feats = g.features.copy()
    log.apply(g)
    assert np.array_equal(g.row_ptr, rp)
    assert np.array_equal(g.col_idx, ci)
    assert np.array_equal(g.features, feats)


def test_remove_edge_drops_all_copies_and_is_lenient():
    from repro.core.updates import GraphUpdateLog
    from repro.graph.structure import from_edges

    g = from_edges(4, np.array([[0, 1], [0, 1], [2, 3]]))
    log = GraphUpdateLog()
    log.remove_edge(0, 1)
    g2 = log.apply(g)
    assert g2.num_edges == 1                     # both copies dropped
    log.remove_edge(0, 3)                        # absent edge: no-op
    assert log.apply(g).num_edges == 1


def test_apply_rejects_out_of_range_and_bad_ranges(log_g):
    g, _ = log_g
    from repro.core.updates import GraphUpdateLog

    bad = GraphUpdateLog()
    bad.add_edge(0, g.num_nodes + 5)
    with pytest.raises(ValueError):
        bad.apply(g)
    with pytest.raises(ValueError):
        bad.events_between(2, 1)
    with pytest.raises(ValueError):
        bad.events_between(0, 99)
    # a stream recorded against a different featurization must fail with
    # a clear message, not a deep numpy broadcast error
    wrong = GraphUpdateLog()
    wrong.update_features(0, np.zeros(g.features.shape[1] + 3, np.float32))
    with pytest.raises(ValueError, match="different featurization"):
        wrong.apply(g)


def test_delta_touched_sets(log_g):
    g, log = log_g
    d = log.delta(0, 16)
    assert d.n_events == 16
    assert np.array_equal(d.nodes, np.unique(d.nodes))
    # every edge event's endpoints are in the node set
    for u, v in d.edges:
        assert u in d.nodes and v in d.nodes
    # sub-range union covers the full range
    d1, d2 = log.delta(0, 7), log.delta(7, 16)
    assert set(d.nodes) <= set(d1.nodes) | set(d2.nodes)


def test_jsonl_round_trip(tmp_path, log_g):
    g, log = log_g
    from repro.core.updates import load_update_stream

    path = str(tmp_path / "events.jsonl")
    assert log.to_jsonl(path) == 16
    log2 = load_update_stream(path)
    assert log2.last_seq == 16
    ref, got = log.apply(g), log2.apply(g)
    assert np.array_equal(ref.col_idx, got.col_idx)
    assert np.array_equal(ref.features, got.features)


def test_k_hop_frontier(graph):
    from repro.core.updates import k_hop_nodes

    seeds = np.array([0, 5])
    h0 = k_hop_nodes(graph, seeds, 0)
    assert np.array_equal(h0, seeds)
    h1 = k_hop_nodes(graph, seeds, 1)
    h2 = k_hop_nodes(graph, seeds, 2)
    assert set(h0) <= set(h1) <= set(h2)
    # 1-hop contains every out- and in-neighbor of the seeds
    e = graph.edges()
    for u, v in e:
        if u in seeds:
            assert v in h1
        if v in seeds:
            assert u in h1


def test_fold_in_place_mutates_shared_object(log_g):
    from repro.core.updates import fold_in_place

    g, log = log_g
    ref = log.apply(g)
    holder = g                                   # same object, elsewhere
    delta, frontier = fold_in_place(g, log, 0, hops=1)
    assert holder.num_edges == ref.num_edges
    assert np.array_equal(holder.col_idx, ref.col_idx)
    assert set(delta.nodes) <= set(frontier)
    # re-folding the same range is rejected upstream by seq cursors; the
    # primitive itself just re-applies, so delta must match the log
    assert delta.n_events == 16


def test_log_reset_stats_lockstep():
    from repro.core import telemetry
    from repro.core.updates import GraphUpdateLog

    telemetry.set_enabled(True)
    try:
        log = GraphUpdateLog()
        log.reset_stats()          # series are process-global: clean slate
        log.add_edge(0, 1)
        log.update_features(1, np.zeros(3))
        reg = telemetry.get_registry()
        assert reg.value("graph_updates_total", kind="add_edge") == 1
        log.reset_stats()
        assert log.counts["add_edge"] == 0
        assert reg.value("graph_updates_total", kind="add_edge") == 0
        assert log.last_seq == 2                 # events are state, kept
    finally:
        telemetry.set_enabled(False)


# ---------------------------------------------------------------------------
# incremental cache invalidation
# ---------------------------------------------------------------------------

def test_cache_invalidate_rows_is_surgical(graph):
    from repro.serving.cache import NEVER, EmbeddingCache

    cache = EmbeddingCache(graph, [8], policy="degree", max_staleness=4)
    ids = np.arange(32)
    cache.store(0, ids, np.ones((32, 8), np.float32), np.ones(32, bool))
    touched = np.arange(10)
    n = cache.invalidate_rows(touched)
    assert n == 10
    assert cache.invalidated_rows == 10
    vals, fresh = cache.lookup(0, ids)
    assert not fresh[:10].any()                  # touched rows cold
    assert fresh[10:].all()                      # untouched rows stay hot
    assert (cache.planes[0].version[cache.slot[touched]] == NEVER).all()
    # out-of-range / non-admitted ids cost nothing
    assert cache.invalidate_rows(np.array([-3, graph.num_nodes + 7])) == 0


def test_cache_invalidate_rows_ticks_once(graph):
    from repro.serving.cache import EmbeddingCache

    cache = EmbeddingCache(graph, [8], policy="degree", max_staleness=0)
    t0 = cache.clock
    cache.invalidate_rows(np.arange(4))
    assert cache.clock == t0 + 1
    cache.invalidate_rows(np.arange(4), tick=False)
    assert cache.clock == t0 + 1


def test_cache_reset_stats_covers_invalidations(graph):
    from repro.core import telemetry
    from repro.serving.cache import EmbeddingCache

    telemetry.set_enabled(True)
    try:
        cache = EmbeddingCache(graph, [8], policy="degree")
        cache.reset_stats()        # series are process-global: clean slate
        cache.invalidate_rows(np.arange(6))
        reg = telemetry.get_registry()
        assert reg.value("cache_invalidated_rows_total",
                         cache="serving.embedding") == 6
        assert cache.stats()["invalidated_rows"] == 6
        cache.reset_stats()
        assert cache.invalidated_rows == 0
        assert reg.value("cache_invalidated_rows_total",
                         cache="serving.embedding") == 0
    finally:
        telemetry.set_enabled(False)


# ---------------------------------------------------------------------------
# delta-aware halo refresh
# ---------------------------------------------------------------------------

def _exchange(graph, s):
    from repro.core.halo import HaloExchange, build_halo
    from repro.core.partitioning import partition
    layout = build_halo(graph, partition(graph, 2, "hash"))
    return HaloExchange(layout, [8], max_staleness=s, refresh_frac=0.0)


def test_halo_invalidate_rows_forces_refresh(graph):
    ex = _exchange(graph, s=4)
    # steady state: fill every ghost row once
    plan = ex.plan_refresh()
    ex.write_planes(plan, [np.ones((len(ex.copies), 8), np.float32)])
    # freshly written at S=4: next plans refresh (almost) nothing
    quiet = ex.plan_refresh()
    touched = np.flatnonzero(ex.ghost_rows)[:5]
    n = ex.invalidate_rows(touched)
    assert n == 5 * len(ex.buffers)
    assert ex.delta_rows == n
    forced = ex.plan_refresh()
    # every invalidated row is in the new plan's refresh mask, despite
    # being well within the staleness bound before invalidation
    assert forced.masks[0][touched].all()
    assert forced.rows_moved >= quiet.rows_moved


def test_halo_invalidate_rows_ignores_owned_rows(graph):
    ex = _exchange(graph, s=2)
    owned = np.flatnonzero(~ex.ghost_rows)[:4]
    assert ex.invalidate_rows(owned) == 0
    assert ex.invalidate_rows(np.array([-1, len(ex.copies) + 9])) == 0


def test_halo_delta_refresh_keeps_violations_zero(graph):
    from repro.core import telemetry

    telemetry.set_enabled(True)
    try:
        # this series is process-global; start from a clean slate so the
        # registry==instance cross-check below is exact
        telemetry.counter("delta_refresh_rows_total").reset()
        telemetry.counter("halo_staleness_violations_total").reset()
        ex = _exchange(graph, s=3)
        rng = np.random.default_rng(0)
        ghost = np.flatnonzero(ex.ghost_rows)
        for _ in range(8):
            plan = ex.plan_refresh()
            ex.write_planes(plan, [np.ones((len(ex.copies), 8),
                                           np.float32)])
            ex.invalidate_rows(rng.choice(ghost, 3, replace=False))
        reg = telemetry.get_registry()
        assert reg.value("halo_staleness_violations_total") == 0.0
        assert reg.value("delta_refresh_rows_total") == ex.delta_rows > 0
    finally:
        telemetry.set_enabled(False)


# ---------------------------------------------------------------------------
# delta-aware samplers
# ---------------------------------------------------------------------------

def test_sampler_memo_is_semantically_invisible(graph):
    from repro.serving.sampler import ServingSampler

    a = ServingSampler(graph, [5, 5], seed=0)
    b = ServingSampler(graph, [5, 5], seed=0)
    ids = np.arange(16)
    mb_a = a.sample(ids)
    mb_a2 = a.sample(ids)                        # memo-hit pass
    mb_b = b.sample(ids)
    for x, y, z in zip(mb_a.blocks, mb_a2.blocks, mb_b.blocks):
        assert np.array_equal(x.src_nodes, y.src_nodes)
        assert np.array_equal(x.src_nodes, z.src_nodes)
        assert np.array_equal(x.edge_src, z.edge_src)
    assert a.memo_hits > 0


def test_sampler_apply_delta_resamples_only_touched(log_g):
    from repro.core.updates import fold_in_place
    from repro.serving.sampler import ServingSampler

    g, log = log_g
    inc = ServingSampler(g, [5, 5], seed=0)
    inc.sample(np.arange(16))                    # populate the memo
    n_memo = len(inc._memo)
    delta, _ = fold_in_place(g, log, 0, hops=0)
    dropped = inc.apply_delta(delta.nodes)
    assert len(inc._memo) == n_memo - dropped
    # post-delta expansions match a fresh sampler on the mutated graph
    fresh = ServingSampler(g, [5, 5], seed=0)
    mb_i, mb_f = inc.sample(np.arange(16)), fresh.sample(np.arange(16))
    for x, y in zip(mb_i.blocks, mb_f.blocks):
        assert np.array_equal(x.src_nodes, y.src_nodes)
        assert np.array_equal(x.edge_src, y.edge_src)
        assert np.array_equal(x.edge_dst, y.edge_dst)


def test_sampler_affected_seed_mask(log_g):
    from repro.core.updates import fold_in_place, k_hop_nodes
    from repro.serving.sampler import ServingSampler

    g, log = log_g
    s = ServingSampler(g, [5, 5], seed=0)
    delta, _ = fold_in_place(g, log, 0, hops=0)
    s.apply_delta(delta.nodes)
    seeds = np.array([-1, 0, 1, 2, 3])
    mask = s.affected_seed_mask(seeds, delta.nodes)
    ball = set(k_hop_nodes(g, delta.nodes, 2))
    assert not mask[0]                           # pad slot never affected
    for i, sd in enumerate(seeds[1:], start=1):
        assert mask[i] == (int(sd) in ball)


def test_distributed_sampler_apply_delta_recomputes_degrees(log_g):
    from repro.core.updates import fold_in_place
    from repro.distributed.sampler import DistributedMinibatchSampler

    g, log = log_g
    ds = DistributedMinibatchSampler(g, 2, [5, 5], 16, partitioner="hash")
    delta, _ = fold_in_place(g, log, 0, hops=0)
    ds.apply_delta(delta.nodes)
    assert np.array_equal(
        ds.out_deg,
        np.maximum(g.out_degree(), 1).astype(np.float32))
    # sampling still works and stays partition-covering after the fold
    batches = ds.sample_global(np.arange(16))
    assert sum(int(b.label_mask.sum()) for b in batches) == 16


# ---------------------------------------------------------------------------
# serving end-to-end (in-process; the multi-device matrix runs the
# subprocess check below)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serving_delta_equals_rebuild_inprocess(log_g):
    import jax

    from repro.models.gnn import model as GM
    from repro.models.gnn.model import GNNConfig
    from repro.serving import GNNInferenceServer, poisson_workload
    from repro.serving.batcher import MicroBatch

    g, log = log_g
    cfg = GNNConfig(arch="sage", feat_dim=16, hidden=32, num_classes=4)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
    srv = GNNInferenceServer(copy.deepcopy(g), cfg, params,
                             fanouts=[5, 5], buckets=(1, 16),
                             max_staleness=4, seed=0)
    srv.warmup()
    srv.run(poisson_workload(32, np.arange(g.num_nodes), 2000.0, seed=1))
    info = srv.apply_graph_update(log)
    assert info["events"] == 16
    assert srv.apply_graph_update(log)["events"] == 0    # idempotent

    cold = GNNInferenceServer(log.apply(g), cfg, params,
                              fanouts=[5, 5], buckets=(1, 16),
                              max_staleness=4, seed=0)
    cold.warmup()
    for start in range(0, g.num_nodes, 16):
        ids = np.full(16, -1, np.int64)
        chunk = np.arange(start, min(start + 16, g.num_nodes))
        ids[:len(chunk)] = chunk
        a = srv.serve_batch(MicroBatch([], ids, 16, 0.0))
        b = cold.serve_batch(MicroBatch([], ids, 16, 0.0))
        assert np.max(np.abs(a[:len(chunk)] - b[:len(chunk)])) <= 1e-5


# ---------------------------------------------------------------------------
# fold-then-reorder: relabeling commutes with folding (the --reorder +
# --update-stream launcher path relabels the stream ONCE via
# GraphUpdateLog.relabel instead of re-sorting the graph per fold)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["degree", "bfs", "rcm"])
def test_fold_commutes_with_relabeling(log_g, policy):
    from repro.core.reordering import apply_order, reorder_graph

    g, log = log_g
    packed, perm, inv = reorder_graph(g, policy)

    a = apply_order(log.apply(g), perm)          # fold, then reorder
    b = log.relabel(inv).apply(packed)           # reorder, then fold

    def canon(gr):
        e = gr.edges()
        return e[np.lexsort((e[:, 1], e[:, 0]))]

    np.testing.assert_array_equal(canon(a), canon(b))
    np.testing.assert_array_equal(a.out_degree(), b.out_degree())
    np.testing.assert_array_equal(a.features, b.features)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_relabel_preserves_log_metadata(log_g):
    g, log = log_g
    n = g.num_nodes
    inv = np.arange(n)[::-1].copy()              # any permutation works
    r = log.relabel(inv)
    assert r.last_seq == log.last_seq
    assert r.counts == log.counts
    assert r.clock is log.clock                  # shared staleness epochs
    for ev, rev in zip(log.events, r.events):
        assert (rev.seq, rev.kind, rev.clock) == (ev.seq, ev.kind,
                                                  ev.clock)
        assert rev.u == inv[ev.u]
        assert rev.v == (inv[ev.v] if ev.v >= 0 else -1)


# ---------------------------------------------------------------------------
# the multi-device delta-vs-rebuild matrix (subprocess; tier dynamic)
# ---------------------------------------------------------------------------

def _run_check(n_dev, partitioner, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "dynamic_train_check.py"),
         str(n_dev), partitioner],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.mark.distributed
@pytest.mark.parametrize("partitioner", ["hash", "ldg"])
@pytest.mark.parametrize("n_dev", [1, 2])
def test_dynamic_equivalence_matrix(n_dev, partitioner):
    r = _run_check(n_dev, partitioner)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PASS dynamic-equivalence" in r.stdout, r.stdout
