"""Tests for the beyond-baseline extensions: 2PS partitioner, EASE-style
selection, vertex reordering, Hysync auto-switching, push-mode training."""
import numpy as np
import pytest

from repro.core import partitioning as P
from repro.core import reordering as RO
from repro.core.sync import HysyncController
from repro.graph import generators as G


@pytest.fixture(scope="module")
def powerlaw():
    return G.barabasi_albert(300, 3, seed=1)


@pytest.fixture(scope="module")
def er():
    return G.erdos_renyi(250, 6.0, seed=2, directed=False)


def test_2ps_partitioner_valid(powerlaw):
    p = P.partition(powerlaw, 4, "2ps")
    assert p.edge_assignment.shape == (powerlaw.num_edges,)
    assert (p.edge_assignment >= 0).all() and (p.edge_assignment < 4).all()
    assert p.balance() < 1.5
    # 2PS's clustering should not be worse than plain HDRF by much, and
    # both should beat the edge-cut replication factor on power-law graphs
    rf = p.replication_factor(powerlaw)
    rf_hash = P.partition(powerlaw, 4, "hash").replication_factor(powerlaw)
    assert rf < rf_hash


def test_ease_selector(powerlaw, er):
    assert P.select_partitioner(powerlaw, 8) == "hdrf"   # heavy tail
    assert P.select_partitioner(er, 8) == "ldg"          # uniform degrees
    big = G.erdos_renyi(2000, 2.0, seed=0)
    assert P.select_partitioner(big, 64,
                                latency_budget_s=0.01) == "hash"


def test_reordering_improves_locality(er):
    base = RO.edge_locality(er, window=32)
    perm = RO.bfs_locality_order(er)
    g2 = RO.apply_order(er, perm)
    better = RO.edge_locality(g2, window=32)
    assert better > base
    # relabeling preserves the graph (edge count, degree multiset)
    assert g2.num_edges == er.num_edges
    assert sorted(g2.out_degree().tolist()) == \
        sorted(er.out_degree().tolist())


def test_degree_sort_order_is_permutation(powerlaw):
    perm = RO.degree_sort_order(powerlaw)
    assert sorted(perm.tolist()) == list(range(powerlaw.num_nodes))
    g2 = RO.apply_order(powerlaw, perm)
    deg = g2.out_degree()
    assert deg[0] == powerlaw.out_degree().max()


def test_hysync_switches_to_bsp_when_converged():
    ctl = HysyncController(stale_s=4, switch_threshold=0.1)
    losses = [2.0, 1.0, 0.6, 0.4, 0.3, 0.28, 0.279, 0.2789, 0.2788]
    modes = [ctl.observe(i, l) for i, l in enumerate(losses)]
    assert modes[0] == "stale"
    assert modes[-1] == "bsp"
    assert ctl.switch_step is not None
    assert ctl.staleness() == 1
