"""Communication-plane invariants (repro.core.comm): codec round-trips,
framing/byte accounting, error feedback, and the single canonical
HEADER_BYTES shared by every transfer path."""
import numpy as np
import pytest

from repro.core.comm import (CODECS, HEADER_BYTES, INT8_ROW_META_BYTES,
                             Transport, resolve_codec)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # unit tests still run without it
    HAVE_HYPOTHESIS = False

    def given(*a, **k):                  # noqa: D103 - stub decorator
        return lambda f: pytest.mark.skip("hypothesis not installed")(f)

    settings = given

    class st:                            # noqa: D101 - stub strategies
        floats = integers = lists = staticmethod(lambda *a, **k: None)


# ---------------------------------------------------------------------------
# one canonical HEADER_BYTES (the dedup satellite)
# ---------------------------------------------------------------------------

def test_header_bytes_is_canonical_everywhere():
    """`core.caching` and `core.halo` must account the SAME per-RPC
    envelope object the comm plane defines — no more per-subsystem
    copies."""
    from repro.core import caching, halo
    assert caching.HEADER_BYTES is HEADER_BYTES
    assert halo.HEADER_BYTES is HEADER_BYTES


def test_resolve_codec():
    assert resolve_codec(None).name == "fp32"
    assert resolve_codec("int8") is CODECS["int8"]
    assert resolve_codec(CODECS["bf16"]) is CODECS["bf16"]
    with pytest.raises(KeyError):
        resolve_codec("fp16")


# ---------------------------------------------------------------------------
# codec round-trips
# ---------------------------------------------------------------------------

def _rows(n=7, dim=19, seed=0, scale=3.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, dim)) * scale).astype(np.float32)


def test_fp32_roundtrip_bit_exact():
    x = _rows()
    c = CODECS["fp32"]
    p = c.encode(x)
    assert p.nbytes == x.shape[0] * 4 * x.shape[1]
    np.testing.assert_array_equal(c.decode(p), x)
    assert c.identity and not c.error_feedback


def test_bf16_roundtrip_error_bound():
    """bf16 keeps 8 mantissa bits: relative error <= 2**-8 per element."""
    x = _rows(scale=100.0)
    c = CODECS["bf16"]
    p = c.encode(x)
    assert p.nbytes == x.shape[0] * 2 * x.shape[1]
    d = c.decode(p)
    assert (np.abs(d - x) <= np.abs(x) * 2.0 ** -8 + 1e-30).all()
    # exactly-representable values survive untouched
    e = np.asarray([[0.0, 1.0, -2.5, 1024.0]], np.float32)
    np.testing.assert_array_equal(c.qdq(e), e)


def test_int8_roundtrip_error_bound_and_wire_size():
    x = _rows(n=5, dim=64)
    c = CODECS["int8"]
    p = c.encode(x)
    assert p.nbytes == 5 * (64 + INT8_ROW_META_BYTES)
    # the ~4x claim: at hidden=64 (the bench width) the 8-byte row
    # metadata is amortized below the 30% acceptance line
    assert p.nbytes <= 5 * 64 * 4 * 0.30
    d = c.decode(p)
    scale = p.data[2]                            # (n, 1) per-row step
    assert (np.abs(d - x) <= scale * 0.5 + 1e-12).all()


def test_int8_constant_row_is_exact():
    x = np.full((2, 9), 3.25, np.float32)
    np.testing.assert_array_equal(CODECS["int8"].qdq(x), x)


@pytest.mark.parametrize("codec", ["fp32", "bf16", "int8"])
def test_jax_qdq_matches_host_qdq(codec):
    """The in-step quantizer (`jax_qdq`, used by forward_stale) and the
    host transport must agree on the wire loss to float tolerance."""
    import jax.numpy as jnp
    c = CODECS[codec]
    x = _rows(n=6, dim=24, seed=3)
    host = c.qdq(x)
    dev = np.asarray(c.jax_qdq(jnp.asarray(x)))
    scale = (x.max(1, keepdims=True) - x.min(1, keepdims=True)) / 255.0
    tol = 0.0 if codec != "int8" else scale      # rounding-direction ties
    assert (np.abs(dev - host) <= tol + 1e-6).all()


# ---------------------------------------------------------------------------
# transport framing + accounting
# ---------------------------------------------------------------------------

def test_transport_zero_row_send_is_free():
    t = Transport("int8", path="test/zero-row")
    out = t.send(np.zeros((0, 8), np.float32))
    assert out.shape == (0, 8)
    assert t.total_bytes == 0 and t.requests == 0


def test_transport_charges_payload_plus_one_header_per_send():
    t = Transport("int8", path="test/framing")
    t.send(_rows(n=4, dim=16))
    t.send(_rows(n=2, dim=16))
    c = CODECS["int8"]
    assert t.payload_bytes == 6 * c.wire_bytes_per_row(16)
    assert t.header_bytes == 2 * HEADER_BYTES
    assert t.rows_sent == 6 and t.requests == 2
    st = t.stats()
    assert st["total_bytes"] == t.payload_bytes + t.header_bytes
    t.reset_counters()
    assert t.total_bytes == 0 and t.rows_sent == 0


def test_residual_store_values_grow_with_touched_rows():
    """Error-feedback VALUE rows grow with the rows actually sent, not
    with the id space (the id→slot map is a cheap dense int32 vector) —
    never-sent ids read back zeros."""
    from repro.core.comm import ResidualStore
    rs = ResidualStore(n_rows=200_000, dim=4)
    rs.scatter(np.asarray([100_000, 7]), np.ones((2, 4)) * 2.5)
    assert rs._used == 2
    assert len(rs._buf) < 100                    # values, not id space
    got = rs.gather(np.asarray([7, 42, 100_000]))
    np.testing.assert_array_equal(got[0], np.full(4, 2.5, np.float32))
    np.testing.assert_array_equal(got[1], np.zeros(4, np.float32))
    np.testing.assert_array_equal(got[2], np.full(4, 2.5, np.float32))
    # growth past the initial capacity keeps earlier rows intact
    ids = np.arange(40)
    rs.scatter(ids, np.tile(np.arange(40, dtype=np.float32)[:, None],
                            (1, 4)))
    assert float(rs.gather(np.asarray([39]))[0, 0]) == 39.0
    assert float(rs.gather(np.asarray([100_000]))[0, 0]) == 2.5


def test_transport_fp32_send_is_identity():
    t = Transport("fp32", path="test/fp32-identity")
    x = _rows()
    np.testing.assert_array_equal(t.send(x), x)
    assert t.total_bytes == x.shape[0] * 4 * x.shape[1] + HEADER_BYTES


def test_featurestore_all_false_fetch_masked_is_free_under_compression():
    """The dedup-satellite regression, on the compressed path: an
    all-False mask must add 0 bytes even when an int8 transport (with
    residual state) is attached."""
    from repro.core.caching import FeatureStore
    from repro.graph import generators as G
    g = G.featurize(G.sbm(64, 4, p_in=0.9, p_out=0.02, seed=0), 8, seed=0)
    store = FeatureStore(g, np.zeros(0, np.int64), codec="int8")
    out = store.fetch_masked(np.asarray([1, 2, -1]), np.zeros(3, bool))
    assert store.transferred_bytes == 0
    assert (store.hits, store.misses, store.requests) == (0, 0, 0)
    assert not out.any()
    # a real miss pays compressed rows + one header and returns the
    # DECODED value (bounded error, not the raw row)
    got = store.fetch_masked(np.asarray([1, 2, -1]),
                             np.asarray([True, False, False]))
    assert store.transferred_bytes == store.bytes_per_row + HEADER_BYTES
    assert store.bytes_per_row == 8 + INT8_ROW_META_BYTES
    scale = (g.features[1].max() - g.features[1].min()) / 255.0
    assert np.abs(got[0] - g.features[1]).max() <= scale * 0.5 + 1e-12


# ---------------------------------------------------------------------------
# 2-device int8 training subprocess (tier-2 / run_tests.sh comm)
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.parametrize("codec", ["bf16", "int8"])
def test_comm_train_check_subprocess(codec):
    """int8/bf16 full-graph training on 2 forced host devices: finite
    losses, compressed bytes/step (see tests/comm_train_check.py)."""
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(root, "tests",
                                      "comm_train_check.py"), "2", codec],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PASS comm-train" in r.stdout, r.stdout


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

finite_f32 = st.floats(min_value=-3.4e38, max_value=3.4e38,
                       allow_nan=False, allow_infinity=False, width=32)


@settings(deadline=None, max_examples=60)
@given(st.lists(finite_f32, min_size=2, max_size=24))
def test_int8_error_at_most_half_scale_any_finite_row(row):
    """Property (a): per-element int8 encode/decode error <= scale/2 for
    arbitrary finite float32 rows (plus float32 representation spacing —
    when the row range is below the ulp of its magnitude, the codec
    cannot beat the format itself)."""
    x = np.asarray([row], np.float32)
    c = CODECS["int8"]
    p = c.encode(x)
    d = c.decode(p)
    scale = float(p.data[2][0, 0])
    slack = np.spacing(np.maximum(np.abs(x), np.float32(scale)))
    assert (np.abs(d - x).astype(np.float64)
            <= 0.5 * scale + 2.0 * slack).all()


@settings(deadline=None, max_examples=40)
@given(st.lists(st.floats(min_value=-1e4, max_value=1e4,
                          allow_nan=False, width=32),
                min_size=2, max_size=16),
       st.integers(min_value=2, max_value=12))
def test_error_feedback_mean_converges_to_truth(row, sends):
    """Property (b): with sender-side error feedback, the running mean of
    decoded sends of one fixed row converges to the true row — the
    accumulated bias after T sends is the (bounded) residual / T."""
    x = np.asarray([row], np.float32)
    t = Transport("int8", n_rows=4, path="test/error-feedback")
    ids = np.asarray([2])
    acc = np.zeros_like(x, np.float64)
    max_scale = 0.0
    for _ in range(sends):
        p = CODECS["int8"].encode(x.astype(np.float64)
                                  + (t.residuals.gather(ids)
                                     if t.residuals is not None else 0.0))
        max_scale = max(max_scale, float(p.data[2].max()))
        acc += t.send(x, row_ids=ids)
    err = np.abs(acc / sends - x).max()
    # slack: float32 decode rounding + float32 residual storage rounding
    slack = float(np.spacing(np.float32(np.abs(x).max() + max_scale)))
    assert err <= (0.5 * max_scale) / sends + 4.0 * slack + 1e-12
    # and the channel accounted every send
    assert t.requests == sends
    assert t.payload_bytes == sends * CODECS["int8"].wire_bytes_per_row(
        x.shape[1])
