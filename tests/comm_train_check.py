"""Communication-plane training check — run in a subprocess with
``--xla_force_host_platform_device_count=N``.

argv: n_dev codec (default: 2 int8)

Trains a small staleness-bounded full-graph GCN (S=1 with a refresh
budget, so both the quantized-refresh AND quantized-stale-read paths are
exercised) under the requested wire codec and asserts:

1. the loss stays finite every epoch (no NaNs from quantization /
   error-feedback residuals);
2. the consumed bytes/step are compressed: strictly below the fp32
   synchronous volume for the same layout (for int8, below 35% of it);
3. the reported plan accounting matches the codec's per-row wire size.

Used by ``scripts/run_tests.sh comm`` and the ``comm`` dev-smoke stage.
"""
import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 2
CODEC = sys.argv[2] if len(sys.argv) > 2 else "int8"

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", ""))

import jax                                              # noqa: E402
import numpy as np                                      # noqa: E402

from repro.core.comm import resolve_codec               # noqa: E402
from repro.distributed import AsyncFullGraphTrainer     # noqa: E402
from repro.graph import generators as G                 # noqa: E402
from repro.models.gnn import model as GM                # noqa: E402
from repro.models.gnn.model import GNNConfig            # noqa: E402
from repro.optim import AdamW                           # noqa: E402

assert jax.device_count() == N_DEV, jax.device_count()

HIDDEN = 64          # metadata amortized: int8 row = (64+8)/256 = 28%
EPOCHS = 8

g = G.sbm(144, 4, p_in=0.9, p_out=0.02, seed=0)
g = G.featurize(g, 16, seed=0, class_sep=1.5)

cfg = GNNConfig(arch="gcn", feat_dim=16, hidden=HIDDEN, num_classes=4,
                wire_codec=CODEC)
params0 = GM.init_gnn(cfg, jax.random.PRNGKey(0))
opt = AdamW(lr=1e-2, weight_decay=0.0)

losses = []
tr = AsyncFullGraphTrainer(g, cfg, opt, N_DEV, partitioner="hash",
                           staleness=1, refresh_frac=0.05)
p, o = params0, opt.init(params0)
for _ in range(EPOCHS):
    p, o, loss = tr.run(p, o, 1)
    assert np.isfinite(loss), f"non-finite loss under {CODEC}: {losses}"
    losses.append(loss)

st = tr.stats()
codec = resolve_codec(CODEC)
# the plan accounting must price rows at the codec's wire size: the
# fp32-synchronous baseline for the same layout differs exactly by the
# per-row byte ratio (header terms aside)
fp32_sync = AsyncFullGraphTrainer(
    g, GNNConfig(arch="gcn", feat_dim=16, hidden=HIDDEN, num_classes=4),
    opt, N_DEV, partitioner="hash", staleness=0
).exchange.sync_bytes_per_step()
assert st["bytes_per_step"] < fp32_sync, st
if CODEC == "int8":
    assert st["bytes_per_step"] <= 0.35 * fp32_sync, (st, fp32_sync)
assert st["wire_codec"] == codec.name

print(f"PASS comm-train n_dev={N_DEV} codec={CODEC} "
      f"loss={losses[-1]:.4f} bytes/step={st['bytes_per_step']:.0f} "
      f"fp32_sync={fp32_sync} "
      f"compressed_to={st['bytes_per_step'] / fp32_sync:.1%}")
