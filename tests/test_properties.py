"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.abstraction import DeviceGraph
from repro.graph import generators as G
from repro.kernels import ref
from repro.kernels.segment_sum import segment_sum_pallas
from repro.models.transformer import layers as L


# ---------------------------------------------------------------------------
# segment_sum kernel algebraic invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(e=st.integers(1, 200), f=st.integers(1, 40), n=st.integers(1, 50),
       seed=st.integers(0, 100))
def test_segment_sum_matches_oracle_random_shapes(e, f, n, seed):
    rng = np.random.default_rng(seed)
    msgs = jnp.asarray(rng.normal(size=(e, f)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    got = segment_sum_pallas(msgs, ids, n)
    want = ref.segment_sum(msgs, ids, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100))
def test_segment_sum_linearity(seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 10, 64), jnp.int32)
    lhs = segment_sum_pallas(a + 2.0 * b, ids, 10)
    rhs = (segment_sum_pallas(a, ids, 10)
           + 2.0 * segment_sum_pallas(b, ids, 10))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_segment_sum_edge_permutation_invariance(seed):
    rng = np.random.default_rng(seed)
    msgs = jnp.asarray(rng.normal(size=(80, 6)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 12, 80), jnp.int32)
    perm = rng.permutation(80)
    a = segment_sum_pallas(msgs, ids, 12)
    b = segment_sum_pallas(msgs[perm], ids[perm], 12)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# attention invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), sq=st.integers(2, 24))
def test_causal_attention_prefix_property(seed, sq):
    """Causal attention outputs for a prefix equal the prefix of outputs —
    the invariant that makes KV-cache decode valid at all."""
    rng = np.random.default_rng(seed)
    B, H, hd = 1, 2, 16
    S = 24
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    full = L.attention(q, k, v, causal=True, q_offset=0)
    pre = L.attention(q[:, :sq], k[:, :sq], v[:, :sq], causal=True,
                      q_offset=0)
    np.testing.assert_allclose(np.asarray(full[:, :sq]), np.asarray(pre),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_attention_rows_are_convex_combinations(seed):
    """Each attention output lies in the convex hull of V rows: max |out|
    <= max |v| per feature (softmax weights sum to 1)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 8, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 8, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 8, 2, 8)), jnp.float32)
    out = np.asarray(L.attention(q, k, v, causal=True, q_offset=0))
    assert np.all(out.max() <= np.asarray(v).max() + 1e-5)
    assert np.all(out.min() >= np.asarray(v).min() - 1e-5)


# ---------------------------------------------------------------------------
# graph substrate invariants
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(10, 150), d=st.floats(1.0, 8.0),
       seed=st.integers(0, 50))
def test_degree_sum_equals_edges(n, d, seed):
    g = G.erdos_renyi(n, d, seed=seed, directed=False)
    assert g.out_degree().sum() == g.num_edges
    assert g.in_degree().sum() == g.num_edges
    # undirected: in == out
    np.testing.assert_array_equal(g.in_degree(), g.out_degree())


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 100), seed=st.integers(0, 20))
def test_subgraph_is_induced(n, seed):
    g = G.erdos_renyi(n, 5.0, seed=seed, directed=False)
    rng = np.random.default_rng(seed)
    nodes = rng.choice(n, n // 2, replace=False)
    sub = g.subgraph(nodes)
    assert sub.num_nodes == len(nodes)
    node_set = set(nodes.tolist())
    e = g.edges()
    expect = sum(1 for u, v in e if u in node_set and v in node_set)
    assert sub.num_edges == expect


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 30))
def test_device_graph_degrees_match(seed):
    g = G.erdos_renyi(60, 4.0, seed=seed, directed=True)
    dg = DeviceGraph.from_graph(g)
    np.testing.assert_array_equal(
        np.asarray(dg.in_deg), np.maximum(g.in_degree(), 1))


# ---------------------------------------------------------------------------
# halo layer invariants (core/halo.py)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(20, 120), p=st.integers(2, 5),
       method=st.sampled_from(["hash", "ldg"]), seed=st.integers(0, 30))
def test_halo_every_endpoint_owned_or_ghost(n, p, method, seed):
    """For every partition, every endpoint of every edge touching it is
    either owned by it or in its halo (ghost) set."""
    from repro.core import partitioning as PT
    from repro.core.halo import build_halo
    g = G.erdos_renyi(n, 4.0, seed=seed, directed=False)
    part = PT.partition(g, p, method)
    lay = build_halo(g, part)
    e = g.edges()
    for q in range(p):
        present = np.zeros(n, bool)
        present[lay.owned[q]] = True
        present[lay.halo[q]] = True
        touches = (lay.owner[e[:, 0]] == q) | (lay.owner[e[:, 1]] == q)
        assert present[e[touches]].all()
        assert not np.intersect1d(lay.owned[q], lay.halo[q]).size
        # halo_in/halo_out partition the ghost set by fetch direction
        np.testing.assert_array_equal(
            lay.halo[q], np.union1d(lay.halo_in[q], lay.halo_out[q]))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 100), p=st.integers(2, 4), seed=st.integers(0, 20))
def test_halo_exchange_round_trips_features(n, p, seed):
    """Fixed-shape gather/scatter through the exchange indices reproduces
    every ghost feature row exactly."""
    from repro.core import partitioning as PT
    from repro.core.halo import build_halo
    g = G.erdos_renyi(n, 5.0, seed=seed, directed=False)
    g = G.featurize(g, 8, seed=seed, num_classes=3)
    lay = build_halo(g, PT.partition(g, p, "hash"))
    gathered = lay.gather_halo(g.features)
    assert gathered.shape == (p, lay.halo_cap, 8)
    for q in range(p):
        np.testing.assert_array_equal(gathered[q][lay.halo_mask[q]],
                                      g.features[lay.halo[q]])
        # pad slots stay zero (never alias a real vertex)
        assert not gathered[q][~lay.halo_mask[q]].any()
    back = lay.scatter_halo(gathered, 8)
    ghosts = np.unique(lay.halo_idx[lay.halo_mask])
    np.testing.assert_array_equal(back[ghosts], g.features[ghosts])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), fill=st.integers(0, 8))
def test_padded_rows_never_leak_into_aggregation(seed, fill):
    """Garbage in a padded block's pad-slot feature rows must not change
    any valid destination's output (the masking contract the distributed
    mini-batch step relies on)."""
    from repro.core.sampling import sample_block_padded
    from repro.models.gnn.layers import SAGELayer
    g = G.erdos_renyi(60, 5.0, seed=seed, directed=False)
    gr = g.reverse()
    rng = np.random.default_rng(seed)
    dst = np.full(8, -1, np.int64)
    if fill:
        dst[:fill] = rng.choice(g.num_nodes, fill, replace=False)

    def rng_for(node):
        return np.random.default_rng((seed, node))

    b = sample_block_padded(g, gr, dst, 3, rng_for)
    dg = DeviceGraph.from_block(b)
    x = rng.normal(size=(b.num_src, 6)).astype(np.float32)
    poisoned = x.copy()
    poisoned[np.asarray(b.src_nodes) < 0] = 1e9
    layer = SAGELayer()
    p = SAGELayer.init(jax.random.PRNGKey(0), 6, 5)
    clean = np.asarray(layer(p, dg, jnp.asarray(x)))
    dirty = np.asarray(layer(p, dg, jnp.asarray(poisoned)))
    valid = np.asarray(b.dst_nodes) >= 0
    np.testing.assert_allclose(dirty[valid], clean[valid],
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# shared staleness clock invariants (core/caching.py + core/halo.py)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(20, 100), p=st.integers(2, 4),
       s=st.integers(0, 4), frac=st.floats(0.0, 0.5),
       steps=st.integers(1, 15), seed=st.integers(0, 30))
def test_ghost_buffer_never_served_beyond_staleness_bound(n, p, s, frac,
                                                          steps, seed):
    """A ghost buffer row refreshed at version v is never served once
    clock - v > S: every plan's stale-served set has age <= S, for any
    bound, budget, and step count."""
    from repro.core import partitioning as PT
    from repro.core.halo import HaloExchange, build_halo
    g = G.erdos_renyi(n, 4.0, seed=seed, directed=False)
    lay = build_halo(g, PT.partition(g, p, "hash"))
    ex = HaloExchange(lay, [4, 8], max_staleness=s, refresh_frac=frac)
    for _ in range(steps):
        ages = [b.age() for b in ex.buffers]
        plan = ex.plan_refresh()
        assert plan.step == ex.clock.now - 1
        for age, mask in zip(ages, plan.masks):
            served_stale = ex.ghost_rows & ~mask
            assert (age[served_stale] <= s).all()
            # refresh never targets non-ghost rows
            assert not mask[~ex.ghost_rows].any()


@settings(max_examples=20, deadline=None)
@given(s=st.integers(0, 3), writes=st.lists(st.integers(0, 9), min_size=1,
                                            max_size=12),
       seed=st.integers(0, 20))
def test_versioned_buffer_fresh_iff_within_bound(s, writes, seed):
    """The unified VersionedBuffer serves exactly the rows written within
    the last S ticks — the single staleness predicate both the serving
    EmbeddingCache and the training HaloExchange rely on."""
    from repro.core.caching import VersionClock, VersionedBuffer
    clock = VersionClock()
    buf = VersionedBuffer(clock, 10, 3)
    last_write = {}
    rng = np.random.default_rng(seed)
    for row in writes:
        buf.write(np.asarray([row]), rng.normal(size=(1, 3)))
        last_write[row] = clock.now
        if rng.random() < 0.5:
            clock.tick()
        fresh = buf.fresh_mask(s)
        for r in range(10):
            want = r in last_write and clock.now - last_write[r] <= s
            assert fresh[r] == want, (r, clock.now, last_write.get(r))


# ---------------------------------------------------------------------------
# dynamic-graph update-log invariants (core/updates.py + serving cache)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(16, 80), n_ev=st.integers(1, 24),
       k=st.integers(0, 24), seed=st.integers(0, 30))
def test_update_log_apply_composes_over_any_split(n, n_ev, k, seed):
    """Applying ``[0, k]`` then ``(k, last]`` is BITWISE identical to
    applying ``[0, last]`` in one shot, for any split point — the
    composition property every incremental fold relies on (from_edges
    stable-sorts by source, so removals commute with the sort)."""
    from repro.core.updates import synthesize_updates
    g = G.featurize(G.erdos_renyi(n, 4.0, seed=seed, directed=False), 6,
                    seed=seed, num_classes=3)
    log = synthesize_updates(g, n_ev, seed=seed)
    k = min(k, log.last_seq)
    one = log.apply(g)
    two = log.apply(log.apply(g, k), from_seq=k)
    np.testing.assert_array_equal(one.row_ptr, two.row_ptr)
    np.testing.assert_array_equal(one.col_idx, two.col_idx)
    np.testing.assert_array_equal(one.features, two.features)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(16, 60), n_ev=st.integers(2, 24),
       cuts=st.tuples(st.integers(0, 24), st.integers(0, 24),
                      st.integers(0, 24)),
       seed=st.integers(0, 30))
def test_update_log_delta_union_covers_range(n, n_ev, cuts, seed):
    """``delta(a,b) ∪ delta(b,c) ⊇ delta(a,c)`` for any a <= b <= c —
    folding a stream in chunks never invalidates less than folding the
    whole range at once (in fact the touched sets are equal)."""
    from repro.core.updates import synthesize_updates
    g = G.featurize(G.erdos_renyi(n, 4.0, seed=seed, directed=False), 6,
                    seed=seed, num_classes=3)
    log = synthesize_updates(g, n_ev, seed=seed)
    a, b, c = sorted(min(x, log.last_seq) for x in cuts)
    ab, bc, ac = log.delta(a, b), log.delta(b, c), log.delta(a, c)
    union_nodes = set(ab.nodes.tolist()) | set(bc.nodes.tolist())
    assert set(ac.nodes.tolist()) <= union_nodes
    union_edges = ({tuple(e) for e in ab.edges}
                   | {tuple(e) for e in bc.edges})
    assert {tuple(e) for e in ac.edges} <= union_edges
    assert ab.n_events + bc.n_events == ac.n_events


@settings(max_examples=25, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["store", "inv", "tick"]),
                              st.integers(0, 9)),
                    min_size=1, max_size=30),
       seed=st.integers(0, 20))
def test_cache_never_serves_pre_invalidation_rows(ops, seed):
    """A cache row is never served from a value written BEFORE that
    row's last invalidation: ``invalidate_rows`` ticks the shared clock,
    so any re-fill is stamped strictly after the invalidation.  With an
    effectively infinite staleness bound, freshness is *exactly* 'stored
    since last invalidation', and served bytes equal the last store."""
    from repro.serving.cache import EmbeddingCache
    g = G.featurize(G.erdos_renyi(10, 3.0, seed=seed, directed=False), 4,
                    seed=seed, num_classes=2)
    cache = EmbeddingCache(g, [4], max_staleness=10 ** 6)
    rng = np.random.default_rng(seed)
    current = {}        # node -> value stored since its last invalidation
    for op, node in ops:
        if op == "store":
            val = rng.normal(size=(1, 4)).astype(np.float32)
            cache.store(0, np.asarray([node]), val, np.asarray([True]))
            current[node] = val[0]
        elif op == "inv":
            before = cache.clock
            cache.invalidate_rows(np.asarray([node]))
            assert cache.clock == before + 1      # fold == refresh epoch
            current.pop(node, None)
        else:
            cache.tick()
        vals, fresh = cache.lookup(0, np.arange(10))
        for i in range(10):
            assert fresh[i] == (i in current), (i, op, node)
            if fresh[i]:
                np.testing.assert_array_equal(vals[i], current[i])
