"""Crash-safe checkpoint invariants: a kill at ANY point during save can
never corrupt resume — ``latest_step`` only ever selects a fully written
step, partial directories are skipped and rejected, and the manifest is
validated against the npz payload before any leaf is restored."""
import os

import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint


def _tree(seed: int):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.normal(size=(4, 3)).astype(np.float32),
        "b": rng.normal(size=(3,)).astype(np.float32),
        "inner": {"scale": np.asarray(float(seed), np.float64)},
    }


def _assert_tree_equal(a, b):
    assert np.allclose(a["w"], b["w"])
    assert np.allclose(a["b"], b["b"])
    assert np.allclose(a["inner"]["scale"], b["inner"]["scale"])


# ---------------------------------------------------------------------------
# happy path: roundtrip, meta, dtype restoration
# ---------------------------------------------------------------------------

def test_roundtrip_with_meta(tmp_path):
    t = _tree(0)
    path = save_checkpoint(str(tmp_path), 3, t,
                           meta={"params_version": 3, "note": "x"})
    assert path.endswith("step_00000003")
    assert latest_step(str(tmp_path)) == 3
    restored, manifest = load_checkpoint(str(tmp_path), _tree(99))
    _assert_tree_equal(restored, t)
    assert manifest["meta"] == {"params_version": 3, "note": "x"}
    assert manifest["step"] == 3


def test_restore_casts_to_saved_dtype(tmp_path):
    """The manifest dtype (what was saved) wins over the template's."""
    t = _tree(1)
    save_checkpoint(str(tmp_path), 0, t)
    template = {"w": np.zeros((4, 3), np.float16),
                "b": np.zeros((3,), np.float16),
                "inner": {"scale": np.asarray(0, np.int32)}}
    restored, _ = load_checkpoint(str(tmp_path), template)
    assert restored["w"].dtype == np.float32
    assert restored["inner"]["scale"].dtype == np.float64
    _assert_tree_equal(restored, t)


def test_overwrite_same_step_is_atomic(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree(0))
    t2 = _tree(7)
    save_checkpoint(str(tmp_path), 1, t2)
    restored, _ = load_checkpoint(str(tmp_path), _tree(99))
    _assert_tree_equal(restored, t2)
    assert latest_step(str(tmp_path)) == 1


# ---------------------------------------------------------------------------
# kill mid-save: the partial step is invisible, resume uses the previous
# ---------------------------------------------------------------------------

def test_kill_mid_save_resumes_previous_step(tmp_path, monkeypatch):
    """Simulate a crash between manifest and npz writes: the .tmp staging
    dir remains, step_2 is never published, and resume lands on step 1."""
    good = _tree(0)
    save_checkpoint(str(tmp_path), 1, good)

    real_savez = np.savez

    def crash_savez(*a, **k):
        raise KeyboardInterrupt("killed mid-save")

    monkeypatch.setattr(np, "savez", crash_savez)
    with pytest.raises(KeyboardInterrupt):
        save_checkpoint(str(tmp_path), 2, _tree(1))
    monkeypatch.setattr(np, "savez", real_savez)

    # the torn step was never published: only the .tmp staging dir exists
    assert not os.path.isdir(tmp_path / "step_00000002")
    assert os.path.isdir(tmp_path / "step_00000002.tmp")
    assert latest_step(str(tmp_path)) == 1
    restored, manifest = load_checkpoint(str(tmp_path), _tree(99))
    _assert_tree_equal(restored, good)
    assert manifest["step"] == 1

    # a retry after the crash reuses (and replaces) the stale staging dir
    t2 = _tree(2)
    save_checkpoint(str(tmp_path), 2, t2)
    assert latest_step(str(tmp_path)) == 2
    restored, _ = load_checkpoint(str(tmp_path), _tree(99))
    _assert_tree_equal(restored, t2)


def test_partial_dir_skipped_and_rejected(tmp_path):
    """A pre-rename-style torn step (one file missing) is skipped by
    latest_step and rejected by an explicit load."""
    save_checkpoint(str(tmp_path), 1, _tree(0))
    save_checkpoint(str(tmp_path), 5, _tree(1))
    os.remove(tmp_path / "step_00000005" / "arrays.npz")
    assert latest_step(str(tmp_path)) == 1
    with pytest.raises(FileNotFoundError, match="partial"):
        load_checkpoint(str(tmp_path), _tree(99), step=5)
    restored, _ = load_checkpoint(str(tmp_path), _tree(99))
    _assert_tree_equal(restored, _tree(0))


def test_empty_and_missing_dirs(tmp_path):
    assert latest_step(str(tmp_path)) is None
    assert latest_step(str(tmp_path / "nope")) is None
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(tmp_path), _tree(0))


def test_tmp_only_dir_is_never_a_candidate(tmp_path):
    """Regression: a directory holding ONLY a ``.tmp`` staging step — a
    kill before the very first publish rename — must look empty.  Even
    when the stage contains BOTH payload files, it was never published:
    ``latest_step`` returns None and load/restore raise rather than
    resuming from the torn stage."""
    import shutil

    from repro.serving import restore_params

    src = tmp_path / "src"
    save_checkpoint(str(src), 3, _tree(0), meta={"params_version": 1})
    ckpts = tmp_path / "ckpts"
    ckpts.mkdir()
    shutil.move(str(src / "step_00000003"),
                str(ckpts / "step_00000003.tmp"))
    assert latest_step(str(ckpts)) is None
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(ckpts), _tree(0))
    with pytest.raises(FileNotFoundError):
        restore_params(str(ckpts), _tree(0))


# ---------------------------------------------------------------------------
# manifest validation
# ---------------------------------------------------------------------------

def test_manifest_npz_key_mismatch_rejected(tmp_path):
    """A manifest declaring more leaves than the npz holds (torn copy)
    fails loudly before any leaf is restored."""
    import msgpack

    save_checkpoint(str(tmp_path), 0, _tree(0))
    mpath = tmp_path / "step_00000000" / "manifest.msgpack"
    manifest = msgpack.unpackb(mpath.read_bytes())
    manifest["num_leaves"] += 1
    manifest["shapes"].append([2])
    manifest["dtypes"].append("float32")
    mpath.write_bytes(msgpack.packb(manifest))
    with pytest.raises(ValueError, match="missing"):
        load_checkpoint(str(tmp_path), _tree(0))


def test_template_leaf_count_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 0, _tree(0))
    with pytest.raises(ValueError, match="leaves"):
        load_checkpoint(str(tmp_path), {"only": np.zeros((4, 3), np.float32)})
