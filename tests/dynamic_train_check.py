"""Delta-vs-rebuild equivalence for dynamic graphs — run in a subprocess
with ``--xla_force_host_platform_device_count=N``.

argv: n_dev partitioner

1. **Continual training == cold rebuild.**  Trains 5 async full-graph
   epochs at S=0, folds a 16-event synthetic update stream through
   :meth:`AsyncFullGraphTrainer.fold_updates` (in-place graph mutation,
   re-shard, halo rebuild on the same clock, frontier invalidation),
   trains 5 more — and demands every parameter agree to <= 1e-5 with the
   cold path (5 epochs on the base graph, then a FRESH trainer on
   ``log.apply(g)`` for 5 more).  At S=0 every ghost row refreshes every
   step, so ported buffer values are never read and the fold must be
   *exact* — the same bar as ``async_train_check.py``.
2. **Post-update serving == cold rebuild.**  Serves every node on an
   incrementally invalidated server (graph folded in place via
   :meth:`GNNInferenceServer.apply_graph_update` after a warm serving
   run at staleness 4) and on a cold server built on the mutated graph,
   and demands the logits agree to <= 1e-5.  Hot rows that survive the
   delta frontier are served from cache — equivalence holds because
   memoized sampler picks keep untouched neighborhoods bit-identical
   and the frontier covers every node whose (L-1)-hop ball the delta
   reaches.
"""
import copy
import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 2
METHOD = sys.argv[2] if len(sys.argv) > 2 else "hash"

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", ""))

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from repro.core.updates import synthesize_updates       # noqa: E402
from repro.distributed import AsyncFullGraphTrainer     # noqa: E402
from repro.graph import generators as G                 # noqa: E402
from repro.models.gnn import model as GM                # noqa: E402
from repro.models.gnn.model import GNNConfig            # noqa: E402
from repro.optim import AdamW                           # noqa: E402
from repro.serving import GNNInferenceServer, poisson_workload  # noqa: E402
from repro.serving.batcher import MicroBatch            # noqa: E402

assert jax.device_count() == N_DEV, jax.device_count()

g = G.sbm(144, 4, p_in=0.9, p_out=0.02, seed=0)
g = G.featurize(g, 16, seed=0, class_sep=1.5)
log = synthesize_updates(g, 16, seed=2)

cfg = GNNConfig(arch="gcn", feat_dim=16, hidden=32, num_classes=4)
opt = AdamW(lr=1e-2, weight_decay=0.0)
params0 = GM.init_gnn(cfg, jax.random.PRNGKey(0))

# -- continual training: 5 epochs, fold, 5 epochs ----------------------------
tr = AsyncFullGraphTrainer(copy.deepcopy(g), cfg, opt, N_DEV,
                           partitioner=METHOD, staleness=0)
p, o, _ = tr.run(params0, opt.init(params0), 5)
fold = tr.fold_updates(log)
assert fold["events"] == 16, fold
assert tr.fold_updates(log)["events"] == 0, "fold must be idempotent"
p, o, loss_inc = tr.run(p, o, 5)

# -- cold rebuild: 5 epochs on base, fresh trainer on mutated ----------------
tr_a = AsyncFullGraphTrainer(copy.deepcopy(g), cfg, opt, N_DEV,
                             partitioner=METHOD, staleness=0)
p2, o2, _ = tr_a.run(params0, opt.init(params0), 5)
tr_b = AsyncFullGraphTrainer(log.apply(g), cfg, opt, N_DEV,
                             partitioner=METHOD, staleness=0)
p2, o2, loss_cold = tr_b.run(p2, o2, 5)

diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p, p2)
maxdiff_train = max(jax.tree_util.tree_leaves(diffs))
assert maxdiff_train <= 1e-5, (maxdiff_train, diffs)
assert abs(loss_inc - loss_cold) < 1e-5, (loss_inc, loss_cold)

# -- serving: warm run, incremental fold, compare against cold ---------------
scfg = GNNConfig(arch="sage", feat_dim=16, hidden=32, num_classes=4)
sparams = GM.init_gnn(scfg, jax.random.PRNGKey(1))
srv = GNNInferenceServer(copy.deepcopy(g), scfg, sparams, fanouts=[5, 5],
                         buckets=(1, 4, 16), max_staleness=4, seed=0)
srv.warmup()
srv.run(poisson_workload(48, np.arange(g.num_nodes), 2000.0, seed=1))
info = srv.apply_graph_update(log)
assert info["events"] == 16, info

cold = GNNInferenceServer(log.apply(g), scfg, sparams, fanouts=[5, 5],
                          buckets=(1, 4, 16), max_staleness=4, seed=0)
cold.warmup()

maxdiff_serve = 0.0
for start in range(0, g.num_nodes, 16):
    ids = np.full(16, -1, np.int64)
    chunk = np.arange(start, min(start + 16, g.num_nodes))
    ids[:len(chunk)] = chunk
    a = srv.serve_batch(MicroBatch([], ids, 16, 0.0))
    b = cold.serve_batch(MicroBatch([], ids, 16, 0.0))
    maxdiff_serve = max(maxdiff_serve, float(np.max(
        np.abs(a[:len(chunk)] - b[:len(chunk)]))))
assert maxdiff_serve <= 1e-5, maxdiff_serve
# the incremental server must actually have served from its warm cache
assert srv.cache.hits > 0, "incremental server never hit its cache"

print(f"PASS dynamic-equivalence n_dev={N_DEV} part={METHOD} "
      f"train_maxdiff={maxdiff_train:.2e} serve_maxdiff={maxdiff_serve:.2e} "
      f"invalidated={info['invalidated_rows']} "
      f"ghost_delta_rows={fold['invalidated_rows']}")
