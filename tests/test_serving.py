"""Serving subsystem invariants: bucket/shape discipline, embedding-cache
consistency (exact at staleness 0, bounded under staleness), and the serve
loop end-to-end."""
import copy

import jax
import numpy as np
import pytest

from repro.core.sampling import sample_block_padded
from repro.models.gnn import model as GM
from repro.models.gnn.model import GNNConfig
from repro.serving import (BucketedBatcher, EmbeddingCache,
                           GNNInferenceServer, InferenceRequest,
                           RequestQueue, ServingSampler, poisson_workload)
from repro.serving.batcher import MicroBatch
from repro.serving.sampler import needed_feature_mask

BUCKETS = (1, 4, 8)
FANOUTS = (3, 3)


@pytest.fixture(scope="module")
def graph(graph):
    return graph("sbm", 200)


@pytest.fixture(scope="module")
def model(graph):
    cfg = GNNConfig(arch="sage", feat_dim=16, hidden=32,
                    num_classes=graph.num_classes)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _server(graph, model, **kw):
    cfg, params = model
    kw.setdefault("fanouts", FANOUTS)
    kw.setdefault("buckets", BUCKETS)
    kw.setdefault("cache_policy", "degree")
    kw.setdefault("cache_capacity", graph.num_nodes)
    kw.setdefault("seed", 0)
    return GNNInferenceServer(graph, cfg, params, **kw)


def _batch(node_ids, bucket):
    ids = np.full((bucket,), -1, np.int64)
    ids[:len(node_ids)] = node_ids
    return MicroBatch([], ids, bucket, 0.0)


# ---------------------------------------------------------------------------
# batcher: every emitted shape is from the declared bucket set
# ---------------------------------------------------------------------------

def test_batcher_emits_only_declared_buckets():
    b = BucketedBatcher(buckets=BUCKETS, max_wait_s=0.01)
    rng = np.random.default_rng(0)
    q = RequestQueue()
    rid = 0
    for trial in range(50):
        for _ in range(int(rng.integers(1, 12))):
            q.push(InferenceRequest(rid, int(rng.integers(0, 100)),
                                    arrival_s=0.0))
            rid += 1
        mb = b.form(q, now=1.0)          # head-of-line waited > max_wait
        assert mb is not None
        assert mb.bucket in BUCKETS
        assert mb.node_ids.shape == (mb.bucket,)
        # unique real ids form a prefix; pads are -1; every request maps
        # to a real slot
        k = len(set(r.node_id for r in mb.requests))
        assert k <= mb.bucket
        real = mb.node_ids[:k]
        assert np.all(real >= 0)
        assert len(np.unique(real)) == k
        assert np.all(mb.node_ids[k:] == -1)
        assert all(mb.node_ids[s] == r.node_id
                   for s, r in zip(mb.slots, mb.requests))
        q = RequestQueue()               # fresh queue per trial


def test_batcher_waits_below_max_wait():
    b = BucketedBatcher(buckets=BUCKETS, max_wait_s=0.5)
    q = RequestQueue()
    q.push(InferenceRequest(0, 5, arrival_s=0.0))
    assert b.form(q, now=0.1) is None            # not full, not timed out
    assert b.form(q, now=0.6) is not None        # timed out
    q.push(InferenceRequest(1, 5, arrival_s=1.0))
    assert b.form(q, now=1.0, force=True) is not None


def test_batcher_dedups_duplicate_nodes():
    """Requests for the same node share a slot — the sampler requires
    unique dst ids and one prediction serves every duplicate."""
    b = BucketedBatcher(buckets=BUCKETS)
    q = RequestQueue()
    for rid, nid in enumerate([7, 7, 9, 7]):
        q.push(InferenceRequest(rid, nid, arrival_s=0.0))
    mb = b.form(q, now=0.0, force=True)
    assert mb.bucket == 4                        # 2 unique ids -> bucket 4
    real = mb.node_ids[mb.node_ids >= 0]
    assert sorted(real.tolist()) == [7, 9]
    assert [mb.node_ids[s] for s in mb.slots] == [7, 7, 9, 7]


def test_duplicate_requests_get_correct_logits(graph, model):
    """Regression: duplicate node ids in one micro-batch must each be
    served the same (correct) logits as a solo request for that node."""
    srv = _server(graph, model, cache_policy="none")
    solo = srv.serve_batch(_batch(np.asarray([7]), 1))[0]
    srv2 = _server(graph, model, cache_policy="none")
    srv2.warmup()
    wl = [InferenceRequest(0, 7, 0.0), InferenceRequest(1, 7, 0.0),
          InferenceRequest(2, 7, 0.0)]
    srv2.run(wl)
    for r in wl:
        np.testing.assert_array_equal(r.logits, solo)


def test_run_respects_max_wait_deadline(graph, model):
    """Regression: with requests queued, the virtual clock must advance to
    the head-of-line max_wait deadline, not to the next arrival."""
    srv = _server(graph, model, max_wait_s=0.002)
    srv.warmup()
    wl = [InferenceRequest(0, 3, 0.0), InferenceRequest(1, 4, 5.0)]
    srv.run(wl)
    # request 0 waits ~max_wait + compute, NOT the 5 s inter-arrival gap
    assert wl[0].latency_s < 2.0, wl[0].latency_s
    assert wl[1].latency_s >= 0


def test_run_never_livelocks_on_deadline_rounding(graph, model):
    """Regression: the event jump can land the virtual clock exactly on
    fl(oldest + max_wait), where the recomputed head-of-line wait
    ``vnow - oldest`` rounds one error SHORT of max_wait_s — the batcher
    keeps refusing to emit and ``max(vnow, min(events))`` never advances
    again.  This exact arrival float reproduced the livelock
    (0.017512410335686807 + 0.002 re-subtracted gives 0.00199…983)."""
    import signal

    srv = _server(graph, model, max_wait_s=0.002)
    srv.warmup()
    wl = [InferenceRequest(0, 3, 0.017512410335686807),
          InferenceRequest(1, 4, 5.0)]

    def _hang(signum, frame):
        raise TimeoutError("serve loop livelocked on the max_wait deadline")

    old = signal.signal(signal.SIGALRM, _hang)
    signal.alarm(60)
    try:
        stats = srv.run(wl)
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
    assert stats.served == 2


def test_batcher_bucket_for():
    b = BucketedBatcher(buckets=BUCKETS)
    assert b.bucket_for(1) == 1
    assert b.bucket_for(2) == 4
    assert b.bucket_for(5) == 8
    assert b.bucket_for(99) == 8                 # capped at largest


# ---------------------------------------------------------------------------
# sampler: block shapes are a pure function of (bucket, fanouts)
# ---------------------------------------------------------------------------

def test_sampler_static_shapes_per_bucket(graph):
    s = ServingSampler(graph, FANOUTS, seed=0)
    rng = np.random.default_rng(1)
    for bucket in BUCKETS:
        declared = s.block_shapes(bucket)
        for fill in (1, bucket):
            ids = np.full((bucket,), -1, np.int64)
            ids[:fill] = rng.choice(graph.num_nodes, fill, replace=False)
            mb = s.sample(ids)
            assert len(mb.blocks) == len(FANOUTS)
            got = [(b.num_dst, b.num_src, len(b.edge_mask))
                   for b in mb.blocks]
            assert got == declared, (bucket, fill)
            for b in mb.blocks:
                # dst nodes are a slot-aligned prefix of src nodes
                np.testing.assert_array_equal(b.src_nodes[:b.num_dst],
                                              b.dst_nodes)
                valid_e = b.edge_mask.sum()
                assert np.all(b.edge_src[:valid_e] < b.num_src)
                assert np.all(b.edge_dst[:valid_e] < b.num_dst)


def test_sampler_deterministic_per_node(graph):
    """A node's sampled neighborhood must not depend on batch composition
    (cache-consistency prerequisite)."""
    s = ServingSampler(graph, FANOUTS, seed=0)
    gr = graph.reverse()
    b1 = sample_block_padded(graph, gr, np.asarray([7, -1]), 3,
                             s._rng_for(1))
    b2 = sample_block_padded(graph, gr, np.asarray([7, 42]), 3,
                             s._rng_for(1))
    e1 = {(int(b1.src_nodes[s_]), int(b1.dst_nodes[d]))
          for s_, d in zip(b1.edge_src[b1.edge_mask],
                           b1.edge_dst[b1.edge_mask])}
    e2 = {(int(b2.src_nodes[s_]), int(b2.dst_nodes[d]))
          for s_, d in zip(b2.edge_src[b2.edge_mask],
                           b2.edge_dst[b2.edge_mask])}
    assert {e for e in e1} <= e2                  # node 7's edges identical


def test_expansion_mask_restricts_sampling(graph):
    s = ServingSampler(graph, FANOUTS, seed=0)
    ids = np.asarray([3, 9, 27, 81], np.int64)
    outer = s.sample_outer(ids)
    none_expanded = s.sample_inner(outer.src_nodes,
                                   np.zeros(outer.num_src, bool))
    assert all(b.edge_mask.sum() == 0 for b in none_expanded)
    need = needed_feature_mask(none_expanded,
                               np.zeros(none_expanded[-1].num_dst, bool))
    assert not need.any()                        # no misses -> no fetches


# ---------------------------------------------------------------------------
# embedding cache: exactness and staleness semantics
# ---------------------------------------------------------------------------

def test_cached_logits_exact_at_staleness_zero(graph, model):
    ids = np.asarray([11, 23, 42, 99], np.int64)
    srv_none = _server(graph, model, cache_policy="none")
    srv = _server(graph, model, max_staleness=0)
    want = srv_none.serve_batch(_batch(ids, 4))
    srv.serve_batch(_batch(ids, 4))              # cold: populates cache
    assert srv.cache.hits == 0 or srv.cache.hit_ratio < 1.0
    got = srv.serve_batch(_batch(ids, 4))        # warm: served from cache
    assert srv.cache.hits > 0
    np.testing.assert_array_equal(got[:4], want[:4])


def test_cached_logits_bounded_at_staleness_s(graph, model):
    eps = 1e-2
    ids = np.asarray([11, 23, 42, 99], np.int64)
    srv = _server(graph, model, max_staleness=2)
    srv.serve_batch(_batch(ids, 4))              # populate at clock 0
    rng = np.random.default_rng(0)
    old_feats = graph.features.copy()
    try:
        graph.features += rng.normal(0, eps, graph.features.shape
                                     ).astype(np.float32)
        srv.cache.tick()                         # staleness 1 <= bound 2
        stale = srv.serve_batch(_batch(ids, 4))
        assert srv.cache.hits > 0                # actually served stale
        fresh = _server(graph, model,
                        cache_policy="none").serve_batch(_batch(ids, 4))
        diff = np.abs(stale[:4] - fresh[:4]).max()
        assert 0 < diff < 50 * eps               # stale but bounded
    finally:
        graph.features[:] = old_feats


def test_capacity_zero_admits_nothing(graph):
    """Regression: capacity=0 must mean 'admit nothing', not full-graph."""
    c = EmbeddingCache(graph, [8], policy="degree", capacity=0)
    ids = np.asarray([0, 1, 2])
    c.store(0, ids, np.ones((3, 8), np.float32), np.ones(3, bool))
    assert not c.lookup(0, ids)[1].any()
    full = EmbeddingCache(graph, [8], policy="degree")   # None = unbounded
    full.store(0, ids, np.ones((3, 8), np.float32), np.ones(3, bool))
    assert full.lookup(0, ids)[1].all()


def test_fetch_masked_all_false_transfers_nothing(graph):
    """Regression: a fetch_masked call whose ``needed`` mask selects no
    rows must add 0 bytes — no per-RPC header, no hits/misses.  The
    envelope constant is the communication plane's canonical one."""
    from repro.core.caching import FeatureStore
    from repro.core.comm import HEADER_BYTES
    store = FeatureStore(graph, np.zeros(0, np.int64))
    ids = np.asarray([1, 2, -1])
    out = store.fetch_masked(ids, np.zeros(3, bool))
    assert store.transferred_bytes == 0
    assert (store.hits, store.misses, store.requests) == (0, 0, 0)
    assert not out.any()                         # zero rows, static shape
    # a call that does transfer pays exactly rows + one header; the -1
    # pad slot is ignored even when marked needed
    store.fetch_masked(ids, np.asarray([True, False, True]))
    assert store.misses == 1
    assert store.transferred_bytes == store.bytes_per_row + HEADER_BYTES


def test_staleness_bound_and_invalidation(graph):
    c = EmbeddingCache(graph, [8], policy="degree",
                       capacity=graph.num_nodes, max_staleness=1)
    ids = np.asarray([1, 2, 3])
    c.store(0, ids, np.ones((3, 8), np.float32), np.ones(3, bool))
    assert c.lookup(0, ids)[1].all()
    c.tick()                                     # staleness 1: still fresh
    assert c.lookup(0, ids)[1].all()
    c.tick()                                     # staleness 2 > bound
    assert not c.lookup(0, ids)[1].any()
    c.store(0, ids, np.ones((3, 8), np.float32), np.ones(3, bool))
    c.invalidate(np.asarray([2]))
    fresh = c.lookup(0, ids)[1]
    assert fresh[0] and not fresh[1] and fresh[2]
    # padded slots are neither hits nor misses
    h0, m0 = c.hits, c.misses
    c.lookup(0, np.asarray([-1, -1]))
    assert (c.hits, c.misses) == (h0, m0)


def test_cache_hits_skip_feature_fetches(graph, model):
    ids = np.asarray([5, 6, 7, 8], np.int64)
    srv = _server(graph, model, max_staleness=0)
    srv.serve_batch(_batch(ids, 4))
    cold_rows = srv.cache.features.hits + srv.cache.features.misses
    assert cold_rows > 0
    srv.serve_batch(_batch(ids, 4))
    # warm serve: every ids1 slot is an embedding hit, so the needed-mask
    # is empty and NO feature rows are requested at all
    warm_rows = srv.cache.features.hits + srv.cache.features.misses
    assert warm_rows == cold_rows


# ---------------------------------------------------------------------------
# server end-to-end
# ---------------------------------------------------------------------------

def test_server_end_to_end(graph, model):
    srv = _server(graph, model, cache_capacity=graph.num_nodes // 4)
    srv.warmup()
    wl = poisson_workload(40, np.arange(graph.num_nodes), 2000.0, seed=2)
    stats = srv.run(copy.deepcopy(wl))
    assert stats.served == 40
    assert stats.throughput_rps > 0
    assert all(lat >= 0 for lat in stats.latencies_s)
    assert stats.latency_quantile(0.99) >= stats.latency_quantile(0.50)
    # static-shape discipline: at most one jit entry per declared bucket
    assert len(stats.jit_shapes) <= len(BUCKETS)
    s = srv.summary()
    assert 0.0 <= s["embedding_hit_ratio"] <= 1.0


@pytest.mark.parametrize("arch", ["gcn", "gat", "gin"])
def test_server_other_archs(graph, arch):
    cfg = GNNConfig(arch=arch, feat_dim=16, hidden=32,
                    num_classes=graph.num_classes)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(1))
    srv = GNNInferenceServer(graph, cfg, params, fanouts=FANOUTS,
                             buckets=(4,), cache_policy="degree",
                             cache_capacity=graph.num_nodes, seed=0)
    ids = np.asarray([10, 20, 30], np.int64)
    cold = srv.serve_batch(_batch(ids, 4))
    warm = srv.serve_batch(_batch(ids, 4))
    assert np.isfinite(cold[:3]).all()
    np.testing.assert_allclose(warm[:3], cold[:3], atol=1e-5)


def test_advance_vclock_strict_progress():
    """The shared clock helper (PR 8 fix, enforced by lint rule RL003)
    must make strictly positive progress in every case — including the
    exact-landing case that livelocked `max(vnow, nxt)`."""
    import math

    from repro.serving.request import advance_vclock

    # normal jump: lands exactly on the next event
    assert advance_vclock(1.0, 2.5) == 2.5
    # exact landing (nxt == vnow): one-ulp strict march, never a stall
    v = advance_vclock(1.0, 1.0)
    assert v > 1.0 and v == math.nextafter(1.0, math.inf)
    # stale event (nxt < vnow): still strictly advances
    assert advance_vclock(1.0, 0.5) == math.nextafter(1.0, math.inf)
    # iterating from an exact landing terminates (the PR 8 livelock shape)
    vnow, nxt = 3.0, 3.0
    for _ in range(4):
        prev, vnow = vnow, advance_vclock(vnow, nxt)
        assert vnow > prev
