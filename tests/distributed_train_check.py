"""Gradient-equivalence check for the distributed mini-batch pipeline —
run in a subprocess with ``--xla_force_host_platform_device_count=N``.

argv: n_dev partitioner arch

Trains 3 steps with the partition-parallel shard_map step (N devices,
seeds split by ownership, halo-cached remote fetches) and 3 steps with
the single-device reference step on the SAME global seed batches, then
demands every parameter agree to <= 1e-5 — the regression class tier-1
could not previously catch.
"""
import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 2
METHOD = sys.argv[2] if len(sys.argv) > 2 else "hash"
ARCH = sys.argv[3] if len(sys.argv) > 3 else "sage"

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", ""))

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from repro.distributed import (DistributedMinibatchSampler,   # noqa: E402
                               collate, device_blocks,
                               make_distributed_minibatch_step)
from repro.graph import generators as G                 # noqa: E402
from repro.models.gnn import model as GM                # noqa: E402
from repro.models.gnn.model import GNNConfig            # noqa: E402
from repro.optim import AdamW                           # noqa: E402

assert jax.device_count() == N_DEV, jax.device_count()

g = G.sbm(144, 4, p_in=0.9, p_out=0.02, seed=0)
g = G.featurize(g, 16, seed=0, class_sep=1.5)

cfg = GNNConfig(arch=ARCH, feat_dim=16, hidden=32, num_classes=4)
params0 = GM.init_gnn(cfg, jax.random.PRNGKey(0))
opt = AdamW(lr=1e-2, weight_decay=0.0)

B, FANOUTS, STEPS = 24, [3, 3], 3

dist = DistributedMinibatchSampler(
    g, N_DEV, FANOUTS, B, partitioner=METHOD, cache_policy="degree",
    cache_capacity=g.num_nodes // 10, seed=0)
mesh, dstep = make_distributed_minibatch_step(cfg, opt, N_DEV,
                                              dist.block_shapes())

# reference: ONE partition (everything owned/local) -> the deterministic
# sampler emits the identical per-seed trees; step is the plain
# single-device mini-batch trainer
ref = DistributedMinibatchSampler(g, 1, FANOUTS, B, partitioner="hash",
                                  cache_policy="none", seed=0)
ref_step = jax.jit(GM.make_minibatch_train_step(cfg, opt))

pd, od = params0, opt.init(params0)
pr, orr = jax.tree.map(lambda a: a, params0), opt.init(params0)

rng = np.random.default_rng(1)
for it in range(STEPS):
    seeds = rng.choice(g.num_nodes, B, replace=False)
    arrays = collate(dist.sample_global(seeds), dist.out_deg)
    pd, od, loss_d = dstep(pd, od, arrays)

    rb = ref.sample_global(seeds)[0]
    pr, orr, loss_r = ref_step(
        pr, orr, device_blocks(rb, ref.out_deg), jnp.asarray(rb.x_in),
        jnp.asarray(rb.labels), jnp.asarray(rb.label_mask))
    dl = abs(float(loss_d) - float(loss_r))
    assert dl < 1e-5, (it, float(loss_d), float(loss_r))

diffs = jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), pd, pr)
maxdiff = max(jax.tree_util.tree_leaves(diffs))
assert maxdiff <= 1e-5, (maxdiff, diffs)

stats = dist.stats()
assert stats["cross_partition_bytes"] > 0   # remote traffic really flowed
print(f"PASS dist-equivalence n_dev={N_DEV} part={METHOD} arch={ARCH} "
      f"maxdiff={maxdiff:.2e} halo_hit={stats['halo_hit_ratio']:.2f} "
      f"xpart_kib={stats['cross_partition_bytes'] / 1024:.1f}")
