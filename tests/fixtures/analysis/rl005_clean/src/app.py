"""RL005 clean: every registered metric has a catalog row and vice
versa.  The dynamically-built name is skipped by design."""
from repro.obs import telemetry


def instrument(shard: int):
    telemetry.counter("app_requests_total", "Requests served.")
    telemetry.gauge("app_queue_depth", "Current queue depth.")
    telemetry.counter("app_" + str(shard), "Dynamic: skipped.")
