"""RL001 true positive: psum reachable inside a differentiated function.

This is the PR 2 bug verbatim in miniature — under shard_map
check_rep=False, the transpose of the psum is a second psum, so the
gradients come back scaled by the axis size.
"""
import jax
import jax.numpy as jnp

AXIS = "dev"


def local_loss(params, x, y):
    pred = x @ params["w"]
    err = jnp.sum((pred - y) ** 2)
    return jax.lax.psum(err, AXIS)          # BAD: collective inside grad


def train_step(params, x, y):
    grads = jax.grad(local_loss)(params, x, y)
    return grads
