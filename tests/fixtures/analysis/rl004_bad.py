"""RL004 true positives: misaligned Pallas tile shapes and a VMEM blowout.

Covers: last dim not lane-aligned, a BlockSpec with last dim 1
(lane-tile padding — the scalar-accumulator exemption is VMEM-only),
second-to-last not sublane-aligned, and a scratch buffer over the
module's VMEM_BUDGET.  Shapes resolve through literals, module
constants, and parameter defaults.
"""
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

VMEM_BUDGET = 8 * 2**20
BN = 100                                         # not lane-aligned


def build_specs(bq=24):
    bad_lane = pl.BlockSpec((8, BN), lambda i: (i, 0))       # BAD: 100 % 128
    bad_sub = pl.BlockSpec((12, 128), lambda i: (i, 0))      # BAD: 12 % 8
    bad_col = pl.BlockSpec((8, 1), lambda i: (i, 0))         # BAD: last dim 1
    return bad_lane, bad_sub, bad_col, bq


def scratch():
    huge = pltpu.VMEM((4096, 1024), jnp.float32)             # BAD: 16 MiB
    return huge
