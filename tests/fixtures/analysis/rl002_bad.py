"""RL002 true positive: dispatch decisions resolved inside a jit body.

The PR 4 class — ``jax.default_backend()`` and ``os.environ`` reads
inside a jitted function are evaluated once at first trace and pinned in
the jit cache; later environment changes are silently ignored.
"""
import os

import jax
import jax.numpy as jnp


@jax.jit
def dispatch(x):
    backend = jax.default_backend()         # BAD: pinned at trace time
    if os.environ.get("REPRO_INTERPRET"):   # BAD: pinned at trace time
        return x
    flag = os.environ["REPRO_MODE"]         # BAD: pinned at trace time
    del backend, flag
    return jnp.sum(x)


def helper():
    return jax.default_backend()            # BAD via call chain


@jax.jit
def dispatch_transitive(x):
    if helper() == "cpu":
        return x
    return jnp.sum(x)
