"""Suppression fixture: a justified disable silences the finding."""
from repro.core.comm import Transport


def make_link():
    # repro-lint: disable=RL006 -- fixture exercising the justified-suppression path
    return Transport("int8")
