"""RL004 scalar-accumulator idiom — the codified clean shapes.

A 2-D ``pltpu.VMEM`` scratch ``(rows, 1)`` with sublane-aligned rows is
the online-softmax running max/denominator pattern
(``kernels/flash_attention.py``, ``kernels/gat_fused.py``): one scalar
per row is inherent to the algorithm, and the rule accepts it without a
suppression comment.
"""
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

VMEM_BUDGET = 8 * 2**20


def scratch(bq=128):
    running_max = pltpu.VMEM((64, 1), jnp.float32)     # 8-aligned rows
    running_den = pltpu.VMEM((bq, 1), jnp.float32)     # via param default
    return running_max, running_den
