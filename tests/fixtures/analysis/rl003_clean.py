"""RL003 clean: clock stepped through the shared strict-progress helper.

Also shows a legitimate non-self-referencing use of ``max`` with a clock
on the RIGHT-hand side only as an operand of a fresh variable — the rule
must not fire on ordinary accumulators or fresh derivations.
"""
from repro.serving.request import advance_vclock


def run_loop(events, vnow=0.0):
    busy = []
    while events:
        vnow = advance_vclock(vnow, min(events))  # strict progress: fine
        events = [e for e in events if e > vnow]
    v_end = max([vnow] + busy)                    # fresh name: fine
    return v_end
