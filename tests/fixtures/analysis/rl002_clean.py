"""RL002 clean: backend resolved OUTSIDE jit, passed as a static arg.

The fixed idiom from PR 4 (and ``kernels/ops.py``): a plain wrapper
resolves the environment per call and hands the decision to jit as a
static argument, so each distinct value gets its own trace.
"""
import functools
import os

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("interpret",))
def _kernel(x, interpret=False):
    del interpret
    return jnp.sum(x)


def dispatch(x):
    interpret = bool(os.environ.get("REPRO_INTERPRET"))   # per call: fine
    backend = jax.default_backend()                       # per call: fine
    del backend
    return _kernel(x, interpret=interpret)
