"""RL001 clean: loss stays local inside grad; collectives run OUTSIDE.

This is the fixed idiom from PR 2 — value_and_grad over a purely local
loss, then psum the loss and the gradients once, afterwards.
"""
import jax
import jax.numpy as jnp

AXIS = "dev"


def local_loss(params, x, y):
    pred = x @ params["w"]
    return jnp.sum((pred - y) ** 2)


def train_step(params, x, y):
    local, grads = jax.value_and_grad(local_loss)(params, x, y)
    loss = jax.lax.psum(local, AXIS)        # outside the grad: fine
    grads = jax.lax.psum(grads, AXIS)
    return loss, grads
