"""RL004 scalar-accumulator idiom — the shapes that do NOT qualify.

The codified exception is narrow: a 2-D ``pltpu.VMEM`` scratch
``(rows, 1)`` with sublane-aligned rows.  Everything adjacent to it
stays flagged: misaligned rows, a 3-D scratch with a trailing 1, and a
``pl.BlockSpec`` shaped around a scalar column (an HBM block, not a
VMEM accumulator).
"""
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

VMEM_BUDGET = 8 * 2**20


def scratch():
    ragged = pltpu.VMEM((12, 1), jnp.float32)    # BAD: rows not 8-aligned
    deep = pltpu.VMEM((1, 8, 1), jnp.float32)    # BAD: 3-D, not the idiom
    return ragged, deep


def spec():
    # BAD: BlockSpec last-dim-1 is never exempt — a scalar column in HBM
    # should ride along a wider block, not get its own lane tile
    return pl.BlockSpec((8, 1), lambda i: (i, 0))
