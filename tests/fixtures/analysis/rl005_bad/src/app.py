"""RL005 true positive: one metric missing from the catalog, and the
catalog carries one stale row registered nowhere."""
from repro.obs import telemetry


def instrument():
    telemetry.counter("app_requests_total", "Requests served.")
    telemetry.counter("app_shiny_new_total", "Not in the catalog.")  # drift
    telemetry.gauge("app_queue_depth", "Current queue depth.")
