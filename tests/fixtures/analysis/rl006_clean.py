"""RL006 clean: every Transport names its transfer path."""
from repro.core.comm import Transport


def make_links(kw):
    a = Transport("int8", path="halo/fwd")
    b = Transport("fp32", n_rows=4, path="weights/broadcast")
    c = Transport("int8", **kw)                  # **kwargs may carry path
    return a, b, c
