"""RL006 true positive: Transport constructed without a path label."""
from repro.core.comm import Transport


def make_links():
    a = Transport("int8")                        # BAD: no path=
    b = Transport("fp32", n_rows=4)              # BAD: no path=
    return a, b
