"""Suppression fixture: a BARE disable (no justification) suppresses
nothing and is itself flagged (RL000)."""
from repro.core.comm import Transport


def make_link():
    # repro-lint: disable=RL006
    return Transport("int8")
