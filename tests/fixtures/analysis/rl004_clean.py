"""RL004 clean: lane/sublane-aligned tiles within the VMEM budget.

Includes the ``_pick_bf`` narrow-sliver case (an 8-aligned last dim
below 128), a runtime-computed dimension the rule must skip rather than
guess, and a reassigned parameter default that invalidates resolution.
"""
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

VMEM_BUDGET = 8 * 2**20
BK = 256


def build_specs(n, bq=128):
    bq = min(bq, n)                              # reassigned: unresolvable
    aligned = pl.BlockSpec((8, BK), lambda i: (i, 0))
    sliver = pl.BlockSpec((8, 24), lambda i: (i, 0))    # _pick_bf rule
    dynamic = pl.BlockSpec((bq, n), lambda i: (i, 0))   # skipped
    return aligned, sliver, dynamic


def scratch():
    return pltpu.VMEM((128, 256), jnp.float32)   # 128 KiB: within budget
