"""RL003 true positive: inline virtual-clock advances (PR 8 livelock).

``max()`` and self-referencing ternaries can return the clock unchanged
when the next event lands exactly on the current instant — the serve
loop then spins forever.
"""
import math


def run_loop(events, vnow=0.0):
    while events:
        nxt = min(events)
        vnow = max(vnow, nxt)                     # BAD: can not-advance
        events = [e for e in events if e > vnow]
    return vnow


def run_loop_ternary(events, vnow=0.0):
    while events:
        nxt = min(events)
        vnow = nxt if nxt > vnow else math.nextafter(vnow, math.inf)  # BAD: inline
        events = [e for e in events if e > vnow]
    return vnow
