"""Per-kernel shape/dtype sweeps asserting allclose vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU), gradient tests for
the custom VJPs, hypothesis properties over random shapes, and the
kernel-vs-reference training-equivalence subprocess matrix."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gat_fused import gat_fused_attention_pallas
from repro.kernels.segment_sum import (gather_scale_segment_sum_pallas,
                                       gather_scale_segment_sum_q_pallas,
                                       segment_sum_pallas)
from repro.kernels.ssd_chunk import ssd_chunk_state_pallas

RNG = np.random.default_rng(42)
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("E,F,N", [(64, 32, 16), (300, 70, 45),
                                   (1000, 128, 128), (17, 5, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_sum(E, F, N, dtype):
    msgs = jnp.asarray(RNG.normal(size=(E, F)), dtype)
    ids = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    got = segment_sum_pallas(msgs, ids, N)
    # the kernel accumulates in fp32 scratch; compare against the fp32
    # ground truth with dtype-appropriate tolerance
    want = ref.segment_sum(msgs.astype(jnp.float32), ids, N)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_segment_sum_empty_segments():
    msgs = jnp.ones((8, 4), jnp.float32)
    ids = jnp.zeros((8,), jnp.int32)          # everything into segment 0
    got = segment_sum_pallas(msgs, ids, 5)
    assert float(got[0, 0]) == 8.0
    assert float(jnp.abs(got[1:]).sum()) == 0.0


def test_segment_sum_no_edges():
    """E=0 degenerates to a single all-pad tile: zeros out, zeros grad."""
    msgs = jnp.zeros((0, 6), jnp.float32)
    ids = jnp.zeros((0,), jnp.int32)
    got = segment_sum_pallas(msgs, ids, 7)
    assert got.shape == (7, 6)
    assert float(jnp.abs(got).sum()) == 0.0
    grad = jax.grad(lambda m: jnp.sum(segment_sum_pallas(m, ids, 7)))(msgs)
    assert grad.shape == (0, 6)


# ---------------------------------------------------------------------------
# custom-VJP gradients: kernel vs jax.ops autodiff
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,F,N", [(64, 32, 16), (300, 70, 45),
                                   (17, 5, 3), (129, 130, 129)])
def test_segment_sum_grad_matches_reference(E, F, N):
    """d/d(msgs) of a weighted sum through the kernel == through
    jax.ops.segment_sum (the backward gather kernel vs XLA's VJP)."""
    msgs = jnp.asarray(RNG.normal(size=(E, F)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(N, F)), jnp.float32)

    def loss(seg_fn):
        return lambda m: jnp.sum(seg_fn(m, ids, N) * w)

    gk = jax.grad(loss(lambda m, i, n: segment_sum_pallas(m, i, n)))(msgs)
    gr = jax.grad(loss(jax.ops.segment_sum))(msgs)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               atol=2e-5, rtol=2e-5)


def _fused_ref(h, src, dst, coef, num_dst):
    msgs = jnp.take(h, src, axis=0) * coef[:, None]
    return jax.ops.segment_sum(msgs, dst, num_dst)


@pytest.mark.parametrize("S,E,F,N", [(50, 200, 33, 40), (16, 64, 128, 16),
                                     (130, 300, 5, 71)])
def test_fused_forward_matches_reference(S, E, F, N):
    h = jnp.asarray(RNG.normal(size=(S, F)), jnp.float32)
    src = jnp.asarray(RNG.integers(0, S, E), jnp.int32)
    dst = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    coef = jnp.asarray(RNG.normal(size=(E,)), jnp.float32)
    got = gather_scale_segment_sum_pallas(h, src, dst, coef, N)
    want = _fused_ref(h, src, dst, coef, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,E,F,N", [(50, 200, 33, 40), (130, 300, 5, 71)])
def test_fused_grads_match_reference(S, E, F, N):
    """dh (fused kernel with src/dst swapped) and dcoef (edge-dot
    kernel) both match the XLA VJP of the unfused expression."""
    h = jnp.asarray(RNG.normal(size=(S, F)), jnp.float32)
    src = jnp.asarray(RNG.integers(0, S, E), jnp.int32)
    dst = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    coef = jnp.asarray(RNG.normal(size=(E,)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(N, F)), jnp.float32)

    def loss(fn):
        return lambda h_, c_: jnp.sum(fn(h_, src, dst, c_, N) * w)

    gk = jax.grad(loss(gather_scale_segment_sum_pallas),
                  argnums=(0, 1))(h, coef)
    gr = jax.grad(loss(_fused_ref), argnums=(0, 1))(h, coef)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gr[0]),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gr[1]),
                               atol=2e-4, rtol=2e-4)


def test_fused_all_masked_edges():
    """coef carries the edge mask: all-masked input aggregates (and
    back-propagates) exactly zero."""
    S, E, F, N = 20, 40, 12, 10
    h = jnp.asarray(RNG.normal(size=(S, F)), jnp.float32)
    src = jnp.asarray(RNG.integers(0, S, E), jnp.int32)
    dst = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    coef = jnp.zeros((E,), jnp.float32)
    out = gather_scale_segment_sum_pallas(h, src, dst, coef, N)
    assert float(jnp.abs(out).sum()) == 0.0
    dh = jax.grad(lambda h_: jnp.sum(
        gather_scale_segment_sum_pallas(h_, src, dst, coef, N)))(h)
    assert float(jnp.abs(dh).sum()) == 0.0


def test_fused_capacity_fallback():
    """Above the fused kernel's VMEM capacity, the ops-layer dispatch
    falls back to the unfused blocked kernel (row-count independent)
    instead of tripping the budget assert — use_kernel=True must work
    on large single-device graphs."""
    from repro.kernels import ops as kops
    from repro.kernels.segment_sum import fused_fits

    S = N = 5000
    E, F = 300, 128
    assert not fused_fits(S, N, F)
    h = jnp.asarray(RNG.normal(size=(S, F)), jnp.float32)
    src = jnp.asarray(RNG.integers(0, S, E), jnp.int32)
    dst = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    coef = jnp.asarray(RNG.normal(size=(E,)), jnp.float32)
    got = kops.gather_scale_segment_sum(h, src, dst, coef, N)
    want = _fused_ref(h, src, dst, coef, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # gradients flow through the fallback path too
    gk = jax.grad(lambda h_: jnp.sum(kops.gather_scale_segment_sum(
        h_, src, dst, coef, N)))(h)
    gr = jax.grad(lambda h_: jnp.sum(_fused_ref(h_, src, dst, coef, N)))(h)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               atol=2e-5, rtol=2e-5)


def test_fused_no_edges():
    h = jnp.asarray(RNG.normal(size=(9, 6)), jnp.float32)
    e = jnp.zeros((0,), jnp.int32)
    out = gather_scale_segment_sum_pallas(h, e, e,
                                          jnp.zeros((0,), jnp.float32), 5)
    assert out.shape == (5, 6)
    assert float(jnp.abs(out).sum()) == 0.0


# ---------------------------------------------------------------------------
# one-pass fused GAT attention (online softmax; logits/alphas never in HBM)
# ---------------------------------------------------------------------------

def _gat_ref(hs, es, ed, src, dst, maskf, N, heads):
    """Multi-pass XLA reference: the exact math GATLayer's non-kernel
    path runs (leaky-relu logits, per-destination softmax with the
    same 1e-9 denominator, weighted segment sum)."""
    hd = hs.shape[1] // heads
    logits = jax.nn.leaky_relu(
        jnp.take(es, src, axis=0) + jnp.take(ed, dst, axis=0), 0.2)
    logits = jnp.where(maskf[:, None] > 0, logits, -1e30)
    mx = jax.ops.segment_max(logits, dst, N)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(logits - mx[dst]) * maskf[:, None]
    den = jax.ops.segment_sum(ex, dst, N)
    alpha = ex / (jnp.take(den, dst, axis=0) + 1e-9)
    msgs = jnp.take(hs.reshape(-1, heads, hd), src, axis=0) \
        * alpha[..., None]
    return jax.ops.segment_sum(msgs.reshape(-1, heads * hd), dst, N)


def _gat_case(S, E, N, heads, hd, seed=0, mask_frac=0.0):
    rng = np.random.default_rng(seed)
    hs = jnp.asarray(rng.normal(size=(S, heads * hd)), jnp.float32)
    es = jnp.asarray(rng.normal(size=(S, heads)), jnp.float32) * 0.3
    ed = jnp.asarray(rng.normal(size=(N, heads)), jnp.float32) * 0.3
    src = jnp.asarray(rng.integers(0, S, E), jnp.int32)
    dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
    mask = jnp.asarray(rng.random(E) >= mask_frac)
    return hs, es, ed, src, dst, mask


@pytest.mark.parametrize("S,E,N,heads,hd", [
    (40, 150, 40, 4, 16), (25, 90, 17, 2, 8), (64, 300, 64, 1, 32),
    (30, 100, 12, 4, 4),       # bipartite N < S, tiny heads
])
def test_gat_fused_forward_matches_reference(S, E, N, heads, hd):
    hs, es, ed, src, dst, mask = _gat_case(S, E, N, heads, hd,
                                           mask_frac=0.2)
    got = gat_fused_attention_pallas(hs, es, ed, src, dst, mask, N,
                                     heads=heads)
    want = _gat_ref(hs, es, ed, src, dst, mask.astype(jnp.float32), N,
                    heads)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("S,E,N,heads,hd", [(40, 150, 40, 4, 16),
                                            (25, 90, 17, 2, 8)])
def test_gat_fused_grads_match_reference(S, E, N, heads, hd):
    """The composed VJP (flash-style alpha recompute + swapped fused
    kernels + closed-form softmax backward) matches XLA autodiff through
    the multi-pass expression on every differentiable input."""
    hs, es, ed, src, dst, mask = _gat_case(S, E, N, heads, hd, seed=1,
                                           mask_frac=0.2)
    maskf = mask.astype(jnp.float32)
    w = jnp.asarray(np.random.default_rng(9).normal(
        size=(N, heads * hd)), jnp.float32)

    def loss(fn):
        return lambda a, b, c: jnp.sum(fn(a, b, c) * w)

    k = loss(lambda a, b, c: gat_fused_attention_pallas(
        a, b, c, src, dst, mask, N, heads=heads))
    r = loss(lambda a, b, c: _gat_ref(a, b, c, src, dst, maskf, N, heads))
    gk = jax.grad(k, argnums=(0, 1, 2))(hs, es, ed)
    gr = jax.grad(r, argnums=(0, 1, 2))(hs, es, ed)
    for got, want, name in zip(gk, gr, ("dhs", "des", "ded")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5, err_msg=name)


def test_gat_fused_no_edges():
    hs, es, ed, _, _, _ = _gat_case(9, 10, 7, 2, 8)
    z = jnp.zeros((0,), jnp.int32)
    out = gat_fused_attention_pallas(hs, es, ed, z, z,
                                     jnp.zeros((0,), bool), 7, heads=2)
    assert out.shape == (7, 16)
    assert float(jnp.abs(out).sum()) == 0.0
    dhs = jax.grad(lambda a: jnp.sum(gat_fused_attention_pallas(
        a, es, ed, z, z, jnp.zeros((0,), bool), 7, heads=2)))(hs)
    assert float(jnp.abs(dhs).sum()) == 0.0


def test_gat_fused_all_masked():
    """Every edge masked: softmax has no support anywhere -> exact
    zeros out (no NaNs from exp around the -1e30 sentinel)."""
    hs, es, ed, src, dst, _ = _gat_case(20, 60, 15, 4, 8, seed=2)
    mask = jnp.zeros((60,), bool)
    out = gat_fused_attention_pallas(hs, es, ed, src, dst, mask, 15,
                                     heads=4)
    assert not bool(jnp.any(jnp.isnan(out)))
    assert float(jnp.abs(out).sum()) == 0.0


def test_gat_fused_single_neighbor_copies_source_row():
    """One valid in-edge per destination -> alpha = 1 exactly, so the
    output is the source hs row verbatim; untouched dsts stay zero."""
    heads, hd = 2, 8
    hs, es, ed, _, _, _ = _gat_case(6, 4, 5, heads, hd, seed=3)
    src = jnp.asarray([4, 1, 0], jnp.int32)
    dst = jnp.asarray([0, 2, 3], jnp.int32)
    mask = jnp.ones((3,), bool)
    out = np.asarray(gat_fused_attention_pallas(
        hs, es, ed, src, dst, mask, 5, heads=heads))
    np.testing.assert_allclose(out[0], np.asarray(hs)[4], atol=1e-5)
    np.testing.assert_allclose(out[2], np.asarray(hs)[1], atol=1e-5)
    np.testing.assert_allclose(out[3], np.asarray(hs)[0], atol=1e-5)
    assert np.abs(out[[1, 4]]).sum() == 0.0


# ---------------------------------------------------------------------------
# int8-in / fp32-accumulate aggregation
# ---------------------------------------------------------------------------

def _quantize_rows(h):
    mn = h.min(axis=1, keepdims=True)
    scale = np.maximum((h.max(axis=1, keepdims=True) - mn) / 255.0, 1e-12)
    q = np.rint((h - mn) / scale).astype(np.uint8)
    return q, mn.astype(np.float32), scale.astype(np.float32)


@pytest.mark.parametrize("S,E,F,N", [(50, 200, 33, 40), (16, 64, 128, 16),
                                     (130, 300, 5, 71)])
def test_int8_in_matches_decode_then_fp32(S, E, F, N):
    """The quantized kernel dequantizes per source slab in VMEM — it
    must agree with decode-to-fp32-then-aggregate to fp32 roundoff
    (same affine, same accumulation order)."""
    rng = np.random.default_rng(7)
    h = rng.normal(size=(S, F)).astype(np.float32)
    q, mn, scale = _quantize_rows(h)
    src = jnp.asarray(rng.integers(0, S, E), jnp.int32)
    dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
    coef = jnp.asarray(rng.normal(size=(E,)), jnp.float32)
    got = gather_scale_segment_sum_q_pallas(
        jnp.asarray(q), jnp.asarray(mn), jnp.asarray(scale), src, dst,
        coef, N)
    decoded = mn + q.astype(np.float32) * scale
    want = gather_scale_segment_sum_pallas(jnp.asarray(decoded), src,
                                           dst, coef, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_int8_in_error_bound_vs_fp32_truth():
    """Against the TRUE fp32 aggregation, the int8-in result is bounded
    by the codec's per-row quantization error: |err| <= sum over
    contributing edges of |coef_e| * scale_src[e] / 2, row-feature-wise."""
    rng = np.random.default_rng(11)
    S, E, F, N = 40, 160, 24, 30
    h = rng.normal(size=(S, F)).astype(np.float32)
    q, mn, scale = _quantize_rows(h)
    src = rng.integers(0, S, E)
    dst = rng.integers(0, N, E)
    coef = rng.normal(size=(E,)).astype(np.float32)
    got = np.asarray(gather_scale_segment_sum_q_pallas(
        jnp.asarray(q), jnp.asarray(mn), jnp.asarray(scale),
        jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
        jnp.asarray(coef), N))
    truth = np.zeros((N, F), np.float64)
    np.add.at(truth, dst, h[src].astype(np.float64) * coef[:, None])
    bound = np.zeros((N,), np.float64)
    np.add.at(bound, dst,
              np.abs(coef) * (scale[src, 0] / 2.0 + 1e-7))
    err = np.abs(got - truth).max(axis=1)
    assert (err <= bound + 1e-5).all(), (err - bound).max()


def test_int8_in_no_edges():
    q = jnp.zeros((9, 6), jnp.uint8)
    mn = jnp.zeros((9, 1), jnp.float32)
    sc = jnp.ones((9, 1), jnp.float32)
    z = jnp.zeros((0,), jnp.int32)
    out = gather_scale_segment_sum_q_pallas(
        q, mn, sc, z, z, jnp.zeros((0,), jnp.float32), 5)
    assert out.shape == (5, 6)
    assert float(jnp.abs(out).sum()) == 0.0


# ---------------------------------------------------------------------------
# hypothesis properties over random (E, F, num_segments)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(E=st.integers(0, 260), F=st.integers(1, 140),
           N=st.integers(1, 150), seed=st.integers(0, 2**31 - 1))
    def test_property_segment_sum_fwd_bwd(E, F, N, seed):
        """Forward and VJP match jax.ops for arbitrary shapes, including
        E=0 and non-multiples of every tile size."""
        rng = np.random.default_rng(seed)
        msgs = jnp.asarray(rng.normal(size=(E, F)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, N, E), jnp.int32)
        got = segment_sum_pallas(msgs, ids, N)
        want = jax.ops.segment_sum(msgs, ids, N)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)
        w = jnp.asarray(rng.normal(size=(N, F)), jnp.float32)
        gk = jax.grad(lambda m: jnp.sum(
            segment_sum_pallas(m, ids, N) * w))(msgs)
        gr = jax.grad(lambda m: jnp.sum(
            jax.ops.segment_sum(m, ids, N) * w))(msgs)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                                   atol=3e-5, rtol=3e-5)

    @settings(max_examples=15, deadline=None)
    @given(S=st.integers(1, 120), E=st.integers(0, 200),
           F=st.integers(1, 140), N=st.integers(1, 90),
           mask_all=st.booleans(), seed=st.integers(0, 2**31 - 1))
    def test_property_fused_fwd_bwd(S, E, F, N, mask_all, seed):
        """Fused kernel (fwd + dh) matches the unfused XLA expression,
        including all-masked edge sets (coef == 0 everywhere)."""
        rng = np.random.default_rng(seed)
        h = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
        src = jnp.asarray(rng.integers(0, S, E), jnp.int32)
        dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
        coef = jnp.zeros((E,), jnp.float32) if mask_all else \
            jnp.asarray(rng.normal(size=(E,)), jnp.float32)
        got = gather_scale_segment_sum_pallas(h, src, dst, coef, N)
        want = _fused_ref(h, src, dst, coef, N)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)
        w = jnp.asarray(rng.normal(size=(N, F)), jnp.float32)
        gk = jax.grad(lambda h_: jnp.sum(gather_scale_segment_sum_pallas(
            h_, src, dst, coef, N) * w))(h)
        gr = jax.grad(lambda h_: jnp.sum(
            _fused_ref(h_, src, dst, coef, N) * w))(h)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                                   atol=3e-5, rtol=3e-5)

    @settings(max_examples=15, deadline=None)
    @given(S=st.integers(1, 60), E=st.integers(0, 150),
           N=st.integers(1, 50), heads=st.sampled_from([1, 2, 4]),
           hd=st.sampled_from([4, 8, 16]),
           seed=st.integers(0, 2**31 - 1))
    def test_property_gat_alphas_sum_to_one(S, E, N, heads, hd, seed):
        """The alpha-sum softmax property, observed through the fused
        kernel: with every source's hs row set to the same constant
        vector c, out[d] = c * (sum of d's alphas) — exactly c wherever
        d has a valid in-edge, exactly 0 elsewhere (pad/masked edges
        contribute nothing)."""
        rng = np.random.default_rng(seed)
        c = rng.normal(size=(1, heads * hd)).astype(np.float32)
        hs = jnp.asarray(np.repeat(c, S, axis=0))
        es = jnp.asarray(rng.normal(size=(S, heads)), jnp.float32)
        ed = jnp.asarray(rng.normal(size=(N, heads)), jnp.float32)
        src = jnp.asarray(rng.integers(0, S, E), jnp.int32)
        dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
        mask = jnp.asarray(rng.random(E) < 0.7)
        out = np.asarray(gat_fused_attention_pallas(
            hs, es, ed, src, dst, mask, N, heads=heads))
        has_edge = np.zeros(N, bool)
        np.add.at(has_edge, np.asarray(dst), np.asarray(mask))
        np.testing.assert_allclose(out[has_edge],
                                   np.repeat(c, has_edge.sum(), axis=0),
                                   atol=3e-5, rtol=3e-5)
        assert np.abs(out[~has_edge]).sum() == 0.0


# ---------------------------------------------------------------------------
# training equivalence: jax.grad through use_kernel=True over a device
# matrix (subprocess so the forced host-device topology can be set)
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.parametrize("n_dev", [1, 2, 4])
def test_kernel_training_equivalence(n_dev):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "kernel_train_check.py"),
         str(n_dev), "hash"],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PASS kernel-equivalence" in r.stdout, r.stdout


@pytest.mark.distributed
@pytest.mark.parametrize("n_dev", [1, 2])
def test_gat_fused_training_equivalence(n_dev):
    """Full GAT training through the fused one-pass kernel vs the XLA
    reference from the same init: every parameter within 1e-5 after 10
    steps, single-device and under a forced 2-device pmap."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "gat_train_check.py"), str(n_dev)],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PASS gat-fused-equivalence" in r.stdout, r.stdout


@pytest.mark.parametrize("B,H,K,Sq,Skv,hd", [
    (1, 2, 2, 32, 32, 16),
    (2, 4, 2, 64, 64, 32),     # GQA G=2
    (1, 8, 1, 48, 96, 64),     # MQA, decode-ish Sq<Skv, non-multiple of 32
])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, K, Sq, Skv, hd, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, Sq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, K, Skv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, K, Skv, hd)), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 bq=32, bk=32)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_non_causal():
    q = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=False, bq=16, bk=16)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("B,L,H,P,G,N", [
    (1, 16, 4, 8, 1, 16), (2, 32, 8, 16, 1, 24), (1, 64, 8, 32, 2, 64),
])
def test_ssd_chunk_state(B, L, H, P, G, N):
    x = jnp.asarray(RNG.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.random((B, L, H)), jnp.float32)
    A = -jnp.asarray(RNG.random(H) + 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    got = ssd_chunk_state_pallas(x, dt, A, Bm, bh=min(4, H))
    want = ref.ssd_chunk_state(x, dt, A, Bm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)
