"""Per-kernel shape/dtype sweeps asserting allclose vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU), gradient tests for
the custom VJPs, hypothesis properties over random shapes, and the
kernel-vs-reference training-equivalence subprocess matrix."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.segment_sum import (gather_scale_segment_sum_pallas,
                                       segment_sum_pallas)
from repro.kernels.ssd_chunk import ssd_chunk_state_pallas

RNG = np.random.default_rng(42)
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("E,F,N", [(64, 32, 16), (300, 70, 45),
                                   (1000, 128, 128), (17, 5, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_sum(E, F, N, dtype):
    msgs = jnp.asarray(RNG.normal(size=(E, F)), dtype)
    ids = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    got = segment_sum_pallas(msgs, ids, N)
    # the kernel accumulates in fp32 scratch; compare against the fp32
    # ground truth with dtype-appropriate tolerance
    want = ref.segment_sum(msgs.astype(jnp.float32), ids, N)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_segment_sum_empty_segments():
    msgs = jnp.ones((8, 4), jnp.float32)
    ids = jnp.zeros((8,), jnp.int32)          # everything into segment 0
    got = segment_sum_pallas(msgs, ids, 5)
    assert float(got[0, 0]) == 8.0
    assert float(jnp.abs(got[1:]).sum()) == 0.0


def test_segment_sum_no_edges():
    """E=0 degenerates to a single all-pad tile: zeros out, zeros grad."""
    msgs = jnp.zeros((0, 6), jnp.float32)
    ids = jnp.zeros((0,), jnp.int32)
    got = segment_sum_pallas(msgs, ids, 7)
    assert got.shape == (7, 6)
    assert float(jnp.abs(got).sum()) == 0.0
    grad = jax.grad(lambda m: jnp.sum(segment_sum_pallas(m, ids, 7)))(msgs)
    assert grad.shape == (0, 6)


# ---------------------------------------------------------------------------
# custom-VJP gradients: kernel vs jax.ops autodiff
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,F,N", [(64, 32, 16), (300, 70, 45),
                                   (17, 5, 3), (129, 130, 129)])
def test_segment_sum_grad_matches_reference(E, F, N):
    """d/d(msgs) of a weighted sum through the kernel == through
    jax.ops.segment_sum (the backward gather kernel vs XLA's VJP)."""
    msgs = jnp.asarray(RNG.normal(size=(E, F)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(N, F)), jnp.float32)

    def loss(seg_fn):
        return lambda m: jnp.sum(seg_fn(m, ids, N) * w)

    gk = jax.grad(loss(lambda m, i, n: segment_sum_pallas(m, i, n)))(msgs)
    gr = jax.grad(loss(jax.ops.segment_sum))(msgs)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               atol=2e-5, rtol=2e-5)


def _fused_ref(h, src, dst, coef, num_dst):
    msgs = jnp.take(h, src, axis=0) * coef[:, None]
    return jax.ops.segment_sum(msgs, dst, num_dst)


@pytest.mark.parametrize("S,E,F,N", [(50, 200, 33, 40), (16, 64, 128, 16),
                                     (130, 300, 5, 71)])
def test_fused_forward_matches_reference(S, E, F, N):
    h = jnp.asarray(RNG.normal(size=(S, F)), jnp.float32)
    src = jnp.asarray(RNG.integers(0, S, E), jnp.int32)
    dst = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    coef = jnp.asarray(RNG.normal(size=(E,)), jnp.float32)
    got = gather_scale_segment_sum_pallas(h, src, dst, coef, N)
    want = _fused_ref(h, src, dst, coef, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,E,F,N", [(50, 200, 33, 40), (130, 300, 5, 71)])
def test_fused_grads_match_reference(S, E, F, N):
    """dh (fused kernel with src/dst swapped) and dcoef (edge-dot
    kernel) both match the XLA VJP of the unfused expression."""
    h = jnp.asarray(RNG.normal(size=(S, F)), jnp.float32)
    src = jnp.asarray(RNG.integers(0, S, E), jnp.int32)
    dst = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    coef = jnp.asarray(RNG.normal(size=(E,)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(N, F)), jnp.float32)

    def loss(fn):
        return lambda h_, c_: jnp.sum(fn(h_, src, dst, c_, N) * w)

    gk = jax.grad(loss(gather_scale_segment_sum_pallas),
                  argnums=(0, 1))(h, coef)
    gr = jax.grad(loss(_fused_ref), argnums=(0, 1))(h, coef)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gr[0]),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gr[1]),
                               atol=2e-4, rtol=2e-4)


def test_fused_all_masked_edges():
    """coef carries the edge mask: all-masked input aggregates (and
    back-propagates) exactly zero."""
    S, E, F, N = 20, 40, 12, 10
    h = jnp.asarray(RNG.normal(size=(S, F)), jnp.float32)
    src = jnp.asarray(RNG.integers(0, S, E), jnp.int32)
    dst = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    coef = jnp.zeros((E,), jnp.float32)
    out = gather_scale_segment_sum_pallas(h, src, dst, coef, N)
    assert float(jnp.abs(out).sum()) == 0.0
    dh = jax.grad(lambda h_: jnp.sum(
        gather_scale_segment_sum_pallas(h_, src, dst, coef, N)))(h)
    assert float(jnp.abs(dh).sum()) == 0.0


def test_fused_capacity_fallback():
    """Above the fused kernel's VMEM capacity, the ops-layer dispatch
    falls back to the unfused blocked kernel (row-count independent)
    instead of tripping the budget assert — use_kernel=True must work
    on large single-device graphs."""
    from repro.kernels import ops as kops
    from repro.kernels.segment_sum import fused_fits

    S = N = 5000
    E, F = 300, 128
    assert not fused_fits(S, N, F)
    h = jnp.asarray(RNG.normal(size=(S, F)), jnp.float32)
    src = jnp.asarray(RNG.integers(0, S, E), jnp.int32)
    dst = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    coef = jnp.asarray(RNG.normal(size=(E,)), jnp.float32)
    got = kops.gather_scale_segment_sum(h, src, dst, coef, N)
    want = _fused_ref(h, src, dst, coef, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # gradients flow through the fallback path too
    gk = jax.grad(lambda h_: jnp.sum(kops.gather_scale_segment_sum(
        h_, src, dst, coef, N)))(h)
    gr = jax.grad(lambda h_: jnp.sum(_fused_ref(h_, src, dst, coef, N)))(h)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               atol=2e-5, rtol=2e-5)


def test_fused_no_edges():
    h = jnp.asarray(RNG.normal(size=(9, 6)), jnp.float32)
    e = jnp.zeros((0,), jnp.int32)
    out = gather_scale_segment_sum_pallas(h, e, e,
                                          jnp.zeros((0,), jnp.float32), 5)
    assert out.shape == (5, 6)
    assert float(jnp.abs(out).sum()) == 0.0


# ---------------------------------------------------------------------------
# hypothesis properties over random (E, F, num_segments)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(E=st.integers(0, 260), F=st.integers(1, 140),
           N=st.integers(1, 150), seed=st.integers(0, 2**31 - 1))
    def test_property_segment_sum_fwd_bwd(E, F, N, seed):
        """Forward and VJP match jax.ops for arbitrary shapes, including
        E=0 and non-multiples of every tile size."""
        rng = np.random.default_rng(seed)
        msgs = jnp.asarray(rng.normal(size=(E, F)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, N, E), jnp.int32)
        got = segment_sum_pallas(msgs, ids, N)
        want = jax.ops.segment_sum(msgs, ids, N)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)
        w = jnp.asarray(rng.normal(size=(N, F)), jnp.float32)
        gk = jax.grad(lambda m: jnp.sum(
            segment_sum_pallas(m, ids, N) * w))(msgs)
        gr = jax.grad(lambda m: jnp.sum(
            jax.ops.segment_sum(m, ids, N) * w))(msgs)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                                   atol=3e-5, rtol=3e-5)

    @settings(max_examples=15, deadline=None)
    @given(S=st.integers(1, 120), E=st.integers(0, 200),
           F=st.integers(1, 140), N=st.integers(1, 90),
           mask_all=st.booleans(), seed=st.integers(0, 2**31 - 1))
    def test_property_fused_fwd_bwd(S, E, F, N, mask_all, seed):
        """Fused kernel (fwd + dh) matches the unfused XLA expression,
        including all-masked edge sets (coef == 0 everywhere)."""
        rng = np.random.default_rng(seed)
        h = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
        src = jnp.asarray(rng.integers(0, S, E), jnp.int32)
        dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
        coef = jnp.zeros((E,), jnp.float32) if mask_all else \
            jnp.asarray(rng.normal(size=(E,)), jnp.float32)
        got = gather_scale_segment_sum_pallas(h, src, dst, coef, N)
        want = _fused_ref(h, src, dst, coef, N)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)
        w = jnp.asarray(rng.normal(size=(N, F)), jnp.float32)
        gk = jax.grad(lambda h_: jnp.sum(gather_scale_segment_sum_pallas(
            h_, src, dst, coef, N) * w))(h)
        gr = jax.grad(lambda h_: jnp.sum(
            _fused_ref(h_, src, dst, coef, N) * w))(h)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                                   atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# training equivalence: jax.grad through use_kernel=True over a device
# matrix (subprocess so the forced host-device topology can be set)
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.parametrize("n_dev", [1, 2, 4])
def test_kernel_training_equivalence(n_dev):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "kernel_train_check.py"),
         str(n_dev), "hash"],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PASS kernel-equivalence" in r.stdout, r.stdout


@pytest.mark.parametrize("B,H,K,Sq,Skv,hd", [
    (1, 2, 2, 32, 32, 16),
    (2, 4, 2, 64, 64, 32),     # GQA G=2
    (1, 8, 1, 48, 96, 64),     # MQA, decode-ish Sq<Skv, non-multiple of 32
])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, K, Sq, Skv, hd, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, Sq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, K, Skv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, K, Skv, hd)), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 bq=32, bk=32)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_non_causal():
    q = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=False, bq=16, bk=16)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("B,L,H,P,G,N", [
    (1, 16, 4, 8, 1, 16), (2, 32, 8, 16, 1, 24), (1, 64, 8, 32, 2, 64),
])
def test_ssd_chunk_state(B, L, H, P, G, N):
    x = jnp.asarray(RNG.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.random((B, L, H)), jnp.float32)
    A = -jnp.asarray(RNG.random(H) + 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    got = ssd_chunk_state_pallas(x, dt, A, Bm, bh=min(4, H))
    want = ref.ssd_chunk_state(x, dt, A, Bm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)
