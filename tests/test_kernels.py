"""Per-kernel shape/dtype sweeps asserting allclose vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.segment_sum import segment_sum_pallas
from repro.kernels.ssd_chunk import ssd_chunk_state_pallas

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("E,F,N", [(64, 32, 16), (300, 70, 45),
                                   (1000, 128, 128), (17, 5, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_sum(E, F, N, dtype):
    msgs = jnp.asarray(RNG.normal(size=(E, F)), dtype)
    ids = jnp.asarray(RNG.integers(0, N, E), jnp.int32)
    got = segment_sum_pallas(msgs, ids, N)
    # the kernel accumulates in fp32 scratch; compare against the fp32
    # ground truth with dtype-appropriate tolerance
    want = ref.segment_sum(msgs.astype(jnp.float32), ids, N)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_segment_sum_empty_segments():
    msgs = jnp.ones((8, 4), jnp.float32)
    ids = jnp.zeros((8,), jnp.int32)          # everything into segment 0
    got = segment_sum_pallas(msgs, ids, 5)
    assert float(got[0, 0]) == 8.0
    assert float(jnp.abs(got[1:]).sum()) == 0.0


@pytest.mark.parametrize("B,H,K,Sq,Skv,hd", [
    (1, 2, 2, 32, 32, 16),
    (2, 4, 2, 64, 64, 32),     # GQA G=2
    (1, 8, 1, 48, 96, 64),     # MQA, decode-ish Sq<Skv, non-multiple of 32
])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, K, Sq, Skv, hd, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, Sq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, K, Skv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, K, Skv, hd)), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 bq=32, bk=32)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_non_causal():
    q = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=False, bq=16, bk=16)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("B,L,H,P,G,N", [
    (1, 16, 4, 8, 1, 16), (2, 32, 8, 16, 1, 24), (1, 64, 8, 32, 2, 64),
])
def test_ssd_chunk_state(B, L, H, P, G, N):
    x = jnp.asarray(RNG.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.random((B, L, H)), jnp.float32)
    A = -jnp.asarray(RNG.random(H) + 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    got = ssd_chunk_state_pallas(x, dt, A, Bm, bh=min(4, H))
    want = ref.ssd_chunk_state(x, dt, A, Bm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)
