"""Multi-device MoE check: the shard_map expert-parallel block computes the
same function as the GShard dense-dispatch block under a real (data, model)
mesh — run in a subprocess with 4 forced host devices."""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4")

import jax                                       # noqa: E402
import jax.numpy as jnp                          # noqa: E402
import numpy as np                               # noqa: E402

from repro.configs.base import get_config        # noqa: E402
from repro.core.parallel import moe_expert_parallel  # noqa: E402
from repro.launch import sharding as shd         # noqa: E402
from repro.models.transformer import moe as M    # noqa: E402

assert jax.device_count() == 4

mesh = jax.make_mesh((2, 2), ("data", "model"))
cfg = get_config("granite-moe-1b-a400m").reduced()  # 4 experts, top-2
key = jax.random.PRNGKey(0)
p = M.init_moe(cfg, key, jnp.float32)
B, S = 4, 16
x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)

rules = shd.ShardingRules(mesh, batch_size=B, fsdp=False)

# generous capacity so no path drops tokens
want = M.moe_block(cfg, p, x, capacity_factor=8.0)

with mesh:
    def f(p_, x_):
        with rules.activate():
            return moe_expert_parallel(cfg, p_, x_, capacity_factor=8.0)

    got = jax.jit(f)(p, x)

err = float(jnp.max(jnp.abs(got - want)))
assert err < 1e-4, err
print(f"PASS moe ep==gshard maxerr={err:.2e}")

# dropping behaviour: tight capacity must drop the same token mass order
tight_g = M.moe_block(cfg, p, x, capacity_factor=0.5)
with mesh:
    tight_e = jax.jit(f)(p, x)  # still cf=8 inside f; rebuild with 0.5

    def f2(p_, x_):
        with rules.activate():
            return moe_expert_parallel(cfg, p_, x_, capacity_factor=0.5)

    tight_e = jax.jit(f2)(p, x)
drop_g = float(jnp.mean(jnp.abs(want - tight_g) > 1e-6))
drop_e = float(jnp.mean(jnp.abs(want - tight_e) > 1e-6))
print(f"PASS moe dropping gshard={drop_g:.2f} ep={drop_e:.2f}")
print("ALL MOE SPMD CHECKS PASS")
