"""GNN model tests: abstraction equivalences, full-batch vs blocks,
learning on planted communities, kernel-path equality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sampling as S
from repro.core.abstraction import DeviceGraph, saga_layer, segment_softmax
from repro.models.gnn import model as GM
from repro.models.gnn.layers import LAYER_TYPES
from repro.models.gnn.model import GNNConfig
from repro.optim import AdamW


@pytest.fixture(scope="module")
def sbm_graph(graph):
    return graph("sbm", 240)


@pytest.mark.parametrize("arch", ["gcn", "sage", "gat", "gin", "ggnn",
                                  "appnp"])
def test_forward_shapes(sbm_graph, arch):
    cfg = GNNConfig(arch=arch, feat_dim=16, hidden=32,
                    num_classes=sbm_graph.num_classes)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
    dg = DeviceGraph.from_graph(sbm_graph)
    x = jnp.asarray(sbm_graph.features)
    logits = GM.forward_full(cfg, params, dg, x)
    assert logits.shape == (sbm_graph.num_nodes, 4)
    assert not np.any(np.isnan(np.asarray(logits)))


@pytest.mark.parametrize("arch", ["gcn", "sage", "gin"])
def test_kernel_path_matches_reference(sbm_graph, arch):
    cfg_ref = GNNConfig(arch=arch, feat_dim=16, hidden=32, num_classes=4)
    cfg_k = GNNConfig(arch=arch, feat_dim=16, hidden=32, num_classes=4,
                      use_kernel=True)
    params = GM.init_gnn(cfg_ref, jax.random.PRNGKey(0))
    dg = DeviceGraph.from_graph(sbm_graph)
    x = jnp.asarray(sbm_graph.features)
    a = GM.forward_full(cfg_ref, params, dg, x)
    b = GM.forward_full(cfg_k, params, dg, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3,
                               rtol=1e-3)


def test_fullgraph_training_learns(sbm_graph):
    """End-to-end: GCN on planted communities reaches high train accuracy
    (the survey's node-classification task family, Table 9)."""
    cfg = GNNConfig(arch="gcn", feat_dim=16, hidden=32, num_classes=4)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    ostate = opt.init(params)
    dg = DeviceGraph.from_graph(sbm_graph)
    x = jnp.asarray(sbm_graph.features)
    y = jnp.asarray(sbm_graph.labels)
    mask = jnp.ones_like(y, jnp.float32)
    step = jax.jit(GM.make_fullgraph_train_step(cfg, opt))
    losses = []
    for _ in range(60):
        params, ostate, loss = step(params, ostate, dg, x, y, mask)
        losses.append(float(loss))
    logits = GM.forward_full(cfg, params, dg, x)
    acc = float(GM.accuracy(logits, y))
    assert losses[-1] < losses[0] * 0.5
    assert acc > 0.9


def test_blocks_on_full_graph_match_fullbatch(sbm_graph):
    """A block covering the whole graph must reproduce full-batch output —
    ties the sampling path to the full-graph path."""
    cfg = GNNConfig(arch="sage", feat_dim=16, hidden=32, num_classes=4,
                    num_layers=2)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(1))
    dg = DeviceGraph.from_graph(sbm_graph)
    x = jnp.asarray(sbm_graph.features)
    full = GM.forward_full(cfg, params, dg, x)
    blocks = [dg, dg]           # identity blocks: src == dst == all nodes
    via_blocks = GM.forward_blocks(cfg, params, blocks, x)
    np.testing.assert_allclose(np.asarray(full), np.asarray(via_blocks),
                               atol=1e-4, rtol=1e-4)


def test_minibatch_training_learns(sbm_graph):
    cfg = GNNConfig(arch="sage", feat_dim=16, hidden=32, num_classes=4)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(2))
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    ostate = opt.init(params)
    sampler = S.NeighborSampler(sbm_graph, [5, 5], seed=0)
    step = jax.jit(GM.make_minibatch_train_step(cfg, opt))
    rng = np.random.default_rng(0)
    first = last = None
    for it in range(40):
        seeds = rng.choice(sbm_graph.num_nodes, 32, replace=False)
        mb = sampler.sample(seeds)
        blocks = [DeviceGraph.from_block(b) for b in mb.blocks]
        x_in = jnp.asarray(
            sbm_graph.features[np.maximum(mb.blocks[0].src_nodes, 0)])
        y = jnp.asarray(sbm_graph.labels[seeds])
        mask = jnp.ones_like(y, jnp.float32)
        params, ostate, loss = step(params, ostate, blocks, x_in, y, mask)
        if it == 0:
            first = float(loss)
        last = float(loss)
    assert last < first


@pytest.mark.parametrize("arch", ["ggnn", "appnp"])
def test_new_archs_learn(sbm_graph, arch):
    cfg = GNNConfig(arch=arch, feat_dim=16, hidden=32, num_classes=4)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    ostate = opt.init(params)
    dg = DeviceGraph.from_graph(sbm_graph)
    x = jnp.asarray(sbm_graph.features)
    y = jnp.asarray(sbm_graph.labels)
    mask = jnp.ones_like(y, jnp.float32)
    step = jax.jit(GM.make_fullgraph_train_step(cfg, opt))
    losses = []
    for _ in range(40):
        params, ostate, loss = step(params, ostate, dg, x, y, mask)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.6


def test_datasets_registry():
    from repro.graph.datasets import load
    ds = load("citeseer-like")
    g = ds.graph
    assert g.num_nodes == 3300 and g.num_classes == 6
    assert g.features.shape == (3300, 64)
    assert (ds.train_mask | ds.val_mask | ds.test_mask).all()
    assert not (ds.train_mask & ds.test_mask).any()
    rl = load("reddit-like", scale=0.005)
    deg = rl.graph.out_degree()
    assert deg.max() > 10 * deg.mean()   # heavy tail preserved


def test_saga_layer_manual_equivalence(sbm_graph):
    dg = DeviceGraph.from_graph(sbm_graph)
    x = jnp.asarray(sbm_graph.features)
    out = saga_layer(
        dg, x, x,
        apply_edge=lambda s, d, e: s,
        gather="sum",
        apply_vertex=lambda a, h: a)
    # manual: sum of in-neighbor features
    e = sbm_graph.edges()
    want = np.zeros_like(sbm_graph.features)
    np.add.at(want, e[:, 1], sbm_graph.features[e[:, 0]])
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-4)


def test_segment_softmax_normalizes(sbm_graph):
    dg = DeviceGraph.from_graph(sbm_graph)
    logits = jnp.asarray(
        np.random.default_rng(0).normal(size=(dg.edge_src.shape[0], 2)),
        jnp.float32)
    alpha = segment_softmax(logits, dg.edge_dst, dg.num_dst, dg.edge_mask)
    sums = jax.ops.segment_sum(alpha, dg.edge_dst, dg.num_dst)
    has_edges = np.asarray(
        jax.ops.segment_sum(dg.edge_mask.astype(jnp.float32),
                            dg.edge_dst, dg.num_dst)) > 0
    np.testing.assert_allclose(np.asarray(sums)[has_edges], 1.0, atol=1e-4)
