"""End-to-end behaviour tests for the framework."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticLMDataset
from repro.models.transformer import model as M
from repro.optim import AdamW, cosine_schedule


def test_lm_training_learns_planted_bigrams():
    """A tiny dense LM trained on the synthetic corpus must beat the
    unigram entropy floor (it can only do so by learning the planted
    bigram table) — end-to-end proof the substrate trains."""
    cfg = get_config("qwen2.5-14b").reduced().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
        vocab_size=256)
    ds = SyntheticLMDataset(cfg.vocab_size, 32, seed=0)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt = AdamW(lr=cosine_schedule(3e-3, 10, 200), weight_decay=0.01)
    ostate = opt.init(params)
    step = jax.jit(M.make_train_step(cfg, opt, remat=False))
    it = ds.batches(16)
    losses = []
    for _ in range(120):
        b = next(it)
        params, ostate, m = step(params, ostate,
                                 {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    # unigram entropy of the Zipf distribution:
    H_uni = -np.sum(ds.unigram * np.log(ds.unigram))
    assert losses[-1] < losses[0]
    assert np.mean(losses[-10:]) < 0.8 * H_uni, (losses[0], losses[-1], H_uni)


def test_greedy_decode_roundtrip():
    """prefill + iterated decode_step reproduces forward() argmax chain."""
    cfg = get_config("phi3-mini-3.8b").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, S, GEN = 2, 12, 4
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    # reference: grow the sequence with forward() argmax
    seq = tokens
    for _ in range(GEN):
        lg = M.forward(cfg, params, {"tokens": seq})
        nxt = jnp.argmax(lg[:, -1, :cfg.vocab_size], -1)[:, None]
        seq = jnp.concatenate([seq, nxt], axis=1)

    # serving path: prefill + decode with a pre-sized cache
    cache = M.init_cache(cfg, B, S + GEN)
    lg, _ = M.prefill(cfg, params, {"tokens": tokens})
    # re-run prefill writes into the right-sized cache via decode steps
    cache = M.init_cache(cfg, B, S + GEN)
    out = []
    for t in range(S + GEN - 1):
        tok = seq[:, t:t + 1]
        lg, cache = M.decode_step(cfg, params, cache,
                                  {"token": tok,
                                   "pos": jnp.asarray(t, jnp.int32)})
        out.append(jnp.argmax(lg[:, :cfg.vocab_size], -1))
    # decode chain must predict the same continuation tokens
    for i in range(GEN):
        np.testing.assert_array_equal(np.asarray(out[S - 1 + i]),
                                      np.asarray(seq[:, S + i]))
