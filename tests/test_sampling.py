"""Sampling invariants (§3.2.2 / Table 4): fanout bounds, block structure,
neighborhood-explosion containment."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import sampling as S


@pytest.fixture(scope="module")
def graph(graph):
    return graph("er", 300)


def _check_block_invariants(b: S.Block):
    valid_src = b.src_nodes[b.src_nodes >= 0]
    valid_dst = b.dst_nodes[b.dst_nodes >= 0]
    # dst nodes are a prefix of src nodes
    np.testing.assert_array_equal(b.src_nodes[:len(valid_dst)], valid_dst)
    # masked edges index inside the valid ranges
    es = b.edge_src[b.edge_mask]
    ed = b.edge_dst[b.edge_mask]
    assert (es < len(b.src_nodes)).all()
    assert (ed < len(valid_dst)).all()


def test_neighbor_sampler_fanout_bound(graph):
    fanouts = [4, 4]
    s = S.NeighborSampler(graph, fanouts, seed=0)
    seeds = np.arange(16)
    mb = s.sample(seeds)
    assert len(mb.blocks) == 2
    for b, f in zip(mb.blocks, reversed(fanouts)):
        _check_block_invariants(b)
    # neighborhood must not explode beyond seeds * prod(fanouts+1)
    assert mb.blocks[0].num_src <= 16 * (1 + 4) * (1 + 4)
    np.testing.assert_array_equal(mb.blocks[-1].dst_nodes, seeds)


def test_importance_sampler(graph):
    s = S.ImportanceSampler(graph, [3, 3], seed=0)
    mb = s.sample(np.arange(8))
    for b in mb.blocks:
        _check_block_invariants(b)


@pytest.mark.parametrize("dependent", [False, True])
def test_layerwise_samplers(graph, dependent):
    s = S.LayerWiseSampler(graph, [32, 32], dependent=dependent, seed=0)
    mb = s.sample(np.arange(8))
    for b in mb.blocks:
        _check_block_invariants(b)
        # layer budget respected
        assert b.num_src <= 8 + 32 + b.num_dst


def test_cluster_sampler_covers_all_nodes(graph):
    cs = S.ClusterSampler(graph, n_clusters=8, clusters_per_batch=2, seed=0)
    assert (cs.assign >= 0).all() and (cs.assign < 8).all()
    nodes, sub = cs.sample_subgraph()
    assert sub.num_nodes == len(nodes)
    assert sub.num_classes == graph.num_classes


def test_saint_rw_sampler(graph):
    s = S.SaintRWSampler(graph, n_roots=10, walk_len=4, seed=0)
    nodes, sub = s.sample_subgraph()
    assert 10 <= sub.num_nodes <= 10 * 5
    assert sub.features.shape[0] == sub.num_nodes


def test_neighborhood_explosion_motivation(graph):
    """Survey §3.2.2: unsampled k-hop neighborhoods explode; sampled ones
    stay bounded."""
    sizes = S.neighborhood_growth(graph, np.arange(4), hops=3)
    s = S.NeighborSampler(graph, [4, 4, 4], seed=0)
    mb = s.sample(np.arange(4))
    sampled_input = int((mb.blocks[0].src_nodes >= 0).sum())
    assert sizes[-1] > sampled_input  # sampling contains the explosion


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), batch=st.integers(1, 12))
def test_property_blocks_are_consistent(graph, seed, batch):
    s = S.NeighborSampler(graph, [3, 3], seed=seed)
    rng = np.random.default_rng(seed)
    seeds = rng.choice(graph.num_nodes, batch, replace=False)
    mb = s.sample(seeds)
    # features flow: every block's dst appears in next block's src prefix
    np.testing.assert_array_equal(mb.blocks[-1].dst_nodes, seeds)
    for b in mb.blocks:
        _check_block_invariants(b)
