"""Training equivalence check for the differentiable Pallas aggregation
kernels — run in a subprocess with
``--xla_force_host_platform_device_count=N``.

argv: n_dev [partitioner]

Trains 10 full-graph GCN steps with ``use_kernel=True`` (the fused
gather-scale-segment-sum Pallas kernel, interpret mode on CPU) and with
the ``jax.ops`` reference from the same init, then demands every
parameter agree to <= 1e-5 — i.e. ``jax.grad`` through the kernels'
custom VJPs matches the XLA autodiff path step for step.

* ``n_dev == 1`` uses the single-device full-graph trainer
  (:func:`repro.models.gnn.model.make_fullgraph_train_step` driven by
  ``GNNConfig.use_kernel``), which exercises the fused GCN layer path.
* ``n_dev > 1`` uses the distributed pull step
  (:func:`repro.core.propagation.make_distributed_gcn_step`), which
  exercises the fused kernel *inside shard_map* — custom VJP under
  ``check_rep=False`` with psum'd gradients.
"""
import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 2
METHOD = sys.argv[2] if len(sys.argv) > 2 else "hash"
STEPS = 10
TOL = 1e-5

if N_DEV > 1:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEV} "
        + os.environ.get("XLA_FLAGS", ""))

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402

from repro.core import propagation as PR                # noqa: E402
from repro.graph import generators as G                 # noqa: E402
from repro.models.gnn import model as GM                # noqa: E402
from repro.models.gnn.model import GNNConfig            # noqa: E402
from repro.optim import AdamW                           # noqa: E402

assert jax.device_count() >= N_DEV, jax.device_count()

g = G.sbm(144, 4, p_in=0.9, p_out=0.02, seed=0)
g = G.featurize(g, 16, seed=0, class_sep=1.5)

opt = AdamW(lr=1e-2, weight_decay=0.0)


def run(use_kernel: bool):
    cfg = GNNConfig(arch="gcn", feat_dim=16, hidden=32, num_classes=4,
                    use_kernel=use_kernel)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
    ostate = opt.init(params)
    if N_DEV == 1:
        from repro.core.abstraction import DeviceGraph
        dg = DeviceGraph.from_graph(g)
        x = jnp.asarray(g.features)
        y = jnp.asarray(g.labels)
        mask = jnp.ones_like(y, jnp.float32)
        step = jax.jit(GM.make_fullgraph_train_step(cfg, opt))
        for _ in range(STEPS):
            params, ostate, loss = step(params, ostate, dg, x, y, mask)
        return params, float(loss)
    sg = PR.shard_graph(g, N_DEV, method=METHOD)
    _, step = PR.make_distributed_gcn_step(opt, N_DEV, mode="pull",
                                           use_kernel=use_kernel)
    for _ in range(STEPS):
        params, ostate, loss = step(params, ostate, sg)
    return params, float(loss)


p_ref, loss_ref = run(use_kernel=False)
p_ker, loss_ker = run(use_kernel=True)

assert abs(loss_ref - loss_ker) < TOL, (loss_ref, loss_ker)
diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     p_ker, p_ref)
maxdiff = max(jax.tree_util.tree_leaves(diffs))
assert maxdiff <= TOL, (maxdiff, diffs)

print(f"PASS kernel-equivalence n_dev={N_DEV} part={METHOD} "
      f"steps={STEPS} maxdiff={maxdiff:.2e} loss={loss_ker:.4f}")
