"""Equivalence + monotonicity check for staleness-bounded async full-graph
training — run in a subprocess with
``--xla_force_host_platform_device_count=N``.

argv: n_dev partitioner

1. Trains 5 full-graph epochs with the asynchronous step at S=0 under the
   default fp32 wire codec and with the synchronous pull reference
   (:func:`repro.core.propagation.make_distributed_gcn_step`) from the
   same init, then demands every parameter agree to <= 1e-5 — S=0 must
   degrade *exactly* to the synchronous halo exchange, proving the
   communication-plane refactor is behavior-preserving.
2. Re-runs at S=1 and S=2 and demands cross-partition bytes/step strictly
   decrease as the staleness bound grows (each ghost row crosses the wire
   at most every S+1 steps).
3. Codec matrix: re-runs S=0 with the int8 wire codec (every ghost read
   is a quantized wire value + error feedback) and demands the final
   loss stay within late_rel < 0.05 of the synchronous reference, with
   bytes/step <= 35% of the fp32 run (hidden=32: 8 bytes/row of scale
   metadata keep the per-row ratio at (32+8)/128 ≈ 31%).
"""
import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 2
METHOD = sys.argv[2] if len(sys.argv) > 2 else "hash"

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", ""))

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from repro.core import propagation as PR                # noqa: E402
from repro.distributed import AsyncFullGraphTrainer     # noqa: E402
from repro.graph import generators as G                 # noqa: E402
from repro.models.gnn import model as GM                # noqa: E402
from repro.models.gnn.model import GNNConfig            # noqa: E402
from repro.optim import AdamW                           # noqa: E402

assert jax.device_count() == N_DEV, jax.device_count()

g = G.sbm(144, 4, p_in=0.9, p_out=0.02, seed=0)
g = G.featurize(g, 16, seed=0, class_sep=1.5)

cfg = GNNConfig(arch="gcn", feat_dim=16, hidden=32, num_classes=4)
params0 = GM.init_gnn(cfg, jax.random.PRNGKey(0))
opt = AdamW(lr=1e-2, weight_decay=0.0)
EPOCHS = 5

# -- synchronous reference ---------------------------------------------------
sg = PR.shard_graph(g, N_DEV, method=METHOD)
_, sync_step = PR.make_distributed_gcn_step(opt, N_DEV, mode="pull")
pr, orr = params0, opt.init(params0)
for _ in range(EPOCHS):
    pr, orr, loss_r = sync_step(pr, orr, sg)

# -- async S=0 must match exactly --------------------------------------------
bytes_per_step = {}
tr0 = AsyncFullGraphTrainer(g, cfg, opt, N_DEV, partitioner=METHOD,
                            staleness=0)
pa, oa, loss_a = tr0.run(params0, opt.init(params0), EPOCHS)
bytes_per_step[0] = tr0.stats()["bytes_per_step"]

dl = abs(float(loss_r) - loss_a)
assert dl < 1e-5, (float(loss_r), loss_a)
diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), pa, pr)
maxdiff = max(jax.tree_util.tree_leaves(diffs))
assert maxdiff <= 1e-5, (maxdiff, diffs)

# -- bytes/step strictly drops as S grows ------------------------------------
for S in (1, 2):
    tr = AsyncFullGraphTrainer(g, cfg, opt, N_DEV, partitioner=METHOD,
                               staleness=S, refresh_frac=0.05)
    p, o, loss_s = tr.run(params0, opt.init(params0), 6)
    assert np.isfinite(loss_s), loss_s
    bytes_per_step[S] = tr.stats()["bytes_per_step"]
assert bytes_per_step[0] > bytes_per_step[1] > bytes_per_step[2], \
    bytes_per_step

# -- int8 wire codec at S=0: compressed bytes, bounded loss drift ------------
cfg8 = GNNConfig(arch="gcn", feat_dim=16, hidden=32, num_classes=4,
                 wire_codec="int8")
tr8 = AsyncFullGraphTrainer(g, cfg8, opt, N_DEV, partitioner=METHOD,
                            staleness=0)
p8, o8, loss_8 = tr8.run(params0, opt.init(params0), EPOCHS)
assert np.isfinite(loss_8), loss_8
late_rel = abs(loss_8 - float(loss_r)) / abs(float(loss_r))
assert late_rel < 0.05, (loss_8, float(loss_r), late_rel)
bytes_int8 = tr8.stats()["bytes_per_step"]
ratio = bytes_int8 / bytes_per_step[0]
assert ratio <= 0.35, (bytes_int8, bytes_per_step[0])

print(f"PASS async-equivalence n_dev={N_DEV} part={METHOD} "
      f"maxdiff={maxdiff:.2e} "
      f"bytes/step S0={bytes_per_step[0]:.0f} S1={bytes_per_step[1]:.0f} "
      f"S2={bytes_per_step[2]:.0f} "
      f"int8_late_rel={late_rel:.3f} int8_bytes_ratio={ratio:.2f}")
