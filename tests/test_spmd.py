"""Multi-device integration tests (8 forced host devices in a subprocess —
the in-process runtime already locked to 1 device)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", script)],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.mark.slow
def test_spmd_gnn_suite():
    r = _run("spmd_gnn_check.py")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALL SPMD CHECKS PASS" in r.stdout
    assert "PASS pull-equivalence" in r.stdout
    assert "PASS push-equivalence" in r.stdout
    assert "PASS stale-mode" in r.stdout
    assert "PASS p3-hybrid" in r.stdout
    assert "PASS coordination" in r.stdout


@pytest.mark.slow
def test_spmd_moe_expert_parallel():
    r = _run("spmd_moe_check.py")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALL MOE SPMD CHECKS PASS" in r.stdout


@pytest.mark.slow
def test_dryrun_single_combo():
    """The dry-run entry point itself (512 devices) on the smallest arch."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "train_4k"],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "1 ok, 0 skip, 0 fail" in r.stdout
