import os
import sys

# tests run on the single real CPU device (the 512-device forcing is ONLY
# inside launch/dryrun.py, per the brief).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest       # noqa: E402


def _build_graph(name: str, nodes: int, seed: int):
    from repro.graph import generators as G

    if name == "er":
        g = G.erdos_renyi(nodes, 8.0, seed=seed, directed=False)
        return G.featurize(g, 16, seed=seed, num_classes=4)
    if name == "sbm":
        g = G.sbm(nodes, 4, p_in=0.9, p_out=0.02, seed=seed)
        return G.featurize(g, 16, seed=seed, class_sep=1.5)
    if name == "reddit-like":
        from repro.graph.datasets import load
        return load("reddit-like", seed=seed, scale=nodes / 233_000).graph
    raise KeyError(f"unknown test graph family {name!r}")


@pytest.fixture(scope="session")
def graph():
    """Session-scoped ``graph(name, nodes)`` factory for the shared test
    graphs (SBM community / ER / reddit-like), cached by (name, nodes,
    seed) so suites stop rebuilding identical graphs.  Module fixtures
    override this name and call through, e.g.::

        @pytest.fixture(scope="module")
        def graph(graph):
            return graph("sbm", 200)

    NOTE: returned graphs are shared across the whole session — tests
    that mutate features must restore them (see test_serving).
    """
    cache = {}

    def factory(name: str, nodes: int, seed: int = 0):
        key = (name, nodes, seed)
        if key not in cache:
            cache[key] = _build_graph(name, nodes, seed)
        return cache[key]

    return factory
