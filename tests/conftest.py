import os
import sys

# tests run on the single real CPU device (the 512-device forcing is ONLY
# inside launch/dryrun.py, per the brief).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
