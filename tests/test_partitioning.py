"""Partitioning invariants + survey-claim sanity (§3.2.1 / Table 3)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import partitioning as P
from repro.graph import generators as G


@pytest.fixture(scope="module")
def powerlaw():
    return G.barabasi_albert(400, 3, seed=1)


@pytest.fixture(scope="module")
def er():
    return G.erdos_renyi(300, 6.0, seed=2, directed=False)


EDGE_CUT = ["hash", "ldg", "fennel"]
VERTEX_CUT = ["hdrf", "hybrid"]


@pytest.mark.parametrize("method", EDGE_CUT)
@pytest.mark.parametrize("n_parts", [2, 4, 8])
def test_edge_cut_valid(powerlaw, method, n_parts):
    p = P.partition(powerlaw, n_parts, method)
    assert p.assignment.shape == (powerlaw.num_nodes,)
    assert p.assignment.min() >= 0 and p.assignment.max() < n_parts
    assert 0.0 <= p.edge_cut_fraction(powerlaw) <= 1.0
    assert p.replication_factor(powerlaw) >= 1.0
    # streaming partitioners should be reasonably balanced
    if method in ("ldg", "fennel"):
        assert p.balance() < 2.0


@pytest.mark.parametrize("method", VERTEX_CUT)
@pytest.mark.parametrize("n_parts", [2, 4])
def test_vertex_cut_valid(powerlaw, method, n_parts):
    p = P.partition(powerlaw, n_parts, method)
    assert p.edge_assignment.shape == (powerlaw.num_edges,)
    assert p.edge_assignment.min() >= 0
    assert p.edge_assignment.max() < n_parts
    assert p.replication_factor(powerlaw) >= 1.0


def test_grid_partitioner(er):
    p = P.partition(er, 4, "grid")
    assert p.edge_assignment.max() < 4
    # block id must equal (chunk(src), chunk(dst))
    e = er.edges()
    cu = e[:, 0] * 2 // er.num_nodes
    cv = e[:, 1] * 2 // er.num_nodes
    np.testing.assert_array_equal(p.edge_assignment, cu * 2 + cv)


def test_ldg_cuts_fewer_edges_than_hash(er):
    """LDG's locality heuristic must beat random hashing (survey §2.2.2)."""
    cut_hash = P.partition(er, 4, "hash").edge_cut_fraction(er)
    cut_ldg = P.partition(er, 4, "ldg").edge_cut_fraction(er)
    assert cut_ldg < cut_hash


def test_hdrf_beats_edge_cut_replication_on_powerlaw(powerlaw):
    """PowerGraph/HDRF claim: vertex-cut lowers the replication factor on
    skewed-degree graphs vs hash edge-cut (survey §3.2.1)."""
    rf_vertex = P.partition(powerlaw, 4, "hdrf").replication_factor(powerlaw)
    rf_edge = P.partition(powerlaw, 4, "hash").replication_factor(powerlaw)
    assert rf_vertex < rf_edge


def test_contiguousize_is_permutation(er):
    p = P.partition(er, 4, "hash")
    order, counts = P.contiguousize(er, p)
    assert sorted(order.tolist()) == list(range(er.num_nodes))
    assert counts.sum() == er.num_nodes


@settings(max_examples=25, deadline=None)
@given(n=st.integers(10, 120), n_parts=st.integers(2, 6),
       seed=st.integers(0, 10))
def test_property_every_vertex_assigned(n, n_parts, seed):
    g = G.erdos_renyi(n, 4.0, seed=seed, directed=False)
    for method in EDGE_CUT:
        p = P.partition(g, n_parts, method)
        assert len(p.assignment) == g.num_nodes
        assert (p.assignment >= 0).all() and (p.assignment < n_parts).all()


@settings(max_examples=15, deadline=None)
@given(n=st.integers(20, 80), seed=st.integers(0, 5))
def test_property_vertex_cut_assigns_every_edge(n, seed):
    g = G.erdos_renyi(n, 4.0, seed=seed, directed=False)
    p = P.partition(g, 4, "hdrf")
    assert len(p.edge_assignment) == g.num_edges
    assert (p.edge_assignment >= 0).all() and (p.edge_assignment < 4).all()
