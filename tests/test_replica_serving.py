"""Elastic replicated-serving invariants: zero drops, zero version-torn
batches under a rolling hot-swap, autoscaler behavior, dispatch policies,
and crash-safe stop/resume through the checkpoint plane."""
import jax
import numpy as np
import pytest

from repro.models.gnn import model as GM
from repro.models.gnn.model import GNNConfig
from repro.serving import (AutoscalePolicy, AutoScaler, ReplicaRouter,
                           RouterStats, ServeStats, poisson_workload,
                           restore_params)

BUCKETS = (1, 4, 8)
FANOUTS = (3, 3)


@pytest.fixture(scope="module")
def graph(graph):
    return graph("sbm", 200)


@pytest.fixture(scope="module")
def model(graph):
    cfg = GNNConfig(arch="sage", feat_dim=16, hidden=32,
                    num_classes=graph.num_classes)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _router(graph, model, **kw):
    cfg, params = model
    kw.setdefault("n_replicas", 2)
    kw.setdefault("fanouts", FANOUTS)
    kw.setdefault("buckets", BUCKETS)
    kw.setdefault("cache_policy", "degree")
    kw.setdefault("cache_capacity", graph.num_nodes)
    kw.setdefault("seed", 0)
    return ReplicaRouter(graph, cfg, params, **kw)


def _workload(graph, n, rate=4000.0, seed=1):
    return poisson_workload(n, np.arange(graph.num_nodes), rate, seed=seed)


# ---------------------------------------------------------------------------
# basics: completion, zero drops, per-replica accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["round_robin", "least_queue"])
def test_all_requests_served_no_drops(graph, model, policy):
    router = _router(graph, model, policy=policy)
    wl = _workload(graph, 48)
    stats = router.run(wl)
    assert stats.served == 48
    assert stats.dropped == 0
    assert sum(r.served for r in router.replicas) == 48
    # every request carries logits and a version stamp
    for r in wl:
        assert r.logits is not None
        assert r.params_version == 0
        assert r.done_s >= r.arrival_s


def test_round_robin_spreads_traffic(graph, model):
    router = _router(graph, model, policy="round_robin", n_replicas=2)
    router.run(_workload(graph, 40))
    served = sorted(r.served for r in router.replicas)
    # alternating dispatch: both replicas carry work (not all-on-one)
    assert served[0] >= 10, served


def test_bad_config_rejected(graph, model):
    with pytest.raises(ValueError, match="policy"):
        _router(graph, model, policy="fastest")
    with pytest.raises(ValueError, match="replica"):
        _router(graph, model, n_replicas=0)


# ---------------------------------------------------------------------------
# rolling hot-swap: zero torn batches, one version per response
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shared_cache", [True, False])
def test_rolling_hot_swap_zero_torn(graph, model, shared_cache):
    cfg, _ = model
    router = _router(graph, model, shared_cache=shared_cache)

    def fresh(version):
        return GM.init_gnn(cfg, jax.random.PRNGKey(100 + version))

    wl = _workload(graph, 96)
    stats = router.run(wl, hot_swap_every=30, new_params_fn=fresh)
    assert stats.served == 96 and stats.dropped == 0
    assert stats.torn_batches == 0
    assert stats.hot_swaps >= 1
    assert router.version == stats.hot_swaps
    # every response is tagged with exactly one of the served versions,
    # and the version counts partition the workload
    versions = {r.params_version for r in wl}
    assert versions <= set(range(router.version + 1))
    assert len(versions) >= 2, "swap must happen mid-stream"
    assert sum(stats.version_counts.values()) == 96
    for r in wl:
        assert stats.version_counts[r.params_version] > 0


def test_hot_swap_staged_then_applied_between_runs(graph, model):
    cfg, params = model
    router = _router(graph, model)
    new = GM.init_gnn(cfg, jax.random.PRNGKey(42))
    v = router.hot_swap(new)
    assert v == 1
    with pytest.raises(RuntimeError, match="in flight"):
        router.hot_swap(new)
    stats = router.run(_workload(graph, 16))
    assert router.version == 1
    assert all(r.version == 1 for r in router.replicas)
    assert stats.torn_batches == 0


def test_hot_swap_version_must_grow(graph, model):
    cfg, _ = model
    router = _router(graph, model)
    with pytest.raises(ValueError, match="grow"):
        router.hot_swap(GM.init_gnn(cfg, jax.random.PRNGKey(1)), version=0)


def test_shared_cache_flips_with_first_replica(graph, model):
    """After a rollout, the shared cache serves the new version only —
    its params_version matches the router's and no replica disagrees."""
    cfg, _ = model
    router = _router(graph, model, shared_cache=True)
    router.run(_workload(graph, 64), hot_swap_every=32,
               new_params_fn=lambda v: GM.init_gnn(
                   cfg, jax.random.PRNGKey(v)))
    assert router.shared_cache.params_version == router.version
    assert all(r.version == router.version for r in router.replicas)


# ---------------------------------------------------------------------------
# autoscaler
# ---------------------------------------------------------------------------

def test_autoscaler_scales_up_on_queue_depth():
    sc = AutoScaler(AutoscalePolicy(max_replicas=4,
                                    target_queue_per_replica=4.0))
    assert sc.decide(1.0, [10, 10], 2) == 1         # 10 qpr > 4
    assert sc.decide(1.01, [10, 10], 3) == 0        # cooldown
    assert sc.decide(2.0, [10, 10, 10], 3) == 1
    assert sc.events[0]["action"] == "up"


def test_autoscaler_respects_max_and_scales_down():
    p = AutoscalePolicy(min_replicas=1, max_replicas=2,
                        target_queue_per_replica=4.0,
                        low_queue_per_replica=1.0, scale_down_after=2,
                        cooldown_s=0.0)
    sc = AutoScaler(p)
    assert sc.decide(1.0, [100, 100], 2) == 0       # at max: no scale-up
    assert sc.decide(2.0, [0, 0], 2) == 0           # low check 1
    assert sc.decide(3.0, [0, 0], 2) == -1          # low check 2 -> down
    assert sc.decide(4.0, [0], 1) == 0              # at min: stays
    assert [e["action"] for e in sc.events] == ["down"]


def test_autoscaler_p99_slo_trigger():
    sc = AutoScaler(AutoscalePolicy(slo_p99_s=0.010,
                                    target_queue_per_replica=1e9))
    for _ in range(32):
        sc.observe_latency(0.050)
    assert sc.recent_p99() > 0.010
    assert sc.decide(1.0, [0], 1) == 1              # p99 breach, not queue


def test_router_scales_up_under_burst(graph, model):
    router = _router(graph, model, n_replicas=1,
                     autoscale=AutoscalePolicy(
                         min_replicas=1, max_replicas=4,
                         target_queue_per_replica=4.0,
                         check_every_s=0.002, cooldown_s=0.004))
    stats = router.run(_workload(graph, 96, rate=12000.0))
    assert stats.served == 96 and stats.dropped == 0
    assert stats.replicas_peak >= 2, stats.summary()
    assert any(e["action"] == "up" for e in stats.scale_events)
    # scale-up decisions were driven by observed queue depth
    up = next(e for e in stats.scale_events if e["action"] == "up")
    assert up["queue_per_replica"] > 4.0


def test_hot_swap_completes_while_replica_draining(graph, model):
    """Regression: a rollout staged while a replica is mid-drain must
    still complete — the draining replica either flips while serving its
    queue dry or is reaped, and the rollout never wedges waiting on a
    replica that no longer takes new traffic."""
    cfg, _ = model
    router = _router(graph, model, n_replicas=3)
    router.replicas[2].draining = True
    assert router.hot_swap(GM.init_gnn(cfg, jax.random.PRNGKey(7))) == 1
    stats = router.run(_workload(graph, 48))
    assert router._rollout is None, "rollout wedged on a draining replica"
    assert router.version == 1
    assert stats.served == 48 and stats.dropped == 0
    assert stats.torn_batches == 0
    assert len(router.replicas) == 2        # the drained replica is reaped
    assert all(r.version == 1 for r in router.replicas)


def test_least_queue_tie_break_is_deterministic(graph, model):
    """Regression: with equal queue depths AND equal busy_until, dispatch
    must break ties by lowest replica id — not iteration order — so a
    tied fleet fills round-robin-like and reruns are reproducible."""
    router = _router(graph, model, n_replicas=3, policy="least_queue")
    for r in router.replicas:
        r.busy_until = 0.0
    want = [(1, 0, 0), (1, 1, 0), (1, 1, 1),
            (2, 1, 1), (2, 2, 1), (2, 2, 2)]
    for req, expect in zip(_workload(graph, 6), want):
        router._dispatch(req)
        assert tuple(r.queue_depth() for r in router.replicas) == expect


def test_router_never_livelocks_on_deadline_rounding(graph, model):
    """Regression: same rounding livelock as the single-server loop (see
    test_serving.py) — the clock jump lands exactly on
    fl(oldest + max_wait), the recomputed wait rounds one error short of
    max_wait_s, and a plain max() pins the fleet clock forever."""
    import signal

    from repro.serving import InferenceRequest

    router = _router(graph, model, n_replicas=1)
    wl = [InferenceRequest(0, 3, 0.017512410335686807),
          InferenceRequest(1, 4, 5.0)]

    def _hang(signum, frame):
        raise TimeoutError("router loop livelocked on the max_wait deadline")

    old = signal.signal(signal.SIGALRM, _hang)
    signal.alarm(60)
    try:
        stats = router.run(wl)
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
    assert stats.served == 2 and stats.dropped == 0


def test_router_drains_on_scale_down(graph, model):
    """A forced drain serves its queue dry before removal — no drops."""
    router = _router(graph, model, n_replicas=3)
    wl = _workload(graph, 48)
    # mark one replica draining before the run: it must still finish any
    # work the dispatcher can no longer send it (its queue starts empty,
    # so it should be reaped)
    router.replicas[2].draining = True
    stats = router.run(wl)
    assert stats.served == 48 and stats.dropped == 0
    assert len(router.replicas) == 2


# ---------------------------------------------------------------------------
# stop/resume through the checkpoint plane
# ---------------------------------------------------------------------------

def test_save_restore_roundtrip(graph, model, tmp_path):
    cfg, params = model
    router = _router(graph, model)
    router.run(_workload(graph, 32), hot_swap_every=16,
               new_params_fn=lambda v: GM.init_gnn(
                   cfg, jax.random.PRNGKey(v)))
    assert router.version >= 1
    router.save(str(tmp_path))
    restored, version = restore_params(str(tmp_path), params)
    assert version == router.version
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(router.params)):
        assert np.allclose(np.asarray(a), np.asarray(b))


def test_resume_serves_restored_version(graph, model, tmp_path):
    cfg, params = model
    saver = _router(graph, model, n_replicas=1)
    saver.run(_workload(graph, 24), hot_swap_every=12,
              new_params_fn=lambda v: GM.init_gnn(
                  cfg, jax.random.PRNGKey(v)))
    saver.save(str(tmp_path))
    restored, version = restore_params(str(tmp_path), params)

    fresh = _router(graph, model, n_replicas=2)
    fresh.hot_swap(restored, version=version)
    wl = _workload(graph, 24, seed=5)
    stats = fresh.run(wl)
    assert fresh.version == version
    assert stats.torn_batches == 0
    # the tail of the stream is served on the restored version
    assert wl[-1].params_version == version


# ---------------------------------------------------------------------------
# stats hardening (satellite: no NaNs out of empty/zero-elapsed stats)
# ---------------------------------------------------------------------------

def test_serve_stats_empty_and_zero_elapsed():
    s = ServeStats()
    assert s.throughput_rps == 0.0
    assert s.latency_quantile(0.5) == 0.0
    out = s.summary()
    assert out["p50_ms"] == 0.0 and out["p99_ms"] == 0.0
    assert out["throughput_rps"] == 0.0
    s.served = 10
    s.wall_s = 0.0
    assert s.throughput_rps == 0.0
    s.wall_s = float("inf")
    assert s.throughput_rps == 0.0


def test_router_stats_empty():
    s = RouterStats()
    assert s.throughput_rps == 0.0
    assert s.latency_quantile(0.99) == 0.0
    out = s.summary()
    assert out["served"] == 0 and out["p99_ms"] == 0.0
