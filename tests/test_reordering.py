"""Locality reordering (survey §3.2.4): policy determinism, the RCM
bandwidth guarantee on a known graph, hand-checkable locality metrics,
the perm/inv id round-trip behind the launchers' ``--reorder`` flag, and
relabeling-invariance of the aggregation the reorder exists to speed up.
"""
import numpy as np
import pytest

from repro.core import reordering as RO
from repro.graph import generators as G
from repro.graph.structure import from_edges


@pytest.fixture(scope="module")
def graph(graph):
    return graph("sbm", 200)


def _path_graph(n=8, shuffle_seed=3):
    """A path 0-1-...-n-1 with scrambled labels: RCM must recover a
    bandwidth-1 ordering regardless of the labeling."""
    rng = np.random.default_rng(shuffle_seed)
    relabel = rng.permutation(n)
    e = np.stack([relabel[np.arange(n - 1)], relabel[np.arange(1, n)]], 1)
    return from_edges(n, np.concatenate([e, e[:, [1, 0]]], 0))


# ---------------------------------------------------------------------------
# policies: determinism + permutation validity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", sorted(RO.REORDER_POLICIES))
def test_policy_is_deterministic_permutation(graph, policy):
    p1 = RO.REORDER_POLICIES[policy](graph)
    p2 = RO.REORDER_POLICIES[policy](graph)
    np.testing.assert_array_equal(p1, p2)          # ties break stably
    assert sorted(p1.tolist()) == list(range(graph.num_nodes))


def test_bfs_deque_visits_levels_in_csr_order():
    """Known graph, known traversal: root = max degree, neighbors
    enqueue in ascending-id (CSR) order, FIFO frontier."""
    #   1 - 0 - 2,  0 - 3,  2 - 4   (0 has degree 3 -> root)
    e = np.array([[0, 1], [0, 2], [0, 3], [2, 4]])
    g = from_edges(5, np.concatenate([e, e[:, [1, 0]]], 0))
    perm = RO.bfs_locality_order(g)
    assert perm.tolist() == [0, 1, 2, 3, 4]


def test_degree_ties_break_by_ascending_id():
    """All degrees equal (a cycle) -> degree sort degenerates to the
    identity, not an arbitrary shuffle."""
    n = 10
    e = np.stack([np.arange(n), (np.arange(n) + 1) % n], 1)
    g = from_edges(n, np.concatenate([e, e[:, [1, 0]]], 0))
    assert RO.degree_sort_order(g).tolist() == list(range(n))


def test_rcm_recovers_path_bandwidth():
    g = _path_graph(16)
    e0 = g.edges()
    assert np.abs(e0[:, 0] - e0[:, 1]).max() > 1   # scrambled
    packed, perm, inv = RO.reorder_graph(g, "rcm")
    e = packed.edges()
    assert np.abs(e[:, 0] - e[:, 1]).max() == 1    # bandwidth-1 band


def test_reorder_graph_rejects_unknown_policy(graph):
    with pytest.raises(KeyError, match="unknown reorder policy"):
        RO.reorder_graph(graph, "hilbert")


# ---------------------------------------------------------------------------
# perm/inv round-trip (the launcher id contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["none", "degree", "bfs", "rcm"])
def test_perm_inv_round_trip(graph, policy):
    packed, perm, inv = graph.reordered(policy)
    n = graph.num_nodes
    np.testing.assert_array_equal(perm[inv], np.arange(n))
    np.testing.assert_array_equal(inv[perm], np.arange(n))
    if policy == "none":
        assert packed is graph                     # no-copy fast path
    # features/labels moved with their nodes: packed new_id row is the
    # original perm[new_id] row
    np.testing.assert_array_equal(packed.features, graph.features[perm])
    np.testing.assert_array_equal(packed.labels, graph.labels[perm])
    assert sorted(packed.out_degree().tolist()) == \
        sorted(graph.out_degree().tolist())


@pytest.mark.parametrize("policy", ["degree", "bfs", "rcm"])
def test_aggregation_commutes_with_relabeling(graph, policy):
    """sum over in-neighbors on the packed graph == the original
    aggregation read back through perm — the invariant that makes
    --reorder transparent to training."""
    packed, perm, inv = graph.reordered(policy)

    def agg(g):
        e = g.edges()
        out = np.zeros((g.num_nodes, g.features.shape[1]), np.float64)
        np.add.at(out, e[:, 1], g.features[e[:, 0]])
        return out

    np.testing.assert_allclose(agg(packed), agg(graph)[perm], rtol=1e-12)


# ---------------------------------------------------------------------------
# locality metrics on hand-checkable graphs
# ---------------------------------------------------------------------------

def test_locality_metrics_on_known_chain():
    # directed chain 0->1->2->3: strides of exactly 1 on both streams,
    # every edge inside a 2-wide band, no dst is ever revisited
    g = from_edges(4, np.array([[0, 1], [1, 2], [2, 3]]))
    assert RO.edge_locality(g, window=2) == 1.0
    assert RO.avg_gather_stride(g) == 1.0
    assert RO.reuse_distance_hit_rate(g) == 0.0

    # fan-in: every edge hits dst 0 -> all but the first access reuse it
    g2 = from_edges(4, np.array([[1, 0], [2, 0], [3, 0]]))
    assert RO.reuse_distance_hit_rate(g2) == pytest.approx(2 / 3)


def test_locality_metrics_empty_graph():
    g = from_edges(5, np.zeros((0, 2), np.int64))
    rep = RO.locality_report(g)
    assert rep == {"edge_locality": 0.0, "avg_gather_stride": 0.0,
                   "reuse_hit_rate": 0.0}


def test_reordering_improves_tile_density(graph):
    """RCM's banded edges activate fewer (dst-tile, edge-tile) grid
    cells than the raw labeling — the VMEM-residency metric the blocked
    kernels' wall-clock follows."""
    from repro.kernels.segment_sum import edge_tile_density
    packed, perm, inv = graph.reordered("rcm")
    e0, e1 = graph.edges(), packed.edges()
    d0 = edge_tile_density(e0[:, 0], e0[:, 1], graph.num_nodes,
                           be=32, bn=32)
    d1 = edge_tile_density(e1[:, 0], e1[:, 1], packed.num_nodes,
                           be=32, bn=32)
    assert 0.0 < d1["active_tile_frac"] <= d0["active_tile_frac"] <= 1.0


def test_tile_density_no_edges():
    from repro.kernels.segment_sum import edge_tile_density
    z = np.zeros(0, np.int64)
    d = edge_tile_density(z, z, 10)
    assert d == {"active_tile_frac": 0.0, "src_rows_per_edge_tile": 0.0}
