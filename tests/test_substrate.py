"""Substrate tests: optimizer, checkpoint, data pipeline, caching,
scheduling, sync policies, dryrun helpers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import ARCH_ALIASES, INPUT_SHAPES, get_config
from repro.core import caching as CA
from repro.core import scheduling as SC
from repro.core.sync import HaloCache, SyncPolicy
from repro.data.pipeline import SyntheticLMDataset, input_specs
from repro.graph import generators as G
from repro.optim import AdamW, Sgd, cosine_schedule


def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        loss, g = jax.value_and_grad(
            lambda q: jnp.sum(jnp.square(q["w"])))(p)
        p, s = opt.apply(p, g, s)
        return p, s, loss

    for _ in range(100):
        params, state, loss = step(params, state)
    assert float(loss) < 1e-2


def test_sgd_momentum():
    opt = Sgd(lr=0.05, momentum=0.9)
    params = {"w": jnp.asarray([4.0])}
    state = opt.init(params)
    for _ in range(80):
        g = {"w": 2 * params["w"]}
        params, state = opt.apply(params, g, state)
    assert abs(float(params["w"][0])) < 0.1


def test_cosine_schedule():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < 1e-5
    assert float(lr(5)) == pytest.approx(5e-4)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray(7, jnp.int32)}}
    save_checkpoint(str(tmp_path), 3, tree, meta={"note": "x"})
    restored, manifest = load_checkpoint(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert manifest["step"] == 3 and manifest["meta"]["note"] == "x"


def test_input_specs_all_combinations():
    for arch in ARCH_ALIASES:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            specs = input_specs(cfg, shape)
            for k, v in specs.items():
                assert all(d > 0 for d in v.shape), (arch, shape.name, k)
            if shape.kind == "train":
                assert "labels" in specs
            if shape.kind == "decode":
                assert "pos" in specs


def test_synthetic_dataset_deterministic_and_learnable_structure():
    ds1 = SyntheticLMDataset(64, 32, seed=1)
    ds2 = SyntheticLMDataset(64, 32, seed=1)
    a, b = ds1.sample(4), ds2.sample(4)
    np.testing.assert_array_equal(a, b)
    # planted bigram: next token equals next_tok[prev] most of the time
    follows = ds1.next_tok[a[:, :-1]]
    frac = np.mean(follows == a[:, 1:])
    assert frac > 0.6


def test_degree_cache_beats_random():
    """PaGraph claim (§3.2.4): degree-ordered caching yields a higher hit
    ratio than random caching under neighbor-sampled access streams."""
    g = G.barabasi_albert(500, 4, seed=0)
    g = G.featurize(g, 8, seed=0)
    rng = np.random.default_rng(0)
    from repro.core.sampling import NeighborSampler
    s = NeighborSampler(g, [5, 5], seed=0)
    batches = []
    for _ in range(20):
        seeds = rng.choice(g.num_nodes, 16, replace=False)
        batches.append(s.sample(seeds).input_nodes)
    cap = g.num_nodes // 10
    r_deg = CA.measure_cache(g, "degree", cap, batches)
    r_rnd = CA.measure_cache(g, "random", cap, batches)
    assert r_deg["hit_ratio"] > r_rnd["hit_ratio"]
    assert r_deg["transferred_mb"] < r_rnd["transferred_mb"]


def test_pipelined_loader_overlaps():
    import time
    def slow_sample():
        time.sleep(0.01)
        return 1

    loader = SC.PipelinedLoader(slow_sample, depth=4, n_workers=2)
    t0 = time.perf_counter()
    got = [next(loader) for _ in range(20)]
    wall = time.perf_counter() - t0
    loader.close()
    assert len(got) == 20
    assert wall < 20 * 0.01 * 1.5  # overlap beats sequential


def test_work_stealing_completes_and_steals():
    import time
    tasks = [[lambda: time.sleep(0.002) or 1] * 12] + [[] for _ in range(3)]
    pool = SC.WorkStealingPool(tasks)
    out = pool.run()
    assert out["done"] == 12
    assert out["stolen"] > 0  # idle workers stole from the loaded one


def test_lpt_balance():
    costs = np.asarray([10, 9, 8, 1, 1, 1, 1, 1], np.float64)
    assign = SC.cost_balanced_assignment(costs, 4)
    loads = np.zeros(4)
    for c, a in zip(costs, assign):
        loads[a] += c
    assert loads.max() <= 12  # LPT bound comfortably met


def test_sync_policy_accounting():
    pol = SyncPolicy(mode="stale", staleness=4)
    cache = HaloCache("v0")
    for step in range(12):
        cache.maybe_refresh(pol, step, f"v{step}")
    assert cache.refreshes == 3
    assert cache.comm_savings() == pytest.approx(0.75)


def test_dryrun_collective_parser():
    from repro.launch.hlo_analysis import collective_bytes
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[16]{0} all-reduce(%y), to_apply=%sum
  %rs = (f32[4,4]{1,0}, f32[4,4]{1,0}) reduce-scatter(%a, %b)
  %noise = f32[2]{0} add(%p, %q)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 8 * 128 * 2
    assert got["all-reduce"] == 16 * 4
    assert got["reduce-scatter"] == 2 * 16 * 4
    assert got["total"] == sum(v for k, v in got.items() if k != "total")
