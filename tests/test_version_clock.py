"""Unit tests for the unified staleness substrate in ``core/caching.py``:
:class:`VersionClock` / :class:`VersionedBuffer`, and the contract that the
serving :class:`EmbeddingCache` and the training
:class:`~repro.core.halo.HaloExchange` are views over the same clock
semantics (the 18 serving behaviors themselves are regression-guarded by
``tests/test_serving.py``)."""
import numpy as np
import pytest

from repro.core.caching import NEVER, VersionClock, VersionedBuffer


def test_never_written_rows_fail_every_bound():
    buf = VersionedBuffer(VersionClock(), 5, 2)
    assert (buf.version == NEVER).all()
    assert not buf.fresh_mask(0).any()
    assert not buf.fresh_mask(10**9).any()
    # and age computation does not overflow int64
    assert (buf.age() > 0).all()


def test_write_stamps_current_clock_and_bounds_reads():
    clock = VersionClock()
    buf = VersionedBuffer(clock, 4, 3)
    buf.write(np.asarray([0, 2]), np.ones((2, 3), np.float32))
    assert buf.fresh_mask(0)[[0, 2]].all()
    assert not buf.fresh_mask(0)[[1, 3]].any()
    clock.tick()
    assert not buf.fresh_mask(0)[[0, 2]].any()       # staleness 1 > 0
    assert buf.fresh_mask(1)[[0, 2]].all()           # within bound 1
    clock.tick()
    assert not buf.fresh_mask(1)[[0, 2]].any()       # staleness 2 > 1


def test_boolean_mask_writes_and_age_subsets():
    clock = VersionClock()
    buf = VersionedBuffer(clock, 6, 2)
    mask = np.asarray([True, False, True, False, False, True])
    buf.write(mask, np.full((3, 2), 5.0, np.float32))
    np.testing.assert_array_equal(buf.values[mask],
                                  np.full((3, 2), 5.0, np.float32))
    assert not buf.values[~mask].any()
    clock.tick(3)
    np.testing.assert_array_equal(buf.age(np.flatnonzero(mask)),
                                  np.full(3, 3))


def test_invalidate_is_permanent_until_rewrite():
    clock = VersionClock()
    buf = VersionedBuffer(clock, 3, 2)
    buf.write(np.arange(3), np.ones((3, 2), np.float32))
    buf.invalidate(np.asarray([1]))
    fresh = buf.fresh_mask(10)
    assert fresh[0] and not fresh[1] and fresh[2]
    buf.write(np.asarray([1]), np.zeros((1, 2), np.float32))
    assert buf.fresh_mask(0)[1]


def test_shared_clock_ages_every_buffer_together():
    clock = VersionClock()
    a = VersionedBuffer(clock, 4, 2)
    b = VersionedBuffer(clock, 7, 5)
    a.write(np.asarray([0]), np.ones((1, 2), np.float32))
    clock.tick()
    b.write(np.asarray([3]), np.ones((1, 5), np.float32))
    assert a.age()[0] == 1 and b.age()[3] == 0
    clock.tick(2)
    assert a.age()[0] == 3 and b.age()[3] == 2


def test_embedding_cache_rides_the_shared_substrate(graph):
    """The serving cache's staleness semantics are exactly the buffer's:
    tick via the shared clock, bounded lookup via fresh_mask."""
    from repro.serving.cache import EmbeddingCache
    g = graph("sbm", 120)
    c = EmbeddingCache(g, [8], policy="degree", capacity=g.num_nodes,
                       max_staleness=1)
    assert isinstance(c.vclock, VersionClock)
    assert all(isinstance(pl, VersionedBuffer) for pl in c.planes.values())
    ids = np.asarray([1, 2, 3])
    c.store(0, ids, np.ones((3, 8), np.float32), np.ones(3, bool))
    assert c.clock == c.vclock.now
    c.tick()
    assert c.lookup(0, ids)[1].all()                 # age 1 <= bound 1
    c.tick()
    assert not c.lookup(0, ids)[1].any()             # age 2 > bound 1


def test_halo_exchange_can_share_a_serving_clock(graph):
    """One clock can drive both subsystems: a serving tick ages training
    ghosts and vice versa (the unified-staleness design goal)."""
    from repro.core.halo import HaloExchange, build_halo
    from repro.core.partitioning import partition
    from repro.serving.cache import EmbeddingCache
    g = graph("sbm", 120)
    cache = EmbeddingCache(g, [8], policy="degree", max_staleness=2)
    lay = build_halo(g, partition(g, 2, "hash"))
    ex = HaloExchange(lay, [8], max_staleness=2, clock=cache.vclock)
    plan = ex.plan_refresh()                         # ticks the SHARED clock
    assert cache.clock == 1
    ex.write_planes(plan, [np.ones((ex.buffers[0].rows, 8), np.float32)])
    cache.tick(2)
    # ghost rows were stamped at clock 0; now at 3 they exceed bound 2
    assert not ex.buffers[0].fresh_mask(2)[ex.ghost_rows].any()
