"""Training-equivalence check for the one-pass fused GAT kernel — run
in a subprocess so ``--xla_force_host_platform_device_count=N`` can be
set before JAX imports.

argv: n_dev

Trains 10 full-graph GAT steps with ``use_kernel=True`` (the fused
online-softmax Pallas kernel, interpret mode on CPU) and with the XLA
reference path from the same init, then demands every parameter agree to
<= 1e-5 — ``jax.grad`` through the composed custom VJP (alpha recompute
+ swapped fused kernels + closed-form softmax backward) matches XLA
autodiff step for step.

* ``n_dev == 1`` uses the single-device full-graph trainer.
* ``n_dev > 1`` replicates the same step under ``jax.pmap`` with
  ``pmean``'d gradients — identical data per replica, so the result must
  still match the single-device reference while the kernel executes on
  every forced host device.
"""
import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 1
STEPS = 10
TOL = 1e-5

if N_DEV > 1:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEV} "
        + os.environ.get("XLA_FLAGS", ""))

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402

from repro.core.abstraction import DeviceGraph          # noqa: E402
from repro.graph import generators as G                 # noqa: E402
from repro.models.gnn import model as GM                # noqa: E402
from repro.models.gnn.model import GNNConfig            # noqa: E402
from repro.optim import AdamW                           # noqa: E402

assert jax.device_count() >= N_DEV, jax.device_count()

g = G.sbm(144, 4, p_in=0.9, p_out=0.02, seed=0)
g = G.featurize(g, 16, seed=0, class_sep=1.5)

opt = AdamW(lr=1e-2, weight_decay=0.0)
dg = DeviceGraph.from_graph(g)
x = jnp.asarray(g.features)
y = jnp.asarray(g.labels)
mask = jnp.ones_like(y, jnp.float32)


def run(use_kernel: bool):
    cfg = GNNConfig(arch="gat", feat_dim=16, hidden=32, num_classes=4,
                    use_kernel=use_kernel)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(0))
    ostate = opt.init(params)
    if N_DEV == 1:
        step = jax.jit(GM.make_fullgraph_train_step(cfg, opt))
        for _ in range(STEPS):
            params, ostate, loss = step(params, ostate, dg, x, y, mask)
        return params, float(loss)

    def dp_step(params, ostate):
        def loss_fn(p):
            logits = GM.forward_full(cfg, p, dg, x)
            return GM.nll_loss(logits, y, mask)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = jax.lax.pmean(grads, "dp")      # identical replicas:
        loss = jax.lax.pmean(loss, "dp")        # pmean is the identity
        params, ostate = opt.apply(params, grads, ostate)
        return params, ostate, loss

    step = jax.pmap(dp_step, axis_name="dp")
    rep = jax.tree.map(lambda a: jnp.stack([a] * N_DEV), params)
    ostate = jax.tree.map(lambda a: jnp.stack([a] * N_DEV), ostate)
    for _ in range(STEPS):
        rep, ostate, loss = step(rep, ostate)
    return jax.tree.map(lambda a: a[0], rep), float(loss[0])


p_ref, loss_ref = run(use_kernel=False)
p_ker, loss_ker = run(use_kernel=True)

assert abs(loss_ref - loss_ker) < TOL, (loss_ref, loss_ker)
diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     p_ker, p_ref)
maxdiff = max(jax.tree_util.tree_leaves(diffs))
assert maxdiff <= TOL, (maxdiff, diffs)

print(f"PASS gat-fused-equivalence n_dev={N_DEV} steps={STEPS} "
      f"maxdiff={maxdiff:.2e} loss={loss_ker:.4f}")
