"""Acceptance check for the unified telemetry plane — run in a subprocess
with 2 forced host devices.

Phase 1 (serving): a short cached serve; the registry snapshot's cache
hit/miss counters and per-path comm bytes must equal the
``EmbeddingCache`` / ``Transport`` instance counters exactly.

Phase 2 (training): a 2-device ``--minibatch --wire-codec int8
--use-kernel``-equivalent run; the snapshot must expose per-path comm
bytes (matching the partition stores' ``Transport.total_bytes``), a
step-time histogram with one sample per executed step, and nonzero
kernel dispatch counts.

Phase 3 (dynamic graphs): the update-log / invalidation counters
(``graph_updates_total{kind}``, ``cache_invalidated_rows_total``,
``delta_refresh_rows_total``) must equal their instance counters exactly,
and the PR-6 warmup-reset rule must hold — ``reset_stats`` zeroes the
instance counter AND its registry series in lockstep, so no stale count
leaks across a warmup reset.

Then: the Prometheus exposition round-trips through
``parse_prometheus`` and the JSONL trace validates.  Prints
``PASS telemetry-plane`` on success.
"""
import os
import sys
import tempfile

N_DEV = 2
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", ""))

import jax                                              # noqa: E402
import numpy as np                                      # noqa: E402

from repro.core import telemetry                        # noqa: E402
from repro.graph import generators as G                 # noqa: E402
from repro.models.gnn import model as GM                # noqa: E402
from repro.models.gnn.model import GNNConfig            # noqa: E402
from repro.optim import AdamW                           # noqa: E402

assert jax.device_count() == N_DEV, jax.device_count()

telemetry.set_enabled(True)
reg = telemetry.get_registry()

g = G.sbm(144, 4, p_in=0.9, p_out=0.02, seed=0)
g = G.featurize(g, 16, seed=0, class_sep=1.5)

# ---------------------------------------------------------------------------
# phase 1: serving — snapshot vs EmbeddingCache / Transport counters
# ---------------------------------------------------------------------------
from repro.serving import GNNInferenceServer, poisson_workload  # noqa: E402

cfg_s = GNNConfig(arch="sage", feat_dim=16, hidden=32, num_classes=4)
srv = GNNInferenceServer(
    g, cfg_s, GM.init_gnn(cfg_s, jax.random.PRNGKey(0)),
    fanouts=[3, 3], buckets=[1, 4, 8], cache_policy="degree",
    cache_capacity=g.num_nodes // 2, seed=0)
srv.warmup()     # resets cache stats AND the matching telemetry series
srv.run(poisson_workload(48, np.arange(g.num_nodes), 2000.0, seed=1))

hits = reg.value("cache_lookups_total",
                 cache="serving.embedding", result="hit")
misses = reg.value("cache_lookups_total",
                   cache="serving.embedding", result="miss")
assert int(hits) == srv.cache.hits, (hits, srv.cache.hits)
assert int(misses) == srv.cache.misses, (misses, srv.cache.misses)
assert hits + misses > 0

feat_bytes = reg.total("comm_bytes_total", path="serving.features")
assert int(feat_bytes) == srv.cache.features.transport.total_bytes
fill_bytes = reg.total("comm_bytes_total", path="serving.fill")
assert int(fill_bytes) == sum(t.total_bytes for t in srv.cache.fill.values())
assert fill_bytes > 0    # the cached policy really wrote fills

lat = reg.get_histogram("serving_request_latency_seconds")
assert lat is not None and lat.count == srv.stats.served == 48
assert reg.value("serving_requests_total") == 48
assert len(reg.tracer.events) > 0       # serve spans recorded

# ---------------------------------------------------------------------------
# phase 2: 2-device minibatch training, int8 wire codec, Pallas kernels
# ---------------------------------------------------------------------------
from repro.distributed import (DistributedMinibatchSampler,   # noqa: E402
                               collate,
                               make_distributed_minibatch_step)

cfg_t = GNNConfig(arch="gcn", feat_dim=16, hidden=32, num_classes=4,
                  use_kernel=True, wire_codec="int8")
params = GM.init_gnn(cfg_t, jax.random.PRNGKey(0))
opt = AdamW(lr=1e-2, weight_decay=0.0)
ostate = opt.init(params)

dist = DistributedMinibatchSampler(
    g, N_DEV, [3, 3], 24, partitioner="hash", cache_policy="degree",
    cache_capacity=g.num_nodes // 10, wire_codec="int8", seed=0)
mesh, dstep = make_distributed_minibatch_step(cfg_t, opt, N_DEV,
                                              dist.block_shapes())

import time                                             # noqa: E402
m_step = telemetry.histogram("train_step_seconds", mode="minibatch_dist")
rng = np.random.default_rng(1)
STEPS = 3
for _ in range(STEPS):
    seeds = rng.choice(g.num_nodes, 24, replace=False)
    arrays = collate(dist.sample_global(seeds), dist.out_deg)
    t0 = time.perf_counter()
    params, ostate, loss = dstep(params, ostate, arrays)
    m_step.observe(time.perf_counter() - t0)

snap = reg.snapshot()

# per-path comm bytes match the sum over the partition stores' transports
mb_bytes = reg.total("comm_bytes_total", path="minibatch.features")
want = sum(s.transport.total_bytes for s in dist.stores)
assert int(mb_bytes) == want, (mb_bytes, want)
assert mb_bytes > 0
codecs = {k for k in snap["comm_bytes_total"]["series"]
          if "path=minibatch.features" in k}
assert all("codec=int8" in k for k in codecs), codecs

# cache hit counters match the stores
mb_hits = reg.value("cache_lookups_total",
                    cache="minibatch.features", result="hit")
mb_miss = reg.value("cache_lookups_total",
                    cache="minibatch.features", result="miss")
assert int(mb_hits) == sum(s.hits for s in dist.stores)
assert int(mb_miss) == sum(s.misses for s in dist.stores)

# step-time histogram: one sample per executed step
hs = snap["train_step_seconds"]["series"]["mode=minibatch_dist"]
assert hs["count"] == STEPS, hs

# kernel dispatch counters: use_kernel=True traced the fused aggregation
kd = snap["kernel_dispatch_total"]["series"]
fused = sum(v for k, v in kd.items()
            if "kernel=gather_scale_segment_sum" in k)
assert fused > 0, kd

# ---------------------------------------------------------------------------
# phase 3: dynamic-graph counters — registry == instance, reset in lockstep
# ---------------------------------------------------------------------------
from repro.core import partitioning as PT               # noqa: E402
from repro.core.halo import HaloExchange, build_halo    # noqa: E402
from repro.core.updates import (GraphUpdateLog,         # noqa: E402
                                synthesize_updates)

# update-log event counters, per kind
log = GraphUpdateLog()
log.reset_stats()        # clean slate: the series is process-global
synthesize_updates(g, 20, seed=5, log=log)
assert sum(log.counts.values()) == 20
for kind, n in log.counts.items():
    got = reg.value("graph_updates_total", kind=kind)
    assert int(got) == n, (kind, got, n)

# serving-cache invalidation counter, through a real graph-delta fold;
# warmup-reset rule: reset_stats zeroes instance + series together
srv.cache.reset_stats()
assert reg.value("cache_invalidated_rows_total",
                 cache="serving.embedding") == 0.0
n_inv = srv.apply_graph_update(log)["invalidated_rows"]
got_inv = reg.value("cache_invalidated_rows_total",
                    cache="serving.embedding")
assert int(got_inv) == srv.cache.invalidated_rows == n_inv, (
    got_inv, srv.cache.invalidated_rows, n_inv)
assert n_inv > 0
srv.cache.reset_stats()
assert srv.cache.invalidated_rows == 0
assert reg.value("cache_invalidated_rows_total",
                 cache="serving.embedding") == 0.0

# halo ghost-row invalidation counter (no warmup on the training side:
# the counter has no reset entry point, so registry must track instance)
telemetry.counter("delta_refresh_rows_total").reset()
ex = HaloExchange(build_halo(g, PT.partition(g, 2, "hash")), [8],
                  max_staleness=2)
ghost = np.where(ex.ghost_rows)[0][:6]
n_ghost_inv = ex.invalidate_rows(ghost)
assert int(reg.value("delta_refresh_rows_total")) == ex.delta_rows \
    == n_ghost_inv > 0

# log reset zeroes counts and series in lockstep
log.reset_stats()
assert all(v == 0 for v in log.counts.values())
for kind in log.counts:
    assert reg.value("graph_updates_total", kind=kind) == 0.0

# ---------------------------------------------------------------------------
# exposition round trip + trace validation
# ---------------------------------------------------------------------------
with tempfile.TemporaryDirectory() as td:
    prom = os.path.join(td, "metrics.prom")
    trace = os.path.join(td, "trace.jsonl")
    reg.write_prometheus(prom)
    parsed = telemetry.parse_prometheus(open(prom).read())
    key = (("codec", "int8"), ("kind", "payload"),
           ("path", "minibatch.features"))
    assert key in parsed["comm_bytes_total"], sorted(parsed)
    n_ev = reg.tracer.export_jsonl(trace)
    assert telemetry.validate_trace_jsonl(trace) == n_ev > 0

print(f"PASS telemetry-plane n_dev={N_DEV} "
      f"serve_hits={int(hits)} mb_kib={mb_bytes / 1024:.1f} "
      f"steps={STEPS} fused_dispatch={int(fused)} events={n_ev} "
      f"dyn_invalidated={n_inv} dyn_ghost_rows={n_ghost_inv}")
