"""Staleness-bounded asynchronous full-graph training invariants.

The subprocess matrix (``tests/async_train_check.py``, forced multi-device)
proves S=0 degrades exactly to the synchronous pull step and that
bytes/step strictly drops as the bound grows.  The in-process tests cover
the host-side refresh planning layer: staleness-bound enforcement,
monotonic traffic, value write-back discipline, and the relabeled
``ShardedGraph`` ghost membership.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_check(n_dev, partitioner, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "async_train_check.py"),
         str(n_dev), partitioner],
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.mark.distributed
@pytest.mark.parametrize("partitioner", ["hash", "ldg"])
@pytest.mark.parametrize("n_dev", [2, 4])
def test_async_equivalence_and_monotonicity(n_dev, partitioner):
    r = _run_check(n_dev, partitioner)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PASS async-equivalence" in r.stdout, r.stdout


# ---------------------------------------------------------------------------
# in-process host-side refresh planning (no devices needed)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def graph(graph):
    return graph("reddit-like", 800)


@pytest.fixture(scope="module")
def layout(graph):
    from repro.core.halo import build_halo
    from repro.core.partitioning import partition
    return build_halo(graph, partition(graph, 4, "hash"))


def _avg_bytes(layout, s, steps=12, frac=0.05, dims=(32,)):
    from repro.core.halo import HaloExchange
    ex = HaloExchange(layout, dims, max_staleness=s, refresh_frac=frac)
    total = sum(ex.plan_refresh().bytes for _ in range(steps))
    return total / steps


def test_bytes_per_step_strictly_decreasing_in_staleness(layout):
    """The acceptance property, host-side: avg bytes/step drops strictly
    as S goes 0 -> 1 -> 2 on the reddit-like graph."""
    b0, b1, b2 = (_avg_bytes(layout, s) for s in (0, 1, 2))
    assert b0 > b1 > b2, (b0, b1, b2)


def test_staleness_zero_plans_every_ghost_every_step(layout):
    from repro.core.halo import HaloExchange
    ex = HaloExchange(layout, [16], max_staleness=0)
    for _ in range(3):
        plan = ex.plan_refresh()
        np.testing.assert_array_equal(plan.masks[0], ex.ghost_rows)
        assert plan.rows_moved == int(ex.copies.sum())
    # every plan moves the full synchronous volume
    assert ex.stats()["bytes_per_step"] == ex.sync_bytes_per_step()


def test_stale_reads_never_exceed_bound(layout):
    """Plans must refresh every ghost row whose age would exceed S, so any
    row served stale is at most S steps old."""
    from repro.core.halo import HaloExchange
    S = 3
    ex = HaloExchange(layout, [8, 8], max_staleness=S, refresh_frac=0.1)
    for _ in range(10):
        ages_before = [b.age() for b in ex.buffers]
        plan = ex.plan_refresh()
        for age, mask in zip(ages_before, plan.masks):
            served = ex.ghost_rows & ~mask
            assert (age[served] <= S).all()


def test_write_planes_only_touches_masked_rows(layout):
    from repro.core.halo import HaloExchange
    ex = HaloExchange(layout, [4], max_staleness=1, refresh_frac=0.0)
    n = ex.buffers[0].rows
    ex.plan_refresh()                                # cold: all ghosts
    ex.plan_refresh()                                # warm: none (S=1)
    plan = ex.plan_refresh()                         # expiry: all again
    before = ex.buffers[0].values.copy()
    plane = np.full((n, 4), 7.0, np.float32)
    ex.write_planes(plan, [plane])
    after = ex.buffers[0].values
    np.testing.assert_array_equal(after[~plan.masks[0]],
                                  before[~plan.masks[0]])
    assert (after[plan.masks[0]] == 7.0).all()


def test_exchange_for_shards_ghosts_are_cut_edge_sources(graph):
    """In the relabeled space, a row is a ghost of partition p iff it is a
    remote source of an edge into p's owned destinations (pull direction),
    and owned rows are never their own ghosts."""
    from repro.core.propagation import shard_graph
    from repro.distributed import exchange_for_shards

    sg = shard_graph(graph, 4, method="hash")
    ex = exchange_for_shards(graph, sg, [8], max_staleness=0)
    e = graph.edges()
    src_new, dst_new = sg.perm[e[:, 0]], sg.perm[e[:, 1]]
    owner_src, owner_dst = src_new // sg.n_local, dst_new // sg.n_local
    want = np.zeros_like(ex.member)
    cut = owner_src != owner_dst
    for s_, p in zip(src_new[cut], owner_dst[cut]):
        want[p, s_] = True
    np.testing.assert_array_equal(ex.member, want)
    for p in range(4):
        own = (np.arange(ex.member.shape[1]) // sg.n_local) == p
        assert not (ex.member[p] & own).any()


def test_refresh_frac_budget_spreads_refreshes(layout):
    """With a budget, steady-state per-step traffic sits between the pure
    expiry rate and the synchronous volume, and planning stays smooth."""
    from repro.core.halo import HaloExchange
    ex = HaloExchange(layout, [16], max_staleness=4, refresh_frac=0.25)
    plans = [ex.plan_refresh() for _ in range(12)]
    rows = [p.rows_moved for p in plans[2:]]         # skip cold start
    assert max(rows) > 0
    budget = int(0.25 * ex.n_ghost)
    # after warmup no step should need to move every ghost again
    assert max(rows) < int(ex.copies.sum())
    assert min(r for r in rows if r) >= min(budget, 1)
