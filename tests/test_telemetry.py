"""Telemetry plane invariants (``repro.core.telemetry``).

The contract pinned here:

* :meth:`Histogram.quantile` is EXACT — numpy linear interpolation over
  the raw samples, property-tested against ``numpy.percentile``;
* the registry aggregates by ``(name, labels)``: two ``Transport``
  instances on the same path feed one series;
* span nesting/ordering survives the JSONL round trip (depth, parent,
  dense seq);
* a disabled registry records NOTHING — counters, gauges, histograms,
  and spans are all single-branch no-ops (the overhead guard in
  ``benchmarks/bench_serving.py`` prices the enabled side);
* the Prometheus exposition round-trips through the stdlib validator
  with cumulative bucket series.
"""
import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import telemetry as T

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture()
def reg():
    """A fresh *enabled* global registry, restored after the test (the
    instrumented modules hold references into the global one, so tests
    exercise exactly the registry production code uses)."""
    r = T.get_registry()
    prev = T.set_enabled(True)
    r.reset()
    try:
        yield r
    finally:
        r.reset()
        T.set_enabled(prev)


# ---------------------------------------------------------------------------
# histogram quantiles vs numpy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("q", [0.0, 0.25, 0.5, 0.9, 0.99, 1.0])
def test_quantile_matches_numpy_percentile(seed, q):
    rng = np.random.default_rng(seed)
    samples = rng.lognormal(mean=-3.0, sigma=2.0, size=501).astype(np.float32)
    h = T.Histogram("h_test", buckets=T.DEFAULT_TIME_BUCKETS)
    # mix scalar and batched observation paths
    for v in samples[:100]:
        h.observe(float(v))
    h.observe_batch(samples[100:])
    want = np.percentile(samples.astype(np.float64), q * 100,
                         method="linear")
    assert h.quantile(q) == pytest.approx(float(want), rel=1e-6)


def test_histogram_bucket_counts_cumulative():
    h = T.Histogram("h_cum", buckets=[1.0, 10.0, 100.0])
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    cum = h.cumulative_buckets()
    assert cum == [(1.0, 1), (10.0, 2), (100.0, 3), (math.inf, 4)]
    assert h.sum == pytest.approx(555.5)


def test_histogram_empty_quantile_is_zero():
    h = T.Histogram("h_empty")
    assert h.quantile(0.5) == 0.0
    assert h.count == 0


# ---------------------------------------------------------------------------
# bounded reservoir: memory cap with exact-below / estimate-above semantics
# ---------------------------------------------------------------------------

def test_reservoir_exact_below_cap():
    h = T.Histogram("h_cap", max_samples=256)
    vals = np.arange(256, dtype=np.float64)
    h.observe_batch(vals)
    assert not h.saturated
    assert len(h.samples) == 256
    assert h.quantile(0.5) == pytest.approx(np.percentile(vals, 50))


def test_reservoir_caps_memory_and_estimates_above():
    cap = 512
    h = T.Histogram("h_cap2", max_samples=cap)
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-3.0, sigma=2.0, size=20 * cap)
    h.observe_batch(vals[:10 * cap])
    for v in vals[10 * cap:]:            # scalar path past saturation too
        h.observe(float(v))
    assert h.saturated
    assert h.count == len(vals)          # exact observation count kept
    assert len(h.samples) == cap         # memory bounded at the cap
    assert h.sum == pytest.approx(vals.sum(), rel=1e-9)
    # cumulative buckets stay EXACT under saturation (they never sample)
    total = sum(1 for _ in vals)
    assert h.cumulative_buckets()[-1] == (math.inf, total)
    # the reservoir is an unbiased subsample: quantiles track the true
    # distribution within a loose tolerance
    want = np.percentile(vals, 50)
    assert h.quantile(0.5) == pytest.approx(want, rel=0.25)
    # every retained sample is a genuine observation (modulo the
    # histogram's float32 storage)
    assert np.isin(np.asarray(h.samples),
                   vals.astype(np.float32)).all()


def test_reservoir_reset_clears_saturation():
    h = T.Histogram("h_cap3", max_samples=8)
    h.observe_batch(np.arange(100, dtype=np.float64))
    assert h.saturated
    h.reset()
    assert not h.saturated and h.count == 0 and len(h.samples) == 0
    h.observe(3.0)
    assert h.quantile(1.0) == 3.0


# ---------------------------------------------------------------------------
# registry aggregation across Transport instances
# ---------------------------------------------------------------------------

def test_counters_aggregate_across_transports(reg):
    from repro.core.comm import Transport
    t1 = Transport("fp32", path="agg.test")
    t2 = Transport("fp32", path="agg.test")
    rows = np.ones((4, 8), np.float32)
    t1.send(rows)
    t2.send(rows)
    t2.send(rows)
    total = reg.total("comm_bytes_total", path="agg.test")
    assert int(total) == t1.total_bytes + t2.total_bytes
    assert reg.value("comm_sends_total", path="agg.test",
                     codec="fp32") == 3
    assert reg.value("comm_rows_total", path="agg.test",
                     codec="fp32") == 12
    # same (name, labels) key -> the SAME metric instance
    assert reg.counter("comm_sends_total", path="agg.test",
                       codec="fp32") is t1._m_sends
    assert t1._m_sends is t2._m_sends


def test_transport_reset_keeps_registry_in_lockstep(reg):
    from repro.core.comm import Transport
    t = Transport("fp32", path="reset.test")
    t.send(np.ones((4, 8), np.float32))
    assert reg.total("comm_bytes_total", path="reset.test") > 0
    t.reset_counters()
    assert t.total_bytes == 0
    assert reg.total("comm_bytes_total", path="reset.test") == 0


def test_kind_conflict_rejected(reg):
    reg.counter("one_name", x="1")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("one_name", x="2")


def test_counter_rejects_negative(reg):
    c = reg.counter("neg_test")
    with pytest.raises(ValueError):
        c.inc(-1)


# ---------------------------------------------------------------------------
# span nesting / ordering / JSONL
# ---------------------------------------------------------------------------

def test_span_nesting_and_jsonl_roundtrip(reg, tmp_path):
    with T.span("outer", phase="a"):
        with T.span("inner1"):
            pass
        with T.span("inner2"):
            with T.span("leaf"):
                pass
    path = str(tmp_path / "trace.jsonl")
    n = reg.tracer.export_jsonl(path)
    assert n == 4
    assert T.validate_trace_jsonl(path) == 4
    evs = [json.loads(l) for l in open(path)]
    by_name = {e["name"]: e for e in evs}
    # spans close innermost-first
    assert [e["name"] for e in evs] == ["inner1", "leaf", "inner2", "outer"]
    assert [e["seq"] for e in evs] == [0, 1, 2, 3]
    assert by_name["outer"]["depth"] == 0
    assert by_name["outer"]["parent"] is None
    assert by_name["outer"]["attrs"] == {"phase": "a"}
    assert by_name["inner1"]["parent"] == "outer"
    assert by_name["inner2"]["depth"] == 1
    assert by_name["leaf"]["depth"] == 2
    assert by_name["leaf"]["parent"] == "inner2"
    # children are contained in the parent on the same clock
    assert by_name["outer"]["ts"] <= by_name["inner1"]["ts"]
    assert by_name["inner1"]["dur"] <= by_name["outer"]["dur"]


def test_span_custom_clock(reg):
    t = {"now": 100.0}

    def clk():
        return t["now"]

    with T.span("virtual", clock=clk):
        t["now"] = 103.5
    ev = reg.tracer.events[-1]
    assert ev["ts"] == 100.0
    assert ev["dur"] == pytest.approx(3.5)


# ---------------------------------------------------------------------------
# disabled registry: everything is a no-op
# ---------------------------------------------------------------------------

def test_disabled_registry_records_nothing():
    r = T.get_registry()
    prev = T.set_enabled(False)
    r.reset()
    try:
        c = T.counter("noop_c")
        g = T.gauge("noop_g")
        h = T.histogram("noop_h")
        c.inc(5)
        g.set(7)
        h.observe(1.0)
        h.observe_batch(np.ones(10))
        with T.span("noop_span"):
            pass
        assert c.value == 0
        assert g.value == 0
        assert h.count == 0 and len(h.samples) == 0
        assert r.tracer.events == []
    finally:
        r.reset()
        T.set_enabled(prev)


def test_standalone_metric_ignores_global_flag():
    prev = T.set_enabled(False)
    try:
        h = T.Histogram("standalone")   # registry=None: always on
        h.observe(2.0)
        assert h.count == 1
        assert h.quantile(0.5) == 2.0
    finally:
        T.set_enabled(prev)


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------

def test_prometheus_roundtrip(reg):
    reg.counter("bytes_total", "help text", path="x").inc(42)
    reg.gauge("depth").set(3)
    h = reg.histogram("lat_seconds", buckets=[0.1, 1.0], mode="m")
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.to_prometheus()
    parsed = T.parse_prometheus(text)
    assert parsed["bytes_total"][(("path", "x"),)] == 42
    assert parsed["depth"][()] == 3
    b = parsed["lat_seconds_bucket"]
    assert b[(("le", "0.1"), ("mode", "m"))] == 1
    assert b[(("le", "1.0"), ("mode", "m"))] == 2
    assert b[(("le", "+Inf"), ("mode", "m"))] == 3
    assert parsed["lat_seconds_count"][(("mode", "m"),)] == 3
    assert parsed["lat_seconds_sum"][(("mode", "m"),)] == pytest.approx(5.55)
    assert "# HELP bytes_total help text" in text
    assert "# TYPE lat_seconds histogram" in text


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError):
        T.parse_prometheus("no_type_line 1")
    with pytest.raises(ValueError):
        T.parse_prometheus("# TYPE x counter\nx{bad labels} 1")
    with pytest.raises(ValueError):
        T.parse_prometheus("# TYPE x counter\nx notanumber")


def test_snapshot_shape(reg):
    reg.counter("c_total", path="p").inc(3)
    h = reg.histogram("h_seconds")
    h.observe_batch([1.0, 2.0, 3.0])
    snap = reg.snapshot()
    assert snap["c_total"]["series"]["path=p"] == 3
    hs = snap["h_seconds"]["series"][""]
    assert hs["count"] == 3
    assert hs["p50"] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# the 2-device serve+train acceptance cross-check (tier-2 / obs tier)
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_telemetry_plane_cross_check_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "telemetry_check.py")],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PASS telemetry-plane" in r.stdout, r.stdout


# ---------------------------------------------------------------------------
# serving stats ride the shared histogram
# ---------------------------------------------------------------------------

def test_servestats_quantiles_use_shared_histogram():
    from repro.serving.server import ServeStats
    st = ServeStats()
    vals = [0.001 * (i + 1) for i in range(100)]
    for v in vals:
        st.latency_hist.observe(v)
    assert st.latencies_s == pytest.approx(vals)
    assert st.latency_quantile(0.5) == pytest.approx(
        float(np.percentile(vals, 50)), rel=1e-6)
    assert isinstance(st.latency_hist, T.Histogram)
