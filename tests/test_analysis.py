"""Tests for ``repro.analysis`` — the AST invariant linter.

Each rule gets at least one true-positive fixture and one clean fixture
(``tests/fixtures/analysis/``); the suppression contract, JSON output,
CLI exit codes, and the repo-wide clean gate are covered end-to-end.
The RL001 mutation test reintroduces the PR 2 double-psum bug into a
copy of ``core/propagation.py`` and asserts the linter catches it.
"""
import json
import math
import os
import shutil
import subprocess
import sys

import pytest

from repro.analysis import Finding, LintEngine, RULE_CLASSES, build_rules
from repro.analysis.rules.telemetry_drift import TelemetryCatalogRule

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
FIXTURES = os.path.join(HERE, "fixtures", "analysis")

# The fixture corpus is excluded from real runs by default; tests lint it
# on purpose, so drop that exclude (keep __pycache__).
FIXTURE_EXCLUDES = ("__pycache__",)


def lint_fixture(*names, select=None):
    engine = LintEngine(build_rules(REPO, select=select), root=REPO,
                        excludes=FIXTURE_EXCLUDES)
    return engine.run([os.path.join(FIXTURES, n) for n in names])


def rule_ids(result):
    return [f.rule for f in result.findings]


# ---------------------------------------------------------------------------
# per-rule: true positive + clean fixture
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule_id,bad,clean,min_hits", [
    ("RL001", "rl001_bad.py", "rl001_clean.py", 1),
    ("RL002", "rl002_bad.py", "rl002_clean.py", 4),
    ("RL003", "rl003_bad.py", "rl003_clean.py", 2),
    ("RL004", "rl004_bad.py", "rl004_clean.py", 4),
    ("RL004", "rl004_scalar_bad.py", "rl004_scalar_clean.py", 3),
    ("RL006", "rl006_bad.py", "rl006_clean.py", 2),
])
def test_rule_fires_on_bad_and_passes_clean(rule_id, bad, clean, min_hits):
    bad_res = lint_fixture(bad, select=[rule_id])
    hits = [f for f in bad_res.findings if f.rule == rule_id]
    assert len(hits) >= min_hits, bad_res.format_human()
    assert bad_res.exit_code == 1

    clean_res = lint_fixture(clean, select=[rule_id])
    assert [f for f in clean_res.findings if f.rule == rule_id] == [], \
        clean_res.format_human()


def test_rl002_catches_each_pinning_form():
    res = lint_fixture("rl002_bad.py", select=["RL002"])
    lines = sorted(f.line for f in res.findings)
    # backend call, environ.get call, environ subscript, transitive helper
    assert len(lines) >= 4 and len(set(lines)) >= 4, res.format_human()


def test_rl004_flags_each_shape_class():
    res = lint_fixture("rl004_bad.py", select=["RL004"])
    msgs = "\n".join(f.message for f in res.findings)
    assert "not 128-lane aligned" in msgs
    assert "not 8-sublane aligned" in msgs
    assert "last dim is 1" in msgs
    assert "exceeds" in msgs and "budget" in msgs


def test_rl004_scalar_accumulator_idiom_is_narrow():
    """The (rows, 1) VMEM exemption must not leak: BlockSpec last-dim-1,
    misaligned rows, and 3-D scratches all still fire."""
    res = lint_fixture("rl004_scalar_bad.py", select=["RL004"])
    col_hits = [f for f in res.findings if "last dim is 1" in f.message]
    assert len(col_hits) >= 2, res.format_human()
    assert any(f.message.startswith("BlockSpec") for f in col_hits)
    assert any("not 8-sublane aligned" in f.message for f in res.findings)


def _run_rl005(tree):
    root = os.path.join(FIXTURES, tree)
    rule = TelemetryCatalogRule(
        doc_path=os.path.join(root, "docs", "observability.md"))
    engine = LintEngine([rule], root=root, excludes=FIXTURE_EXCLUDES)
    return engine.run([os.path.join(root, "src")])


def test_rl005_flags_both_drift_directions():
    res = _run_rl005("rl005_bad")
    msgs = [f.message for f in res.findings]
    assert any("app_shiny_new_total" in m and "missing" in m for m in msgs)
    assert any("app_removed_total" in m and "stale" in m.lower()
               or "app_removed_total" in m and "registered" in m
               for m in msgs)
    assert res.exit_code == 1


def test_rl005_clean_catalog_passes():
    res = _run_rl005("rl005_clean")
    assert res.findings == [], res.format_human()


# ---------------------------------------------------------------------------
# suppression contract
# ---------------------------------------------------------------------------

def test_justified_suppression_silences_finding():
    res = lint_fixture("suppress_justified.py")
    assert res.findings == [], res.format_human()
    assert [f.rule for f in res.suppressed] == ["RL006"]
    assert res.exit_code == 0


def test_bare_suppression_suppresses_nothing_and_is_flagged():
    res = lint_fixture("suppress_bare.py")
    ids = rule_ids(res)
    assert "RL006" in ids          # the finding survives
    assert "RL000" in ids          # the bare disable is itself flagged
    assert res.suppressed == []
    assert res.exit_code == 1


def test_rl000_is_never_suppressible(tmp_path):
    bad = tmp_path / "m.py"
    # a bare disable with a justified wildcard disable on the same line
    # range must STILL report the RL000
    bad.write_text(
        "# repro-lint: disable=* -- blanket\n"
        "# repro-lint: disable=RL006\n"
        "x = 1\n")
    engine = LintEngine(build_rules(REPO), root=str(tmp_path),
                        excludes=FIXTURE_EXCLUDES)
    res = engine.run([str(bad)])
    assert "RL000" in rule_ids(res)


# ---------------------------------------------------------------------------
# findings model / JSON
# ---------------------------------------------------------------------------

def test_finding_json_round_trip():
    f = Finding("RL003", "a/b.py", 17, "msg with `ticks`")
    assert Finding.from_dict(json.loads(json.dumps(f.to_dict()))) == f
    assert f.format() == "a/b.py:17: error RL003 msg with `ticks`"


def _cli(args, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True)


def test_cli_json_report_and_exit_code_on_findings():
    proc = _cli(["--json", "--root", REPO,
                 os.path.join(FIXTURES, "rl006_bad.py")])
    # the fixture dir is default-excluded: single files passed explicitly
    # are still excluded, so point the CLI at a tmp-free copy instead
    assert proc.returncode == 0    # excluded -> nothing linted -> clean
    report = json.loads(proc.stdout)
    assert report["files_checked"] == 0


def test_cli_json_on_fixture_copy(tmp_path):
    dst = tmp_path / "rl006_case.py"
    shutil.copy(os.path.join(FIXTURES, "rl006_bad.py"), dst)
    proc = _cli(["--json", "--root", str(tmp_path), str(dst)])
    assert proc.returncode == 1, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    found = [Finding.from_dict(d) for d in report["findings"]]
    assert {f.rule for f in found} == {"RL006"}
    assert report["files_checked"] == 1


def test_cli_list_rules_and_bad_select():
    proc = _cli(["--list-rules"])
    assert proc.returncode == 0
    listed = {line.split()[0] for line in proc.stdout.splitlines()}
    assert listed == set(RULE_CLASSES)
    proc = _cli(["--select", "RL999", "src"])
    assert proc.returncode == 2


# ---------------------------------------------------------------------------
# repo-wide gates
# ---------------------------------------------------------------------------

def test_real_tree_is_clean():
    """The merged tree must lint clean — this is the CI gate."""
    proc = _cli(["--json", "src", "tests"])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    assert report["findings"] == []
    # the deliberate exceptions are visible, not invisible (the RL004
    # scalar-accumulator scratches are codified in the rule now, so only
    # the RL001 replicated-loss exceptions remain suppressed)
    assert len(report["suppressed"]) >= 2


def test_rl001_mutation_catches_pr2_double_psum(tmp_path):
    """Reintroduce the PR 2 bug into a copy of core/propagation.py and
    assert RL001 fires; the unmutated original must be RL001-clean."""
    src = os.path.join(REPO, "src", "repro", "core", "propagation.py")
    original = open(src, encoding="utf-8").read()
    target = "return jnp.sum((logz - gold) * lmask) / cnt"
    assert target in original, "mutation anchor moved: update this test"
    mutant_text = original.replace(
        target,
        "return jax.lax.psum(jnp.sum((logz - gold) * lmask) / cnt, AXIS)")
    mutant = tmp_path / "propagation.py"
    mutant.write_text(mutant_text)

    engine = LintEngine(build_rules(REPO, select=["RL001"]),
                        root=str(tmp_path), excludes=FIXTURE_EXCLUDES)
    res = engine.run([str(mutant)])
    hits = [f for f in res.findings if f.rule == "RL001"]
    assert hits, "linter missed the reintroduced double-psum"
    assert any("psum" in f.message for f in hits)

    clean = LintEngine(build_rules(REPO, select=["RL001"]), root=REPO,
                       excludes=FIXTURE_EXCLUDES).run([src])
    assert [f for f in clean.findings if f.rule == "RL001"] == [], \
        clean.format_human()
