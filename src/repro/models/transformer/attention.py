"""Attention variants: GQA (with KV / sliding-window ring caches) and
DeepSeek-style MLA (multi-head latent attention, absorbed-matmul decode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import layers as L

NEG_INF = L.NEG_INF


# ===========================================================================
# GQA
# ===========================================================================

def init_gqa(cfg, key, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": L.dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": L.dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": L.dense_init(ks[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


def _qkv(cfg, p, x):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    return q, k, v


def _rope_qk(cfg, q, k, positions):
    if cfg.pos_emb != "rope":
        return q, k
    q = L.apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary,
                     cfg.mrope_sections)
    k = L.apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary,
                     cfg.mrope_sections)
    return q, k


def gqa_forward(cfg, p, x, positions, *, causal=True, window=0,
                return_kv=False):
    """Full-sequence attention (train / prefill). positions: (B,S) or (3,B,S)."""
    q, k, v = _qkv(cfg, p, x)
    q, k = _rope_qk(cfg, q, k, positions)
    out = L.attention(q, k, v, causal=causal, q_offset=0, window=window,
                      q_chunk=cfg.attn_q_chunk,
                      unroll=cfg.scan_unroll > 1)
    out = out.reshape(x.shape[0], x.shape[1], -1) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def gqa_decode(cfg, p, x, cache_k, cache_v, pos, *, window=0):
    """One-token decode.  x: (B, 1, D); pos: scalar absolute position.

    cache_[kv]: (B, C, K, hd) where C = seq capacity (full) or window size
    (ring buffer).  Returns (out, cache_k, cache_v).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k, v = _qkv(cfg, p, x)
    if cfg.pos_emb == "rope":
        pos_arr = jnp.full((B, 1), pos, jnp.int32)
        if cfg.mrope_sections is not None:
            pos_arr = jnp.broadcast_to(pos_arr, (3, B, 1))
        q, k = _rope_qk(cfg, q, k, pos_arr)

    C = cache_k.shape[1]
    slot = jnp.mod(pos, C) if window else pos
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, slot, 0, 0))

    slots = jnp.arange(C)
    if window:
        # ring buffer: slot s currently holds absolute position
        # pos - ((pos - s) mod C); valid iff that position has been written.
        abs_pos = pos - jnp.mod(pos - slots, C)
        valid = abs_pos >= 0
    else:
        valid = slots <= pos

    K = cfg.num_kv_heads
    G = cfg.num_heads // K
    qg = (q * (1.0 / np.sqrt(hd))).reshape(B, 1, K, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32))
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, cache_v.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.num_heads * hd).astype(x.dtype) @ p["wo"]
    return out, cache_k, cache_v


# ===========================================================================
# MLA (DeepSeek-V3)
# ===========================================================================

def init_mla(cfg, key, dtype):
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dc, dq = cfg.kv_lora_rank, cfg.q_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq_a": L.dense_init(ks[0], cfg.d_model, dq, dtype),
        "q_norm": jnp.ones((dq,), jnp.float32),
        "wq_b": L.dense_init(ks[1], dq, H * (dn + dr), dtype),
        "wkv_a": L.dense_init(ks[2], cfg.d_model, dc + dr, dtype),
        "kv_norm": jnp.ones((dc,), jnp.float32),
        "w_k_nope": (jax.random.normal(ks[3], (dc, H, dn), jnp.float32)
                     / np.sqrt(dc)).astype(dtype),
        "w_v": (jax.random.normal(ks[4], (dc, H, dv), jnp.float32)
                / np.sqrt(dc)).astype(dtype),
        "wo": L.dense_init(ks[5], H * dv, cfg.d_model, dtype),
    }


def _mla_q(cfg, p, x, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = L.rmsnorm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg, p, x, positions):
    """Latent path: returns (c_n normalized latent (B,S,dc), k_rope (B,S,1,dr))."""
    dc, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ckr = x @ p["wkv_a"]
    c, k_rope = ckr[..., :dc], ckr[..., dc:]
    c_n = L.rmsnorm(c, p["kv_norm"])
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c_n, k_rope


def mla_forward(cfg, p, x, positions, *, window=0, return_cache=False):
    """Train / prefill: decompress latent to per-head K/V, chunked attention."""
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    c_n, k_rope = _mla_latent(cfg, p, x, positions)

    k_nope = jnp.einsum("bsc,chn->bshn", c_n, p["w_k_nope"])
    v = jnp.einsum("bsc,chv->bshv", c_n, p["w_v"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    out = L.attention(q, k, v, causal=True, q_offset=0, window=window,
                      q_chunk=cfg.attn_q_chunk,
                      unroll=cfg.scan_unroll > 1)
    out = out.reshape(B, S, H * dv) @ p["wo"]
    if return_cache:
        return out, (c_n, k_rope[:, :, 0, :])
    return out


def mla_decode(cfg, p, x, cache_c, cache_kr, pos, *, window=0):
    """Absorbed-matmul decode: attention scores/values computed in the
    dc-dim latent space (never materializes per-head K/V for the cache).

    cache_c: (B, C, dc) normalized latents; cache_kr: (B, C, dr).
    """
    B = x.shape[0]
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dc = cfg.kv_lora_rank
    pos_arr = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(cfg, p, x, pos_arr)          # (B,1,H,dn/dr)
    c_n, k_rope = _mla_latent(cfg, p, x, pos_arr)        # (B,1,dc), (B,1,1,dr)

    C = cache_c.shape[1]
    slot = jnp.mod(pos, C) if window else pos
    cache_c = jax.lax.dynamic_update_slice(
        cache_c, c_n.astype(cache_c.dtype), (0, slot, 0))
    cache_kr = jax.lax.dynamic_update_slice(
        cache_kr, k_rope[:, :, 0, :].astype(cache_kr.dtype), (0, slot, 0))

    slots = jnp.arange(C)
    if window:
        valid = (pos - jnp.mod(pos - slots, C)) >= 0
    else:
        valid = slots <= pos

    # absorb W_k_nope into the query
    q_abs = jnp.einsum("bqhn,chn->bqhc", q_nope, p["w_k_nope"])  # (B,1,H,dc)
    scores = (jnp.einsum("bqhc,bsc->bhqs", q_abs.astype(jnp.float32),
                         cache_c.astype(jnp.float32))
              + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                           cache_kr.astype(jnp.float32)))
    scores = scores / np.sqrt(dn + dr)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsc->bqhc", w, cache_c.astype(jnp.float32))
    out = jnp.einsum("bqhc,chv->bqhv", ctx.astype(x.dtype), p["w_v"])
    out = out.reshape(B, 1, H * dv) @ p["wo"]
    return out, cache_c, cache_kr
