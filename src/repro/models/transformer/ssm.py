"""Mamba2 (SSD — state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: intra-chunk attention-like
matmuls (MXU-friendly) + an inter-chunk recurrence over per-chunk states
(``lax.scan`` over S/chunk steps).  Decode is the O(1) recurrent update.

Layout follows the reference implementation:
  in_proj -> [z (d_inner), xBC (d_inner + 2*G*N), dt (H)]
  causal depthwise conv over xBC, SiLU
  SSD over x:(B,S,H,P) with B,C:(B,S,G,N), dt:(B,S,H), A:(H,)
  gated RMSNorm (y * silu(z)), out_proj
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import layers as L


def conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_ssm(cfg, key, dtype):
    """The reference packs [z|xBC|dt] into one in_proj; we keep three
    separate projections so each output dim shards cleanly on the `model`
    mesh axis (packed-slice boundaries don't align with 16-way shards —
    a TPU adaptation recorded in DESIGN.md)."""
    D = cfg.d_model
    H = cfg.ssm_nheads
    din = cfg.d_inner
    cdim = conv_dim(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_z": L.dense_init(ks[0], D, din, dtype),
        "w_xbc": L.dense_init(ks[1], D, cdim, dtype),
        "w_dt": L.dense_init(ks[2], D, H, dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.ssm_conv, cdim), jnp.float32)
                   / np.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((cdim,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((din,), jnp.float32),
        "out_proj": L.dense_init(ks[4], din, D, dtype),
    }


def _project(cfg, p, x):
    return x @ p["w_z"], x @ p["w_xbc"], x @ p["w_dt"]


def _causal_conv(cfg, xBC, conv_w, conv_b):
    """Depthwise causal conv along S.  xBC: (B, S, Cd)."""
    kw = cfg.ssm_conv
    pad = jnp.pad(xBC, ((0, 0), (kw - 1, 0), (0, 0)))
    # windows: out[:, s] = sum_i w[i] * pad[:, s + i]
    out = sum(pad[:, i:i + xBC.shape[1]] * conv_w[i] for i in range(kw))
    return jax.nn.silu(out + conv_b)


def _segsum_decay(dA_cum):
    """exp(cum_i - cum_j) masked to i >= j.  dA_cum: (..., L, H) -> (..., H, L, L).

    The exponent is masked BEFORE the exp: the i < j entries are exp(+large)
    = inf, and reverse-mode through `where` would turn the masked cotangent
    into 0 * inf = NaN (the classic masked-softmax trap)."""
    Lc = dA_cum.shape[-2]
    diff = dA_cum[..., :, None, :] - dA_cum[..., None, :, :]   # (..., i, j, H)
    diff = jnp.moveaxis(diff, -1, -3)                          # (..., H, i, j)
    tril = jnp.tril(jnp.ones((Lc, Lc), bool))
    diff = jnp.where(tril, diff, -jnp.inf)
    return jnp.exp(diff)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None,
                return_final_state=False):
    """SSD scan.  x: (B,S,H,P); dt: (B,S,H); A: (H,) (negative);
    Bm, Cm: (B,S,G,N).  Returns y: (B,S,H,P) [, final_state (B,H,P,N)].
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Lc = min(chunk, S)
    nc = S // Lc
    assert nc * Lc == S, "seq len must be divisible by chunk"

    xc = x.reshape(Bsz, nc, Lc, H, P)
    dtc = dt.reshape(Bsz, nc, Lc, H)
    Bc = Bm.reshape(Bsz, nc, Lc, G, N)
    Cc = Cm.reshape(Bsz, nc, Lc, G, N)

    xdt = xc * dtc[..., None]                              # dt folded into x
    dA = dtc * A                                           # (B,nc,L,H)
    dA_cum = jnp.cumsum(dA, axis=2)

    # --- intra-chunk (quadratic within chunk, matmul-rich) ---
    CB = jnp.einsum("bmign,bmjgn->bmgij", Cc, Bc)          # (B,nc,G,L,L)
    Mdecay = _segsum_decay(dA_cum)                         # (B,nc,H,L,L)
    CB = jnp.repeat(CB, rep, axis=2)                       # G -> H
    scores = CB * Mdecay
    y_intra = jnp.einsum("bmhij,bmjhp->bmihp", scores, xdt)

    # --- per-chunk states ---
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (B,nc,L,H)
    Bh = jnp.repeat(Bc, rep, axis=3)                       # (B,nc,L,H,N)
    states = jnp.einsum("bmlhn,bmlh,bmlhp->bmhpn",
                        Bh, decay_to_end, xdt)             # (B,nc,H,P,N)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])             # (B,nc,H)
    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s, inp):
        st, dec = inp
        prev = s
        s = dec[:, :, None, None] * s + st.astype(jnp.float32)
        return s, prev

    final, prev_states = jax.lax.scan(
        step, s0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)               # (B,nc,H,P,N)

    Ch = jnp.repeat(Cc, rep, axis=3)                       # (B,nc,L,H,N)
    y_inter = jnp.einsum("bmlhn,bmhpn,bmlh->bmlhp",
                         Ch, prev_states.astype(x.dtype),
                         jnp.exp(dA_cum).astype(x.dtype))
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    if return_final_state:
        return y, final
    return y


def ssm_forward(cfg, p, x, *, return_cache=False):
    """Full-sequence Mamba2 block.  x: (B, S, D)."""
    B, S, D = x.shape
    H, P = cfg.ssm_nheads, cfg.ssm_head_dim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    din = cfg.d_inner

    z, xBC_raw, dt = _project(cfg, p, x)
    xBC = _causal_conv(cfg, xBC_raw, p["conv_w"], p["conv_b"])
    xs = xBC[..., :din].reshape(B, S, H, P)
    Bm = xBC[..., din:din + G * N].reshape(B, S, G, N)
    Cm = xBC[..., din + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    out = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk,
                      return_final_state=return_cache)
    if return_cache:
        y, final_state = out
    else:
        y = out
    y = y.astype(x.dtype) + xs * p["D"][:, None].astype(x.dtype)
    y = y.reshape(B, S, din)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm"])
    y_out = (y @ p["out_proj"]).astype(x.dtype)
    if return_cache:
        # conv cache holds the *pre-activation* last kw-1 raw inputs
        conv_state = xBC_raw[:, -(cfg.ssm_conv - 1):, :]
        return y_out, (final_state, conv_state)
    return y_out


def ssm_decode(cfg, p, x, ssm_state, conv_state):
    """One-token recurrent update.

    x: (B, 1, D); ssm_state: (B, H, P, N) fp32; conv_state: (B, kw-1, Cd).
    """
    B = x.shape[0]
    H, P = cfg.ssm_nheads, cfg.ssm_head_dim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    din = cfg.d_inner
    kw = cfg.ssm_conv

    z, xBC_new, dt = _project(cfg, p, x)
    window = jnp.concatenate([conv_state, xBC_new], axis=1)   # (B, kw, Cd)
    conv_state = window[:, 1:, :]
    xBC = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(xBC)

    xs = xBC[:, :din].reshape(B, H, P)
    Bm = xBC[:, din:din + G * N].reshape(B, G, N)
    Cm = xBC[:, din + G * N:].reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                          # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                      # (B,H)

    ssm_state = (dA[:, :, None, None] * ssm_state
                 + jnp.einsum("bh,bhp,bhn->bhpn", dt,
                              xs.astype(jnp.float32),
                              Bh.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bhn->bhp", ssm_state, Ch.astype(jnp.float32))
    y = y.astype(x.dtype) + xs * p["D"][:, None].astype(x.dtype)
    y = y.reshape(B, 1, din)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm"])
    return (y @ p["out_proj"]).astype(x.dtype), ssm_state, conv_state
