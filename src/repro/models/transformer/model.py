"""Unified model zoo: init / forward / prefill / decode for all six
architecture families.  Everything is functional; layer stacks carry a
leading ``num_layers`` axis and are consumed with ``jax.lax.scan``.

Batch conventions
-----------------
train / prefill:
  lm families:  {"tokens": (B,S) i32, "labels": (B,S) i32}
  vlm:          {"embeds": (B,S,D), "positions": (3,B,S) i32, "labels": (B,S)}
  encdec:       {"enc_embeds": (B,S,D), "tokens": (B,S), "labels": (B,S)}
decode:
  {"token": (B,1) i32  (or "embeds": (B,1,D) for vlm), "pos": () i32}
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import attention as A
from repro.models.transformer import layers as L
from repro.models.transformer import moe as M
from repro.models.transformer import ssm as S
from repro.launch import sharding as shd


# ===========================================================================
# init
# ===========================================================================

def _init_dense_layer(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    return {"attn": A.init_gqa(cfg, ks[0], dtype),
            "mlp": L.init_mlp(cfg, ks[1], cfg.d_model, cfg.d_ff, dtype),
            "ln1": L.init_norm(cfg, cfg.d_model),
            "ln2": L.init_norm(cfg, cfg.d_model)}


def _init_moe_layer(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    return {"attn": A.init_gqa(cfg, ks[0], dtype),
            "moe": M.init_moe(cfg, ks[1], dtype),
            "ln1": L.init_norm(cfg, cfg.d_model),
            "ln2": L.init_norm(cfg, cfg.d_model)}


def _init_mla_dense_layer(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    return {"attn": A.init_mla(cfg, ks[0], dtype),
            "mlp": L.init_mlp(cfg, ks[1], cfg.d_model, cfg.d_ff, dtype),
            "ln1": L.init_norm(cfg, cfg.d_model),
            "ln2": L.init_norm(cfg, cfg.d_model)}


def _init_mla_moe_layer(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    return {"attn": A.init_mla(cfg, ks[0], dtype),
            "moe": M.init_moe(cfg, ks[1], dtype),
            "ln1": L.init_norm(cfg, cfg.d_model),
            "ln2": L.init_norm(cfg, cfg.d_model)}


def _init_ssm_layer(cfg, key, dtype):
    return {"ssm": S.init_ssm(cfg, key, dtype),
            "ln": L.init_norm(cfg, cfg.d_model)}


def _init_encdec_layer(cfg, key, dtype, cross: bool):
    ks = jax.random.split(key, 3)
    p = {"attn": A.init_gqa(cfg, ks[0], dtype),
         "mlp": L.init_mlp(cfg, ks[1], cfg.d_model, cfg.d_ff, dtype),
         "ln1": L.init_norm(cfg, cfg.d_model),
         "ln2": L.init_norm(cfg, cfg.d_model)}
    if cross:
        p["xattn"] = A.init_gqa(cfg, ks[2], dtype)
        p["ln_x"] = L.init_norm(cfg, cfg.d_model)
    return p


def init_params(cfg, key, *, max_seq: int = 4096) -> Dict[str, Any]:
    dtype = L.dtype_of(cfg.param_dtype)
    k_embed, k_layers, k_extra = jax.random.split(key, 3)
    params: Dict[str, Any] = {"embed": L.init_embed(cfg, k_embed, dtype),
                              "ln_f": L.init_norm(cfg, cfg.d_model)}

    def stack(n, fn, key):
        return L.stacked(jax.random.split(key, n), lambda k: fn(cfg, k, dtype))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = stack(cfg.num_layers, _init_dense_layer, k_layers)
    elif fam == "moe":
        params["layers"] = stack(cfg.num_layers, _init_moe_layer, k_layers)
    elif fam == "mla_moe":
        nd = cfg.first_dense_layers
        params["dense_layers"] = stack(nd, _init_mla_dense_layer, k_layers)
        params["moe_layers"] = stack(cfg.num_layers - nd, _init_mla_moe_layer,
                                     jax.random.fold_in(k_layers, 1))
    elif fam == "ssm":
        params["layers"] = stack(cfg.num_layers, _init_ssm_layer, k_layers)
    elif fam == "hybrid":
        params["layers"] = stack(cfg.num_layers, _init_ssm_layer, k_layers)
        params["shared_attn"] = _init_dense_layer(cfg, k_extra, dtype)
    elif fam == "encdec":
        params["enc_layers"] = stack(
            cfg.encoder_layers,
            lambda c, k, d: _init_encdec_layer(c, k, d, cross=False), k_layers)
        params["dec_layers"] = stack(
            cfg.num_layers,
            lambda c, k, d: _init_encdec_layer(c, k, d, cross=True),
            jax.random.fold_in(k_layers, 2))
        params["ln_enc"] = L.init_norm(cfg, cfg.d_model)
        params["enc_pos"] = (jax.random.normal(
            jax.random.fold_in(k_extra, 0), (max_seq, cfg.d_model),
            jnp.float32) * 0.02).astype(dtype)
        params["dec_pos"] = (jax.random.normal(
            jax.random.fold_in(k_extra, 1), (max_seq, cfg.d_model),
            jnp.float32) * 0.02).astype(dtype)
    else:
        raise ValueError(fam)
    return params


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))


# ===========================================================================
# layer bodies (shared by forward and decode scans)
# ===========================================================================

def _dense_body(cfg, x, p, positions, *, window=0, causal=True):
    h = L.apply_norm(cfg, x, p["ln1"])
    x = x + A.gqa_forward(cfg, p["attn"], h, positions, causal=causal,
                          window=window)
    h = L.apply_norm(cfg, x, p["ln2"])
    x = x + L.mlp(cfg, h, p["mlp"])
    return shd.constrain(x, "act")


def _moe_body(cfg, x, p, positions, *, window=0):
    h = L.apply_norm(cfg, x, p["ln1"])
    x = x + A.gqa_forward(cfg, p["attn"], h, positions, window=window)
    h = L.apply_norm(cfg, x, p["ln2"])
    x = x + _moe(cfg, p["moe"], h)
    return shd.constrain(x, "act")


def _mla_body(cfg, x, p, positions, *, window=0, use_moe=True):
    h = L.apply_norm(cfg, x, p["ln1"])
    x = x + A.mla_forward(cfg, p["attn"], h, positions, window=window)
    h = L.apply_norm(cfg, x, p["ln2"])
    if use_moe:
        x = x + _moe(cfg, p["moe"], h)
    else:
        x = x + L.mlp(cfg, h, p["mlp"])
    return shd.constrain(x, "act")


def _ssm_body(cfg, x, p):
    h = L.apply_norm(cfg, x, p["ln"])
    x = x + S.ssm_forward(cfg, p["ssm"], h)
    return shd.constrain(x, "act")


def _moe(cfg, p, x):
    """MoE implementation dispatch: GShard one-hot dispatch (baseline) or
    explicit shard_map expert parallelism (cfg.moe_impl == "ep")."""
    if cfg.moe_impl == "ep":
        from repro.core.parallel import moe_expert_parallel
        return moe_expert_parallel(cfg, p, x,
                                   capacity_factor=cfg.moe_capacity_factor)
    return M.moe_block(cfg, p, x)


def _scan(cfg, f, init, xs):
    """lax.scan that fully unrolls when cfg.scan_unroll > 1 (dry-run cost
    extrapolation needs every body instance visible to HLO cost analysis)."""
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    return jax.lax.scan(f, init, xs, unroll=n if cfg.scan_unroll > 1 else 1)


def _scan_layers(cfg, body, x, stacked_params, *, remat=False):
    fn = jax.checkpoint(body) if remat else body

    def step(carry, p):
        return fn(carry, p), None

    x, _ = _scan(cfg, step, x, stacked_params)
    return x


# ===========================================================================
# forward (train / scoring path; no cache)
# ===========================================================================

def forward(cfg, params, batch, *, remat=False, window=0):
    fam = cfg.family
    if fam == "vlm":
        x = batch["embeds"].astype(L.dtype_of(cfg.compute_dtype))
        positions = batch["positions"]
    elif fam == "encdec":
        return _encdec_forward(cfg, params, batch, remat=remat)
    else:
        x = L.embed(cfg, params["embed"], batch["tokens"])
        B, Ssz = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(Ssz)[None], (B, Ssz))
    x = shd.constrain(x, "act")

    if fam in ("dense", "vlm"):
        body = lambda h, p: _dense_body(cfg, h, p, positions, window=window)
        x = _scan_layers(cfg, body, x, params["layers"], remat=remat)
    elif fam == "moe":
        body = lambda h, p: _moe_body(cfg, h, p, positions, window=window)
        x = _scan_layers(cfg, body, x, params["layers"], remat=remat)
    elif fam == "mla_moe":
        body_d = lambda h, p: _mla_body(cfg, h, p, positions, window=window,
                                        use_moe=False)
        body_m = lambda h, p: _mla_body(cfg, h, p, positions, window=window,
                                        use_moe=True)
        x = _scan_layers(cfg, body_d, x, params["dense_layers"], remat=remat)
        x = _scan_layers(cfg, body_m, x, params["moe_layers"], remat=remat)
    elif fam == "ssm":
        body = lambda h, p: _ssm_body(cfg, h, p)
        x = _scan_layers(cfg, body, x, params["layers"], remat=remat)
    elif fam == "hybrid":
        x = _hybrid_forward(cfg, params, x, positions, remat=remat,
                            window=window)
    else:
        raise ValueError(fam)

    x = L.apply_norm(cfg, x, params["ln_f"])
    logits = L.unembed(cfg, params["embed"], x)
    return shd.constrain(logits, "logits")


def _hybrid_groups(cfg):
    n_groups = cfg.num_layers // cfg.attn_every
    return n_groups, cfg.attn_every


def _hybrid_forward(cfg, params, x, positions, *, remat=False, window=0):
    """Zamba2: groups of `attn_every` mamba layers, shared attn block between."""
    n_groups, per = _hybrid_groups(cfg)
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, per) + a.shape[1:]), params["layers"])
    shared = params["shared_attn"]

    def group_body(h, p_group):
        h = _scan_layers(cfg, lambda hh, p: _ssm_body(cfg, hh, p), h,
                         p_group, remat=remat)
        h = _dense_body(cfg, h, shared, positions, window=window)
        return h, None

    x, _ = _scan(cfg, group_body, x, grouped)
    return x


def _encdec_forward(cfg, params, batch, *, remat=False):
    dt = L.dtype_of(cfg.compute_dtype)
    enc = batch["enc_embeds"].astype(dt)
    Se = enc.shape[1]
    enc = enc + params["enc_pos"][:Se].astype(dt)
    B = enc.shape[0]
    pos_e = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))

    enc_body = lambda h, p: _dense_body(cfg, h, p, pos_e, causal=False)
    enc = _scan_layers(cfg, enc_body, enc, params["enc_layers"], remat=remat)
    enc = L.apply_norm(cfg, enc, params["ln_enc"])

    tok = batch["tokens"]
    Sd = tok.shape[1]
    x = L.embed(cfg, params["embed"], tok) + params["dec_pos"][:Sd].astype(dt)
    pos_d = jnp.broadcast_to(jnp.arange(Sd)[None], (B, Sd))

    def dec_body(h, p):
        hh = L.apply_norm(cfg, h, p["ln1"])
        h = h + A.gqa_forward(cfg, p["attn"], hh, pos_d, causal=True)
        hh = L.apply_norm(cfg, h, p["ln_x"])
        # cross attention: q from decoder, kv from encoder output
        q, _, _ = A._qkv(cfg, p["xattn"], hh)
        _, k, v = A._qkv(cfg, p["xattn"], enc)
        o = L.attention(q, k, v, causal=False, q_offset=0)
        h = h + o.reshape(h.shape[0], h.shape[1], -1) @ p["xattn"]["wo"]
        hh = L.apply_norm(cfg, h, p["ln2"])
        h = h + L.mlp(cfg, hh, p["mlp"])
        return shd.constrain(h, "act")

    x = _scan_layers(cfg, dec_body, x, params["dec_layers"], remat=remat)
    x = L.apply_norm(cfg, x, params["ln_f"])
    return L.unembed(cfg, params["embed"], x)


# ===========================================================================
# loss / train step
# ===========================================================================

def loss_fn(cfg, params, batch, *, remat=True):
    logits = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    return jnp.mean(nll)


def make_train_step(cfg, optimizer, *, remat=True, donate=True):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat=remat))(params)
        params, opt_state = optimizer.apply(params, grads, opt_state)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


# ===========================================================================
# caches
# ===========================================================================

def init_cache(cfg, batch_size: int, cache_len: int, *, enc_len: int = 0):
    """Zero-initialized cache pytree for decode.  ``cfg.cache_dtype``
    (e.g. float8_e4m3fn) selects a narrower storage dtype — decode writes
    cast on store and reads upcast to fp32 (see attention.py)."""
    dt = L.cache_dtype_of(cfg)
    LN = cfg.num_layers
    hd = cfg.resolved_head_dim
    K = cfg.num_kv_heads
    fam = cfg.family
    C = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len

    def kv(n_layers, length):
        return {"k": jnp.zeros((n_layers, batch_size, length, K, hd), dt),
                "v": jnp.zeros((n_layers, batch_size, length, K, hd), dt)}

    if fam in ("dense", "vlm", "moe"):
        return kv(LN, C)
    if fam == "mla_moe":
        def lat(n):
            return {"c": jnp.zeros((n, batch_size, C, cfg.kv_lora_rank), dt),
                    "kr": jnp.zeros((n, batch_size, C, cfg.qk_rope_head_dim),
                                    dt)}
        return {"dense": lat(cfg.first_dense_layers),
                "moe": lat(LN - cfg.first_dense_layers)}
    if fam == "ssm":
        return _ssm_cache(cfg, LN, batch_size)
    if fam == "hybrid":
        n_groups, _ = _hybrid_groups(cfg)
        return {"ssm": _ssm_cache(cfg, LN, batch_size),
                "attn": kv(n_groups, C)}
    if fam == "encdec":
        return {"self": kv(LN, C), "cross": kv(LN, enc_len)}
    raise ValueError(fam)


def _ssm_cache(cfg, n_layers, batch_size):
    return {
        "state": jnp.zeros((n_layers, batch_size, cfg.ssm_nheads,
                            cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((n_layers, batch_size, cfg.ssm_conv - 1,
                           S.conv_dim(cfg)),
                          L.dtype_of(cfg.compute_dtype)),
    }


# ===========================================================================
# decode step (one token, KV/state cache)
# ===========================================================================

def decode_step(cfg, params, cache, batch):
    """batch: {"token": (B,1)} or {"embeds": (B,1,D)} plus {"pos": ()}.
    Returns (logits (B, vocab), new_cache)."""
    fam = cfg.family
    pos = batch["pos"]
    W = cfg.sliding_window

    if fam == "vlm":
        x = batch["embeds"].astype(L.dtype_of(cfg.compute_dtype))
    else:
        x = L.embed(cfg, params["embed"], batch["token"])
    x = shd.constrain(x, "act")

    def attn_scan(x, stacked_p, kv_cache, body):
        def step(carry, inp):
            p, ck, cv = inp
            h, ck, cv = body(carry, p, ck, cv)
            return h, (ck, cv)

        x, (ks, vs) = _scan(cfg, step, x, (stacked_p, kv_cache["k"],
                                            kv_cache["v"]))
        return x, {"k": ks, "v": vs}

    if fam in ("dense", "vlm", "moe"):
        def body(h, p, ck, cv):
            hh = L.apply_norm(cfg, h, p["ln1"])
            o, ck, cv = A.gqa_decode(cfg, p["attn"], hh, ck, cv, pos, window=W)
            h = h + o
            hh = L.apply_norm(cfg, h, p["ln2"])
            if fam == "moe":
                h = h + _moe(cfg, p["moe"], hh)
            else:
                h = h + L.mlp(cfg, hh, p["mlp"])
            return h, ck, cv

        x, cache = attn_scan(x, params["layers"], cache, body)

    elif fam == "mla_moe":
        def make_body(use_moe):
            def body(carry, inp):
                p, cc, ckr = inp
                h = carry
                hh = L.apply_norm(cfg, h, p["ln1"])
                o, cc, ckr = A.mla_decode(cfg, p["attn"], hh, cc, ckr, pos,
                                          window=W)
                h = h + o
                hh = L.apply_norm(cfg, h, p["ln2"])
                if use_moe:
                    h = h + _moe(cfg, p["moe"], hh)
                else:
                    h = h + L.mlp(cfg, hh, p["mlp"])
                return h, (cc, ckr)
            return body

        x, (cs_d, krs_d) = _scan(
            cfg, make_body(False), x,
            (params["dense_layers"], cache["dense"]["c"],
             cache["dense"]["kr"]))
        x, (cs_m, krs_m) = _scan(
            cfg, make_body(True), x,
            (params["moe_layers"], cache["moe"]["c"], cache["moe"]["kr"]))
        cache = {"dense": {"c": cs_d, "kr": krs_d},
                 "moe": {"c": cs_m, "kr": krs_m}}

    elif fam == "ssm":
        x, cache = _ssm_decode_scan(cfg, params["layers"], cache, x)

    elif fam == "hybrid":
        n_groups, per = _hybrid_groups(cfg)
        grouped_p = jax.tree.map(
            lambda a: a.reshape((n_groups, per) + a.shape[1:]),
            params["layers"])
        grouped_c = jax.tree.map(
            lambda a: a.reshape((n_groups, per) + a.shape[1:]), cache["ssm"])
        shared = params["shared_attn"]

        def group_body(h, inp):
            p_g, c_g, ck, cv = inp
            h, c_g = _ssm_decode_scan(cfg, p_g, c_g, h)
            hh = L.apply_norm(cfg, h, shared["ln1"])
            o, ck, cv = A.gqa_decode(cfg, shared["attn"], hh, ck, cv, pos,
                                     window=W)
            h = h + o
            hh = L.apply_norm(cfg, h, shared["ln2"])
            h = h + L.mlp(cfg, hh, shared["mlp"])
            return h, (c_g, ck, cv)

        x, (c_new, ks, vs) = _scan(
            cfg, group_body, x,
            (grouped_p, grouped_c, cache["attn"]["k"], cache["attn"]["v"]))
        cache = {"ssm": jax.tree.map(
            lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), c_new),
            "attn": {"k": ks, "v": vs}}

    elif fam == "encdec":
        x = x + params["dec_pos"][pos].astype(x.dtype)  # learned positions

        def body(h, inp):
            p, ck, cv, xk, xv = inp
            hh = L.apply_norm(cfg, h, p["ln1"])
            o, ck, cv = A.gqa_decode(cfg, p["attn"], hh, ck, cv, pos, window=W)
            h = h + o
            hh = L.apply_norm(cfg, h, p["ln_x"])
            q, _, _ = A._qkv(cfg, p["xattn"], hh)
            o = L.attention(q, xk, xv, causal=False, q_offset=0)
            h = h + o.reshape(h.shape[0], 1, -1) @ p["xattn"]["wo"]
            hh = L.apply_norm(cfg, h, p["ln2"])
            h = h + L.mlp(cfg, hh, p["mlp"])
            return h, (ck, cv)

        x, (ks, vs) = _scan(
            cfg, body, x, (params["dec_layers"], cache["self"]["k"],
                      cache["self"]["v"], cache["cross"]["k"],
                      cache["cross"]["v"]))
        cache = {"self": {"k": ks, "v": vs}, "cross": cache["cross"]}
    else:
        raise ValueError(fam)

    x = L.apply_norm(cfg, x, params["ln_f"])
    logits = L.unembed(cfg, params["embed"], x)[:, 0]
    return logits, cache


def _ssm_decode_scan(cfg, stacked_p, cache, x):
    def body(h, inp):
        p, st, cv = inp
        hh = L.apply_norm(cfg, h, p["ln"])
        o, st, cv = S.ssm_decode(cfg, p["ssm"], hh, st, cv)
        return h + o, (st, cv)

    x, (sts, cvs) = _scan(cfg, body, x,
                          (stacked_p, cache["state"], cache["conv"]))
    return x, {"state": sts, "conv": cvs}


# ===========================================================================
# prefill (forward + cache construction)
# ===========================================================================

def prefill(cfg, params, batch):
    """Processes a full prompt and returns (last-token logits, cache).

    For the dry-run ``prefill_32k`` shape this is the lowered entry point.
    Sliding-window configs keep a ring cache of the last `window` positions.
    """
    fam = cfg.family
    if fam == "vlm":
        x = batch["embeds"].astype(L.dtype_of(cfg.compute_dtype))
        positions = batch["positions"]
        B, Ssz = x.shape[0], x.shape[1]
    elif fam == "encdec":
        return _encdec_prefill(cfg, params, batch)
    else:
        B, Ssz = batch["tokens"].shape
        x = L.embed(cfg, params["embed"], batch["tokens"])
        positions = jnp.broadcast_to(jnp.arange(Ssz)[None], (B, Ssz))
    x = shd.constrain(x, "act")
    W = cfg.sliding_window
    C = min(Ssz, W) if W else Ssz

    cache_dt = L.cache_dtype_of(cfg)

    def to_ring(k):
        # keep the last C positions; ring slot of position p is p % C
        tail = k[:, Ssz - C:].astype(cache_dt)
        roll = (Ssz - C) % C if C else 0
        return jnp.roll(tail, shift=roll, axis=1)

    if fam in ("dense", "vlm", "moe"):
        def body(h, p):
            hh = L.apply_norm(cfg, h, p["ln1"])
            o, (k, v) = A.gqa_forward(cfg, p["attn"], hh, positions, window=W,
                                      return_kv=True)
            h = h + o
            hh = L.apply_norm(cfg, h, p["ln2"])
            if fam == "moe":
                h = h + _moe(cfg, p["moe"], hh)
            else:
                h = h + L.mlp(cfg, hh, p["mlp"])
            return h, (to_ring(k), to_ring(v))

        def step(carry, p):
            h, kv = body(carry, p)
            return h, kv

        x, (ks, vs) = _scan(cfg, step, x, params["layers"])
        cache = {"k": ks, "v": vs}

    elif fam == "mla_moe":
        def make_body(use_moe):
            def body(h, p):
                hh = L.apply_norm(cfg, h, p["ln1"])
                o, (c_n, kr) = A.mla_forward(cfg, p["attn"], hh, positions,
                                             window=W, return_cache=True)
                h = h + o
                hh = L.apply_norm(cfg, h, p["ln2"])
                if use_moe:
                    h = h + _moe(cfg, p["moe"], hh)
                else:
                    h = h + L.mlp(cfg, hh, p["mlp"])
                return h, (to_ring(c_n), to_ring(kr))
            return body

        x, (cs, krs) = _scan(cfg, lambda c, p: make_body(False)(c, p), x,
                             params["dense_layers"])
        cache_d = {"c": cs, "kr": krs}
        x, (cs, krs) = _scan(cfg, lambda c, p: make_body(True)(c, p), x,
                             params["moe_layers"])
        cache = {"dense": cache_d, "moe": {"c": cs, "kr": krs}}

    elif fam == "ssm":
        def body(h, p):
            hh = L.apply_norm(cfg, h, p["ln"])
            o, (st, cv) = S.ssm_forward(cfg, p["ssm"], hh, return_cache=True)
            return h + o, (st, cv)

        x, (sts, cvs) = _scan(cfg, body, x, params["layers"])
        cache = {"state": sts, "conv": cvs}

    elif fam == "hybrid":
        n_groups, per = _hybrid_groups(cfg)
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, per) + a.shape[1:]),
            params["layers"])
        shared = params["shared_attn"]

        def ssm_body(h, p):
            hh = L.apply_norm(cfg, h, p["ln"])
            o, (st, cv) = S.ssm_forward(cfg, p["ssm"], hh, return_cache=True)
            return h + o, (st, cv)

        def group_body(h, p_g):
            h, ssm_c = _scan(cfg, ssm_body, h, p_g)
            hh = L.apply_norm(cfg, h, shared["ln1"])
            o, (k, v) = A.gqa_forward(cfg, shared["attn"], hh, positions,
                                      window=W, return_kv=True)
            h = h + o
            hh = L.apply_norm(cfg, h, shared["ln2"])
            h = h + L.mlp(cfg, hh, shared["mlp"])
            return h, (ssm_c, to_ring(k), to_ring(v))

        x, (ssm_c, ks, vs) = _scan(cfg, group_body, x, grouped)
        sts, cvs = ssm_c  # inner scan stacks (state, conv) as a tuple
        merge = lambda a: a.reshape((cfg.num_layers,) + a.shape[2:])
        cache = {"ssm": {"state": merge(sts), "conv": merge(cvs)},
                 "attn": {"k": ks, "v": vs}}
    else:
        raise ValueError(fam)

    x = L.apply_norm(cfg, x, params["ln_f"])
    logits = L.unembed(cfg, params["embed"], x[:, -1:])[:, 0]
    return logits, cache


def _encdec_prefill(cfg, params, batch):
    dt = L.dtype_of(cfg.compute_dtype)
    enc = batch["enc_embeds"].astype(dt)
    B, Se = enc.shape[0], enc.shape[1]
    enc = enc + params["enc_pos"][:Se].astype(dt)
    pos_e = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    enc_body = lambda h, p: _dense_body(cfg, h, p, pos_e, causal=False)
    enc = _scan_layers(cfg, enc_body, enc, params["enc_layers"])
    enc = L.apply_norm(cfg, enc, params["ln_enc"])

    tok = batch["tokens"]
    Sd = tok.shape[1]
    x = L.embed(cfg, params["embed"], tok) + params["dec_pos"][:Sd].astype(dt)
    pos_d = jnp.broadcast_to(jnp.arange(Sd)[None], (B, Sd))

    def dec_body(h, p):
        hh = L.apply_norm(cfg, h, p["ln1"])
        o, (k, v) = A.gqa_forward(cfg, p["attn"], hh, pos_d, causal=True,
                                  return_kv=True)
        h = h + o
        hh = L.apply_norm(cfg, h, p["ln_x"])
        q, _, _ = A._qkv(cfg, p["xattn"], hh)
        _, xk, xv = A._qkv(cfg, p["xattn"], enc)
        o = L.attention(q, xk, xv, causal=False, q_offset=0)
        h = h + o.reshape(B, Sd, -1) @ p["xattn"]["wo"]
        hh = L.apply_norm(cfg, h, p["ln2"])
        h = h + L.mlp(cfg, hh, p["mlp"])
        return h, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = _scan(cfg, dec_body, x, params["dec_layers"])
    x = L.apply_norm(cfg, x, params["ln_f"])
    logits = L.unembed(cfg, params["embed"], x[:, -1:])[:, 0]
    cdt = L.cache_dtype_of(cfg)
    return logits, {"self": {"k": ks.astype(cdt), "v": vs.astype(cdt)},
                    "cross": {"k": xks.astype(cdt), "v": xvs.astype(cdt)}}
