"""Shared transformer building blocks (pure JAX, functional).

Params are plain nested dicts of jnp arrays. Layer stacks keep a leading
``num_layers`` axis and are consumed with ``jax.lax.scan`` so the lowered
HLO is O(1) in depth (essential for the 80 dry-run compiles).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16,
            "float8_e4m3fn": jnp.float8_e4m3fn}[name]


def cache_dtype_of(cfg):
    return dtype_of(cfg.cache_dtype or cfg.compute_dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
            ).astype(dtype)


def stacked(keys, fn):
    """vmap an init function over a leading layer axis of keys."""
    return jax.vmap(fn)(keys)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(cfg, x, p):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def init_norm(cfg, dim: int):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((dim,), jnp.float32),
                "bias": jnp.zeros((dim,), jnp.float32)}
    return {"scale": jnp.ones((dim,), jnp.float32)}


# ---------------------------------------------------------------------------
# rotary embeddings (standard / partial / M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rotary_frac: float = 1.0,
               mrope_sections=None) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (3, B, S) for M-RoPE."""
    hd = x.shape[-1]
    rot = int(hd * rotary_frac)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    freqs = jnp.asarray(rope_freqs(rot, theta))            # (rot/2,)

    if mrope_sections is not None:
        # qwen2-vl M-RoPE: frequency bands split into (t, h, w) sections,
        # each using its own position stream.  positions: (3, B, S)
        sec = np.cumsum(np.array(mrope_sections))[:-1]
        pos_per_band = jnp.concatenate(
            [jnp.broadcast_to(positions[i][..., None],
                              positions.shape[1:] + (n,))
             for i, n in enumerate(mrope_sections)], axis=-1)  # (B,S,rot/2)
        del sec
        angles = pos_per_band.astype(jnp.float32) * freqs      # (B,S,rot/2)
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,rot/2)

    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg, key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], d_model, d_ff, dtype),
         "w_out": dense_init(ks[1], d_ff, d_model, dtype)}
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(cfg, x: jax.Array, p) -> jax.Array:
    act = jax.nn.silu if cfg.act == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    h = x @ p["w_in"]
    if cfg.mlp_gated:
        h = act(x @ p["w_gate"]) * h
    else:
        h = act(h)
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# chunked (flash-style) attention — pure JAX oracle-grade implementation
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attend_block(q, k, v, bias):
    """q:(B,Sq,K,G,hd) k:(B,Skv,K,hd) v:(B,Skv,K,hd) bias:(B?,Sq,Skv)->out."""
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    logits = logits + bias[:, None, None, :, :]
    return logits


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool,
              q_offset,
              window: int = 0,
              kv_valid_len=None,
              q_chunk: int = 1024,
              unroll: bool = False,
              out_dtype=None) -> jax.Array:
    """Grouped-query attention with online-softmax chunking over queries.

    q: (B, Sq, H, hd);  k, v: (B, Skv, K, hd) with H = K * G.
    ``q_offset``: absolute position of q[0] (int or traced scalar) so that
    causal/sliding-window masks work for prefill and decode alike.
    ``window`` > 0 enables sliding-window masking |i-j| < window.
    ``kv_valid_len``: mask out kv positions >= this (ragged caches).
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    hd_v = v.shape[-1]
    out_dtype = out_dtype or q.dtype
    scale = 1.0 / np.sqrt(hd)
    qg = (q * scale).reshape(B, Sq, K, G, hd)
    kv_pos = jnp.arange(k.shape[1])

    def block(q_blk, q_pos):
        # q_blk: (B, c, K, G, hd); q_pos: (c,) absolute positions
        logits = jnp.einsum("bqkgh,bskh->bkgqs", q_blk.astype(jnp.float32),
                            k.astype(jnp.float32))
        mask = jnp.ones((q_blk.shape[1], k.shape[1]), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        if kv_valid_len is not None:
            mask &= (kv_pos < kv_valid_len)[None, :]
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))

    if Sq <= q_chunk:
        out = block(qg, q_offset + jnp.arange(Sq))
    else:
        nblk = -(-Sq // q_chunk)
        pad = nblk * q_chunk - Sq
        qg_p = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        qg_p = qg_p.reshape(B, nblk, q_chunk, K, G, hd).swapaxes(0, 1)
        pos = (q_offset + jnp.arange(nblk * q_chunk)).reshape(nblk, q_chunk)

        def body(_, inp):
            qb, pb = inp
            return None, block(qb, pb)

        _, outs = jax.lax.scan(body, None, (qg_p, pos),
                               unroll=nblk if unroll else 1)
        out = outs.swapaxes(0, 1).reshape(B, nblk * q_chunk, K, G, hd_v)[:, :Sq]

    return out.reshape(B, Sq, H, hd_v).astype(out_dtype)


# ---------------------------------------------------------------------------
# token embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(cfg, key, dtype):
    V = cfg.padded_vocab
    p = {"embedding": (jax.random.normal(key, (V, cfg.d_model), jnp.float32)
                       * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(jax.random.fold_in(key, 1), cfg.d_model, V,
                                  dtype)
    return p


def embed(cfg, p, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    return x.astype(dtype_of(cfg.compute_dtype))


def unembed(cfg, p, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, p["embedding"])
    return x @ p["lm_head"]
