"""Mixture-of-Experts block.

Baseline: GShard-style capacity-based one-hot dispatch einsums — the
canonical TPU-SPMD MoE (all-to-all emerges from GSPMD propagation when the
expert axis is sharded over ``model``).  The dispatch/combine einsums carry
*bookkeeping* FLOPs on top of the useful expert GEMMs; this is recorded in
the roofline (MODEL_FLOPS / HLO_FLOPs) and is the target of §Perf hillclimb
#1, which replaces this path with an explicit shard_map all-to-all
expert-parallel implementation (`repro.core.parallel.moe_expert_parallel`).

Tokens are grouped into sequence chunks of ``group_size`` so the dispatch
tensor is (B, n_groups, g, E, C) with C = ceil(g*k/E * capacity_factor)
independent of the full sequence length (GShard's grouping).  Overflowing
tokens are dropped (GShard dropping semantics, capacity_factor 1.25).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import layers as L


def init_moe(cfg, key, dtype):
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (D, E), jnp.float32) * 0.02),
        "w_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32)
                   / np.sqrt(D)).astype(dtype),
        "w_in": (jax.random.normal(ks[2], (E, D, F), jnp.float32)
                 / np.sqrt(D)).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
                  / np.sqrt(F)).astype(dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.init_mlp(
            cfg, ks[4], D, cfg.moe_d_ff * cfg.num_shared_experts, dtype)
    return p


def _capacity(group: int, k: int, E: int, factor: float) -> int:
    return max(1, int(np.ceil(group * k / E * factor)))


def route(cfg, p, x):
    """Router: returns (weights (..., k), indices (..., k)) normalized."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(gates, cfg.experts_per_token)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w, idx, gates


def moe_block(cfg, p, x, *, capacity_factor: float = None,
              group_size: int = 1024):
    """x: (B, S, D) -> (B, S, D).  GShard dense-dispatch baseline.

    Tokens are flattened to T = B*S and grouped into chunks of
    ``group_size`` (so decode steps with S == 1 group over the batch)."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    g = min(T, group_size)
    n = T // g  # T is a power of two for all assigned shapes
    C = _capacity(g, k, E, capacity_factor)

    xg = x.reshape(n, g, D)
    w, idx, _ = route(cfg, p, xg)                    # (n, g, k)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)        # (n, g, k, E)
    # position of each (token, k) inside its expert queue, computed over the
    # flattened (g*k) order — GShard's cumsum trick.
    flat = onehot.reshape(n, g * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                      # (n, g*k, E)
    pos_of = jnp.sum(flat * pos, axis=-1).reshape(n, g, k)     # (n, g, k)
    pos_of = pos_of.astype(jnp.int32)
    keep = (pos_of < C).astype(jnp.float32)

    pos_oh = jax.nn.one_hot(pos_of, C, dtype=jnp.float32)      # (n, g, k, C)
    # dispatch/combine tensors: (n, g, E, C)
    dispatch = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, pos_oh, keep)
    combine = jnp.einsum("gtec,gtk,gtke->gtec", dispatch, w,
                         onehot)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)
    # (n, E, C, D)
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_in"])
    hg = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    act = jax.nn.silu if cfg.act == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    h = act(hg) * h
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_out"])           # (n, E, C, D)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    y = y.reshape(B, S, D)

    if cfg.num_shared_experts:
        y = y + L.mlp(cfg, x, p["shared"])
    return y


def moe_block_gathered(cfg, p, x, *, capacity_factor: float = None):
    """Beyond-baseline single-device reference: sort-free gather dispatch.

    Computes the same function as ``moe_block`` (same drop semantics under
    per-group capacity) but with gathers instead of one-hot einsums, so the
    HLO FLOPs ≈ the useful expert GEMMs.  Used by §Perf hillclimb #1 and
    validated against ``moe_block`` in tests.
    """
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = _capacity(T, k, E, capacity_factor)

    xf = x.reshape(T, D)
    w, idx, _ = route(cfg, p, xf[None])                 # (1, T, k)
    w, idx = w[0], idx[0]

    flat_e = idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_of = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_of < C
    slot = jnp.where(keep, flat_e * C + pos_of, E * C)          # E*C = dropped

    # scatter token ids into slots (one int per slot — cheap), then gather.
    src = jnp.full((E * C + 1,), T, jnp.int32)
    src = src.at[slot].set(jnp.arange(T * k, dtype=jnp.int32) // k)
    src = src[:E * C]
    xe = jnp.take(jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)]), src,
                  axis=0).reshape(E, C, D)

    h = jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    hg = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    act = jax.nn.silu if cfg.act == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    ye = jnp.einsum("ecf,efd->ecd", act(hg) * h, p["w_out"])    # (E, C, D)

    ye_flat = jnp.concatenate([ye.reshape(E * C, D),
                               jnp.zeros((1, D), ye.dtype)])
    contrib = jnp.take(ye_flat, jnp.minimum(slot, E * C), axis=0)  # (T*k, D)
    wk = (w.reshape(T * k) * keep).astype(contrib.dtype)
    y = jnp.sum((contrib * wk[:, None]).reshape(T, k, D), axis=1)
    y = y.reshape(B, S, D)
    if cfg.num_shared_experts:
        y = y + L.mlp(cfg, x, p["shared"])
    return y
