"""GNN layers built on the SAGA-NN / message-passing abstraction
(survey Table 5 algorithms: GCN, GraphSAGE, GAT, GIN).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abstraction import (DeviceGraph, MessagePassing,
                                    gather_scale_segment_sum,
                                    segment_softmax, segment_sum)
from repro.core.comm import QuantizedRows


def _dense(key, din, dout):
    return (jax.random.normal(key, (din, dout), jnp.float32)
            / np.sqrt(din))


class GCNLayer(MessagePassing):
    """Kipf & Welling: h' = ReLU(D^-1/2 A D^-1/2 H W)."""

    aggregate = "sum"

    @staticmethod
    def init(key, din, dout):
        return {"w": _dense(key, din, dout),
                "b": jnp.zeros((dout,), jnp.float32)}

    def __call__(self, p, g: DeviceGraph, x_src, x_dst=None, *,
                 use_kernel=False):
        if isinstance(x_src, QuantizedRows):
            x_src = jnp.asarray(x_src.dequantize())   # projects first
        if x_dst is None:
            x_dst = x_src[:g.num_dst]
        h = x_src @ p["w"]
        norm_src = jax.lax.rsqrt(g.out_deg)
        norm_dst = jax.lax.rsqrt(g.in_deg)
        coef = jnp.take(norm_src, g.edge_src) * jnp.take(norm_dst, g.edge_dst)
        # fused gather+scale+reduce: the (E, F) message tensor only ever
        # exists tile-by-tile in VMEM on the kernel path
        agg = gather_scale_segment_sum(h, g.edge_src, g.edge_dst,
                                       coef * g.edge_mask, g.num_dst,
                                       use_kernel=use_kernel)
        return agg + p["b"]


class SAGELayer(MessagePassing):
    """GraphSAGE-mean: h' = W_self h + W_nbr mean(neighbors).

    The neighbor mean routes through the fused
    gather→scale→segment-sum (mask as the per-edge coefficient, degree
    normalization after) — same math as the previous ``segment_mean``
    path, but on the kernel path the (E, F) message tensor stays in
    VMEM, and because features aggregate *before* any projection,
    layer 0 can consume :class:`~repro.core.comm.QuantizedRows` int8
    wire rows directly: the kernel dequantizes per source slab, so the
    wire fetch never takes a decode round-trip through HBM
    (``--wire-codec int8 --use-kernel``)."""

    aggregate = "mean"

    @staticmethod
    def init(key, din, dout):
        k1, k2 = jax.random.split(key)
        return {"w_self": _dense(k1, din, dout),
                "w_nbr": _dense(k2, din, dout),
                "b": jnp.zeros((dout,), jnp.float32)}

    def update(self, p, agg, self_feat):
        return self_feat @ p["w_self"] + agg @ p["w_nbr"] + p["b"]

    def __call__(self, p, g: DeviceGraph, x_src, x_dst=None, *,
                 use_kernel=False):
        if x_dst is None:
            # the self path needs fp32 rows; only the num_dst prefix
            # is ever dequantized host-side on the int8-in path
            x_dst = (jnp.asarray(
                x_src.rows(slice(0, g.num_dst)).dequantize())
                if isinstance(x_src, QuantizedRows)
                else x_src[:g.num_dst])
        coef = g.edge_mask.astype(jnp.float32)
        agg = gather_scale_segment_sum(x_src, g.edge_src, g.edge_dst,
                                       coef, g.num_dst,
                                       use_kernel=use_kernel)
        agg = agg / g.in_deg[:, None]
        return self.update(p, agg, x_dst)


class GATLayer(MessagePassing):
    """Single-projection multi-head GAT with per-destination softmax."""

    def __init__(self, heads: int = 4):
        self.heads = heads

    @staticmethod
    def init(key, din, dout, heads: int = 4):
        hd = dout // heads
        k1, k2, k3 = jax.random.split(key, 3)
        return {"w": _dense(k1, din, dout),
                "a_src": jax.random.normal(k2, (heads, hd), jnp.float32) * 0.1,
                "a_dst": jax.random.normal(k3, (heads, hd), jnp.float32) * 0.1}

    def __call__(self, p, g: DeviceGraph, x_src, x_dst=None, *,
                 use_kernel=False):
        if isinstance(x_src, QuantizedRows):
            # attention projects before aggregating, so the int8-in
            # kernel path does not apply — decode up front
            x_src = jnp.asarray(x_src.dequantize())
        if x_dst is None:
            x_dst = x_src[:g.num_dst]
        heads, hd = p["a_src"].shape
        hs = (x_src @ p["w"]).reshape(-1, heads, hd)
        hdst = (x_dst @ p["w"]).reshape(-1, heads, hd)
        es = jnp.einsum("nhd,hd->nh", hs, p["a_src"])
        ed = jnp.einsum("nhd,hd->nh", hdst, p["a_dst"])
        if use_kernel:
            # one-pass fused online-softmax kernel: edge logits and
            # alphas never reach HBM (falls back to the multi-pass
            # kernel path when the VMEM capacity predicate says no)
            from repro.kernels import ops as kops
            return kops.gat_attention(
                hs.reshape(-1, heads * hd), es, ed, g.edge_src,
                g.edge_dst, g.edge_mask, g.num_dst, heads=heads)
        logits = jax.nn.leaky_relu(
            jnp.take(es, g.edge_src, axis=0)
            + jnp.take(ed, g.edge_dst, axis=0), 0.2)        # (E, heads)
        alpha = segment_softmax(logits, g.edge_dst, g.num_dst, g.edge_mask,
                                use_kernel=use_kernel)
        msgs = jnp.take(hs, g.edge_src, axis=0) * alpha[..., None]
        agg = segment_sum(msgs.reshape(-1, heads * hd), g.edge_dst,
                          g.num_dst, use_kernel=use_kernel)
        return agg


class GINLayer(MessagePassing):
    """GIN: h' = MLP((1 + eps) h + sum(neighbors))."""

    aggregate = "sum"

    @staticmethod
    def init(key, din, dout):
        k1, k2 = jax.random.split(key)
        return {"w1": _dense(k1, din, dout),
                "w2": _dense(k2, dout, dout),
                "b1": jnp.zeros((dout,), jnp.float32),
                "b2": jnp.zeros((dout,), jnp.float32),
                "eps": jnp.zeros((), jnp.float32)}

    def update(self, p, agg, self_feat):
        h = (1.0 + p["eps"]) * self_feat + agg
        h = jax.nn.relu(h @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]


class GGNNLayer(MessagePassing):
    """Gated Graph NN [Li+ 2015] (survey Table 5): GRU update over the
    aggregated neighbor messages; dimensions stay constant across layers."""

    aggregate = "sum"

    @staticmethod
    def init(key, din, dout):
        # GG-NN requires din == dout (recurrent state); project if needed
        ks = jax.random.split(key, 4)
        return {"w_msg": _dense(ks[0], dout, dout),
                "w_zrh": _dense(ks[1], dout, 3 * dout),
                "u_zrh": _dense(ks[2], dout, 3 * dout),
                "proj": _dense(ks[3], din, dout) if din != dout else None,
                "b": jnp.zeros((3 * dout,), jnp.float32)}

    def __call__(self, p, g, x_src, x_dst=None, *, use_kernel=False):
        if isinstance(x_src, QuantizedRows):
            x_src = jnp.asarray(x_src.dequantize())   # projects first
        if p.get("proj") is not None:
            x_src = x_src @ p["proj"]
        if x_dst is None:
            x_dst = x_src[:g.num_dst]
        hm = x_src @ p["w_msg"]
        agg = gather_scale_segment_sum(
            hm, g.edge_src, g.edge_dst,
            g.edge_mask.astype(hm.dtype), g.num_dst,
            use_kernel=use_kernel)
        d = x_dst.shape[-1]
        gates = agg @ p["w_zrh"] + x_dst @ p["u_zrh"] + p["b"]
        z = jax.nn.sigmoid(gates[:, :d])
        r = jax.nn.sigmoid(gates[:, d:2 * d])
        # candidate uses reset-gated state through the U path
        h_tilde = jnp.tanh(agg @ p["w_zrh"][:, 2 * d:]
                           + (r * x_dst) @ p["u_zrh"][:, 2 * d:])
        return (1 - z) * x_dst + z * h_tilde


class APPNPLayer(MessagePassing):
    """APPNP [Klicpera+ 2019] (PyG's Table 5 list): personalized-PageRank
    propagation h' = (1-α)·Â h + α·h0 (no weights; pair with an MLP head)."""

    aggregate = "sum"

    def __init__(self, alpha: float = 0.1):
        self.alpha = alpha

    @staticmethod
    def init(key, din, dout):
        return {"w": _dense(key, din, dout)}  # used only by the first hop

    def propagate(self, g, h, h0, *, use_kernel=False):
        coef = (jax.lax.rsqrt(g.out_deg)[g.edge_src]
                * jax.lax.rsqrt(g.in_deg)[g.edge_dst] * g.edge_mask)
        agg = gather_scale_segment_sum(h, g.edge_src, g.edge_dst, coef,
                                       g.num_dst, use_kernel=use_kernel)
        return (1 - self.alpha) * agg + self.alpha * h0


LAYER_TYPES = {"gcn": GCNLayer, "sage": SAGELayer, "gat": GATLayer,
               "gin": GINLayer, "ggnn": GGNNLayer}
