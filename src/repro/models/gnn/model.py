"""GNN models: stacks of abstraction-layer GNN layers, usable in
full-graph mode (one DeviceGraph) or mini-batch mode (list of Blocks).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abstraction import DeviceGraph, gather_scale_segment_sum
from repro.models.gnn.layers import LAYER_TYPES, GATLayer


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    arch: str = "gcn"                 # gcn | sage | gat | gin | ggnn | appnp
    feat_dim: int = 64
    hidden: int = 128
    num_classes: int = 8
    num_layers: int = 2
    gat_heads: int = 4
    appnp_k: int = 4                  # APPNP propagation hops
    appnp_alpha: float = 0.1
    use_kernel: bool = False          # Pallas segment-sum for aggregation
    wire_codec: str = "fp32"          # comm-plane codec: fp32 | bf16 | int8


def init_gnn(cfg: GNNConfig, key) -> List[dict]:
    if cfg.arch == "appnp":
        # MLP head (feat -> hidden -> classes), then weightless propagation
        from repro.models.gnn.layers import _dense
        return [{"w": _dense(jax.random.fold_in(key, 0), cfg.feat_dim,
                             cfg.hidden)},
                {"w": _dense(jax.random.fold_in(key, 1), cfg.hidden,
                             cfg.num_classes)}]
    layer_cls = LAYER_TYPES[cfg.arch]
    dims = ([cfg.feat_dim] + [cfg.hidden] * (cfg.num_layers - 1)
            + [cfg.num_classes])
    params = []
    for i in range(cfg.num_layers):
        k = jax.random.fold_in(key, i)
        if cfg.arch == "gat":
            params.append(layer_cls.init(k, dims[i], dims[i + 1],
                                         heads=cfg.gat_heads))
        else:
            params.append(layer_cls.init(k, dims[i], dims[i + 1]))
    return params


def _make_layer(cfg: GNNConfig):
    if cfg.arch == "gat":
        return GATLayer(cfg.gat_heads)
    return LAYER_TYPES[cfg.arch]()


def forward_full(cfg: GNNConfig, params, g: DeviceGraph, x) -> jax.Array:
    """Full-graph forward (NeuGraph/ROC style, no sampling)."""
    if cfg.arch == "appnp":
        from repro.models.gnn.layers import APPNPLayer
        layer = APPNPLayer(cfg.appnp_alpha)
        h = jax.nn.relu(x @ params[0]["w"]) @ params[1]["w"]
        h0 = h
        for _ in range(cfg.appnp_k):
            h = layer.propagate(g, h, h0, use_kernel=cfg.use_kernel)
        return h
    layer = _make_layer(cfg)
    h = x
    for i, p in enumerate(params):
        h = layer(p, g, h, use_kernel=cfg.use_kernel)
        if i + 1 < len(params):
            h = jax.nn.relu(h)
    return h


def forward_blocks(cfg: GNNConfig, params, blocks: Sequence[DeviceGraph],
                   x_input) -> jax.Array:
    """Mini-batch forward over sampled bipartite blocks (DistDGL style).
    ``x_input``: features of blocks[0].src_nodes."""
    layer = _make_layer(cfg)
    h = x_input
    for i, (p, g) in enumerate(zip(params, blocks)):
        h = layer(p, g, h, use_kernel=cfg.use_kernel)
        if i + 1 < len(params):
            h = jax.nn.relu(h)
    return h


def forward_stale(params, h_own, sg_local, ghosts, refresh, own_rows,
                  *, axis: str = "g", use_kernel: bool = False,
                  codec=None, residuals=None):
    """Staleness-bounded full-graph GCN forward (runs under ``shard_map``).

    The asynchronous counterpart of
    :func:`repro.core.propagation.gcn_forward_local`: layer ``i >= 1``
    aggregates *historical* activations for ghost sources (per-layer stale
    planes from a :class:`repro.core.halo.HaloExchange`) and fresh
    activations only for owned rows and the rows this step's refresh plan
    exchanges synchronously.  Layer 0 consumes the static input features,
    which never go stale.

    Args:
        params: per-layer GCN params ``[{"w", "b"}, ...]``.
        h_own: ``(n_local, F)`` this device's owned input features.
        sg_local: ``(es, ed, em, indeg_l, outdeg_all, n_local)`` — the
            per-device pull edge slices, local in-degree, replicated global
            out-degree, and owned-row count (``ShardedGraph`` layout; pad
            edges are masked out by ``em`` so pad rows never aggregate).
        ghosts: per-layer ``(N_pad, F_l)`` replicated stale activation
            planes, innermost first (layer ``l`` plane feeds layer ``l+1``).
        refresh: per-layer ``(N_pad,)`` bool — rows served *fresh* this
            step (this step's synchronous exchange).  All-True degrades
            exactly to the synchronous pull forward.
        own_rows: ``(N_pad,)`` bool — rows this device owns (always fresh).
        axis: mesh axis name (default ``"g"``).
        use_kernel: aggregate through the fused Pallas
            gather-scale-segment-sum kernel instead of XLA take +
            ``jax.ops.segment_sum``.
        codec: optional :class:`repro.core.comm.WireCodec`.  Under a
            lossy codec, a refreshed row's sender quantizes the plane on
            the wire (``codec.jax_qdq``; with error feedback iff
            ``codec.error_feedback``), so every device that does *not*
            own the row — refreshed or stale — reads the decoded wire
            value; the owner keeps its exact local activations.
            ``None`` or the identity fp32 codec compiles the exact
            pre-codec computation (bit-identical jaxpr).
        residuals: per-layer ``(N_pad, F_l)`` error-feedback residuals
            (required iff ``codec.error_feedback``, e.g. int8): the
            sender adds them before quantizing and the returned
            residuals carry ``pre - decoded`` for rows refreshed this
            step.  Codecs without feedback (bf16) quantize statelessly,
            matching the host :class:`~repro.core.comm.Transport`.

    Returns:
        ``(h, planes, residuals_out)`` — ``h`` is the ``(n_local,
        num_classes)`` output for owned rows; ``planes`` are the global
        layer outputs ``h_0 .. h_{L-2}`` *as they crossed the wire*
        (codec-decoded; exact under fp32) for the host to write back into
        the ghost buffers at the refreshed rows; ``residuals_out`` the
        updated error-feedback state (``()`` under an exact codec).

    Gradient semantics: stale rows enter as constants (no gradient flows
    into the buffers); refreshed rows participate in the synchronous
    all-gather and carry exact gradients — under a lossy codec via a
    straight-through estimator (the wire value enters the forward, the
    gradient of the unquantized activation flows back).  The S=0 fp32
    case is bitwise the synchronous step.
    """
    es, ed, em, indeg_l, outdeg_all, n_local = sg_local
    quantize = codec is not None and not codec.identity
    h = h_own
    planes = []
    res_out = []
    n_layers = len(params)
    for i, p in enumerate(params):
        h_all_fresh = jax.lax.all_gather(h, axis, tiled=True)  # (N_pad, F)
        if i == 0:
            h_all = h_all_fresh          # static inputs: never stale
        elif not quantize:
            planes.append(h_all_fresh)   # global layer-(i-1) output
            use_fresh = refresh[i - 1] | own_rows
            h_all = jnp.where(use_fresh[:, None], h_all_fresh,
                              ghosts[i - 1])
        else:
            mask = refresh[i - 1][:, None]
            if codec.error_feedback:
                # sender-side error feedback before quantizing the wire
                # plane; residuals advance only for rows sent this step
                res = residuals[i - 1]
                pre = h_all_fresh + jax.lax.stop_gradient(res)
                dec_raw = codec.jax_qdq(pre)
                res_out.append(jax.lax.stop_gradient(
                    jnp.where(mask, pre - dec_raw, res)))
            else:                        # stateless codec (bf16)
                dec_raw = codec.jax_qdq(h_all_fresh)
            # straight-through: forward sees the wire value, backward the
            # exact all-gather (refreshed rows keep exact gradients)
            dec = h_all_fresh + jax.lax.stop_gradient(dec_raw - h_all_fresh)
            planes.append(dec)           # wire view: what receivers store
            h_all = jnp.where(own_rows[:, None], h_all_fresh,
                              jnp.where(mask, dec, ghosts[i - 1]))
        hw = h_all @ p["w"]
        coef = (jax.lax.rsqrt(jnp.take(outdeg_all, es))
                * jax.lax.rsqrt(jnp.take(indeg_l, ed)))
        h = gather_scale_segment_sum(hw, es, ed, coef * em, n_local,
                                     use_kernel=use_kernel) + p["b"]
        if i + 1 < n_layers:
            h = jax.nn.relu(h)
    return h, planes, tuple(res_out)


def forward_blocks_cached(cfg: GNNConfig, params,
                          inner_blocks: Sequence[DeviceGraph],
                          outer_block: DeviceGraph, x_input,
                          cached_h, fresh_mask):
    """Serving forward with historical-embedding splice (GNNAutoScale).

    Computes the first ``L-1`` layers over the (possibly miss-restricted)
    inner blocks, then replaces rows of the final-layer input with cached
    historical embeddings where ``fresh_mask`` holds, and applies the last
    layer over ``outer_block``.  Returns ``(logits, h_fresh)`` where
    ``h_fresh`` is the pre-splice hidden state — the rows to write back for
    cache misses.  Shapes are static per (bucket, fanouts), so each bucket
    compiles once."""
    layer = _make_layer(cfg)
    h = x_input
    for i in range(len(params) - 1):
        h = layer(params[i], inner_blocks[i], h, use_kernel=cfg.use_kernel)
        h = jax.nn.relu(h)
    h_fresh = h
    h = jnp.where(fresh_mask[:, None], cached_h, h_fresh)
    logits = layer(params[-1], outer_block, h, use_kernel=cfg.use_kernel)
    return logits, h_fresh


def nll_sum_count(logits, labels, mask):
    """Masked NLL as an (unnormalized sum, count) pair — the combinable
    form a distributed step psums across partitions before dividing, so
    the global mean is identical to the single-device mean regardless of
    how seeds were split."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = logz - gold
    return jnp.sum(nll * mask), jnp.sum(mask)


def nll_loss(logits, labels, mask=None):
    if mask is None:
        mask = jnp.ones(labels.shape, logits.dtype)
    total, cnt = nll_sum_count(logits, labels, mask)
    return total / jnp.maximum(cnt, 1.0)


def accuracy(logits, labels, mask=None):
    correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    if mask is not None:
        return jnp.sum(correct * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(correct)


def make_fullgraph_train_step(cfg: GNNConfig, optimizer):
    def step(params, opt_state, g: DeviceGraph, x, labels, mask):
        def loss_fn(p):
            logits = forward_full(cfg, p, g, x)
            return nll_loss(logits, labels, mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = optimizer.apply(params, grads, opt_state)
        return params, opt_state, loss

    return step


def make_minibatch_train_step(cfg: GNNConfig, optimizer):
    def step(params, opt_state, blocks, x_input, labels, mask):
        def loss_fn(p):
            logits = forward_blocks(cfg, p, blocks, x_input)
            return nll_loss(logits, labels, mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = optimizer.apply(params, grads, opt_state)
        return params, opt_state, loss

    return step
