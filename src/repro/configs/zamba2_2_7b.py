"""Zamba2-2.7B [arXiv:2411.15242] — hybrid: Mamba2 backbone + shared
attention block applied every 6 layers (9 applications over 54 layers,
same weights each time).

Simplification vs the released model (noted in DESIGN.md): the shared block
operates on the d_model-wide stream (the released model concatenates the
original embedding, doubling the block width) and LoRA adapters on the
shared block are omitted.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    citation="arXiv:2411.15242",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    attn_every=6,
)
