"""Qwen2.5-14B [hf:Qwen/Qwen2.5-14B] — dense decoder, GQA, QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    citation="hf:Qwen/Qwen2.5-0.5B (family card, 14B variant)",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
