"""DeepSeek-V3 671B [arXiv:2412.19437].

MLA (multi-head latent attention) + MoE with 1 shared + 256 routed experts
(top-8), first 3 layers dense. The MTP (multi-token-prediction) auxiliary
head is an optional training add-on in the paper and is omitted from the
step functions (noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="mla_moe",
    citation="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,  # MLA: effectively MHA over latent-decompressed KV
    d_ff=18432,        # dense-layer FFN width
    vocab_size=129280,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
)
