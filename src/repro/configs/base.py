"""Config system for repro.

Every assigned architecture gets a module ``repro/configs/<id>.py`` exposing
``CONFIG`` (a :class:`ModelConfig` with the exact published numbers) and the
family-specific ``input_specs`` behaviour is derived from ``CONFIG.family``.

The four assigned input shapes live in :data:`INPUT_SHAPES`.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

ARCH_IDS = (
    "qwen2_vl_7b",
    "deepseek_v3_671b",
    "mamba2_780m",
    "qwen2_5_14b",
    "whisper_tiny",
    "zamba2_2_7b",
    "phi3_mini_3_8b",
    "glm4_9b",
    "gemma_7b",
    "granite_moe_1b_a400m",
)

# public-pool ids (with dashes) -> module names
ARCH_ALIASES = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mamba2-780m": "mamba2_780m",
    "qwen2.5-14b": "qwen2_5_14b",
    "whisper-tiny": "whisper_tiny",
    "zamba2-2.7b": "zamba2_2_7b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "glm4-9b": "glm4_9b",
    "gemma-7b": "gemma_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
}


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    ``family`` selects the forward function:
      dense | moe | mla_moe | ssm | hybrid | encdec | vlm
    """

    name: str
    family: str
    citation: str

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None           # defaults to d_model // num_heads
    qkv_bias: bool = False                   # qwen-style attention bias
    tie_embeddings: bool = False
    norm: str = "rmsnorm"                    # rmsnorm | layernorm
    act: str = "silu"                        # silu (SwiGLU) | gelu (GeGLU)
    rope_theta: float = 10_000.0
    partial_rotary: float = 1.0              # glm4 uses 0.5
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    mlp_gated: bool = True                   # SwiGLU/GeGLU vs plain 2-layer MLP
    pos_emb: str = "rope"                    # rope | learned (whisper)
    embed_scale: bool = False                # gemma: scale embeds by sqrt(d)

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                        # per-expert hidden dim
    first_dense_layers: int = 0              # deepseek-v3: first 3 layers dense
    moe_capacity_factor: float = 1.25        # GShard dropping capacity
    moe_impl: str = "gshard"                 # gshard | ep (shard_map all2all)

    # --- MLA (deepseek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 64
    attn_every: int = 0                      # zamba2: shared attn block period

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0

    # --- serving ---
    sliding_window: int = 0                  # >0: ring-buffer KV cache variant

    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    cache_dtype: str = ""                    # KV/latent cache dtype override
                                             # ("float8_e4m3fn" halves cache
                                             # HBM traffic at decode)

    # --- lowering control ---
    # >1 fully unrolls every lax.scan (used by the dry-run's structural
    # cost extrapolation; XLA cost analysis counts while-bodies once).
    scan_unroll: int = 1
    # chunked-attention query-block size (the XLA-level analogue of the
    # flash kernel's BQ BlockSpec; a §Perf blocking knob)
    attn_q_chunk: int = 1024

    # --- survey axes that transfer to sequence models (DESIGN.md §3) ---
    parallelism: str = "hybrid"              # data | hybrid  (Table 2/7)
    sync_mode: str = "synchronous"           # Table 2, §3.2.7
    coordination: str = "decentralized"      # all-reduce, §3.2.9

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so 16-way sharding divides."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        kw = dict(
            num_layers=2,
            d_model=256,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_ff=512,
            vocab_size=512,
            head_dim=64,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.num_experts:
            kw.update(num_experts=4, experts_per_token=2, moe_d_ff=128,
                      first_dense_layers=min(self.first_dense_layers, 1),
                      moe_capacity_factor=8.0)  # drop-free at smoke scale
        if self.q_lora_rank or self.kv_lora_rank:
            kw.update(q_lora_rank=64, kv_lora_rank=32, qk_rope_head_dim=16,
                      qk_nope_head_dim=32, v_head_dim=32)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
        if self.attn_every:
            kw.update(attn_every=1)
        if self.encoder_layers:
            kw.update(encoder_layers=2)
        if self.mrope_sections:
            kw.update(mrope_sections=(8, 12, 12))  # sums to head_dim//2 = 32
        if self.sliding_window:
            kw.update(sliding_window=64)
        return self.replace(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ARCH_ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]
