"""Mamba2-780m [arXiv:2405.21060] — SSD (state-space duality), attention-free.

vocab 50280 padded to 50432 for 16-way model-axis sharding (recorded).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    citation="arXiv:2405.21060",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
