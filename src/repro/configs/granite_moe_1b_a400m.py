"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] —
MoE with 32 experts, top-8, every layer; GQA 16H/kv8.
vocab 49155 padded to 49408 for 16-way sharding (recorded)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,          # dense path unused; experts use moe_d_ff
    vocab_size=49155,
    head_dim=64,
    num_experts=32,
    experts_per_token=8,
    moe_d_ff=512,
    tie_embeddings=True,
)
