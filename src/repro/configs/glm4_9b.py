"""GLM-4-9B [hf:THUDM/glm-4-9b] — dense decoder, partial (50%) rotary,
extreme GQA (kv=2), QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    citation="hf:THUDM/glm-4-9b",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    head_dim=128,
    qkv_bias=True,
    partial_rotary=0.5,
)
