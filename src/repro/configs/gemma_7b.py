"""Gemma-7B [arXiv:2403.08295] — GeGLU, head_dim=256, tied embeddings,
embeddings scaled by sqrt(d_model). (MQA is the 2b variant; 7b is MHA.)"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    citation="arXiv:2403.08295",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    act="gelu",
    tie_embeddings=True,
    embed_scale=True,
)
