"""Qwen2-VL-7B language backbone [arXiv:2409.12191].

VLM: the ViT vision encoder + projector are STUBBED — ``input_specs`` feeds
precomputed patch/text embeddings (B, S, d_model) plus 3-section M-RoPE
position ids (3, B, S) (temporal/height/width), per the assignment carve-out.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    citation="arXiv:2409.12191",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # sums to head_dim // 2
)
