"""Whisper-tiny [arXiv:2212.04356] — encoder-decoder, 4+4 layers.

The mel-spectrogram + conv frontend is STUBBED: ``input_specs`` feeds
precomputed frame embeddings (B, S, d_model) to the encoder, per the
assignment carve-out. Whisper uses plain (non-gated) GELU MLPs, LayerNorm
and learned absolute positions. vocab 51865 padded to 51968.

long_500k is SKIPPED for this arch (enc-dec cross-attention has no
sliding-window equivalent; decoder positions capped in the real model) —
see DESIGN.md §Shape/skip notes.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    citation="arXiv:2212.04356",
    num_layers=4,          # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    mlp_gated=False,
    pos_emb="learned",
)
