from repro.optim.adamw import AdamW, Sgd, cosine_schedule  # noqa: F401
