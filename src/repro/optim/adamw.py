"""Optimizers (pure JAX, no optax dependency).

AdamW keeps fp32 first/second moments regardless of the parameter dtype
(mixed-precision training: bf16 params, fp32 state).  The moment pytrees
mirror the parameter pytree, so parameter PartitionSpecs apply verbatim
(ZeRO-style state sharding comes for free from the FSDP param specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp
import numpy as np


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(np.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Union[float, Callable] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> Any:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def apply(self, params, grads, state):
        step = state["step"] + 1
        lr = self.lr(step) if callable(self.lr) else self.lr

        if self.clip_norm:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)))
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) * scale), grads)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        t = step.astype(jnp.float32)
        mc = 1 - b1 ** t
        vc = 1 - b2 ** t

        def upd(p, m_, v_):
            u = (m_ / mc) / (jnp.sqrt(v_ / vc) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # no decay on norms/bias
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}


@dataclasses.dataclass(frozen=True)
class Sgd:
    lr: Union[float, Callable] = 1e-2
    momentum: float = 0.0

    def init(self, params):
        if not self.momentum:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}

    def apply(self, params, grads, state):
        step = state["step"] + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        if self.momentum:
            mu = jax.tree.map(
                lambda m, g: self.momentum * m + g.astype(jnp.float32),
                state["mu"], grads)
            params = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
                params, mu)
            return params, {"mu": mu, "step": step}
        params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return params, {"step": step}
