"""Checkpointing: msgpack-serialized pytrees with dtype/shape manifests.

Layout (one directory per step):
  <dir>/step_<n>/manifest.msgpack   — tree structure + shapes/dtypes + meta
  <dir>/step_<n>/arrays.npz         — flattened leaves (host numpy)

Crash safety: ``save_checkpoint`` stages both files in a ``step_<n>.tmp``
sibling and publishes with one ``os.rename`` — a process killed mid-write
leaves at most a ``.tmp`` directory that the step regex never matches, so
``latest_step`` can only ever select a fully written step.  Belt and
braces, a ``step_<n>/`` directory missing either file (e.g. produced by a
pre-rename writer or a torn copy) is skipped by ``latest_step`` and
rejected by ``load_checkpoint``, and the manifest's ``num_leaves`` is
validated against the npz keys before any leaf is touched.

Not a distributed checkpointer (no per-shard files) — on a real cluster one
would swap in tensorstore/orbax; the interface is intentionally identical:
``save_checkpoint(dir, step, tree)`` / ``load_checkpoint(dir, step?)``.
"""
from __future__ import annotations

import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax
import msgpack
import numpy as np

_STEP_RE = re.compile(r"step_(\d+)$")
_REQUIRED = ("manifest.msgpack", "arrays.npz")


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _is_complete(path: str) -> bool:
    """A checkpoint directory is loadable iff both files are present."""
    return all(os.path.isfile(os.path.join(path, f)) for f in _REQUIRED)


def save_checkpoint(directory: str, step: int, tree, *,
                    meta: Optional[dict] = None) -> str:
    """Write one step atomically: stage into ``step_<n>.tmp`` then publish
    via ``os.rename`` (same filesystem, so the step directory appears all
    at once).  Returns the final step path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(directory, exist_ok=True)
    if os.path.isdir(tmp):            # stale staging dir from a prior crash
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrays = [np.asarray(leaf) for leaf in leaves]
    manifest = {
        "treedef": str(treedef),
        "num_leaves": len(arrays),
        "shapes": [list(a.shape) for a in arrays],
        "dtypes": [str(a.dtype) for a in arrays],
        "step": step,
        "meta": meta or {},
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(arrays)})
    if os.path.isdir(final):          # overwrite = replace atomically too
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    """Newest step with BOTH files present (partial/torn dirs are not
    candidates — resume after a kill-mid-save lands on the previous
    step).  ``.tmp`` staging dirs never match the step pattern."""
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := _STEP_RE.match(d))
             and _is_complete(os.path.join(directory, d))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, template, step: Optional[int] = None):
    """Restore into the structure of ``template`` (shapes must match).

    Leaves are cast to the dtype recorded in the manifest (the dtype that
    was saved — not the template's, which may be a differently-typed
    scratch tree).  Raises ``FileNotFoundError`` for absent/partial steps
    and ``ValueError`` when the manifest disagrees with the npz contents
    or the template structure."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    if not _is_complete(path):
        raise FileNotFoundError(
            f"checkpoint {path} is missing or partial "
            f"(needs {' + '.join(_REQUIRED)})")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    n = manifest["num_leaves"]
    missing = [f"leaf_{i}" for i in range(n) if f"leaf_{i}" not in data.files]
    if missing:
        raise ValueError(
            f"checkpoint {path} manifest declares {n} leaves but arrays.npz "
            f"is missing {missing[:3]}{'...' if len(missing) > 3 else ''}")
    arrays = [data[f"leaf_{i}"] for i in range(n)]
    leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(leaves) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, template {len(leaves)}")
    restored = [
        np.asarray(a, dtype=np.dtype(dt)).reshape(l.shape)
        if hasattr(l, "shape") else a
        for a, dt, l in zip(arrays, manifest["dtypes"], leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored), manifest
