"""Checkpointing: msgpack-serialized pytrees with dtype/shape manifests.

Layout (one directory per step):
  <dir>/step_<n>/manifest.msgpack   — tree structure + shapes/dtypes + meta
  <dir>/step_<n>/arrays.npz         — flattened leaves (host numpy)

Not a distributed checkpointer (no per-shard files) — on a real cluster one
would swap in tensorstore/orbax; the interface is intentionally identical:
``save_checkpoint(dir, step, tree)`` / ``load_checkpoint(dir, step?)``.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional, Tuple

import jax
import msgpack
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree, *, meta: dict = None
                    ) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = [np.asarray(leaf) for leaf in leaves]
    manifest = {
        "treedef": str(treedef),
        "num_leaves": len(arrays),
        "shapes": [list(a.shape) for a in arrays],
        "dtypes": [str(a.dtype) for a in arrays],
        "step": step,
        "meta": meta or {},
    }
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    np.savez(os.path.join(path, "arrays.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(arrays)})
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, template, step: Optional[int] = None):
    """Restore into the structure of ``template`` (shapes must match)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    arrays = [data[f"leaf_{i}"] for i in range(manifest["num_leaves"])]
    leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(leaves) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, template {len(leaves)}")
    restored = [np.asarray(a, dtype=l.dtype).reshape(l.shape) if hasattr(
        l, "dtype") else a for a, l in zip(arrays, leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored), manifest
