"""Pallas TPU kernels: blocked segment-sum and the fused
gather-scale-segment-sum (the GNN aggregation hot-spot), both
differentiable via custom VJPs whose backward passes are themselves
blocked Pallas kernels.

The survey's Gather phase is a sparse scatter-add on GPUs.  TPUs have no
efficient scatter, so we re-express the reduction as a *blocked one-hot
matmul* (MXU-friendly; the NeuGraph/GridGraph 2D-grid idea as BlockSpec
tiling):

    out[nb, fb] += onehot(seg_ids[eb] - nb0).T @ msgs[eb, fb]

Grid = (N/BN, F/BF, E/BE) with the edge dimension innermost, so each
(node-tile, feature-tile) output block stays resident in VMEM while all
edge tiles accumulate into it.

**VJP.**  The transpose of a scatter-add is a gather:
``grad_msgs = grad_out[seg_ids]``.  That gather is the same one-hot
trick with the roles of the matmul operands swapped,

    grad_msgs[eb, fb] += onehot(seg_ids[eb] - nb0) @ grad_out[nb, fb]

on grid (E/BE, F/BF, N/BN) with the *node* dimension innermost (each
edge id lands in exactly one node tile, so the accumulation over node
tiles reconstructs the gathered row exactly).

**Fusion.**  :func:`gather_scale_segment_sum_pallas` runs the whole
Scatter -> ApplyEdge (scale) -> Gather pipeline inside one kernel: the
source-feature matrix is kept VMEM-resident one feature-tile at a time
(grid (F/BF, N/BN, E/BE), feature dimension *outermost*, so the block is
DMA'd from HBM once per feature tile, not once per edge tile), rows are
gathered by a one-hot matmul, scaled by the per-edge coefficient, and
accumulated straight into destination tiles — the ``(E, F)`` message
tensor never exists in HBM.  Its VJP reuses the fused kernel with source
and destination swapped (``dh``) plus a per-edge dot-product kernel
(``dcoef``).

**Tiles.**  The feature tile ``bf`` adapts to F (:func:`_pick_bf`): wide
inputs get lane-aligned multiples of 128, narrow inputs (GAT per-head
logits, F of a few) get a sublane-aligned sliver instead of burning a
full 128-lane MXU tile on padding.  Every entry point asserts the VMEM
working set fits (:func:`_assert_vmem`).

VMEM working set per step of the scatter kernel: BE*BF (msgs) + BE*BN
(one-hot) + BN*BF (acc) = 128*128*3 floats ~= 192 KiB with the default
tiles — comfortably inside the ~16 MiB budget, all matmul dims
128-aligned for the MXU.  The fused kernel additionally keeps an
(S_pad, BF) source-feature slab resident, so it only engages while the
gathered source matrix fits VMEM (a few thousand rows at F=128 —
mini-batch blocks always, full graphs up to moderate size; note the
distributed pull path hands it the *all-gathered* (N_pad, F) matrix,
not a per-device shard).  :func:`fused_fits` is the capacity predicate;
the :mod:`repro.kernels.ops` dispatch falls back to the unfused blocked
kernel (row-count-independent working set) with a one-time warning, and
the budget asserts catch direct callers that overshoot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BE = 128   # edge tile
DEFAULT_BN = 128   # segment (node) tile
DEFAULT_BF = 128   # feature tile (upper bound; _pick_bf adapts to F)

LANE = 128         # TPU lane width (last-dim tiling granularity)
SUBLANE = 8        # float32 sublane granularity
MAX_BF = 512
VMEM_BUDGET = 8 * 2**20    # bytes; half the ~16 MiB/core so double
                           # buffering of input blocks still fits


def _pick_bf(F: int) -> int:
    """Adaptive feature tile: the smallest aligned width covering ``F``.

    Wide inputs get lane-aligned (multiples of 128, capped at MAX_BF so
    the VMEM slab stays bounded); narrow inputs — GAT per-head logits
    are F=heads, a handful — get a sublane-aligned sliver, so F=4 costs
    an 8-wide tile instead of the 32x padding waste of a hardcoded 128.
    """
    if F >= LANE:
        return min(-(-F // LANE) * LANE, MAX_BF)
    return max(SUBLANE, -(-F // SUBLANE) * SUBLANE)


def _assert_vmem(n_floats: int, *, what: str) -> None:
    """Fail loudly (at trace time) if a kernel's per-step VMEM working
    set exceeds the budget — mis-sized tiles must not silently spill."""
    bytes_ = 4 * n_floats
    assert bytes_ <= VMEM_BUDGET, (
        f"{what}: VMEM working set {bytes_ / 2**20:.1f} MiB exceeds the "
        f"{VMEM_BUDGET / 2**20:.0f} MiB budget — shrink the tile sizes "
        f"or shard the source dimension")


def _pad_edges(E: int, be: int) -> int:
    """Edge count padded to a whole tile; E=0 still gets one (all-pad)
    tile so the grid is never empty and the kernel always emits."""
    return max(-(-E // be) * be, be)


# ---------------------------------------------------------------------------
# forward scatter-add kernel
# ---------------------------------------------------------------------------

def _scatter_kernel(ids_ref, msgs_ref, out_ref, acc_ref, *, bn: int):
    n_i = pl.program_id(0)
    e_i = pl.program_id(2)
    ne = pl.num_programs(2)

    ids = ids_ref[:]                                   # (BE,)
    base = n_i * bn
    local = ids - base
    onehot = (local[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, bn), 1)).astype(jnp.float32)    # (BE, BN)
    msgs = msgs_ref[:].astype(jnp.float32)             # (BE, BF)
    contrib = jnp.dot(onehot.T, msgs,
                      preferred_element_type=jnp.float32)  # (BN, BF)

    @pl.when(e_i == 0)
    def _init():
        acc_ref[:] = contrib

    @pl.when(e_i != 0)
    def _acc():
        acc_ref[:] = acc_ref[:] + contrib

    @pl.when(e_i == ne - 1)
    def _emit():
        out_ref[:] = acc_ref[:].astype(out_ref.dtype)


def _scatter_add(msgs, seg_ids, num_segments, be, bn, bf, interpret):
    """Raw forward: blocked one-hot-matmul scatter-add (no VJP)."""
    E, F = msgs.shape
    Ep = _pad_edges(E, be)
    Fp = -(-F // bf) * bf
    # one sacrificial segment row absorbs padded edges
    pad_seg = num_segments
    Np = -(-(num_segments + 1) // bn) * bn

    msgs_p = jnp.zeros((Ep, Fp), msgs.dtype).at[:E, :F].set(msgs)
    ids_p = jnp.full((Ep,), pad_seg, jnp.int32).at[:E].set(
        seg_ids.astype(jnp.int32))

    grid = (Np // bn, Fp // bf, Ep // be)
    out = pl.pallas_call(
        functools.partial(_scatter_kernel, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((be,), lambda n, f, e: (e,)),
            pl.BlockSpec((be, bf), lambda n, f, e: (e, f)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda n, f, e: (n, f)),
        out_shape=jax.ShapeDtypeStruct((Np, Fp), msgs.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bf), jnp.float32)],
        interpret=interpret,
    )(ids_p, msgs_p)
    return out[:num_segments, :F]


# ---------------------------------------------------------------------------
# backward gather kernel (the transpose of scatter-add)
# ---------------------------------------------------------------------------

def _gather_kernel(ids_ref, gout_ref, out_ref, acc_ref, *, bn: int):
    n_i = pl.program_id(2)
    nn = pl.num_programs(2)

    ids = ids_ref[:]                                   # (BE,)
    local = ids - n_i * bn
    onehot = (local[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, bn), 1)).astype(jnp.float32)    # (BE, BN)
    gout = gout_ref[:].astype(jnp.float32)             # (BN, BF)
    contrib = jnp.dot(onehot, gout,
                      preferred_element_type=jnp.float32)  # (BE, BF)

    @pl.when(n_i == 0)
    def _init():
        acc_ref[:] = contrib

    @pl.when(n_i != 0)
    def _acc():
        acc_ref[:] = acc_ref[:] + contrib

    @pl.when(n_i == nn - 1)
    def _emit():
        out_ref[:] = acc_ref[:].astype(out_ref.dtype)


def gather_rows_pallas(grad_out, seg_ids, E, *, be=DEFAULT_BE,
                       bn=DEFAULT_BN, bf=None, interpret=True):
    """Blocked row gather ``grad_out[seg_ids]`` — the scatter-add VJP.

    ``grad_out``: (N, F); ``seg_ids``: (E,) int32 with values in
    [0, N] (row N — the sacrificial pad segment — gathers zeros).
    Returns (E, F).  Each edge id lives in exactly one node tile, so
    accumulating one-hot-gathered contributions over the (innermost)
    node-tile axis reconstructs the gathered row exactly.
    """
    N, F = grad_out.shape
    bf = _pick_bf(F) if bf is None else bf
    # 2x (bn, bf) double-buffered input blocks + (be, bf) out + acc
    # + (be, bn) one-hot + ids
    _assert_vmem(2 * be * bf + be * bn + 2 * bn * bf + be,
                 what="gather_rows_pallas")
    Ep = _pad_edges(E, be)
    Fp = -(-F // bf) * bf
    Np = -(-(N + 1) // bn) * bn        # +1: pad ids may point at row N

    gout_p = jnp.zeros((Np, Fp), grad_out.dtype).at[:N, :F].set(grad_out)
    ids_p = jnp.full((Ep,), N, jnp.int32).at[:E].set(
        seg_ids.astype(jnp.int32))

    grid = (Ep // be, Fp // bf, Np // bn)
    out = pl.pallas_call(
        functools.partial(_gather_kernel, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((be,), lambda e, f, n: (e,)),
            pl.BlockSpec((bn, bf), lambda e, f, n: (n, f)),
        ],
        out_specs=pl.BlockSpec((be, bf), lambda e, f, n: (e, f)),
        out_shape=jax.ShapeDtypeStruct((Ep, Fp), grad_out.dtype),
        scratch_shapes=[pltpu.VMEM((be, bf), jnp.float32)],
        interpret=interpret,
    )(ids_p, gout_p)
    return out[:E, :F]


# ---------------------------------------------------------------------------
# differentiable segment_sum
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _segment_sum(msgs, seg_ids, num_segments, be, bn, bf, interpret):
    return _scatter_add(msgs, seg_ids, num_segments, be, bn, bf, interpret)


def _segment_sum_fwd(msgs, seg_ids, num_segments, be, bn, bf, interpret):
    out = _scatter_add(msgs, seg_ids, num_segments, be, bn, bf, interpret)
    return out, seg_ids                   # linear in msgs: ids suffice


def _segment_sum_bwd(num_segments, be, bn, bf, interpret, seg_ids, g):
    E = seg_ids.shape[0]
    grad_msgs = gather_rows_pallas(g, seg_ids, E, be=be, bn=bn, bf=bf,
                                   interpret=interpret)
    return grad_msgs, np.zeros(seg_ids.shape, jax.dtypes.float0)


_segment_sum.defvjp(_segment_sum_fwd, _segment_sum_bwd)


def segment_sum_pallas(msgs: jax.Array, seg_ids: jax.Array,
                       num_segments: int, *,
                       be: int = DEFAULT_BE, bn: int = DEFAULT_BN,
                       bf: int | None = None,
                       interpret: bool = True) -> jax.Array:
    """Differentiable blocked segment-sum.

    ``msgs``: (E, F); ``seg_ids``: (E,) int32.  E, F, num_segments are
    padded to tile multiples internally (padded edges point at one
    sacrificial segment row that is dropped on return; E=0 degenerates
    to a single all-pad tile and returns zeros).  ``bf=None`` picks the
    feature tile adaptively from F (:func:`_pick_bf`).  The VJP gathers
    ``grad_out[seg_ids]`` with :func:`gather_rows_pallas`.
    """
    E, F = msgs.shape
    bf = _pick_bf(F) if bf is None else bf
    # covers forward (scatter) AND its VJP (gather): both hold the same
    # working set — one-hot + 2x double-buffered (·, bf) inputs + out/acc
    _assert_vmem(2 * be * bf + be * bn + 2 * bn * bf + be,
                 what="segment_sum_pallas")
    return _segment_sum(msgs, seg_ids, num_segments, be, bn, bf, interpret)


# ---------------------------------------------------------------------------
# fused gather -> scale -> segment-sum
# ---------------------------------------------------------------------------

def _fused_kernel(src_ref, dst_ref, coef_ref, h_ref, out_ref, acc_ref, *,
                  bn: int, sp: int):
    n_i = pl.program_id(1)
    e_i = pl.program_id(2)
    ne = pl.num_programs(2)

    src = src_ref[:]                                   # (BE,)
    onehot_s = (src[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, sp), 1)).astype(jnp.float32)    # (BE, Sp)
    h = h_ref[:].astype(jnp.float32)                   # (Sp, BF) resident
    msgs = jnp.dot(onehot_s, h,
                   preferred_element_type=jnp.float32)  # (BE, BF) VMEM-only
    msgs = msgs * coef_ref[:].astype(jnp.float32)[:, None]

    local = dst_ref[:] - n_i * bn
    onehot_d = (local[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, bn), 1)).astype(jnp.float32)    # (BE, BN)
    contrib = jnp.dot(onehot_d.T, msgs,
                      preferred_element_type=jnp.float32)  # (BN, BF)

    @pl.when(e_i == 0)
    def _init():
        acc_ref[:] = contrib

    @pl.when(e_i != 0)
    def _acc():
        acc_ref[:] = acc_ref[:] + contrib

    @pl.when(e_i == ne - 1)
    def _emit():
        out_ref[:] = acc_ref[:].astype(out_ref.dtype)


def _fused_impl(h, edge_src, edge_dst, coef, num_dst, be, bn, bf,
                interpret):
    """Raw fused forward (no VJP): out[d] = sum_{e: dst_e=d} coef_e *
    h[src_e].  The (E, F) message tensor lives only tile-by-tile in
    VMEM, never in HBM."""
    S, F = h.shape
    E = edge_src.shape[0]
    Ep = _pad_edges(E, be)
    Fp = -(-F // bf) * bf
    Sp = -(-S // SUBLANE) * SUBLANE
    pad_seg = num_dst
    Np = -(-(num_dst + 1) // bn) * bn

    h_p = jnp.zeros((Sp, Fp), h.dtype).at[:S, :F].set(h)
    src_p = jnp.zeros((Ep,), jnp.int32).at[:E].set(
        edge_src.astype(jnp.int32))
    dst_p = jnp.full((Ep,), pad_seg, jnp.int32).at[:E].set(
        edge_dst.astype(jnp.int32))
    coef_p = jnp.zeros((Ep,), coef.dtype).at[:E].set(coef)

    # feature dimension OUTERMOST: the (Sp, bf) source slab's block index
    # is constant over the whole inner (n, e) sweep, so it is fetched
    # from HBM once per feature tile (Pallas skips the DMA when the
    # block index does not change between steps)
    grid = (Fp // bf, Np // bn, Ep // be)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, bn=bn, sp=Sp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((be,), lambda f, n, e: (e,)),
            pl.BlockSpec((be,), lambda f, n, e: (e,)),
            pl.BlockSpec((be,), lambda f, n, e: (e,)),
            pl.BlockSpec((Sp, bf), lambda f, n, e: (0, f)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda f, n, e: (n, f)),
        out_shape=jax.ShapeDtypeStruct((Np, Fp), h.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bf), jnp.float32)],
        interpret=interpret,
    )(src_p, dst_p, coef_p, h_p)
    return out[:num_dst, :F]


def _edge_dot_kernel(src_ref, dst_ref, h_ref, gout_ref, out_ref, acc_ref,
                     *, sp: int, npd: int):
    f_i = pl.program_id(1)
    nf = pl.num_programs(1)

    onehot_s = (src_ref[:][:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, sp), 1)).astype(jnp.float32)      # (BE, Sp)
    onehot_d = (dst_ref[:][:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, npd), 1)).astype(jnp.float32)     # (BE, Npd)
    hs = jnp.dot(onehot_s, h_ref[:].astype(jnp.float32),
                 preferred_element_type=jnp.float32)     # (BE, BF)
    gd = jnp.dot(onehot_d, gout_ref[:].astype(jnp.float32),
                 preferred_element_type=jnp.float32)     # (BE, BF)
    part = jnp.sum(hs * gd, axis=1)                      # (BE,)

    @pl.when(f_i == 0)
    def _init():
        acc_ref[:] = part

    @pl.when(f_i != 0)
    def _acc():
        acc_ref[:] = acc_ref[:] + part

    @pl.when(f_i == nf - 1)
    def _emit():
        out_ref[:] = acc_ref[:].astype(out_ref.dtype)


def _edge_dot(h, gout, edge_src, edge_dst, be, bf, interpret):
    """Per-edge feature dot <h[src_e], gout[dst_e]> — the coefficient
    cotangent of the fused kernel."""
    S, F = h.shape
    Nd = gout.shape[0]
    E = edge_src.shape[0]
    Ep = _pad_edges(E, be)
    Fp = -(-F // bf) * bf
    Sp = -(-S // SUBLANE) * SUBLANE
    Npd = -(-Nd // SUBLANE) * SUBLANE

    h_p = jnp.zeros((Sp, Fp), h.dtype).at[:S, :F].set(h)
    g_p = jnp.zeros((Npd, Fp), gout.dtype).at[:Nd, :F].set(gout)
    # pad-edge rows of the output are trimmed below, so pad ids only
    # need to be in range
    src_p = jnp.zeros((Ep,), jnp.int32).at[:E].set(
        edge_src.astype(jnp.int32))
    dst_p = jnp.zeros((Ep,), jnp.int32).at[:E].set(
        edge_dst.astype(jnp.int32))

    grid = (Ep // be, Fp // bf)
    out = pl.pallas_call(
        functools.partial(_edge_dot_kernel, sp=Sp, npd=Npd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((be,), lambda e, f: (e,)),
            pl.BlockSpec((be,), lambda e, f: (e,)),
            pl.BlockSpec((Sp, bf), lambda e, f: (0, f)),
            pl.BlockSpec((Npd, bf), lambda e, f: (0, f)),
        ],
        out_specs=pl.BlockSpec((be,), lambda e, f: (e,)),
        out_shape=jax.ShapeDtypeStruct((Ep,), h.dtype),
        scratch_shapes=[pltpu.VMEM((be,), jnp.float32)],
        interpret=interpret,
    )(src_p, dst_p, h_p, g_p)
    return out[:E]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _fused(h, edge_src, edge_dst, coef, num_dst, be, bn, bf, interpret):
    return _fused_impl(h, edge_src, edge_dst, coef, num_dst, be, bn, bf,
                       interpret)


def _fused_fwd(h, edge_src, edge_dst, coef, num_dst, be, bn, bf,
               interpret):
    out = _fused_impl(h, edge_src, edge_dst, coef, num_dst, be, bn, bf,
                      interpret)
    return out, (h, edge_src, edge_dst, coef)


def _fused_bwd(num_dst, be, bn, bf, interpret, res, g):
    h, edge_src, edge_dst, coef = res
    num_src = h.shape[0]
    # transpose of "gather src, scale, scatter to dst" is the same fused
    # op with src and dst swapped: dh[s] = sum_{e: src_e=s} coef_e * g[dst_e]
    dh = _fused_impl(g, edge_dst, edge_src, coef, num_src, be, bn, bf,
                     interpret)
    dcoef = _edge_dot(h, g, edge_src, edge_dst, be, bf, interpret)
    zero_ids = np.zeros(edge_src.shape, jax.dtypes.float0)
    return dh, zero_ids, zero_ids, dcoef.astype(coef.dtype)


_fused.defvjp(_fused_fwd, _fused_bwd)


def gather_scale_segment_sum_pallas(h: jax.Array, edge_src: jax.Array,
                                    edge_dst: jax.Array, coef: jax.Array,
                                    num_dst: int, *,
                                    be: int = DEFAULT_BE,
                                    bn: int = DEFAULT_BN,
                                    bf: int | None = None,
                                    interpret: bool = True) -> jax.Array:
    """Fused differentiable Scatter–ApplyEdge–Gather:
    ``out[d] = sum_{e: edge_dst[e]=d} coef[e] * h[edge_src[e]]``.

    ``h``: (num_src, F) source features; ``edge_src``/``edge_dst``: (E,)
    int32; ``coef``: (E,) per-edge coefficient (fold the edge validity
    mask into it — padded/masked edges must carry coef 0).  Returns
    (num_dst, F).

    One kernel reads source rows (one-hot matmul against a VMEM-resident
    (S_pad, BF) feature slab), scales by ``coef``, and accumulates into
    destination tiles — the (E, F) message tensor never reaches HBM.
    The VJP reuses the same kernel with src/dst swapped for ``dh`` and a
    per-edge dot kernel for ``dcoef``; edge indices get zero (float0)
    cotangents.
    """
    S, F = h.shape
    bf = _pick_bf(F) if bf is None else bf
    _assert_vmem(fused_vmem_floats(S, num_dst, F, be=be, bn=bn, bf=bf),
                 what="gather_scale_segment_sum_pallas (fwd+vjp)")
    return _fused(h, edge_src, edge_dst, coef, num_dst, be, bn, bf,
                  interpret)


# ---------------------------------------------------------------------------
# int8-in / fp32-accumulate variant: consume wire rows without a decode pass
# ---------------------------------------------------------------------------

META_COLS = 8          # (mn, scale) packed into a sublane-aligned block


def _fused_q_kernel(src_ref, dst_ref, coef_ref, q_ref, meta_ref, out_ref,
                    acc_ref, *, bn: int, sp: int):
    n_i = pl.program_id(1)
    e_i = pl.program_id(2)
    ne = pl.num_programs(2)

    src = src_ref[:]                                   # (BE,)
    onehot_s = (src[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, sp), 1)).astype(jnp.float32)    # (BE, Sp)
    # dequantize the resident int8 slab in VMEM: the fp32 rows exist
    # only here, never in HBM (the wire payload feeds the kernel as-is)
    q = q_ref[:].astype(jnp.float32)                   # (Sp, BF)
    mn = meta_ref[:, 0:1]                              # (Sp, 1)
    scale = meta_ref[:, 1:2]                           # (Sp, 1)
    h = mn + q * scale
    msgs = jnp.dot(onehot_s, h,
                   preferred_element_type=jnp.float32)  # (BE, BF)
    msgs = msgs * coef_ref[:].astype(jnp.float32)[:, None]

    local = dst_ref[:] - n_i * bn
    onehot_d = (local[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, bn), 1)).astype(jnp.float32)    # (BE, BN)
    contrib = jnp.dot(onehot_d.T, msgs,
                      preferred_element_type=jnp.float32)  # (BN, BF)

    @pl.when(e_i == 0)
    def _init():
        acc_ref[:] = contrib

    @pl.when(e_i != 0)
    def _acc():
        acc_ref[:] = acc_ref[:] + contrib

    @pl.when(e_i == ne - 1)
    def _emit():
        out_ref[:] = acc_ref[:].astype(out_ref.dtype)


def gather_scale_segment_sum_q_pallas(q: jax.Array, mn: jax.Array,
                                      scale: jax.Array,
                                      edge_src: jax.Array,
                                      edge_dst: jax.Array,
                                      coef: jax.Array, num_dst: int, *,
                                      be: int = DEFAULT_BE,
                                      bn: int = DEFAULT_BN,
                                      bf: int | None = None,
                                      interpret: bool = True) -> jax.Array:
    """int8-in / fp32-accumulate fused aggregation: like
    :func:`gather_scale_segment_sum_pallas` but the source rows arrive in
    the PR 5 wire format — ``q``: (num_src, F) uint8 codes with per-row
    affine metadata ``mn``/``scale``: (num_src, 1) float32, row i
    dequantizing to ``mn[i] + q[i] * scale[i]``.

    Dequantization happens inside the kernel per source slab (the fp32
    feature matrix is never materialized in HBM) and accumulation is
    fp32, so the output matches decode-then-fp32 aggregation to the
    codec's own error bound (≤ scale/2 per element before aggregation).
    Forward-only by design: it sits on the layer-0 data path where the
    quantized inputs carry no gradient (differentiable paths go through
    :func:`gather_scale_segment_sum_pallas` on decoded rows).
    """
    S, F = q.shape
    bf = _pick_bf(F) if bf is None else bf
    _assert_vmem(fused_vmem_floats(S, num_dst, F, be=be, bn=bn, bf=bf)
                 + (-(-S // SUBLANE) * SUBLANE) * META_COLS,
                 what="gather_scale_segment_sum_q_pallas")
    E = edge_src.shape[0]
    Ep = _pad_edges(E, be)
    Fp = -(-F // bf) * bf
    Sp = -(-S // SUBLANE) * SUBLANE
    pad_seg = num_dst
    Np = -(-(num_dst + 1) // bn) * bn

    q_p = jnp.zeros((Sp, Fp), jnp.uint8).at[:S, :F].set(
        q.astype(jnp.uint8))
    # pad rows keep mn = scale = 0 so they dequantize to exact zeros
    meta_p = jnp.zeros((Sp, META_COLS), jnp.float32)
    meta_p = meta_p.at[:S, 0:1].set(mn.astype(jnp.float32))
    meta_p = meta_p.at[:S, 1:2].set(scale.astype(jnp.float32))
    src_p = jnp.zeros((Ep,), jnp.int32).at[:E].set(
        edge_src.astype(jnp.int32))
    dst_p = jnp.full((Ep,), pad_seg, jnp.int32).at[:E].set(
        edge_dst.astype(jnp.int32))
    coef_p = jnp.zeros((Ep,), jnp.float32).at[:E].set(
        coef.astype(jnp.float32))

    grid = (Fp // bf, Np // bn, Ep // be)
    out = pl.pallas_call(
        functools.partial(_fused_q_kernel, bn=bn, sp=Sp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((be,), lambda f, n, e: (e,)),
            pl.BlockSpec((be,), lambda f, n, e: (e,)),
            pl.BlockSpec((be,), lambda f, n, e: (e,)),
            pl.BlockSpec((Sp, bf), lambda f, n, e: (0, f)),
            pl.BlockSpec((Sp, META_COLS), lambda f, n, e: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda f, n, e: (n, f)),
        out_shape=jax.ShapeDtypeStruct((Np, Fp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bf), jnp.float32)],
        interpret=interpret,
    )(src_p, dst_p, coef_p, q_p, meta_p)
    return out[:num_dst, :F]


def edge_tile_density(edge_src, edge_dst, num_dst: int, *,
                      be: int = DEFAULT_BE, bn: int = DEFAULT_BN) -> dict:
    """Pure-numpy VMEM-residency / tile-density metrics of the blocked
    kernels for a given edge ordering (what ``--reorder`` improves).

    Returns a dict:

    * ``active_tile_frac`` — fraction of (dst-tile, edge-tile) grid
      cells holding at least one real edge.  The blocked scatter sweeps
      the full ``n_tiles x e_tiles`` grid regardless, so a low fraction
      is both wasted work today and the headroom a tile-skipping kernel
      would reclaim; locality reordering concentrates edges into few
      cells.
    * ``src_rows_per_edge_tile`` — mean distinct source rows gathered
      per edge tile, normalized by the tile's edge count (1.0 = every
      edge hits a different row, lower = gathers reuse VMEM-resident
      rows within the tile).
    """
    src = np.asarray(edge_src, np.int64)
    dst = np.asarray(edge_dst, np.int64)
    E = len(src)
    if E == 0:
        return {"active_tile_frac": 0.0, "src_rows_per_edge_tile": 0.0}
    e_tiles = -(-E // be)
    n_tiles = -(-(num_dst + 1) // bn)
    e_idx = np.arange(E) // be
    cells = np.unique(e_idx * n_tiles + dst // bn)
    rows = []
    for t in range(e_tiles):
        chunk = src[t * be:(t + 1) * be]
        rows.append(len(np.unique(chunk)) / len(chunk))
    return {
        "active_tile_frac": len(cells) / (n_tiles * e_tiles),
        "src_rows_per_edge_tile": float(np.mean(rows)),
    }


def fused_vmem_floats(num_src: int, num_dst: int, F: int, *,
                      be: int = DEFAULT_BE, bn: int = DEFAULT_BN,
                      bf: int | None = None) -> int:
    """Per-step VMEM working set (floats) of the fused kernel AND its
    VJP — the largest of: the forward (source slab resident), the
    swapped backward (grad slab of ``num_dst`` rows resident), and the
    edge-dot kernel (both slabs + both one-hots resident).  Dispatch
    layers use :func:`fused_fits` to fall back to the unfused blocked
    kernel (whose working set is row-count independent) when the slab
    would not fit."""
    bf = _pick_bf(F) if bf is None else bf
    Sp = -(-num_src // SUBLANE) * SUBLANE
    Gp = -(-num_dst // SUBLANE) * SUBLANE

    def fused_set(sp):
        # resident slab + src one-hot + msgs + dst one-hot + out/acc + ids
        return sp * bf + be * sp + be * bf + be * bn + 2 * bn * bf + 3 * be

    edge_dot_set = ((Sp + Gp) * bf + be * (Sp + Gp) + 2 * be * bf
                    + 4 * be)
    return max(fused_set(Sp), fused_set(Gp), edge_dot_set)


def fused_fits(num_src: int, num_dst: int, F: int, *,
               be: int = DEFAULT_BE, bn: int = DEFAULT_BN,
               bf: int | None = None) -> bool:
    """True iff the fused kernel (fwd + VJP) fits the VMEM budget for
    these row counts — the capacity predicate behind the automatic
    fused/unfused dispatch in :mod:`repro.kernels.ops`."""
    return 4 * fused_vmem_floats(num_src, num_dst, F, be=be, bn=bn,
                                 bf=bf) <= VMEM_BUDGET


# ---------------------------------------------------------------------------
# analytic HBM traffic models (the roofline the bench reports)
# ---------------------------------------------------------------------------

def _tiles(n: int, b: int) -> int:
    return max(-(-n // b), 1)


def hbm_bytes_jax_ops(E: int, F: int, num_dst: int, *,
                      itemsize: int = 4) -> dict:
    """Modeled HBM traffic of the unfused XLA path (``jnp.take`` then
    ``jax.ops.segment_sum``): the (E, F) message tensor is written and
    re-read around the scatter, and the backward gathers/scatters it
    again.  Terms per pass are listed in the returned dict."""
    msgs = E * F * itemsize
    out = num_dst * F * itemsize
    ids = E * 4
    fwd = (msgs          # gather reads E source rows
           + msgs        # write materialized messages
           + msgs + ids  # scatter-add re-reads messages + ids
           + out)        # write aggregate
    bwd = (out           # read grad_out
           + msgs        # gather grad_out[seg_ids] -> grad_msgs (write)
           + msgs + ids  # unscale/scatter grad_msgs back to sources
           + msgs)       # write dh
    return {"fwd": fwd, "bwd": bwd, "total": fwd + bwd}


def hbm_bytes_unfused_kernel(E: int, F: int, num_dst: int, *,
                             be: int = DEFAULT_BE, bn: int = DEFAULT_BN,
                             bf: int | None = None,
                             itemsize: int = 4) -> dict:
    """Modeled HBM traffic of XLA gather+scale followed by the blocked
    Pallas scatter kernel.  The scatter grid (N/BN, F/BF, E/BE) re-reads
    every edge tile once per *node* tile — the price of keeping output
    tiles resident — and the backward gather grid (E/BE, F/BF, N/BN)
    dually re-reads grad_out once per edge tile."""
    bf = _pick_bf(F) if bf is None else bf
    Fp = _tiles(F, bf) * bf
    Ep = _pad_edges(E, be)
    Np = _tiles(num_dst + 1, bn) * bn
    n_tiles = Np // bn
    e_tiles = Ep // be
    f_tiles = Fp // bf
    msgs = E * F * itemsize
    fwd = (msgs                            # XLA gather reads source rows
           + Ep * Fp * itemsize           # write padded messages
           + n_tiles * (Ep * Fp * itemsize            # kernel re-reads
                        + f_tiles * Ep * 4)           # msgs + ids per n
           + Np * Fp * itemsize)          # write aggregate
    bwd = (e_tiles * (Np * Fp * itemsize              # grad_out per e
                      + f_tiles * Ep * 4)             # ids
           + Ep * Fp * itemsize           # write grad_msgs
           + 2 * msgs)                    # XLA unscale/scatter to dh
    return {"fwd": fwd, "bwd": bwd, "total": fwd + bwd}


def hbm_bytes_fused_kernel(E: int, F: int, num_dst: int, num_src: int, *,
                           be: int = DEFAULT_BE, bn: int = DEFAULT_BN,
                           bf: int | None = None,
                           itemsize: int = 4) -> dict:
    """Modeled HBM traffic of :func:`gather_scale_segment_sum_pallas`.
    The source slab crosses HBM once per feature tile (its block index is
    constant over the inner (n, e) sweep); edge ids + coef are re-read
    once per (feature, node) tile pair; the (E, F) message tensor
    contributes nothing.  Backward = the same kernel (src/dst swapped)
    plus the edge-dot kernel, which re-reads both feature slabs once per
    edge tile."""
    bf = _pick_bf(F) if bf is None else bf
    Fp = _tiles(F, bf) * bf
    Ep = _pad_edges(E, be)
    Np = _tiles(num_dst + 1, bn) * bn
    Sp = _tiles(num_src, SUBLANE) * SUBLANE
    n_tiles = Np // bn
    e_tiles = Ep // be
    f_tiles = Fp // bf

    def one_fused(sp, np_):
        return (sp * Fp * itemsize                      # source slab once
                + f_tiles * (np_ // bn) * Ep * 12       # src+dst+coef
                + np_ * Fp * itemsize)                  # write out

    fwd = one_fused(Sp, Np)
    Gp = _tiles(num_dst, SUBLANE) * SUBLANE      # bwd slab = grad_out
    Np_b = _tiles(num_src + 1, bn) * bn
    edge_dot = (e_tiles * (Sp + Gp) * Fp * itemsize     # both slabs per e
                + f_tiles * Ep * 8 + Ep * itemsize)     # ids + dcoef out
    bwd = one_fused(Gp, Np_b) + edge_dot
    return {"fwd": fwd, "bwd": bwd, "total": fwd + bwd}


def hbm_bytes_fused_q_kernel(E: int, F: int, num_dst: int, num_src: int, *,
                             be: int = DEFAULT_BE, bn: int = DEFAULT_BN,
                             bf: int | None = None) -> dict:
    """Modeled HBM traffic of :func:`gather_scale_segment_sum_q_pallas`
    (forward-only).  The source slab crosses HBM at 1 byte/element plus
    8 bytes/row of metadata instead of 4 bytes/element — AND the
    decode round-trip of the wire path (read q, write fp32 rows, re-read
    them in the kernel) disappears entirely."""
    bf = _pick_bf(F) if bf is None else bf
    Fp = _tiles(F, bf) * bf
    Ep = _pad_edges(E, be)
    Np = _tiles(num_dst + 1, bn) * bn
    Sp = _tiles(num_src, SUBLANE) * SUBLANE
    f_tiles = Fp // bf
    fwd = (Sp * Fp * 1                              # int8 slab once
           + f_tiles * Sp * META_COLS * 4           # metadata per f tile
           + f_tiles * (Np // bn) * Ep * 12         # src+dst+coef
           + Np * Fp * 4)                           # write fp32 out
    # what the decode-then-fp32 path would have paid on top of the
    # fp32 fused kernel: read q + meta, write the fp32 feature matrix
    decode_roundtrip = num_src * F * 1 + num_src * 8 + num_src * F * 4
    return {"fwd": fwd, "total": fwd,
            "decode_roundtrip_avoided": decode_roundtrip}
