"""Pallas TPU kernel: blocked segment-sum (the GNN aggregation hot-spot).

The survey's Gather phase is a sparse scatter-add on GPUs.  TPUs have no
efficient scatter, so we re-express the reduction as a *blocked one-hot
matmul* (MXU-friendly; the NeuGraph/GridGraph 2D-grid idea as BlockSpec
tiling):

    out[nb, fb] += onehot(seg_ids[eb] - nb0).T @ msgs[eb, fb]

Grid = (N/BN, F/BF, E/BE) with the edge dimension innermost, so each
(node-tile, feature-tile) output block stays resident in VMEM while all
edge tiles accumulate into it.

VMEM working set per step: BE*BF (msgs) + BE*BN (one-hot) + BN*BF (acc)
= 128*128*3 floats ≈ 192 KiB with the default tiles — comfortably inside
the ~16 MiB VMEM budget, with all matmul dims 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BE = 128   # edge tile
DEFAULT_BN = 128   # segment (node) tile
DEFAULT_BF = 128   # feature tile


def _kernel(ids_ref, msgs_ref, out_ref, acc_ref, *, bn: int):
    n_i = pl.program_id(0)
    e_i = pl.program_id(2)
    ne = pl.num_programs(2)

    ids = ids_ref[:]                                   # (BE,)
    base = n_i * bn
    local = ids - base
    onehot = (local[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, bn), 1)).astype(jnp.float32)    # (BE, BN)
    msgs = msgs_ref[:].astype(jnp.float32)             # (BE, BF)
    contrib = jnp.dot(onehot.T, msgs,
                      preferred_element_type=jnp.float32)  # (BN, BF)

    @pl.when(e_i == 0)
    def _init():
        acc_ref[:] = contrib

    @pl.when(e_i != 0)
    def _acc():
        acc_ref[:] = acc_ref[:] + contrib

    @pl.when(e_i == ne - 1)
    def _emit():
        out_ref[:] = acc_ref[:].astype(out_ref.dtype)


def segment_sum_pallas(msgs: jax.Array, seg_ids: jax.Array,
                       num_segments: int, *,
                       be: int = DEFAULT_BE, bn: int = DEFAULT_BN,
                       bf: int = DEFAULT_BF,
                       interpret: bool = True) -> jax.Array:
    """msgs: (E, F); seg_ids: (E,) int32.  E, F, num_segments are padded to
    tile multiples here (ids padded to num_segments => masked out by the
    one-hot against valid tiles... padded ids point at a padded segment row
    which is dropped on return)."""
    E, F = msgs.shape
    Ep = -(-E // be) * be
    Fp = -(-F // bf) * bf
    # one sacrificial segment row absorbs padded edges
    pad_seg = num_segments
    Np = -(-(num_segments + 1) // bn) * bn

    msgs_p = jnp.zeros((Ep, Fp), msgs.dtype).at[:E, :F].set(msgs)
    ids_p = jnp.full((Ep,), pad_seg, jnp.int32).at[:E].set(
        seg_ids.astype(jnp.int32))

    grid = (Np // bn, Fp // bf, Ep // be)
    out = pl.pallas_call(
        functools.partial(_kernel, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((be,), lambda n, f, e: (e,)),
            pl.BlockSpec((be, bf), lambda n, f, e: (e, f)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda n, f, e: (n, f)),
        out_shape=jax.ShapeDtypeStruct((Np, Fp), msgs.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bf), jnp.float32)],
        interpret=interpret,
    )(ids_p, msgs_p)
    return out[:num_segments, :F]
