"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU — see DESIGN.md).  On a TPU backend the same call sites
compile the real kernels.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import segment_sum as _ss
from repro.kernels import ssd_chunk as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("num_segments",))
def segment_sum(msgs, seg_ids, num_segments: int):
    return _ss.segment_sum_pallas(msgs, seg_ids, num_segments,
                                  interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    return _fa.flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=not _on_tpu())


@functools.partial(jax.jit)
def ssd_chunk_state(x, dt, A, Bm):
    return _ssd.ssd_chunk_state_pallas(x, dt, A, Bm,
                                       interpret=not _on_tpu())
