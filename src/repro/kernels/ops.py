"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU — see DESIGN.md).  On a TPU backend the same call
sites compile the real kernels.

The backend is resolved *per call* in a plain-Python wrapper and passed
into the jit as a static argument.  (The previous design read
``jax.default_backend()`` at first trace inside an ``@jax.jit`` body;
the jit cache never revisits a traced constant, so a process that traced
once on CPU — e.g. an import-time warmup before TPU init — silently
pinned interpret mode for its whole lifetime.)  A caller that embeds
these wrappers inside its own ``jit`` still resolves the backend at its
own trace time, which is the earliest point a backend exists for it.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core import telemetry
from repro.kernels import flash_attention as _fa
from repro.kernels import gat_fused as _gat
from repro.kernels import segment_sum as _ss
from repro.kernels import ssd_chunk as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Dispatch counters.  The wrapper bodies below run when Python calls them
# — eagerly, or ONCE per shape at trace time when embedded in an outer
# ``jit`` — so these count *dispatch decisions* (which implementation the
# capacity check selected for a shape), not per-step kernel launches.
_m_dispatch_ss = telemetry.counter(
    "kernel_dispatch_total", "kernel wrapper dispatch decisions "
    "(trace-time inside jit)", kernel="segment_sum", impl="blocked")
_m_dispatch_fused = telemetry.counter(
    "kernel_dispatch_total", kernel="gather_scale_segment_sum",
    impl="fused")
_m_dispatch_unfused = telemetry.counter(
    "kernel_dispatch_total", kernel="gather_scale_segment_sum",
    impl="unfused_fallback")
# Modeled HBM traffic (total fwd+bwd bytes) of the most recent dispatch,
# from the analytic models in :mod:`repro.kernels.segment_sum`
_m_hbm_fused = telemetry.gauge(
    "kernel_hbm_model_bytes", "modeled HBM bytes (fwd+bwd) of the latest "
    "dispatched shape", kernel="gather_scale_segment_sum", impl="fused")
_m_hbm_unfused = telemetry.gauge(
    "kernel_hbm_model_bytes", kernel="gather_scale_segment_sum",
    impl="unfused_fallback")
_m_dispatch_gat_fused = telemetry.counter(
    "kernel_dispatch_total", kernel="gat_attention", impl="fused_one_pass")
_m_dispatch_gat_multipass = telemetry.counter(
    "kernel_dispatch_total", kernel="gat_attention",
    impl="multipass_fallback")
_m_dispatch_q = telemetry.counter(
    "kernel_dispatch_total", kernel="gather_scale_segment_sum",
    impl="fused_int8_in")
_m_hbm_gat_fused = telemetry.gauge(
    "kernel_hbm_model_bytes", kernel="gat_attention", impl="fused_one_pass")
_m_hbm_gat_multipass = telemetry.gauge(
    "kernel_hbm_model_bytes", kernel="gat_attention",
    impl="multipass_fallback")
# VMEM-residency / tile-density of the most recently recorded edge
# ordering (host-side: launchers and benches call record_tile_density;
# edge ids are tracers inside jit, so the wrappers cannot)
_m_tile_active = telemetry.gauge(
    "kernel_tile_density", "blocked-kernel tile locality of the current "
    "edge ordering", metric="active_tile_frac")
_m_tile_rows = telemetry.gauge(
    "kernel_tile_density", metric="src_rows_per_edge_tile")


def record_tile_density(edge_src, edge_dst, num_dst: int) -> dict:
    """Compute and publish the tile-density metrics of an edge ordering
    (``--reorder`` moves these; the kernel byte models assume dense
    tiles, so active_tile_frac is the fraction of that model actually
    exercised).  Host-side numpy — call outside jit."""
    d = _ss.edge_tile_density(edge_src, edge_dst, num_dst)
    _m_tile_active.set(d["active_tile_frac"])
    _m_tile_rows.set(d["src_rows_per_edge_tile"])
    return d


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def _segment_sum_jit(msgs, seg_ids, num_segments: int, interpret: bool):
    return _ss.segment_sum_pallas(msgs, seg_ids, num_segments,
                                  interpret=interpret)


def segment_sum(msgs, seg_ids, num_segments: int):
    """Differentiable blocked segment-sum (scatter-add); the VJP is a
    blocked gather kernel.  See :mod:`repro.kernels.segment_sum`."""
    _m_dispatch_ss.inc()
    return _segment_sum_jit(msgs, seg_ids, num_segments,
                            interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("num_dst", "interpret"))
def _gss_jit(h, edge_src, edge_dst, coef, num_dst: int, interpret: bool):
    return _ss.gather_scale_segment_sum_pallas(h, edge_src, edge_dst,
                                               coef, num_dst,
                                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_dst", "interpret"))
def _gss_unfused_jit(h, edge_src, edge_dst, coef, num_dst: int,
                     interpret: bool):
    msgs = jnp.take(h, edge_src, axis=0) * coef[:, None]
    return _ss.segment_sum_pallas(msgs, edge_dst, num_dst,
                                  interpret=interpret)


_fallback_warned: set = set()


def gather_scale_segment_sum(h, edge_src, edge_dst, coef, num_dst: int):
    """Fused differentiable gather -> per-edge scale -> segment-sum:
    ``out[d] = sum_{e: edge_dst[e]=d} coef[e] * h[edge_src[e]]`` without
    materializing the (E, F) message tensor in HBM.  Fold the edge mask
    into ``coef``.

    Capacity dispatch: the fused kernel keeps an (S, BF) source slab
    VMEM-resident, which stops fitting somewhere in the thousands of
    rows (exact bound depends on F).  When
    :func:`repro.kernels.segment_sum.fused_fits` says no — e.g. a large
    single-device full graph, where the distributed layouts would have
    sharded the rows — this falls back to XLA gather+scale feeding the
    blocked scatter kernel, whose working set is row-count independent,
    so ``use_kernel=True`` never hits the VMEM assert from this path.
    """
    S, F = h.shape
    E = len(edge_src)
    interpret = not _on_tpu()
    if not _ss.fused_fits(S, num_dst, F):
        key = (S, num_dst, F)
        if key not in _fallback_warned:      # surface the dispatch once
            _fallback_warned.add(key)
            warnings.warn(
                f"gather_scale_segment_sum: fused-kernel VMEM slab for "
                f"num_src={S}, num_dst={num_dst}, F={F} exceeds the "
                f"budget; dispatching to the unfused blocked kernel "
                f"(the (E, F) message tensor WILL cross HBM)")
        _m_dispatch_unfused.inc()
        _m_hbm_unfused.set(
            _ss.hbm_bytes_unfused_kernel(E, F, num_dst)["total"])
        return _gss_unfused_jit(h, edge_src, edge_dst, coef, num_dst,
                                interpret=interpret)
    _m_dispatch_fused.inc()
    _m_hbm_fused.set(_ss.hbm_bytes_fused_kernel(E, F, num_dst, S)["total"])
    return _gss_jit(h, edge_src, edge_dst, coef, num_dst,
                    interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_dst", "interpret"))
def _gss_q_jit(q, mn, scale, edge_src, edge_dst, coef, num_dst: int,
               interpret: bool):
    return _ss.gather_scale_segment_sum_q_pallas(
        q, mn, scale, edge_src, edge_dst, coef, num_dst,
        interpret=interpret)


def gather_scale_segment_sum_q(q, mn, scale, edge_src, edge_dst, coef,
                               num_dst: int):
    """int8-in / fp32-accumulate fused aggregation: source rows arrive
    as wire-format uint8 codes + per-row (min, scale) metadata and are
    dequantized inside the kernel per source slab — the fp32 feature
    matrix never exists in HBM.  Forward-only (layer-0 data path).

    Same capacity dispatch as :func:`gather_scale_segment_sum`: when the
    slab does not fit, fall back to dequantize-in-XLA feeding the
    blocked scatter kernel (correctness identical — the decode
    round-trip saving is a fits-only optimization)."""
    S, F = q.shape
    E = len(edge_src)
    interpret = not _on_tpu()
    if not _ss.fused_fits(S, num_dst, F):
        _m_dispatch_unfused.inc()
        _m_hbm_unfused.set(
            _ss.hbm_bytes_unfused_kernel(E, F, num_dst)["total"])
        h = (mn + q.astype(jnp.float32) * scale).astype(jnp.float32)
        return _gss_unfused_jit(h, edge_src, edge_dst, coef, num_dst,
                                interpret=interpret)
    _m_dispatch_q.inc()
    _m_hbm_fused.set(
        _ss.hbm_bytes_fused_q_kernel(E, F, num_dst, S)["fwd"])
    return _gss_q_jit(q, mn, scale, edge_src, edge_dst, coef, num_dst,
                      interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("num_dst", "heads", "interpret"))
def _gat_fused_jit(hs, es, ed, edge_src, edge_dst, mask, num_dst: int,
                   heads: int, interpret: bool):
    return _gat.gat_fused_attention_pallas(hs, es, ed, edge_src,
                                           edge_dst, mask, num_dst,
                                           heads=heads,
                                           interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("num_dst", "heads", "interpret"))
def _gat_multipass_jit(hs, es, ed, edge_src, edge_dst, mask,
                       num_dst: int, heads: int, interpret: bool):
    """The multi-pass kernel path the fused kernel replaces: logits and
    alphas materialize as (E, heads) tensors; the segment reductions run
    through the blocked Pallas kernels (mirrors
    ``abstraction.segment_softmax`` + ``segment_sum`` in kernel mode)."""
    E = edge_src.shape[0]
    hd = hs.shape[1] // heads
    maskf = mask.astype(jnp.float32)
    pre = (jnp.take(es, edge_src, axis=0)
           + jnp.take(ed, edge_dst, axis=0))
    logits = jax.nn.leaky_relu(pre, 0.2)
    neg = jnp.asarray(-1e30, logits.dtype)
    logits = jnp.where(maskf[:, None] > 0, logits, neg)
    mx = jax.ops.segment_max(logits, edge_dst, num_dst,
                             indices_are_sorted=False)
    ex = jnp.exp(logits - mx[edge_dst]) * maskf[:, None]
    den = _ss.segment_sum_pallas(ex, edge_dst, num_dst,
                                 interpret=interpret)
    alpha = ex / (den[edge_dst] + 1e-9)
    msgs = (jnp.take(hs.reshape(-1, heads, hd), edge_src, axis=0)
            * alpha[..., None])
    return _ss.segment_sum_pallas(msgs.reshape(E, heads * hd), edge_dst,
                                  num_dst, interpret=interpret)


_gat_fallback_warned: set = set()


def gat_attention(hs, es, ed, edge_src, edge_dst, mask, num_dst: int, *,
                  heads: int):
    """One-pass fused GAT attention aggregation (differentiable).

    ``hs``: (num_src, heads·hd) projected source features; ``es``/``ed``:
    per-head logit halves; returns (num_dst, heads·hd) — per-destination
    softmax over ``leaky_relu(es[src] + ed[dst], 0.2)`` weighting a
    segment-sum of ``hs[src]``, computed in a single grid pass with an
    online softmax so edge logits/alphas never reach HBM (see
    :mod:`repro.kernels.gat_fused`).

    Capacity dispatch mirrors :func:`gather_scale_segment_sum`: when the
    source slabs exceed the VMEM budget the multi-pass kernel path runs
    instead, so ``use_kernel=True`` GAT never hits the VMEM assert."""
    S = hs.shape[0]
    E = len(edge_src)
    hd = hs.shape[1] // heads
    interpret = not _on_tpu()
    if not _gat.gat_fused_fits(S, num_dst, heads, hd):
        key = (S, num_dst, heads, hd)
        if key not in _gat_fallback_warned:
            _gat_fallback_warned.add(key)
            warnings.warn(
                f"gat_attention: fused one-pass VMEM working set for "
                f"num_src={S}, num_dst={num_dst}, heads={heads}, hd={hd} "
                f"exceeds the budget; dispatching to the multi-pass "
                f"kernel path (edge logits/alphas WILL cross HBM)")
        _m_dispatch_gat_multipass.inc()
        _m_hbm_gat_multipass.set(
            _gat.hbm_bytes_gat_multipass(E, heads, hd, num_dst,
                                         S)["total"])
        return _gat_multipass_jit(hs, es, ed, edge_src, edge_dst, mask,
                                  num_dst, heads, interpret=interpret)
    _m_dispatch_gat_fused.inc()
    _m_hbm_gat_fused.set(
        _gat.hbm_bytes_gat_fused(E, heads, hd, num_dst, S)["total"])
    return _gat_fused_jit(hs, es, ed, edge_src, edge_dst, mask, num_dst,
                          heads, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret"))
def _flash_attention_jit(q, k, v, causal: bool, window: int,
                         interpret: bool):
    return _fa.flash_attention_pallas(q, k, v, causal=causal,
                                      window=window, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    return _flash_attention_jit(q, k, v, causal, window,
                                interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("interpret",))
def _ssd_chunk_state_jit(x, dt, A, Bm, interpret: bool):
    return _ssd.ssd_chunk_state_pallas(x, dt, A, Bm, interpret=interpret)


def ssd_chunk_state(x, dt, A, Bm):
    return _ssd_chunk_state_jit(x, dt, A, Bm, interpret=not _on_tpu())
