"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU — see DESIGN.md).  On a TPU backend the same call
sites compile the real kernels.

The backend is resolved *per call* in a plain-Python wrapper and passed
into the jit as a static argument.  (The previous design read
``jax.default_backend()`` at first trace inside an ``@jax.jit`` body;
the jit cache never revisits a traced constant, so a process that traced
once on CPU — e.g. an import-time warmup before TPU init — silently
pinned interpret mode for its whole lifetime.)  A caller that embeds
these wrappers inside its own ``jit`` still resolves the backend at its
own trace time, which is the earliest point a backend exists for it.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core import telemetry
from repro.kernels import flash_attention as _fa
from repro.kernels import segment_sum as _ss
from repro.kernels import ssd_chunk as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Dispatch counters.  The wrapper bodies below run when Python calls them
# — eagerly, or ONCE per shape at trace time when embedded in an outer
# ``jit`` — so these count *dispatch decisions* (which implementation the
# capacity check selected for a shape), not per-step kernel launches.
_m_dispatch_ss = telemetry.counter(
    "kernel_dispatch_total", "kernel wrapper dispatch decisions "
    "(trace-time inside jit)", kernel="segment_sum", impl="blocked")
_m_dispatch_fused = telemetry.counter(
    "kernel_dispatch_total", kernel="gather_scale_segment_sum",
    impl="fused")
_m_dispatch_unfused = telemetry.counter(
    "kernel_dispatch_total", kernel="gather_scale_segment_sum",
    impl="unfused_fallback")
# Modeled HBM traffic (total fwd+bwd bytes) of the most recent dispatch,
# from the analytic models in :mod:`repro.kernels.segment_sum`
_m_hbm_fused = telemetry.gauge(
    "kernel_hbm_model_bytes", "modeled HBM bytes (fwd+bwd) of the latest "
    "dispatched shape", kernel="gather_scale_segment_sum", impl="fused")
_m_hbm_unfused = telemetry.gauge(
    "kernel_hbm_model_bytes", kernel="gather_scale_segment_sum",
    impl="unfused_fallback")


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def _segment_sum_jit(msgs, seg_ids, num_segments: int, interpret: bool):
    return _ss.segment_sum_pallas(msgs, seg_ids, num_segments,
                                  interpret=interpret)


def segment_sum(msgs, seg_ids, num_segments: int):
    """Differentiable blocked segment-sum (scatter-add); the VJP is a
    blocked gather kernel.  See :mod:`repro.kernels.segment_sum`."""
    _m_dispatch_ss.inc()
    return _segment_sum_jit(msgs, seg_ids, num_segments,
                            interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("num_dst", "interpret"))
def _gss_jit(h, edge_src, edge_dst, coef, num_dst: int, interpret: bool):
    return _ss.gather_scale_segment_sum_pallas(h, edge_src, edge_dst,
                                               coef, num_dst,
                                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_dst", "interpret"))
def _gss_unfused_jit(h, edge_src, edge_dst, coef, num_dst: int,
                     interpret: bool):
    msgs = jnp.take(h, edge_src, axis=0) * coef[:, None]
    return _ss.segment_sum_pallas(msgs, edge_dst, num_dst,
                                  interpret=interpret)


_fallback_warned: set = set()


def gather_scale_segment_sum(h, edge_src, edge_dst, coef, num_dst: int):
    """Fused differentiable gather -> per-edge scale -> segment-sum:
    ``out[d] = sum_{e: edge_dst[e]=d} coef[e] * h[edge_src[e]]`` without
    materializing the (E, F) message tensor in HBM.  Fold the edge mask
    into ``coef``.

    Capacity dispatch: the fused kernel keeps an (S, BF) source slab
    VMEM-resident, which stops fitting somewhere in the thousands of
    rows (exact bound depends on F).  When
    :func:`repro.kernels.segment_sum.fused_fits` says no — e.g. a large
    single-device full graph, where the distributed layouts would have
    sharded the rows — this falls back to XLA gather+scale feeding the
    blocked scatter kernel, whose working set is row-count independent,
    so ``use_kernel=True`` never hits the VMEM assert from this path.
    """
    S, F = h.shape
    E = len(edge_src)
    interpret = not _on_tpu()
    if not _ss.fused_fits(S, num_dst, F):
        key = (S, num_dst, F)
        if key not in _fallback_warned:      # surface the dispatch once
            _fallback_warned.add(key)
            warnings.warn(
                f"gather_scale_segment_sum: fused-kernel VMEM slab for "
                f"num_src={S}, num_dst={num_dst}, F={F} exceeds the "
                f"budget; dispatching to the unfused blocked kernel "
                f"(the (E, F) message tensor WILL cross HBM)")
        _m_dispatch_unfused.inc()
        _m_hbm_unfused.set(
            _ss.hbm_bytes_unfused_kernel(E, F, num_dst)["total"])
        return _gss_unfused_jit(h, edge_src, edge_dst, coef, num_dst,
                                interpret=interpret)
    _m_dispatch_fused.inc()
    _m_hbm_fused.set(_ss.hbm_bytes_fused_kernel(E, F, num_dst, S)["total"])
    return _gss_jit(h, edge_src, edge_dst, coef, num_dst,
                    interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret"))
def _flash_attention_jit(q, k, v, causal: bool, window: int,
                         interpret: bool):
    return _fa.flash_attention_pallas(q, k, v, causal=causal,
                                      window=window, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    return _flash_attention_jit(q, k, v, causal, window,
                                interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("interpret",))
def _ssd_chunk_state_jit(x, dt, A, Bm, interpret: bool):
    return _ssd.ssd_chunk_state_pallas(x, dt, A, Bm, interpret=interpret)


def ssd_chunk_state(x, dt, A, Bm):
    return _ssd_chunk_state_jit(x, dt, A, Bm, interpret=not _on_tpu())
