"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def segment_sum(msgs: jax.Array, seg_ids: jax.Array,
                num_segments: int) -> jax.Array:
    """msgs: (E, F); seg_ids: (E,) int32 in [0, num_segments)."""
    return jax.ops.segment_sum(msgs, seg_ids, num_segments)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, K, Skv, hd) with H = K * G.
    Dense softmax attention reference (fp32 accumulation)."""
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    G = H // K
    qg = q.reshape(B, K, G, Sq, hd).astype(jnp.float32)
    scale = 1.0 / np.sqrt(hd)
    logits = jnp.einsum("bkgqh,bksh->bkgqs", qg * scale,
                        k.astype(jnp.float32))
    Skv = k.shape[2]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos + (Skv - Sq)   # aligned at the end
    if window:
        mask &= kpos > qpos + (Skv - Sq) - window
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", w, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, hd).astype(q.dtype)


def ssd_chunk_state(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array) -> jax.Array:
    """Per-chunk SSD state: x: (B, L, H, P); dt: (B, L, H); A: (H,);
    Bm: (B, L, G, N).  Returns (B, H, P, N) = sum_l decay_l * dt_l *
    B_l ⊗ x_l with decay to chunk end."""
    rep = x.shape[2] // Bm.shape[2]
    Bh = jnp.repeat(Bm, rep, axis=2)
    dA = dt.astype(jnp.float32) * A
    cum = jnp.cumsum(dA, axis=1)
    decay = jnp.exp(cum[:, -1:, :] - cum)
    xdt = x.astype(jnp.float32) * dt[..., None].astype(jnp.float32)
    return jnp.einsum("blhn,blh,blhp->bhpn", Bh.astype(jnp.float32),
                      decay, xdt)
