"""Pallas TPU kernel: Mamba2 SSD per-chunk state computation.

Computes the per-chunk state contribution
    state[b, h, p, n] = sum_l  exp(cumA_L - cumA_l) * dt_l * x[l,h,p] * B[l,h,n]
for one chunk — the matmul-rich inner step of the SSD algorithm
(arXiv:2405.21060, Listing 1 'chunk state').  Grid = (B, H/BH) with the
full chunk length L resident in VMEM; the outer recurrence across chunks
stays in XLA (cheap, elementwise).

VMEM per step: L*P (x) + L*N (B) + 2*L (dt, decay) + P*N (out) floats —
with L=256, P=64, N=128: ~0.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, out_ref):
    # blocks: x (1, L, BH, P), dt (1, L, BH), a (BH,), b (1, L, BH, N)
    x = x_ref[0].astype(jnp.float32)            # (L, BH, P)
    dt = dt_ref[0].astype(jnp.float32)          # (L, BH)
    A = a_ref[:].astype(jnp.float32)            # (BH,)
    Bm = b_ref[0].astype(jnp.float32)           # (L, BH, N)

    dA = dt * A[None, :]                        # (L, BH)
    cum = jnp.cumsum(dA, axis=0)
    decay = jnp.exp(cum[-1:, :] - cum)          # (L, BH)
    w = decay * dt                              # (L, BH)
    xw = x * w[:, :, None]                      # (L, BH, P)
    # state[h] = x_w[:, h, :].T @ B[:, h, :]  -> (P, N) per head
    out = jax.lax.dot_general(
        xw, Bm,
        dimension_numbers=(((0,), (0,)), ((1,), (1,))),
        preferred_element_type=jnp.float32)     # (BH, P, N)
    out_ref[0] = out.astype(out_ref.dtype)


def ssd_chunk_state_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                           Bm: jax.Array, *, bh: int = 8,
                           interpret: bool = True) -> jax.Array:
    """x: (B, L, H, P); dt: (B, L, H); A: (H,); Bm: (B, L, G, N) with G
    groups broadcast to H.  Returns (B, H, P, N) fp32."""
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)            # (B, L, H, N)
    bh = min(bh, H)
    assert H % bh == 0

    grid = (Bsz, H // bh)
    out = pl.pallas_call(
        functools.partial(_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, bh, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, L, bh), lambda b, h: (b, 0, h)),
            pl.BlockSpec((bh,), lambda b, h: (h,)),
            pl.BlockSpec((1, L, bh, N), lambda b, h: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, bh, P, N), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        interpret=interpret,
    )(x, dt, A, Bh)
    return out
