"""Pallas TPU kernel: flash attention (causal / sliding-window), GQA-aware.

Online-softmax blocked attention: grid = (B, H, Sq/BQ, Skv/BK) with the KV
dimension innermost; running max/denominator/accumulator live in VMEM
scratch across KV steps, so the (Sq, Skv) probability matrix never touches
HBM — this is what removes the attention-probability HBM traffic that
dominates the dry-run memory roofline term (EXPERIMENTS.md §Perf).

Block shapes default to 128 (MXU-aligned); VMEM working set per step is
BQ*hd (q) + 2*BK*hd (k, v) + BQ*BK (logits) + BQ*hd (acc) ≈ 0.4 MiB at
128/128/128 in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, bq: int, bk: int, causal: bool, window: int,
            sq: int, skv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[:] = jnp.full_like(m_scr, NEG_INF)
        l_scr[:] = jnp.zeros_like(l_scr)
        acc_scr[:] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale         # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (BK, hd)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

    # absolute positions: queries are aligned to the END of the kv axis
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (skv - sq)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[:]                                   # (BQ, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[:] + jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_scr[:] = acc_scr[:] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[:] = m_new
    l_scr[:] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[:] / jnp.maximum(l_scr[:], 1e-30)).astype(
            o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, K, Skv, hd); H = K * G (GQA)."""
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / np.sqrt(hd)

    bq = min(bq, Sq)
    bk = min(bk, Skv)
    Sqp = -(-Sq // bq) * bq
    Skp = -(-Skv // bk) * bk
    qp = jnp.zeros((B, H, Sqp, hd), q.dtype).at[:, :, :Sq].set(q)
    kp = jnp.zeros((B, K, Skp, hd), k.dtype).at[:, :, :Skv].set(k)
    vp = jnp.zeros((B, K, Skp, hd), v.dtype).at[:, :, :Skv].set(v)

    grid = (B, H, Sqp // bq, Skp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bq=bq, bk=bk, causal=causal,
                          window=window, sq=Sq, skv=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=G: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=G: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq]
