"""Pallas TPU kernel: one-pass fused GAT attention aggregation.

The multi-pass GAT path (``segment_softmax`` + weighted ``segment_sum``)
makes three HBM round-trips per layer: edge logits are materialized,
re-read for the per-destination max/denominator, and the (E, heads)
alpha tensor plus the (E, heads·hd) message tensor cross HBM again for
the weighted reduction.  This kernel is the flash-attention treatment of
that pipeline (``kernels/flash_attention.py`` is the in-repo exemplar):

    grid = (D/BN, E/BE), edge tiles innermost.  Per destination tile,
    a running max ``m``, denominator ``l`` and weighted accumulator
    ``acc`` live in VMEM scratch across edge tiles; each edge tile
    gathers its source logit halves and per-head source features by
    one-hot matmuls against VMEM-resident slabs, forms the leaky-relu
    logits, and folds them into the online softmax —

        m' = max(m, tile_max)        l' = e^{m-m'} l + Σ e^{z-m'}
        acc' = e^{m-m'} acc + Σ e^{z-m'} · hs[src]

    — so edge logits and alphas NEVER reach HBM.  The final emit divides
    ``acc / (l + 1e-9)``, matching the reference denominator exactly.

Masked / padded edges carry ``mask = 0`` and contribute nothing (their
``p`` is forced to 0 before it can touch ``l`` or ``acc``); destinations
with no valid incoming edge emit exact zeros, like the reference.

**VJP.**  ``jax.custom_vjp`` with the flash-attention recompute strategy:
the backward recomputes the (E, heads) alphas once (heads is small — 4
floats per edge, not heads·hd), then routes every feature-dimension-heavy
cotangent through the existing fused Pallas kernels —

* ``dhs``  = per-head fused gather-scale-segment-sum with src/dst swapped,
* ``dalpha`` = per-head edge-dot kernel ``<hs[src], g[dst]>``,

followed by the closed-form softmax backward and two light (E, heads)
segment sums for ``des`` / ``ded``.  The (E, heads·hd) message tensor
exists in neither pass.  Gradients match the ``segment_softmax``
reference to ≤1e-5/param (asserted by ``tests/gat_train_check.py`` over
{1, 2} devices).

:func:`gat_fused_fits` is the VMEM capacity predicate; the
:mod:`repro.kernels.ops` dispatch falls back to the multi-pass kernel
path when the source slabs would not fit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.segment_sum import (DEFAULT_BE, DEFAULT_BN, SUBLANE,
                                       VMEM_BUDGET, _assert_vmem, _edge_dot,
                                       _fused_impl, _pad_edges, _pick_bf,
                                       fused_vmem_floats, hbm_bytes_jax_ops)

NEG_INF = -1e30
LEAKY_SLOPE = 0.2


def _pad8(n: int) -> int:
    return max(SUBLANE, -(-n // SUBLANE) * SUBLANE)


def _gat_kernel(src_ref, dst_ref, mask_ref, hs_ref, es_ref, ed_ref, o_ref,
                m_scr, l_scr, acc_scr, *, bn: int, sp: int, heads: int,
                hdp: int):
    n_i = pl.program_id(0)
    e_i = pl.program_id(1)
    ne = pl.num_programs(1)

    @pl.when(e_i == 0)
    def _init():
        m_scr[:] = jnp.full_like(m_scr, NEG_INF)
        l_scr[:] = jnp.zeros_like(l_scr)
        acc_scr[:] = jnp.zeros_like(acc_scr)

    src = src_ref[:]                                    # (BE,)
    onehot_s = (src[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, sp), 1)).astype(jnp.float32)     # (BE, Sp)
    es_e = jnp.dot(onehot_s, es_ref[:].astype(jnp.float32),
                   preferred_element_type=jnp.float32)  # (BE, Hp)

    local = dst_ref[:] - n_i * bn
    onehot_d = (local[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, bn), 1)).astype(jnp.float32)     # (BE, BN)
    ed_e = jnp.dot(onehot_d, ed_ref[:].astype(jnp.float32),
                   preferred_element_type=jnp.float32)  # (BE, Hp)

    pre = es_e + ed_e
    logits = jnp.where(pre >= 0, pre, LEAKY_SLOPE * pre)   # (BE, Hp)

    # edges outside this destination tile have an all-zero one-hot row;
    # fold that into the validity so they cannot touch max/denominator
    intile = jnp.sum(onehot_d, axis=1, keepdims=True)      # (BE, 1)
    veff = mask_ref[:].astype(jnp.float32)[:, None] * intile

    hs = hs_ref[:].astype(jnp.float32)                     # (Sp, H*hdp)
    for h in range(heads):                                 # static unroll
        sl = slice(h * hdp, (h + 1) * hdp)
        lh = logits[:, h:h + 1]                            # (BE, 1)
        cond = (onehot_d > 0.5) & (veff > 0.5)             # (BE, BN)
        tile_max = jnp.max(jnp.where(cond, lh, NEG_INF),
                           axis=0, keepdims=True)          # (1, BN)
        m_prev = m_scr[:, h:h + 1]                         # (BN, 1)
        m_new = jnp.maximum(m_prev, tile_max.T)
        m_e = jnp.dot(onehot_d, m_new,
                      preferred_element_type=jnp.float32)  # (BE, 1)
        # guard: an invalid edge may see m_e = 0 or -inf; never exp it
        p = jnp.where(veff > 0.5, jnp.exp(lh - m_e), 0.0)  # (BE, 1)
        corr = jnp.exp(m_prev - m_new)                     # (BN, 1)
        l_scr[:, h:h + 1] = corr * l_scr[:, h:h + 1] + jnp.dot(
            onehot_d.T, p, preferred_element_type=jnp.float32)
        msgs = jnp.dot(onehot_s, hs[:, sl],
                       preferred_element_type=jnp.float32)  # (BE, hdp)
        contrib = jnp.dot(onehot_d.T, p * msgs,
                          preferred_element_type=jnp.float32)  # (BN, hdp)
        acc_scr[:, sl] = corr * acc_scr[:, sl] + contrib
        m_scr[:, h:h + 1] = m_new

    @pl.when(e_i == ne - 1)
    def _finish():
        for h in range(heads):
            sl = slice(h * hdp, (h + 1) * hdp)
            den = l_scr[:, h:h + 1] + 1e-9        # reference denominator
            o_ref[:, sl] = (acc_scr[:, sl] / den).astype(o_ref.dtype)


def _gat_impl(hs, es, ed, edge_src, edge_dst, maskf, num_dst, heads, be,
              bn, interpret):
    """Raw one-pass forward (no VJP).  ``hs``: (S, heads*hd) projected
    source features; ``es``: (S, heads) / ``ed``: (num_dst, heads) logit
    halves; ``maskf``: (E,) float validity.  Returns (num_dst, heads*hd)."""
    S = hs.shape[0]
    hd = hs.shape[1] // heads
    E = edge_src.shape[0]
    hdp = _pick_bf(hd)
    hp = _pad8(heads)
    Sp = _pad8(S)
    Ep = _pad_edges(E, be)
    pad_seg = num_dst
    Np = -(-(num_dst + 1) // bn) * bn

    hs_p = jnp.zeros((Sp, heads * hdp), hs.dtype)
    for h in range(heads):
        hs_p = hs_p.at[:S, h * hdp:h * hdp + hd].set(
            hs[:, h * hd:(h + 1) * hd])
    es_p = jnp.zeros((Sp, hp), es.dtype).at[:S, :heads].set(es)
    ed_p = jnp.zeros((Np, hp), ed.dtype).at[:num_dst, :heads].set(ed)
    src_p = jnp.zeros((Ep,), jnp.int32).at[:E].set(
        edge_src.astype(jnp.int32))
    dst_p = jnp.full((Ep,), pad_seg, jnp.int32).at[:E].set(
        edge_dst.astype(jnp.int32))
    mask_p = jnp.zeros((Ep,), jnp.float32).at[:E].set(
        maskf.astype(jnp.float32))

    # hs/es slabs have a constant block index over the whole grid sweep,
    # so they cross HBM once; the ed block follows the destination tile
    grid = (Np // bn, Ep // be)
    out = pl.pallas_call(
        functools.partial(_gat_kernel, bn=bn, sp=Sp, heads=heads, hdp=hdp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((be,), lambda n, e: (e,)),
            pl.BlockSpec((be,), lambda n, e: (e,)),
            pl.BlockSpec((be,), lambda n, e: (e,)),
            pl.BlockSpec((Sp, heads * hdp), lambda n, e: (0, 0)),
            pl.BlockSpec((Sp, hp), lambda n, e: (0, 0)),
            pl.BlockSpec((bn, hp), lambda n, e: (n, 0)),
        ],
        out_specs=pl.BlockSpec((bn, heads * hdp), lambda n, e: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, heads * hdp), hs.dtype),
        scratch_shapes=[
            pltpu.VMEM((bn, hp), jnp.float32),           # running max
            pltpu.VMEM((bn, hp), jnp.float32),           # running denom
            pltpu.VMEM((bn, heads * hdp), jnp.float32),  # weighted acc
        ],
        interpret=interpret,
    )(src_p, dst_p, mask_p, hs_p, es_p, ed_p)
    if hdp == hd:
        return out[:num_dst]
    out = out[:num_dst].reshape(num_dst, heads, hdp)[:, :, :hd]
    return out.reshape(num_dst, heads * hd)


def _reference_alphas(es, ed, edge_src, edge_dst, maskf, num_dst):
    """(E, heads) attention weights of the multi-pass reference (XLA ops;
    the flash-style backward recomputes these instead of saving them)."""
    pre = (jnp.take(es, edge_src, axis=0)
           + jnp.take(ed, edge_dst, axis=0))               # (E, H)
    z = jnp.where(pre >= 0, pre, LEAKY_SLOPE * pre)
    zm = jnp.where(maskf[:, None] > 0, z, NEG_INF)
    mx = jax.ops.segment_max(zm, edge_dst, num_dst)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)              # empty segments
    ex = jnp.exp(zm - mx[edge_dst]) * maskf[:, None]
    den = jax.ops.segment_sum(ex, edge_dst, num_dst)
    return ex / (den[edge_dst] + 1e-9), pre


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10))
def _gat(hs, es, ed, edge_src, edge_dst, maskf, num_dst, heads, be, bn,
         interpret):
    return _gat_impl(hs, es, ed, edge_src, edge_dst, maskf, num_dst,
                     heads, be, bn, interpret)


def _gat_fwd(hs, es, ed, edge_src, edge_dst, maskf, num_dst, heads, be,
             bn, interpret):
    out = _gat_impl(hs, es, ed, edge_src, edge_dst, maskf, num_dst,
                    heads, be, bn, interpret)
    return out, (hs, es, ed, edge_src, edge_dst, maskf)


def _gat_bwd(num_dst, heads, be, bn, interpret, res, g):
    hs, es, ed, edge_src, edge_dst, maskf = res
    S = hs.shape[0]
    hd = hs.shape[1] // heads
    bf = _pick_bf(hd)
    alpha, pre = _reference_alphas(es, ed, edge_src, edge_dst, maskf,
                                   num_dst)                # (E, H) recompute
    dhs_cols = []
    dalpha_cols = []
    for h in range(heads):                                 # static unroll
        g_h = g[:, h * hd:(h + 1) * hd]
        hs_h = hs[:, h * hd:(h + 1) * hd]
        a_h = alpha[:, h]
        # transpose of "gather src, weight by alpha, scatter to dst":
        # the fused kernel with src and dst swapped
        dhs_cols.append(_fused_impl(g_h, edge_dst, edge_src, a_h, S, be,
                                    bn, bf, interpret))
        dalpha_cols.append(_edge_dot(hs_h, g_h, edge_src, edge_dst, be,
                                     bf, interpret))
    dhs = jnp.concatenate(dhs_cols, axis=1)                # (S, H*hd)
    dalpha = jnp.stack(dalpha_cols, axis=1)                # (E, H)
    # closed-form softmax backward: dz = alpha * (dalpha - sum_dst)
    s = jax.ops.segment_sum(alpha * dalpha, edge_dst, num_dst)
    dz = alpha * (dalpha - s[edge_dst])                    # (E, H)
    dpre = dz * jnp.where(pre >= 0, 1.0, LEAKY_SLOPE)
    des = jax.ops.segment_sum(dpre, edge_src, S)
    ded = jax.ops.segment_sum(dpre, edge_dst, num_dst)
    zero_ids = np.zeros(edge_src.shape, jax.dtypes.float0)
    return (dhs, des.astype(es.dtype), ded.astype(ed.dtype), zero_ids,
            zero_ids, jnp.zeros_like(maskf))


_gat.defvjp(_gat_fwd, _gat_bwd)


def gat_fused_attention_pallas(hs: jax.Array, es: jax.Array, ed: jax.Array,
                               edge_src: jax.Array, edge_dst: jax.Array,
                               mask: jax.Array, num_dst: int, *,
                               heads: int, be: int = DEFAULT_BE,
                               bn: int = DEFAULT_BN,
                               interpret: bool = True) -> jax.Array:
    """Differentiable one-pass fused GAT aggregation.

    ``out[d, h] = Σ_e softmax_d(leaky_relu(es[src_e] + ed[d]))_e ·
    hs[src_e, h]`` for edges with ``edge_dst[e] = d`` and ``mask[e]``
    set.  ``hs``: (num_src, heads·hd); ``es``: (num_src, heads);
    ``ed``: (num_dst, heads); ``mask``: (E,) bool/float validity.
    Returns (num_dst, heads·hd); destinations with no valid incoming
    edge emit zeros, matching the ``segment_softmax`` reference.
    """
    maskf = mask.astype(jnp.float32)
    hd = hs.shape[1] // heads
    _assert_vmem(
        gat_fused_vmem_floats(hs.shape[0], num_dst, heads, hd, be=be,
                              bn=bn),
        what="gat_fused_attention_pallas (fwd+vjp)")
    return _gat(hs, es, ed, edge_src, edge_dst, maskf, num_dst, heads,
                be, bn, interpret)


def gat_fused_vmem_floats(num_src: int, num_dst: int, heads: int, hd: int,
                          *, be: int = DEFAULT_BE,
                          bn: int = DEFAULT_BN) -> int:
    """Per-step VMEM working set (floats) of the one-pass forward AND
    its backward's per-head fused/edge-dot kernels (whichever is
    largest).  Dispatch layers use :func:`gat_fused_fits`."""
    hdp = _pick_bf(hd)
    hp = _pad8(heads)
    sp = _pad8(num_src)
    fwd = (sp * heads * hdp + sp * hp          # hs + es slabs resident
           + bn * hp                           # ed tile
           + be * sp + be * bn                 # both one-hots
           + 3 * be * hp                       # es_e/ed_e/logits
           + be * hdp + bn * hdp + be * bn     # msgs/contrib/cond
           + bn * (2 * hp + 2 * heads * hdp)   # m/l/acc/out
           + 3 * be)                           # ids + mask
    bwd = fused_vmem_floats(max(num_src, num_dst),
                            max(num_src, num_dst), hd, be=be, bn=bn)
    return max(fwd, bwd)


def gat_fused_fits(num_src: int, num_dst: int, heads: int, hd: int, *,
                   be: int = DEFAULT_BE, bn: int = DEFAULT_BN) -> bool:
    """True iff the one-pass GAT kernel (fwd + VJP) fits the VMEM budget
    for these row counts — the capacity predicate behind the automatic
    fused/multi-pass dispatch in :mod:`repro.kernels.ops`."""
    return 4 * gat_fused_vmem_floats(num_src, num_dst, heads, hd, be=be,
                                     bn=bn) <= VMEM_BUDGET


# ---------------------------------------------------------------------------
# analytic HBM traffic models (the quantities BENCH_kernels.json reports)
# ---------------------------------------------------------------------------

def hbm_bytes_gat_multipass(E: int, heads: int, hd: int, num_dst: int,
                            num_src: int, *, itemsize: int = 4) -> dict:
    """Modeled HBM traffic of the multi-pass GAT reference
    (``segment_softmax`` + weighted ``segment_sum``): the (E, heads)
    logit/exp/alpha tensors are written and re-read around the
    per-destination max and denominator reductions, and the
    (E, heads·hd) message tensor crosses HBM in both passes."""
    eh = E * heads * itemsize
    msgs = E * heads * hd * itemsize
    dh = num_dst * heads * itemsize
    out = num_dst * heads * hd * itemsize
    ids = E * 4
    fwd = (2 * eh + ids            # gather es/ed -> write logits
           + eh + dh              # segment_max reads logits, writes mx
           + 2 * eh + dh          # exp: read logits+mx row, write ex
           + eh + dh + ids        # denominator segment-sum
           + 2 * eh + dh          # alpha = ex / den[dst]
           + msgs + eh + msgs     # gather hs, scale by alpha, write msgs
           + msgs + ids + out)    # weighted segment-sum
    # backward re-materializes the same edge tensors (alpha saved or
    # recomputed, message cotangents, softmax backward) — model it as
    # the transpose of the forward traffic
    bwd = fwd
    return {"fwd": fwd, "bwd": bwd, "total": fwd + bwd}


def hbm_bytes_gat_fused(E: int, heads: int, hd: int, num_dst: int,
                        num_src: int, *, be: int = DEFAULT_BE,
                        bn: int = DEFAULT_BN, itemsize: int = 4) -> dict:
    """Modeled HBM traffic of :func:`gat_fused_attention_pallas`: the
    hs/es slabs cross HBM once (constant block index), the ed tile once
    per destination tile, ids+mask once per (dst-tile, edge-tile) pair —
    no (E, ·) tensor is ever written.  The backward recomputes the
    (E, heads) alphas once and reuses the fused/edge-dot kernels per
    head."""
    hdp = _pick_bf(hd)
    hp = _pad8(heads)
    sp = _pad8(num_src)
    Ep = _pad_edges(E, be)
    Np = -(-(num_dst + 1) // bn) * bn
    n_tiles = Np // bn
    eh = E * heads * itemsize
    fwd = (sp * heads * hdp * itemsize         # hs slab once
           + sp * hp * itemsize                # es slab once
           + Np * hp * itemsize                # ed tiles once each
           + n_tiles * Ep * 12                 # src+dst+mask per dst tile
           + Np * heads * hdp * itemsize)      # write out
    # alpha recompute (XLA, (E, heads) tensors) + per-head fused dh +
    # edge-dot dalpha + two light (E, heads) segment sums
    from repro.kernels.segment_sum import hbm_bytes_fused_kernel
    per_head = hbm_bytes_fused_kernel(E, hd, num_src, num_dst, be=be,
                                      bn=bn)["fwd"]
    bwd = (4 * eh                              # recompute + dz/dpre terms
           + heads * per_head                  # dhs via swapped fused
           + (sp + _pad8(num_dst)) * hdp * itemsize + E * 4  # edge-dot
           + 2 * eh)                           # des/ded segment sums
    return {"fwd": fwd, "bwd": bwd, "total": fwd + bwd}
