"""Staleness-bounded asynchronous full-graph training (survey §3.2.7).

The third major system family after sampling-based mini-batch training
(``repro.distributed.sampler``/``pipeline``) and online inference
(``repro.serving``): full-graph training where boundary ("ghost")
activations are exchanged with *bounded staleness* instead of a
synchronous halo exchange every layer — the PipeGCN / DistGNN /
SANCUS recipe that hides communication behind compute.

Composition of existing pieces:

* :class:`repro.core.halo.HaloExchange` — versioned per-layer ghost
  buffers under the shared :class:`repro.core.caching.VersionClock`
  (the same staleness implementation serving's ``EmbeddingCache`` uses);
* :func:`repro.models.gnn.model.forward_stale` — the GCN forward that
  aggregates historical activations for non-refreshed ghosts;
* the double-buffering pattern from :class:`~repro.distributed.pipeline.
  HostPrefetcher` — the refresh *plan* for step ``t+1`` (mask selection,
  version stamping, byte accounting) is produced on a host thread while
  the jitted step still computes step ``t``.

Semantics per step ``t`` with bound ``S`` and budget ``F``:

1. the planner marks every ghost row whose staleness would exceed ``S``
   (plus the oldest ``F``-fraction of the rest) for *synchronous* refresh;
2. the shard_map step computes with fresh activations for owned +
   refreshed rows and historical buffer values for everything else;
3. refreshed rows' freshly gathered values are written back to the
   buffers, stamped with the step's clock value.

``S = 0`` forces every ghost row into every plan, degrading exactly to
the synchronous pull step of
:func:`repro.core.propagation.make_distributed_gcn_step` — the
equivalence ``tests/async_train_check.py`` proves to ≤ 1e-5 per
parameter.  Larger ``S`` strictly reduces cross-partition bytes/step
(each row crosses the wire at most every ``S+1`` steps).

Orthogonally, ``cfg.wire_codec`` compresses what DOES cross the wire
through the unified communication plane (:mod:`repro.core.comm`): ghost
refreshes are quantized in-step (``bf16`` truncation or ``int8`` per-row
affine + error-feedback residuals), the historical buffers store the
decoded wire values, and every plan prices rows at the codec's wire
size — int8 cuts bytes/step ~4x at an accuracy gap ≤ 0.02
(``benchmarks/bench_async.py`` asserts both).
"""
from __future__ import annotations

import time
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import telemetry
from repro.core.comm import resolve_codec
from repro.core.halo import HaloExchange, build_halo
from repro.core.partitioning import EdgeCutPartition
from repro.core.propagation import AXIS, ShardedGraph, shard_graph
from repro.distributed.pipeline import HostPrefetcher
from repro.graph.structure import Graph
from repro.models.gnn import model as GM
from repro.models.gnn.model import GNNConfig


def exchange_for_shards(g: Graph, sg: ShardedGraph,
                        layer_dims: Sequence[int], *,
                        max_staleness: int = 0, refresh_frac: float = 0.0,
                        codec="fp32", clock=None) -> HaloExchange:
    """Build the :class:`HaloExchange` matching a ``ShardedGraph``.

    ``shard_graph`` relabels vertices to contiguous per-device ranges, so
    ownership is recoverable as ``perm[v] // n_local``; the halo layout is
    computed in original ids and the exchange buffers live in the padded
    relabeled space the shard_map step indexes into.

    Args:
        g: the original graph.
        sg: the sharded layout built from it.
        layer_dims: widths of the buffered layer outputs (``[hidden] *
            (num_layers - 1)`` for the GCN stack).
        max_staleness / refresh_frac / codec / clock: forwarded to
            :class:`HaloExchange` (``codec`` selects the wire format of
            the ghost refresh payloads).
    """
    part = EdgeCutPartition(
        assignment=(sg.perm // sg.n_local).astype(np.int64),
        n_parts=sg.n_dev)
    layout = build_halo(g, part)
    return HaloExchange(layout, layer_dims, max_staleness=max_staleness,
                        refresh_frac=refresh_frac, relabel=sg.perm,
                        n_rows=sg.n_local * sg.n_dev, codec=codec,
                        clock=clock)


def make_async_fullgraph_step(optimizer, n_dev: int, *,
                              use_kernel: bool = False, codec="fp32"):
    """Build the jitted staleness-bounded full-graph GCN step.

    Returns ``(mesh, train_step)`` where::

        train_step(params, opt_state, sg, ghosts, refresh, residuals)
            -> (params, opt_state, loss, planes, residuals)

    ``sg`` is a :class:`~repro.core.propagation.ShardedGraph`; ``ghosts``
    are the per-layer ``(N_pad, F_l)`` stale activation planes
    (replicated); ``refresh`` the per-layer ``(N_pad,)`` bool refresh
    masks; ``planes`` the layer outputs *as they crossed the wire*
    (codec-decoded; exact fp32 under the identity codec) to write back;
    ``residuals`` the per-layer error-feedback state (pass ``()`` and
    ignore the returned value under the identity codec, which compiles
    the exact pre-codec step).  Params/opt_state replicated, graph arrays
    sharded over mesh axis ``"g"``, gradients psum'd — identical
    conventions to :func:`repro.core.propagation.make_distributed_gcn_step`.
    ``use_kernel`` runs every layer's aggregation through the fused
    Pallas gather-scale-segment-sum kernel; ``codec`` selects the
    communication-plane wire format (see :mod:`repro.core.comm`).
    """
    mesh = Mesh(np.array(jax.devices()[:n_dev]), (AXIS,))
    codec = resolve_codec(codec)
    quantize = not codec.identity

    def step(params, opt_state, x, es, ed, em, indeg, outdeg, labels,
             lmask, ghosts, refresh, residuals):
        n_local = x.shape[0]
        n_pad = outdeg.shape[0]
        idx = jax.lax.axis_index(AXIS)
        own_rows = (jnp.arange(n_pad, dtype=jnp.int32) // n_local) == idx
        # parameter-free count psum'd OUTSIDE the differentiated function
        # (under check_rep=False a psum inside loss_fn transposes to a
        # second psum, scaling gradients by n_dev — see propagation.py)
        cnt = jnp.maximum(jax.lax.psum(jnp.sum(lmask), AXIS), 1.0)

        def loss_fn(p):
            h, planes, res_out = GM.forward_stale(
                p, x, (es, ed, em, indeg, outdeg, n_local), ghosts,
                refresh, own_rows, axis=AXIS, use_kernel=use_kernel,
                codec=codec if quantize else None,
                residuals=residuals if quantize else None)
            logz = jax.nn.logsumexp(h, axis=-1)
            gold = jnp.take_along_axis(h, labels[:, None], axis=-1)[:, 0]
            return (jnp.sum((logz - gold) * lmask) / cnt,
                    (planes, res_out))

        (local_loss, (planes, res_out)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        loss = jax.lax.psum(local_loss, AXIS)
        grads = jax.tree.map(lambda g_: jax.lax.psum(g_, AXIS), grads)
        params, opt_state = optimizer.apply(params, grads, opt_state)
        return params, opt_state, loss, planes, res_out

    rep, shard = P(), P(AXIS)
    smapped = shard_map(
        step, mesh=mesh,
        in_specs=(rep, rep, shard, shard, shard, shard, shard, rep,
                  shard, shard, rep, rep, rep),
        out_specs=(rep, rep, rep, rep, rep), check_rep=False)
    jitted = jax.jit(smapped)

    def train_step(params, opt_state, sg: ShardedGraph,
                   ghosts: Sequence[jax.Array],
                   refresh: Sequence[jax.Array],
                   residuals: Sequence[jax.Array] = ()):
        return jitted(params, opt_state, sg.x, sg.edge_src_g,
                      sg.edge_dst_l, sg.edge_mask, sg.in_deg, sg.out_deg,
                      sg.labels, sg.label_mask, tuple(ghosts),
                      tuple(refresh), tuple(residuals))

    return mesh, train_step


class AsyncFullGraphTrainer:
    """Host driver for staleness-bounded asynchronous full-graph training.

    Owns the sharded layout, the :class:`HaloExchange`, and the jitted
    step; :meth:`run` overlaps refresh planning with device compute via
    :class:`~repro.distributed.pipeline.HostPrefetcher` and keeps exact
    consumed-plan traffic accounting.

    Args:
        g: the training graph (features + labels required).
        cfg: GCN config (``arch="gcn"``; the full-graph shard_map path is
            GCN-specific, like the synchronous one).  ``cfg.use_kernel``
            routes aggregation through the fused Pallas kernel.
        optimizer: an ``optim``-style optimizer (``init``/``apply``).
        n_dev: mesh size (one partition per device).
        partitioner: edge-cut method name (``hash``/``ldg``/``fennel``).
        staleness: bound ``S`` — a ghost activation may be up to ``S``
            steps old; ``0`` = synchronous halo exchange.
        refresh_frac: extra per-step refresh budget (fraction of ghosts).

    ``cfg.wire_codec`` selects the communication-plane wire format of the
    ghost refresh payloads (``fp32`` is bit-exact with the pre-codec
    trainer; ``bf16``/``int8`` compress, with int8 carrying sender-side
    error-feedback residuals through the step).
    """

    def __init__(self, g: Graph, cfg: GNNConfig, optimizer, n_dev: int, *,
                 partitioner: str = "hash", staleness: int = 0,
                 refresh_frac: float = 0.0):
        if cfg.arch != "gcn":
            raise ValueError("async full-graph training implements GCN "
                             "(like the synchronous shard_map path)")
        self.g = g
        self.cfg = cfg
        self.n_dev = n_dev
        self.partitioner = partitioner
        self.codec = resolve_codec(cfg.wire_codec)
        self.sg = shard_graph(g, n_dev, method=partitioner)
        layer_dims = [cfg.hidden] * (cfg.num_layers - 1)
        self.exchange = exchange_for_shards(
            g, self.sg, layer_dims, max_staleness=staleness,
            refresh_frac=refresh_frac, codec=self.codec)
        self.mesh, self.step = make_async_fullgraph_step(
            optimizer, n_dev, use_kernel=cfg.use_kernel, codec=self.codec)
        # sender-side error-feedback state (error-feedback codecs only):
        # lives next to the ghost buffers so it persists across run()
        # calls — quantization error keeps feeding back epoch over epoch
        self._residuals = (tuple(
            jnp.zeros((self.sg.n_local * n_dev, d), jnp.float32)
            for d in layer_dims) if self.codec.error_feedback else ())
        self.steps_run = 0
        self.consumed_bytes = 0
        self.consumed_rows = 0
        self._update_seq = 0
        self.step_times_s: List[float] = []
        self._m_step = telemetry.histogram(
            "train_step_seconds", "wall time per executed training step",
            buckets=telemetry.DEFAULT_TIME_BUCKETS,
            mode="fullgraph_async")

    # -- dynamic graphs ----------------------------------------------------
    def fold_updates(self, log, upto_seq=None) -> dict:
        """Continual training: fold pending
        :class:`repro.core.updates.GraphUpdateLog` events into the
        training graph between epochs, WITHOUT a cold restart.

        The graph arrays mutate in place, the sharded layout is rebuilt
        (edge deltas change the padded edge lists; ``hash`` keeps the
        same node assignment, ``ldg``/``fennel`` may re-balance), and the
        :class:`HaloExchange` is rebuilt on the SAME version clock with
        every buffer row ported by node id — so untouched ghost rows
        keep their values and version stamps, and their staleness
        accounting survives the fold.  Rows owned by the
        ``(num_layers-1)``-hop delta frontier are then invalidated: the
        next plan force-refreshes exactly them, regardless of the bound
        S, so a stale read never spans a graph mutation
        (``halo_staleness_violations_total`` stays 0).

        The jitted step is reused as-is (it closes over the optimizer and
        mesh, not the layout).  Error-feedback residuals are reset to
        zero — they priced rows of the pre-fold graph.  Idempotent per
        sequence number.  Returns a fold summary dict."""
        from repro.core.updates import fold_in_place
        upto = log.last_seq if upto_seq is None else upto_seq
        if upto <= self._update_seq:
            return {"events": 0, "touched_nodes": 0,
                    "invalidated_rows": 0, "upto_seq": self._update_seq}
        delta, frontier = fold_in_place(
            self.g, log, self._update_seq, upto,
            hops=self.cfg.num_layers - 1)
        old_sg, old_ex = self.sg, self.exchange
        self.sg = shard_graph(self.g, self.n_dev, method=self.partitioner)
        layer_dims = [self.cfg.hidden] * (self.cfg.num_layers - 1)
        self.exchange = exchange_for_shards(
            self.g, self.sg, layer_dims,
            max_staleness=old_ex.max_staleness,
            refresh_frac=old_ex.refresh_frac, codec=self.codec,
            clock=old_ex.clock)
        # port buffer state by NODE id: perm maps original id -> padded
        # row, so row contents and version stamps follow each node across
        # any re-partition; rows nothing maps to keep NEVER (cold)
        for new_buf, old_buf in zip(self.exchange.buffers, old_ex.buffers):
            new_buf.values[self.sg.perm] = old_buf.values[old_sg.perm]
            new_buf.version[self.sg.perm] = old_buf.version[old_sg.perm]
        n_inv = self.exchange.invalidate_rows(self.sg.perm[frontier])
        if self.codec.error_feedback:
            self._residuals = tuple(
                jnp.zeros((self.sg.n_local * self.n_dev, d), jnp.float32)
                for d in layer_dims)
        self._update_seq = upto
        return {"events": delta.n_events,
                "touched_nodes": int(len(delta.nodes)),
                "invalidated_rows": n_inv,
                "upto_seq": upto}

    # -- training loop -----------------------------------------------------
    def run(self, params, opt_state, epochs: int, *, log_every: int = 0,
            prefetch_plans: bool = True):
        """Train ``epochs`` full-graph steps; returns
        ``(params, opt_state, last_loss)``.

        The planner produces exactly ``epochs`` refresh plans (then ``None``
        sentinels), so version stamps and byte accounting correspond
        one-to-one to executed steps even though planning runs ahead on
        the prefetch thread.
        """
        produced = {"n": 0}

        def next_plan():
            if produced["n"] >= epochs:
                return None              # sentinel: planner budget spent
            produced["n"] += 1
            return self.exchange.plan_refresh()

        planner = HostPrefetcher(next_plan) if prefetch_plans else None
        loss = jnp.zeros(())
        # device-resident ghost planes, seeded from the host buffers once;
        # per step only the refreshed rows change (a where(), not a full
        # (N_pad, F) host->device upload), keeping step_ms honest
        ghosts = [jnp.asarray(b) for b in self.exchange.ghost_planes()]
        try:
            for epoch in range(epochs):
                plan = next(planner) if planner else next_plan()
                t0 = time.perf_counter()
                masks = [jnp.asarray(m) for m in plan.masks]
                # residuals are instance state (carried through the step
                # so the wire planes it returns are exactly what
                # receivers decode, and preserved across run() calls)
                (params, opt_state, loss, planes,
                 self._residuals) = self.step(
                    params, opt_state, self.sg, ghosts, masks,
                    self._residuals)
                ghosts = [jnp.where(m[:, None], pl, gh) for m, pl, gh
                          in zip(masks, planes, ghosts)]
                self.exchange.write_planes(
                    plan, [np.asarray(pl) for pl in planes])
                dt = time.perf_counter() - t0
                self.step_times_s.append(dt)
                self._m_step.observe(dt)
                self.steps_run += 1
                self.consumed_bytes += plan.bytes
                self.consumed_rows += plan.rows_moved
                if log_every and (epoch % log_every == 0
                                  or epoch == epochs - 1):
                    print(f"epoch {epoch:3d} loss {float(loss):.4f} "
                          f"refresh_rows {plan.rows_moved} "
                          f"bytes {plan.bytes}")
        finally:
            if planner is not None:
                planner.close()
        return params, opt_state, float(loss)

    # -- evaluation / reporting --------------------------------------------
    def accuracy(self, params) -> float:
        """Full-graph accuracy of ``params`` on a single device (exact,
        no staleness — the number the accuracy-gap benchmark reports)."""
        from repro.core.abstraction import DeviceGraph
        dg = DeviceGraph.from_graph(self.g)
        logits = GM.forward_full(self.cfg, params, dg,
                                 jnp.asarray(self.g.features))
        return float(GM.accuracy(logits, jnp.asarray(self.g.labels)))

    def stats(self) -> dict:
        """Consumed-plan traffic + timing, with the synchronous baseline
        for savings reporting."""
        steps = max(self.steps_run, 1)
        sync = self.exchange.sync_bytes_per_step()
        per_step = self.consumed_bytes / steps
        return {
            "staleness": self.exchange.max_staleness,
            "refresh_frac": self.exchange.refresh_frac,
            "wire_codec": self.codec.name,
            "steps": self.steps_run,
            "ghost_rows": self.exchange.n_ghost,
            "bytes_per_step": per_step,
            "rows_per_step": self.consumed_rows / steps,
            "sync_bytes_per_step": sync,
            "comm_savings": 1.0 - per_step / sync if sync else 0.0,
            "mean_step_s": (sum(self.step_times_s) / steps
                            if self.step_times_s else 0.0),
        }
