"""Distributed mini-batch training pipeline: collate → prefetch → shard_map.

Three pieces (survey §3.2.5–§3.2.8 applied to the mini-batch path):

* :func:`collate` stacks each partition's fixed-shape
  :class:`~repro.distributed.sampler.PartitionBatch` into arrays with a
  leading partition axis — the layout ``shard_map`` shards over mesh axis
  ``"g"`` (one partition per device, same axis name as the full-graph
  path in :mod:`repro.core.propagation`).
* :class:`HostPrefetcher` double-buffers host-side work: while the jitted
  step consumes batch *t* on device, a worker thread samples and
  feature-fetches batch *t+1* (DistDGL's sampler processes / AGL's
  pipelined stages).  Built on
  :class:`repro.core.scheduling.PipelinedLoader`.
* :func:`make_distributed_minibatch_step` builds the SPMD step: each
  device runs the block forward over its partition's batch, losses are
  combined as psum(sum)/psum(count) and gradients are psum'd before a
  replicated optimizer update — bitwise-faithful to the single-device
  reference mean over the same global seed set.
"""
from __future__ import annotations

import time
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import telemetry
from repro.core.abstraction import DeviceGraph
from repro.core.comm import resolve_codec
from repro.core.propagation import AXIS
from repro.core.scheduling import PipelinedLoader
from repro.distributed.sampler import PartitionBatch
from repro.models.gnn import model as GM
from repro.models.gnn.model import GNNConfig


# ---------------------------------------------------------------------------
# collation: per-partition batches -> partition-major arrays
# ---------------------------------------------------------------------------

def collate(batches: List[PartitionBatch], out_deg: np.ndarray) -> dict:
    """Stack P fixed-shape partition batches into shard_map inputs.

    Returns per-layer tuples (leading dim P shards over ``"g"``):
      es/ed/em: (P, E_l) edge indices + mask;  sdeg: (P, S_l) global src
      out-degree (GCN normalization);  x: (P, S0, F);  y/w: (P, B).
    """
    L = len(batches[0].blocks)
    es = tuple(np.stack([b.blocks[l].edge_src for b in batches])
               .astype(np.int32) for l in range(L))
    ed = tuple(np.stack([b.blocks[l].edge_dst for b in batches])
               .astype(np.int32) for l in range(L))
    em = tuple(np.stack([b.blocks[l].edge_mask for b in batches])
               for l in range(L))
    sdeg = tuple(np.stack(
        [out_deg[np.maximum(b.blocks[l].src_nodes, 0)] for b in batches])
        .astype(np.float32) for l in range(L))
    return {
        "es": es, "ed": ed, "em": em, "sdeg": sdeg,
        "x": np.stack([b.x_in for b in batches]),
        "y": np.stack([b.labels for b in batches]).astype(np.int32),
        "w": np.stack([b.label_mask for b in batches]).astype(np.float32),
    }


# ---------------------------------------------------------------------------
# double-buffered host-side prefetch
# ---------------------------------------------------------------------------

class HostPrefetcher:
    """Double-buffered loader: one batch ready in the queue, one being
    produced by the worker thread, one being consumed by the device step —
    sampling + feature fetch of batch *t+1* overlap the jitted step on
    batch *t*.  ``wait_s``/``sample_s`` quantify how much host time the
    overlap actually hid."""

    def __init__(self, make_batch: Callable[[], object], *, depth: int = 2):
        self.sample_s = 0.0
        self.produced = 0
        self._m_stall = telemetry.counter(
            "prefetch_stall_seconds_total",
            "consumer seconds blocked on the prefetch queue (un-hidden "
            "host-side sampling time)")
        self._stall_seen = 0.0

        def timed():
            t0 = time.perf_counter()
            item = make_batch()
            self.sample_s += time.perf_counter() - t0
            self.produced += 1
            return item

        self.loader = PipelinedLoader(timed, depth=max(1, depth - 1),
                                      n_workers=1)

    def __iter__(self):
        return self

    def __next__(self):
        item = next(self.loader)
        # telemetry counters are monotone: feed them the *delta* of the
        # loader's cumulative idle clock since the last batch
        stall = self.loader.idle_s
        self._m_stall.inc(max(0.0, stall - self._stall_seen))
        self._stall_seen = stall
        return item

    @property
    def wait_s(self) -> float:
        """Consumer time spent blocked on the queue (un-hidden sampling)."""
        return self.loader.idle_s

    def overlap_ratio(self) -> float:
        """Fraction of host sampling time hidden behind device compute."""
        if self.sample_s <= 0:
            return 0.0
        return max(0.0, 1.0 - self.wait_s / self.sample_s)

    def close(self):
        self.loader.close()


# ---------------------------------------------------------------------------
# the shard_map training step
# ---------------------------------------------------------------------------

def make_distributed_minibatch_step(cfg: GNNConfig, optimizer, n_dev: int,
                                    caps: Sequence[Tuple[int, int, int]]):
    """Returns (mesh, train_step) for partition-parallel mini-batch
    training.  ``caps`` is the per-layer (dst, src, edge) shape contract
    from ``DistributedMinibatchSampler.block_shapes()`` — static, so the
    step compiles once.

    train_step(params, opt_state, arrays) -> (params, opt_state, loss)
    with ``arrays`` from :func:`collate`; params/opt_state replicated,
    gradients psum'd over ``"g"`` (decentralized all-reduce).

    ``cfg.use_kernel=True`` runs every block layer's aggregation through
    the differentiable Pallas kernels (``forward_blocks`` forwards the
    flag into each layer, including GAT's softmax denominator) — wire it
    from ``train_gnn --use-kernel``.

    ``cfg.wire_codec`` names the communication-plane codec the feature
    path used: the ``arrays["x"]`` rows from :func:`collate` already
    carry the codec-*decoded* values (remote misses crossed the wire in
    :class:`~repro.distributed.sampler.PartitionFeatureStore`, which the
    launcher must configure with the same codec), so the step itself
    consumes them as-is — the name is resolved here only to fail fast on
    a typo before the first batch is sampled.
    """
    mesh = Mesh(np.array(jax.devices()[:n_dev]), (AXIS,))
    resolve_codec(cfg.wire_codec)    # fail fast on unknown codec names
    caps = list(caps)

    def step(params, opt_state, es, ed, em, sdeg, x, y, w):
        blocks = []
        for l, (dcap, scap, _ecap) in enumerate(caps):
            es_l, ed_l, em_l = es[l][0], ed[l][0], em[l][0]
            mf = em_l.astype(jnp.float32)
            indeg = jnp.maximum(
                jnp.zeros((dcap,), jnp.float32).at[ed_l].add(mf), 1.0)
            blocks.append(DeviceGraph(es_l, ed_l, em_l, scap, dcap, indeg,
                                      sdeg[l][0]))
        x_l, y_l, w_l = x[0], y[0], w[0]
        # global seed count has no parameter dependence, so psum it OUTSIDE
        # the differentiated function: under check_rep=False a psum inside
        # loss_fn transposes to another psum, silently scaling gradients by
        # n_dev — Adam's scale-invariance masks it, exact equivalence
        # (tests/distributed_train_check.py) does not
        cnt = jnp.maximum(jax.lax.psum(jnp.sum(w_l), AXIS), 1.0)

        def loss_fn(p):
            logits = GM.forward_blocks(cfg, p, blocks, x_l)
            total, _ = GM.nll_sum_count(logits, y_l, w_l)
            return total / cnt           # this device's share of the mean

        local_loss, grads = jax.value_and_grad(loss_fn)(params)
        loss = jax.lax.psum(local_loss, AXIS)
        grads = jax.tree.map(lambda a: jax.lax.psum(a, AXIS), grads)
        params, opt_state = optimizer.apply(params, grads, opt_state)
        return params, opt_state, loss

    rep, shard = P(), P(AXIS)
    smapped = shard_map(
        step, mesh=mesh,
        in_specs=(rep, rep, shard, shard, shard, shard, shard, shard,
                  shard),
        out_specs=(rep, rep, rep), check_rep=False)
    jitted = jax.jit(smapped)

    def train_step(params, opt_state, arrays: dict):
        return jitted(params, opt_state, arrays["es"], arrays["ed"],
                      arrays["em"], arrays["sdeg"], arrays["x"],
                      arrays["y"], arrays["w"])

    return mesh, train_step
