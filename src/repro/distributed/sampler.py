"""Partition-aware distributed mini-batch sampling (the DistDGL/PaGraph
recipe, survey §3.2: partition → per-partition neighbor sampling → remote
feature fetch through a halo cache).

Each partition samples ONLY its owned seeds; the neighbor expansion itself
reuses the deterministic padded sampler built on
:func:`repro.core.sampling.sample_block_padded` (shared with serving, so a
node's sampled neighborhood is a pure function of ``(seed, layer, node)``).
That determinism is what makes the pipeline *partition-invariant*: the
union of all partitions' per-seed computation trees equals the tree a
single device would sample for the same seeds — the property the
cross-layer gradient-equivalence test matrix asserts.

Remote features flow through :class:`PartitionFeatureStore`: rows the
partition owns are free local reads; rows owned elsewhere are
cross-partition traffic unless they sit in the halo cache (seeded by the
PaGraph ``degree_cache`` / AliGraph ``importance_cache`` policies,
restricted to the partition's ghost set from :mod:`repro.core.halo`).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import caching as CA
from repro.core.caching import FeatureStore
from repro.core.halo import HaloLayout, build_halo
from repro.core.partitioning import (EdgeCutPartition,
                                     partition as make_partition)
from repro.core.sampling import Block
from repro.graph.structure import Graph
from repro.serving.sampler import ServingSampler, needed_feature_mask


class PartitionFeatureStore(FeatureStore):
    """A :class:`FeatureStore` as seen from one partition: owned rows are
    local reads (no traffic), remote rows go through the halo cache, and
    only cache-missing remote rows cross the interconnect — the quantity
    ``transferred_bytes`` counts (rows at the wire codec's per-row size +
    per-RPC header, via the shared :class:`repro.core.comm.Transport`)."""

    def __init__(self, g: Graph, owned_ids: np.ndarray,
                 cache_ids: np.ndarray, *, codec="fp32",
                 path: str = "minibatch.features"):
        super().__init__(g, cache_ids, codec=codec, path=path)
        self.owned = np.zeros(g.num_nodes, bool)
        self.owned[owned_ids] = True
        self.local_rows = 0

    def _local_rows_mask(self, safe_ids: np.ndarray,
                         needed: np.ndarray) -> np.ndarray:
        local = needed & self.owned[safe_ids]
        self.local_rows += int(local.sum())
        return local


@dataclasses.dataclass
class PartitionBatch:
    """One partition's share of a global mini-batch, fixed shapes."""
    part: int
    seeds: np.ndarray            # (B_cap,) padded owned seeds (-1 empty)
    blocks: List[Block]          # innermost first, caps from block_shapes()
    x_in: np.ndarray             # (S0_cap, F) features of blocks[0].src_nodes
    labels: np.ndarray           # (B_cap,) int32 (garbage at pads)
    label_mask: np.ndarray       # (B_cap,) float32 — real owned seeds


class DistributedMinibatchSampler:
    """Splits global seed batches by partition ownership and samples each
    partition's padded mini-batch with the deterministic fixed-shape
    expansion, fetching input features through a partition-aware store.
    """

    def __init__(self, g: Graph, n_parts: int, fanouts: Sequence[int],
                 batch_cap: int, *, partitioner: str = "hash",
                 cache_policy: str = "degree", cache_capacity: int = 0,
                 wire_codec: str = "fp32", seed: int = 0,
                 part: Optional[EdgeCutPartition] = None):
        self.g = g
        if part is None:
            part = make_partition(g, n_parts, partitioner)
        if not isinstance(part, EdgeCutPartition):
            raise ValueError("distributed mini-batch training needs an "
                             "edge-cut partitioner (hash/ldg/fennel)")
        self.part = part
        self.n_parts = part.n_parts
        self.layout: HaloLayout = build_halo(g, part)
        self.sampler = ServingSampler(g, fanouts, seed=seed)
        self.fanouts = list(fanouts)
        self.batch_cap = batch_cap
        # GCN-style normalization uses the GLOBAL degree (precomputed
        # D^-1/2 as in DGL), not the in-block src degree: the block src
        # degree depends on which other seeds share the batch, which would
        # break partition-invariance
        self.out_deg = np.maximum(g.out_degree(), 1).astype(np.float32)
        # the policy ranking is partition-independent: compute it once and
        # restrict per partition to its ghost set
        if cache_policy == "none" or cache_capacity <= 0:
            order = np.zeros(0, np.int64)
        else:
            order = CA.CACHE_POLICIES[cache_policy](g, g.num_nodes)
        self.stores = [
            PartitionFeatureStore(
                g, self.layout.owned[p],
                self._halo_cache_ids(p, order, cache_capacity),
                codec=wire_codec)
            for p in range(self.n_parts)]

    def _halo_cache_ids(self, p: int, order: np.ndarray,
                        capacity: int) -> np.ndarray:
        """Top-``capacity`` ghost vertices of partition ``p`` under the
        policy ranking (PaGraph degree / AliGraph importance)."""
        if not len(order):
            return np.zeros(0, np.int64)
        ghost = np.zeros(self.g.num_nodes, bool)
        ghost[self.layout.halo[p]] = True
        return order[ghost[order]][:capacity]

    # -- delta awareness ---------------------------------------------------
    def apply_delta(self, touched: np.ndarray) -> int:
        """React to an in-place graph fold whose frontier is ``touched``:
        recompute the global-degree normalization (edge deltas change
        degrees, and the GCN step reads ``out_deg``) and forward to the
        underlying :meth:`ServingSampler.apply_delta` so only touched
        nodes are re-expanded.  The partition assignment, halo layout and
        per-partition feature stores are deliberately RETAINED: ownership
        is keyed by node id (unchanged by edge deltas), feature stores
        read ``g.features`` live so feature updates propagate
        automatically, and the halo-cache admitted set is an accounting
        hint, not a correctness surface.  Returns dropped memo entries."""
        self.out_deg = np.maximum(self.g.out_degree(), 1).astype(np.float32)
        return self.sampler.apply_delta(touched)

    # -- shape contract ----------------------------------------------------
    def block_shapes(self):
        """(dst_cap, src_cap, edge_cap) per layer, innermost first —
        identical for every partition and every batch (one jit entry)."""
        return self.sampler.block_shapes(self.batch_cap)

    # -- sampling ----------------------------------------------------------
    def sample_partition(self, p: int, seeds_p: np.ndarray) -> PartitionBatch:
        seeds_p = np.asarray(seeds_p, np.int64)
        if len(seeds_p) > self.batch_cap:
            raise ValueError(f"partition {p} got {len(seeds_p)} seeds "
                             f"> batch_cap {self.batch_cap}")
        padded = np.full((self.batch_cap,), -1, np.int64)
        padded[:len(seeds_p)] = seeds_p
        mb = self.sampler.sample(padded)
        # fetch only rows reachable from REAL seeds; pad-path slots get
        # zero rows and are never counted as traffic
        need = needed_feature_mask(mb.blocks, padded >= 0)
        x_in = self.stores[p].fetch_masked(mb.blocks[0].src_nodes, need)
        safe = np.maximum(padded, 0)
        labels = (self.g.labels[safe].astype(np.int32)
                  if self.g.labels is not None
                  else np.zeros(self.batch_cap, np.int32))
        mask = (padded >= 0).astype(np.float32)
        return PartitionBatch(p, padded, mb.blocks, x_in, labels, mask)

    def sample_global(self, seeds: np.ndarray) -> List[PartitionBatch]:
        """Split a global seed batch by ownership; every partition emits a
        fixed-shape batch (possibly all-padding)."""
        seeds = np.asarray(seeds, np.int64)
        owner = self.layout.owner[seeds]
        return [self.sample_partition(p, seeds[owner == p])
                for p in range(self.n_parts)]

    # -- traffic accounting ------------------------------------------------
    def stats(self) -> dict:
        hits = sum(s.hits for s in self.stores)
        misses = sum(s.misses for s in self.stores)
        return {
            "halo_hit_ratio": hits / (hits + misses) if hits + misses else 0.0,
            "cross_partition_bytes": sum(s.transferred_bytes
                                         for s in self.stores),
            "local_rows": sum(s.local_rows for s in self.stores),
            "remote_requests": sum(s.requests for s in self.stores),
            "ghost_fraction": self.layout.ghost_fraction(),
            "wire_codec": self.stores[0].codec.name,
        }


def device_blocks(batch: PartitionBatch, out_deg: np.ndarray):
    """Host-side block → DeviceGraph conversion with the GLOBAL-degree
    normalization the distributed step uses (see class docstring) — the
    single-device reference path of the equivalence tests."""
    import dataclasses as _dc

    import jax.numpy as jnp

    from repro.core.abstraction import DeviceGraph

    out = []
    for b in batch.blocks:
        dg = DeviceGraph.from_block(b)
        sdeg = out_deg[np.maximum(b.src_nodes, 0)].astype(np.float32)
        out.append(_dc.replace(dg, out_deg=jnp.asarray(sdeg)))
    return out
