"""Partition-aware distributed training (survey §3.2).

Two training families share this package:

* **mini-batch** (DistDGL/PaGraph recipe): halo layout → per-partition
  deterministic sampling → halo-cached remote feature fetch →
  double-buffered prefetch → shard_map psum step
  (:mod:`~repro.distributed.sampler`, :mod:`~repro.distributed.pipeline`);
* **asynchronous full-graph** (PipeGCN/DistGNN recipe): per-layer ghost
  activations exchanged with bounded staleness, refresh planning
  overlapped with device compute
  (:mod:`~repro.distributed.async_train`).
"""
from repro.distributed.async_train import (AsyncFullGraphTrainer,
                                           exchange_for_shards,
                                           make_async_fullgraph_step)
from repro.distributed.pipeline import (HostPrefetcher, collate,
                                        make_distributed_minibatch_step)
from repro.distributed.sampler import (DistributedMinibatchSampler,
                                       PartitionBatch,
                                       PartitionFeatureStore, device_blocks)

__all__ = [
    "AsyncFullGraphTrainer",
    "DistributedMinibatchSampler",
    "PartitionBatch",
    "PartitionFeatureStore",
    "HostPrefetcher",
    "collate",
    "device_blocks",
    "exchange_for_shards",
    "make_async_fullgraph_step",
    "make_distributed_minibatch_step",
]
