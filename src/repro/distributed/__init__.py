"""Partition-aware distributed mini-batch training (DistDGL/PaGraph
recipe): halo layout → per-partition deterministic sampling → halo-cached
remote feature fetch → double-buffered prefetch → shard_map psum step.
"""
from repro.distributed.pipeline import (HostPrefetcher, collate,
                                        make_distributed_minibatch_step)
from repro.distributed.sampler import (DistributedMinibatchSampler,
                                       PartitionBatch,
                                       PartitionFeatureStore, device_blocks)

__all__ = [
    "DistributedMinibatchSampler",
    "PartitionBatch",
    "PartitionFeatureStore",
    "HostPrefetcher",
    "collate",
    "device_blocks",
    "make_distributed_minibatch_step",
]
