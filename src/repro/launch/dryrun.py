"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.  For every (architecture x input shape x mesh) this lowers and
compiles the appropriate step function against ShapeDtypeStruct inputs
(no allocation), then reports memory_analysis / cost_analysis and the
collective-bytes breakdown used by the roofline (EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
      --shape train_4k [--multi-pod] [--all] [--json out.json]
"""
# The first two lines MUST run before any other import (jax locks the
# device count on first init).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.configs.base import (  # noqa: E402
    ARCH_ALIASES, ARCH_IDS, INPUT_SHAPES, get_config, get_shape)
from repro.data.pipeline import input_specs  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.transformer import model as M  # noqa: E402
from repro.optim import AdamW  # noqa: E402

# ---------------------------------------------------------------------------
# hardware constants (TPU v5e target)
# ---------------------------------------------------------------------------
from repro.launch.hlo_analysis import (  # noqa: E402
    HBM_BW, ICI_BW, PEAK_FLOPS, collective_bytes)

SKIPS = {
    # (arch, shape): reason  — documented in DESIGN.md §Shape/skip notes
    ("whisper-tiny", "long_500k"):
        "enc-dec cross-attention has no sliding-window/sub-quadratic variant",
}

ATTENTION_FAMILIES = ("dense", "vlm", "moe", "mla_moe")
LONG_WINDOW = 8192


def adapt_config(cfg, shape):
    """Shape-conditional config tweaks (sliding window for long decode)."""
    if shape.name == "long_500k" and cfg.family in ATTENTION_FAMILIES:
        cfg = cfg.replace(sliding_window=LONG_WINDOW)
    if shape.name == "long_500k" and cfg.family == "hybrid":
        # zamba2's shared attention blocks also ring-buffer at 500k
        cfg = cfg.replace(sliding_window=LONG_WINDOW)
    return cfg


def abstractify(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def build_lowerable(cfg, shape, mesh, *, fsdp=True, seq_parallel=True,
                    serve_fsdp=False, remat=True):
    """Returns (fn, example_args_specs, in_shardings, out_shardings)."""
    P = jax.sharding.PartitionSpec
    repl = jax.sharding.NamedSharding(mesh, P())
    rules = shd.ShardingRules(mesh, batch_size=shape.global_batch, fsdp=False,
                              seq_parallel=seq_parallel)
    batch = input_specs(cfg, shape)
    batch_sh = shd.to_named(shd.batch_specs(batch, mesh, rules), mesh)
    logits_sh = jax.sharding.NamedSharding(
        mesh, P(rules.batch_axis, "model"))

    # abstract params without allocating: eval_shape over init
    params = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0),
                              max_seq=shape.seq_len))
    train = shape.kind == "train"
    use_fsdp = (fsdp and train) or (serve_fsdp and not train)
    p_specs = shd.param_specs(params, mesh, fsdp=use_fsdp)
    params_sh = shd.to_named(p_specs, mesh)

    if train:
        opt = AdamW(lr=1e-4)
        opt_state = jax.eval_shape(opt.init, params)
        opt_sh = {"m": params_sh, "v": params_sh,
                  "step": shd.to_named(jax.sharding.PartitionSpec(), mesh)}
        step_fn = M.make_train_step(cfg, opt, remat=remat)

        def fn(params, opt_state, batch):
            with rules.activate():
                return step_fn(params, opt_state, batch)

        args = (params, opt_state, batch)
        in_sh = (params_sh, opt_sh, batch_sh)
        out_sh = (params_sh, opt_sh, {"loss": repl, "grad_norm": repl})
    elif shape.kind == "prefill":
        def fn(params, batch):
            with rules.activate():
                return M.prefill(cfg, params, batch)

        args = (params, batch)
        cache = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len,
                                 enc_len=shape.seq_len))
        cache_sh = shd.to_named(shd.cache_specs(cache, mesh, rules), mesh)
        in_sh = (params_sh, batch_sh)
        out_sh = (logits_sh, cache_sh)
    else:  # decode
        cache = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len,
                                 enc_len=shape.seq_len))
        cache_sh = shd.to_named(shd.cache_specs(cache, mesh, rules), mesh)

        def fn(params, cache, batch):
            with rules.activate():
                return M.decode_step(cfg, params, cache, batch)

        args = (params, cache, batch)
        in_sh = (params_sh, cache_sh, batch_sh)
        out_sh = (logits_sh, cache_sh)
    return fn, args, in_sh, out_sh


# ---------------------------------------------------------------------------
# structural cost extrapolation
#
# XLA's HLO cost analysis visits a while-loop body ONCE (trip counts are not
# folded in), so a scan-over-layers model under-reports FLOPs/bytes by ~L x.
# We therefore compile tiny fully-unrolled variants (1 and 2 instances of
# each layer stack, scan_unroll forces full unrolling including the chunked
# -attention inner scan), fit the exactly-determined linear model
#     cost(variant) = c0 + sum_i n_i(variant) * body_i
# and report  cost(full) = c0 + sum_i N_i * body_i.
# Optimizer/grad-allreduce work on stacked (L, ...) params is linear in L,
# so it is absorbed by the body coefficients; embed/lm-head/loss land in c0.
# ---------------------------------------------------------------------------

def _variant_cfgs(cfg):
    u = dict(scan_unroll=64)
    fam = cfg.family
    if fam in ("dense", "vlm", "moe", "ssm"):
        stacks = {"layer": cfg.num_layers}
        variants = [
            ({"layer": 1}, cfg.replace(num_layers=1, **u)),
            ({"layer": 2}, cfg.replace(num_layers=2, **u)),
        ]
    elif fam == "mla_moe":
        stacks = {"dense": cfg.first_dense_layers,
                  "moe": cfg.num_layers - cfg.first_dense_layers}
        variants = [
            ({"dense": 1, "moe": 1},
             cfg.replace(num_layers=2, first_dense_layers=1, **u)),
            ({"dense": 2, "moe": 1},
             cfg.replace(num_layers=3, first_dense_layers=2, **u)),
            ({"dense": 1, "moe": 2},
             cfg.replace(num_layers=3, first_dense_layers=1, **u)),
        ]
    elif fam == "hybrid":
        ng = cfg.num_layers // cfg.attn_every
        stacks = {"mamba": cfg.num_layers, "attn": ng}
        variants = [
            ({"mamba": 1, "attn": 1},
             cfg.replace(num_layers=1, attn_every=1, **u)),
            ({"mamba": 2, "attn": 1},
             cfg.replace(num_layers=2, attn_every=2, **u)),
            ({"mamba": 2, "attn": 2},
             cfg.replace(num_layers=2, attn_every=1, **u)),
        ]
    elif fam == "encdec":
        stacks = {"enc": cfg.encoder_layers, "dec": cfg.num_layers}
        variants = [
            ({"enc": 1, "dec": 1},
             cfg.replace(num_layers=1, encoder_layers=1, **u)),
            ({"enc": 2, "dec": 1},
             cfg.replace(num_layers=1, encoder_layers=2, **u)),
            ({"enc": 1, "dec": 2},
             cfg.replace(num_layers=2, encoder_layers=1, **u)),
        ]
    else:
        raise ValueError(fam)
    return stacks, variants


def _cost_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on newer jax and a
    one-element list of dicts on older releases — normalize to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _measure(cfg, shape, mesh, **bl_kwargs) -> dict:
    fn, args, in_sh, out_sh = build_lowerable(cfg, shape, mesh, **bl_kwargs)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh
                           ).lower(*args).compile()
    cost = _cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "hbm_bytes": float(cost.get("bytes accessed", 0.0))}
    for k, v in coll.items():
        out[f"coll/{k}"] = float(v)
    return out


def extrapolated_costs(cfg, shape, mesh, **bl_kwargs) -> dict:
    stacks, variants = _variant_cfgs(cfg)
    names = list(stacks)
    rows, costs = [], []
    for counts, vcfg in variants:
        rows.append([1.0] + [float(counts[n]) for n in names])
        costs.append(_measure(vcfg, shape, mesh, **bl_kwargs))
    keys = set()
    for c in costs:
        keys.update(c)
    A = np.asarray(rows)
    full = np.asarray([1.0] + [float(stacks[n]) for n in names])
    out = {}
    for k in keys:
        y = np.asarray([c.get(k, 0.0) for c in costs])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        out[k] = float(max(0.0, full @ coef))
    return out


def model_flops(cfg, shape) -> float:
    """6*N*D with N = active params (MoE: active experts only)."""
    params = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0),
                              max_seq=min(shape.seq_len, 4096)))
    total = sum(int(np.prod(x.shape))
                for x in jax.tree_util.tree_leaves(params))
    if cfg.num_experts:
        # subtract inactive routed-expert params from the 6*N*D count
        def moe_leaves(t):
            out = []
            def rec(d, path):
                for k, v in d.items():
                    if isinstance(v, dict):
                        rec(v, path + (k,))
                    elif "moe" in path and k in ("w_in", "w_gate", "w_out"):
                        out.append(v)
            rec(t, ())
            return out
        inactive = 0
        for leaf in moe_leaves(params):
            E = cfg.num_experts
            frac = (E - cfg.experts_per_token) / E
            inactive += int(np.prod(leaf.shape)) * frac
        total -= inactive
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * total * tokens


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            save_hlo: str = "", extrapolate: bool = True,
            seq_parallel: bool = True, fsdp: bool = True,
            serve_fsdp: bool = False, remat: bool = True,
            cfg_overrides: dict = None) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if (cfg.name, shape_name) in SKIPS:
        return {"arch": cfg.name, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "SKIP", "reason": SKIPS[(cfg.name, shape_name)]}
    cfg = adapt_config(cfg, shape)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    nchips = int(np.prod(list(mesh.shape.values())))

    t0 = time.time()
    bl_kwargs = dict(fsdp=fsdp, seq_parallel=seq_parallel,
                     serve_fsdp=serve_fsdp, remat=remat)
    fn, args, in_sh, out_sh = build_lowerable(cfg, shape, mesh, **bl_kwargs)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    coll_raw = collective_bytes(hlo)

    raw = {"flops": float(cost.get("flops", 0.0)),
           "hbm_bytes": float(cost.get("bytes accessed", 0.0))}
    if extrapolate and not multi_pod:
        corr = extrapolated_costs(cfg, shape, mesh, **bl_kwargs)
    else:
        corr = dict(raw)
        for k, v in coll_raw.items():
            corr[f"coll/{k}"] = float(v)

    flops_per_dev = corr["flops"]
    bytes_per_dev = corr["hbm_bytes"]
    coll = {k.split("/", 1)[1]: v for k, v in corr.items()
            if k.startswith("coll/")}
    hlo_flops = flops_per_dev * nchips
    mf = model_flops(cfg, shape)

    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = bytes_per_dev / HBM_BW
    coll_s = coll.get("total", 0) / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]

    res = {
        "arch": cfg.name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "OK",
        "chips": nchips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)
                                + getattr(mem, "argument_size_in_bytes", 0)
                                + getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "flops_per_device": flops_per_dev,
        "hlo_flops_total": hlo_flops,
        "model_flops": mf,
        "useful_ratio": round(mf / hlo_flops, 4) if hlo_flops else None,
        "hbm_bytes_per_device": bytes_per_dev,
        "collective_bytes_per_device": coll,
        "raw_uncorrected": raw,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": dominant,
        },
    }
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default="")
    ap.add_argument("--save-hlo", default="")
    args = ap.parse_args(argv)

    combos = []
    archs = list(ARCH_ALIASES) if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape \
        else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    results = []
    failed = 0
    for a, s, mp in combos:
        tag = f"{a} x {s} x {'2x16x16' if mp else '16x16'}"
        try:
            r = run_one(a, s, multi_pod=mp, save_hlo=args.save_hlo)
            results.append(r)
            if r["status"] == "OK":
                rf = r["roofline"]
                print(f"OK   {tag}: mem/dev={r['bytes_per_device']/2**30:.2f}"
                      f"GiB flops/dev={r['flops_per_device']:.3e} "
                      f"useful={r['useful_ratio']} "
                      f"dominant={rf['dominant']} "
                      f"(C={rf['compute_s']:.4f}s M={rf['memory_s']:.4f}s "
                      f"X={rf['collective_s']:.4f}s) "
                      f"compile={r['compile_s']}s", flush=True)
            else:
                print(f"SKIP {tag}: {r['reason']}", flush=True)
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
            results.append({"arch": a, "shape": s,
                            "mesh": "2x16x16" if mp else "16x16",
                            "status": "FAIL", "error": str(e)[:500]})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    print(f"done: {sum(r['status'] == 'OK' for r in results)} ok, "
          f"{sum(r['status'] == 'SKIP' for r in results)} skip, "
          f"{failed} fail")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
