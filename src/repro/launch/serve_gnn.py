"""Online GNN inference serving driver.

Serves per-node prediction requests against a synthetic (or named) graph
through the ``repro.serving`` stack: Poisson/Zipf workload → bucketed
micro-batching → fixed-shape neighbor sampling → historical-embedding +
feature caching → jitted forward.  Runs the same workload twice (no-cache
baseline, then the layered cache) and reports the traffic saved.

  PYTHONPATH=src python -m repro.launch.serve_gnn --nodes 512 \
      --requests 256 --arch sage
  PYTHONPATH=src python -m repro.launch.serve_gnn --dataset reddit-like \
      --requests 512 --cache degree --staleness 2

Replicated mode (``--replicas N`` or ``--autoscale``) serves through the
elastic :class:`repro.serving.router.ReplicaRouter` instead: Zipf traffic
spread over N replicas, optional queue-depth/p99 autoscaling, and rolling
weight hot-swap every K completions with per-response version tags::

  PYTHONPATH=src python -m repro.launch.serve_gnn --replicas 2 \
      --hot-swap-every 100 --requests 256
  PYTHONPATH=src python -m repro.launch.serve_gnn --replicas 1 --autoscale \
      --rate 8000 --requests 512 --router-policy least_queue
"""
from __future__ import annotations

import argparse
import copy
import sys


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=512)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--feat-dim", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--arch", default="sage",
                    choices=["gcn", "sage", "gat", "gin", "ggnn"])
    ap.add_argument("--dataset", default="",
                    help="named dataset from repro.graph.datasets; "
                         "default: SBM sized by --nodes")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="offered load, requests/s (virtual clock)")
    ap.add_argument("--fanouts", type=int, nargs="+", default=[5, 5])
    ap.add_argument("--buckets", type=int, nargs="+", default=[1, 4, 16, 64])
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--cache", default="degree",
                    choices=["none", "degree", "importance", "random"])
    ap.add_argument("--cache-frac", type=float, default=0.2,
                    help="fraction of nodes admitted to the caches")
    ap.add_argument("--staleness", type=int, default=0,
                    help="max staleness (version-clock ticks) served")
    ap.add_argument("--wire-codec", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="communication-plane wire codec "
                         "(repro.core.comm) for remote feature pulls "
                         "and cache-fill payloads; fp32 is bit-exact")
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas segment-sum for the Gather step")
    ap.add_argument("--reorder", default="none",
                    choices=["none", "degree", "bfs", "rcm"],
                    help="locality-reorder the served graph (survey "
                         "§3.2.4); the sampler and caches operate on "
                         "the packed graph while request node ids map "
                         "in through the inverse permutation and "
                         "responses are reported in original ids")
    ap.add_argument("--replicas", type=int, default=1,
                    help="initial replica count; > 1 (or --autoscale) "
                         "serves through the elastic ReplicaRouter")
    ap.add_argument("--router-policy", default="least_queue",
                    choices=["round_robin", "least_queue"],
                    help="request dispatch policy across replicas")
    ap.add_argument("--private-cache", action="store_true",
                    help="one EmbeddingCache per replica instead of the "
                         "default fleet-shared cache")
    ap.add_argument("--autoscale", action="store_true",
                    help="enable the queue-depth/p99 autoscaling "
                         "controller (KEDA-style; scales replicas "
                         "within [--replicas, --max-replicas])")
    ap.add_argument("--max-replicas", type=int, default=8,
                    help="autoscaler upper bound on the fleet size")
    ap.add_argument("--hot-swap-every", type=int, default=0,
                    help="stage a rolling weight hot-swap every K "
                         "completions (0 = never); new weights are a "
                         "fresh init per version, every response is "
                         "tagged with the one version that served it")
    ap.add_argument("--update-stream", default="",
                    help="JSONL graph-update stream "
                         "(repro.core.updates.GraphUpdateLog format) "
                         "folded into the served graph mid-run: "
                         "incremental delta-frontier cache invalidation "
                         "instead of a cold restart; with --replicas the "
                         "router invalidates every replica")
    ap.add_argument("--update-every", type=int, default=0,
                    help="completions between update folds (0 = auto: "
                         "~4 folds across the run)")
    ap.add_argument("--ckpt-dir", default="",
                    help="write a crash-safe (params, version) "
                         "checkpoint here after the run; if it already "
                         "holds a complete step, resume weights from it")
    ap.add_argument("--train-epochs", type=int, default=0,
                    help="optionally pre-train the model full-graph")
    ap.add_argument("--metrics-out", default="",
                    help="enable telemetry and write the Prometheus "
                         "text-format exposition here on exit "
                         "(repro.core.telemetry)")
    ap.add_argument("--trace-out", default="",
                    help="enable telemetry and write the JSONL span "
                         "trace here on exit")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    """Parse args, serve the workload, and (when asked) dump the
    telemetry plane on exit — metrics as Prometheus text, spans as JSONL
    (see docs/observability.md)."""
    args = parse_args(argv)
    from repro.core import telemetry
    if args.metrics_out or args.trace_out:
        telemetry.set_enabled(True)
    try:
        return run(args)
    finally:
        if args.metrics_out:
            telemetry.get_registry().write_prometheus(args.metrics_out)
            print(f"telemetry: metrics -> {args.metrics_out}")
        if args.trace_out:
            n = telemetry.get_registry().tracer.export_jsonl(args.trace_out)
            print(f"telemetry: {n} trace events -> {args.trace_out}")


def run(args):
    """The actual serving driver; ``main`` wraps it with the telemetry
    dump."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.graph import generators as G
    from repro.models.gnn import model as GM
    from repro.models.gnn.model import GNNConfig
    from repro.serving import GNNInferenceServer, poisson_workload

    if args.dataset:
        from repro.graph.datasets import load
        g = load(args.dataset, seed=args.seed).graph
        feat_dim = g.features.shape[1]
    else:
        g = G.sbm(args.nodes, args.classes, p_in=0.9, p_out=0.02,
                  seed=args.seed)
        g = G.featurize(g, args.feat_dim, seed=args.seed, class_sep=1.5)
        feat_dim = args.feat_dim
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges, "
          f"{g.num_classes} classes")

    perm = inv = None
    if args.reorder != "none":
        # the serving stack (sampler, halo, feature + embedding caches)
        # operates entirely on the packed graph; external node ids cross
        # the API boundary through inv (in) and perm (out)
        from repro.core.reordering import locality_report
        from repro.kernels import ops as kops
        g, perm, inv = g.reordered(args.reorder)
        rep = locality_report(g)
        e = g.edges()
        td = kops.record_tile_density(e[:, 0], e[:, 1], g.num_nodes)
        print(f"reorder={args.reorder}: gather stride "
              f"{rep['avg_gather_stride']:.1f}, reuse hit "
              f"{rep['reuse_hit_rate']:.2%}, active tiles "
              f"{td['active_tile_frac']:.2%}")

    cfg = GNNConfig(arch=args.arch, feat_dim=feat_dim, hidden=args.hidden,
                    num_classes=g.num_classes,
                    num_layers=len(args.fanouts),
                    use_kernel=args.use_kernel,
                    wire_codec=args.wire_codec)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(args.seed))

    if args.train_epochs:
        from repro.core.abstraction import DeviceGraph
        from repro.optim import AdamW
        opt = AdamW(lr=1e-2, weight_decay=0.0)
        ostate = opt.init(params)
        dg = DeviceGraph.from_graph(g)
        x = jnp.asarray(g.features)
        y = jnp.asarray(g.labels)
        mask = jnp.ones_like(y, jnp.float32)
        step = jax.jit(GM.make_fullgraph_train_step(cfg, opt))
        for _ in range(args.train_epochs):
            params, ostate, loss = step(params, ostate, dg, x, y, mask)
        print(f"pre-trained {args.train_epochs} epochs, "
              f"loss {float(loss):.4f}")

    # the workload arrives in ORIGINAL node ids (clients know nothing of
    # the packing); ids map into the packed space here, at the boundary
    workload = poisson_workload(args.requests, np.arange(g.num_nodes),
                                args.rate, seed=args.seed + 1)
    if inv is not None:
        for r in workload:
            r.node_id = int(inv[r.node_id])

    def to_original_ids(wl):
        """Report completed responses in the clients' original ids."""
        if perm is not None:
            for r in wl:
                r.node_id = int(perm[r.node_id])
        return wl

    capacity = int(g.num_nodes * args.cache_frac)

    if args.replicas > 1 or args.autoscale:
        out = _run_replicated(args, g, cfg, params, workload, capacity,
                              _update_stream_kw(args, inv))
        to_original_ids(workload)
        return out

    def serve(policy: str) -> dict:
        srv = GNNInferenceServer(
            g, cfg, params, fanouts=args.fanouts, buckets=args.buckets,
            cache_policy=policy, cache_capacity=capacity,
            max_staleness=args.staleness,
            max_wait_s=args.max_wait_ms / 1e3, seed=args.seed)
        srv.warmup()
        # each serve pass folds a fresh copy of the stream into a fresh
        # copy of the graph, so baseline and cached runs stay comparable
        kw = _update_stream_kw(args, inv)
        if kw:
            srv.g = srv.sampler.g = copy.deepcopy(g)
            srv.cache.g = srv.cache.features.g = srv.g
            srv.sampler.apply_delta(np.zeros(0, np.int64))
        wl = copy.deepcopy(workload)
        srv.run(wl, **kw)
        to_original_ids(wl)
        out = srv.summary()
        out["update_seq"] = srv._update_seq
        return out

    base = serve("none")
    print(f"[no-cache ] {base['throughput_rps']:8.1f} req/s  "
          f"p50 {base['p50_ms']:6.2f} ms  p99 {base['p99_ms']:6.2f} ms  "
          f"feature bytes {base['feature_bytes'] / 2**20:.2f} MiB")

    if args.cache == "none":
        print("done (cache disabled)")
        return base

    res = serve(args.cache)
    saved = base["feature_bytes"] - res["feature_bytes"]
    print(f"[{args.cache:9s}] {res['throughput_rps']:8.1f} req/s  "
          f"p50 {res['p50_ms']:6.2f} ms  p99 {res['p99_ms']:6.2f} ms  "
          f"feature bytes {res['feature_bytes'] / 2**20:.2f} MiB")
    print(f"embedding hit rate {res['embedding_hit_ratio']:.2%}  "
          f"feature hit rate {res['feature_hit_ratio']:.2%}  "
          f"pad overhead {res['pad_overhead']:.2%}  "
          f"jit entries {res['jit_entries']}")
    print(f"wire codec {res['wire_codec']}: feature "
          f"{res['feature_bytes'] / 2**20:.2f} MiB + cache-fill "
          f"{res['fill_bytes'] / 2**20:.2f} MiB = "
          f"{res['wire_bytes'] / 2**20:.2f} MiB on the wire")
    print(f"bytes saved vs no-cache: {saved / 2**20:.2f} MiB "
          f"({saved / max(base['feature_bytes'], 1):.1%})")
    return res


def _update_stream_kw(args, inv=None) -> dict:
    """Build the ``run(update_log=, update_every=, update_chunk=)``
    kwargs for ``--update-stream``: default cadence folds after every
    quarter of the workload, spreading the stream across ~4 chunks so
    mutations actually interleave with traffic (an end-of-run fold would
    never exercise mid-run invalidation).  ``inv`` relabels an
    original-id stream into the packed id space under ``--reorder``."""
    if not args.update_stream:
        return {}
    from repro.core.updates import load_update_stream
    log = load_update_stream(args.update_stream)
    if inv is not None:
        log = log.relabel(inv)
    every = args.update_every or max(1, args.requests // 4)
    chunk = max(1, -(-log.last_seq // 4))          # ceil(last_seq / 4)
    print(f"update stream: {log.last_seq} events from "
          f"{args.update_stream}, folding {chunk} events every "
          f"{every} completions")
    return {"update_log": log, "update_every": every,
            "update_chunk": chunk}


def _run_replicated(args, g, cfg, params, workload, capacity, update_kw):
    """Serve through the elastic ReplicaRouter: N replicas, optional
    autoscaling, rolling hot-swap every K completions, crash-safe
    stop/resume via ``--ckpt-dir``."""
    import jax

    from repro.checkpoint import latest_step
    from repro.models.gnn import model as GM
    from repro.serving import AutoscalePolicy, ReplicaRouter, restore_params

    router = ReplicaRouter(
        g, cfg, params,
        n_replicas=args.replicas,
        policy=args.router_policy,
        shared_cache=not args.private_cache,
        cache_policy=args.cache,
        cache_capacity=capacity,
        max_staleness=args.staleness,
        fanouts=args.fanouts,
        buckets=args.buckets,
        max_wait_s=args.max_wait_ms / 1e3,
        seed=args.seed,
        autoscale=AutoscalePolicy(
            min_replicas=args.replicas,
            max_replicas=args.max_replicas) if args.autoscale else None)

    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        resumed, version = restore_params(args.ckpt_dir, params)
        print(f"resumed weights from {args.ckpt_dir} "
              f"(params version {version})")
        if version > 0:
            router.hot_swap(resumed, version=version)
        else:
            router.params = resumed
            for rep in router.replicas:
                rep.server.params = resumed

    def fresh_params(version: int):
        return GM.init_gnn(cfg, jax.random.PRNGKey(args.seed + version))

    stats = router.run(workload,
                       hot_swap_every=args.hot_swap_every,
                       new_params_fn=(fresh_params
                                      if args.hot_swap_every else None),
                       **update_kw)
    out = router.summary()
    if update_kw:
        print(f"graph updates folded through seq {router._update_seq}")
    mode = "autoscale" if args.autoscale else "fixed"
    print(f"[replicated] {args.router_policy}/{mode}  "
          f"{out['throughput_rps']:8.1f} req/s  "
          f"p50 {out['p50_ms']:6.2f} ms  p99 {out['p99_ms']:6.2f} ms")
    print(f"served {out['served']}  dropped {out['dropped']}  "
          f"torn batches {out['torn_batches']}  "
          f"hot swaps {out['hot_swaps']}  "
          f"replicas peak {stats.replicas_peak} "
          f"final {stats.replicas_final}  "
          f"scale events {out['scale_events']}")
    print(f"version counts {out['version_counts']}  "
          f"serving version {out['params_version']}")
    if "embedding_hit_ratio" in out:
        kind = "shared" if out["shared_cache"] else "private"
        print(f"{kind} cache hit rate {out['embedding_hit_ratio']:.2%}  "
              f"wire {out['wire_bytes'] / 2**20:.2f} MiB")
    if args.ckpt_dir:
        path = router.save(args.ckpt_dir)
        print(f"checkpoint -> {path}")
    return out


if __name__ == "__main__":
    sys.exit(0 if main() is not None else 1)
