"""Production mesh builders.

These are FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; smoke tests and benchmarks see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (smoke tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
