"""Sharding rules: logical-axis tables mapping param/cache/batch pytree
paths to ``PartitionSpec``s (MaxText-style), plus a context-var driven
``constrain`` used inside model code (no-op when no rules are active).

Mesh axes:
  single-pod:  ("data", "model")           = (16, 16)
  multi-pod:   ("pod", "data", "model")    = (2, 16, 16)

Policy (see DESIGN.md §4):
  * weights: "model" on the feature/expert/head output dim; for *training*
    an additional FSDP-style "data" shard on the other dim (ZeRO-ish; the
    optimizer moments inherit the same spec);
  * batch dims over ("pod", "data") when divisible, else replicated
    (long_500k has B=1);
  * KV/latent cache sequence dim over "model" (heads are often too few),
    and additionally over "data" when the batch can't be sharded.
"""
from __future__ import annotations

import contextvars
import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_rules", default=None)


class ShardingRules:
    """Holds the mesh + activation specs; installed via ``activate()``."""

    def __init__(self, mesh: Mesh, *, batch_size: int, fsdp: bool,
                 seq_parallel: bool = True):
        self.mesh = mesh
        self.fsdp = fsdp
        self.seq_parallel = seq_parallel
        axes = mesh.axis_names
        self.multi_pod = "pod" in axes
        self.model_size = mesh.shape["model"]
        batch_axes = ("pod", "data") if self.multi_pod else ("data",)
        n_batch_shards = int(np.prod([mesh.shape[a] for a in batch_axes]))
        self.batch_axis = batch_axes if batch_size % n_batch_shards == 0 \
            else None
        # when the batch is unshardable (long_500k), spread caches over data
        self.seq_axes = ("data", "model") if self.batch_axis is None \
            else ("model",)

    # -- activation specs used by shd.constrain ---------------------------
    def spec_for(self, kind: str, shape) -> Optional[P]:
        b = self.batch_axis
        if kind == "act":      # (B, S, D) or (B, 1, D)
            # Megatron-style sequence parallelism on the residual stream:
            # shards the per-layer saved activations over `model` too.
            if (self.seq_parallel and len(shape) == 3
                    and shape[1] % self.model_size == 0):
                return P(b, "model", None)
            return P(b, None, None)
        if kind == "logits":   # (B, S, V)
            return P(b, None, "model")
        return None

    def activate(self):
        return _ActiveRules(self)


class _ActiveRules:
    def __init__(self, rules):
        self.rules = rules

    def __enter__(self):
        self.tok = _ACTIVE.set(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE.reset(self.tok)


def constrain(x: jax.Array, kind: str) -> jax.Array:
    rules = _ACTIVE.get()
    if rules is None:
        return x
    spec = rules.spec_for(kind, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


# ===========================================================================
# parameter specs
# ===========================================================================

# (regex on '/'-joined path, spec builder).  `d` = "data" iff fsdp else None.
_PARAM_RULES = [
    # embeddings: (V, D) vocab over model
    (r"embedding$",            lambda d: P("model", d)),
    (r"lm_head$",              lambda d: P(d, "model")),
    (r"(enc|dec)_pos$",        lambda d: P(None, None)),
    # attention
    (r"attn/w[qkv]$",          lambda d: P(d, "model")),
    (r"attn/wo$",              lambda d: P("model", d)),
    (r"attn/b[qkv]$",          lambda d: P("model")),
    (r"xattn/w[qkv]$",         lambda d: P(d, "model")),
    (r"xattn/wo$",             lambda d: P("model", d)),
    (r"xattn/b[qkv]$",         lambda d: P("model")),
    # MLA
    (r"attn/wq_a$",            lambda d: P(d, "model")),
    (r"attn/wq_b$",            lambda d: P(d, "model")),
    (r"attn/wkv_a$",           lambda d: P(d, None)),
    (r"attn/w_k_nope$",        lambda d: P(d, "model", None)),
    (r"attn/w_v$",             lambda d: P(d, "model", None)),
    # MLP
    (r"mlp/w_(in|gate)$",      lambda d: P(d, "model")),
    (r"mlp/w_out$",            lambda d: P("model", d)),
    (r"shared/w_(in|gate)$",   lambda d: P(d, "model")),
    (r"shared/w_out$",         lambda d: P("model", d)),
    # MoE: experts over model (expert parallel)
    (r"moe/router$",           lambda d: P(None, None)),
    (r"moe/w_(in|gate)$",      lambda d: P("model", d, None)),
    (r"moe/w_out$",            lambda d: P("model", None, d)),
    # SSM
    (r"ssm/w_z$",              lambda d: P(d, "model")),
    (r"ssm/w_xbc$",            lambda d: P(d, "model")),
    (r"ssm/w_dt$",             lambda d: P(d, "model")),
    (r"ssm/conv_w$",           lambda d: P(None, "model")),
    (r"ssm/conv_b$",           lambda d: P("model")),
    (r"ssm/(A_log|D|dt_bias)$", lambda d: P("model")),
    (r"ssm/norm$",             lambda d: P("model")),
    (r"ssm/out_proj$",         lambda d: P("model", d)),
]


def _path_str(path) -> str:
    parts = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            parts.append(str(e.key))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            parts.append(e.name)
        else:
            parts.append(str(e))
    return "/".join(parts)


def _spec_matches(spec: P, shape, mesh: Mesh, stacked: bool) -> P:
    """Prepend the layer-stack axis, drop axes that don't divide."""
    spec = tuple(spec)
    if stacked:
        spec = (None,) + spec
    spec = spec + (None,) * (len(shape) - len(spec))
    fixed = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        fixed.append(ax if dim % n == 0 else None)
    return P(*fixed)


def param_specs(params, mesh: Mesh, *, fsdp: bool):
    """PartitionSpec pytree matching ``params``."""
    d = "data" if fsdp else None

    def one(path, leaf):
        s = _path_str(path)
        stacked = bool(re.search(r"(^|/)((enc_|dec_|dense_|moe_)?layers)/",
                                 s))
        for pat, builder in _PARAM_RULES:
            if re.search(pat, s):
                return _spec_matches(builder(d), leaf.shape, mesh, stacked)
        # norms, scalars, biases — replicate
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, params)


def cache_specs(cache, mesh: Mesh, rules: ShardingRules):
    """KV/state cache specs.  Leaves are (L, B, C, ...) or (L, B, H, P, N)."""
    b = rules.batch_axis
    seq = rules.seq_axes

    def one(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        if re.search(r"(^|/)(k|v|c|kr)$", s):
            # (L, B, C, K, hd) or (L, B, C, dc)
            spec = [None, b, seq] + [None] * (len(shape) - 3)
        elif s.endswith("state"):
            spec = [None, b, "model"] + [None] * (len(shape) - 3)
        elif s.endswith("conv"):
            spec = [None, b, None, "model"]
        else:
            spec = [None] * len(shape)
        return _spec_matches(P(*spec[1:]), shape, mesh, stacked=True)

    return jax.tree_util.tree_map_with_path(one, cache)


def batch_specs(batch, mesh: Mesh, rules: ShardingRules):
    b = rules.batch_axis

    def one(path, leaf):
        s = _path_str(path)
        if s.endswith("pos"):
            return P()
        if s.endswith("positions"):          # (3, B, S)
            return _spec_matches(P(None, b), leaf.shape, mesh, False)
        return _spec_matches(P(b), leaf.shape, mesh, False)

    return jax.tree_util.tree_map_with_path(one, batch)


def to_named(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
