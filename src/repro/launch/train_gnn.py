"""Distributed GNN training driver — the paper-faithful entry point.

Full-graph mode distributes the graph over N (forced-host) devices with a
selectable partitioner and propagation/sync mode; ``--fullgraph`` runs the
staleness-bounded *asynchronous* full-graph path instead (versioned
per-layer ghost buffers, ``--staleness S`` age bound, ``--refresh-frac F``
budget); mini-batch mode runs a selectable sampler + caching policy —
single-device, or partition-parallel when ``--minibatch --devices N``
(repro.distributed: halo-cached remote fetches, double-buffered prefetch,
shard_map psum step).  ``--use-kernel`` routes every path's Gather step
through the differentiable fused Pallas aggregation kernels
(``repro.kernels``; interpret mode off-TPU, same numbers to <= 1e-5).
``--wire-codec {fp32,bf16,int8}`` selects the communication-plane wire
format (``repro.core.comm``) on the paths wired onto it — ghost
refreshes under ``--fullgraph``, remote feature rows under
``--minibatch``; ``fp32`` is bit-exact, ``int8`` cuts bytes/step ~4x
with sender-side error feedback.  The synchronous distributed
full-graph modes (``--mode pull/push/stale/hysync``) still move raw
fp32 and reject other codecs rather than misreport their traffic.

  PYTHONPATH=src python -m repro.launch.train_gnn --devices 8 \
      --partitioner ldg --mode pull --epochs 30 --use-kernel
  PYTHONPATH=src python -m repro.launch.train_gnn --fullgraph --devices 4 \
      --staleness 2 --refresh-frac 0.05 --epochs 30
  PYTHONPATH=src python -m repro.launch.train_gnn --minibatch \
      --sampler neighbor --cache degree --epochs 5
  PYTHONPATH=src python -m repro.launch.train_gnn --minibatch --devices 4 \
      --partitioner ldg --cache degree --epochs 5

See docs/architecture.md for the dataflow of all three paths.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--nodes", type=int, default=512)
    ap.add_argument("--avg-degree", type=float, default=8.0)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--feat-dim", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--arch", default="gcn",
                    choices=["gcn", "sage", "gat", "gin", "ggnn", "appnp"])
    ap.add_argument("--dataset", default="",
                    help="named dataset from repro.graph.datasets "
                         "(citeseer-like, pubmed-like, reddit-like, ...); "
                         "default: SBM sized by --nodes")
    ap.add_argument("--partitioner", default="hash",
                    choices=["hash", "ldg", "fennel", "auto"])
    ap.add_argument("--mode", default="pull",
                    choices=["pull", "push", "stale", "hysync"])
    ap.add_argument("--staleness", type=int, default=4,
                    help="staleness bound S: full-epoch snapshot period "
                         "for --mode stale/hysync, per-row ghost age bound "
                         "for --fullgraph")
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--minibatch", action="store_true")
    ap.add_argument("--fullgraph", action="store_true",
                    help="staleness-bounded asynchronous full-graph "
                         "training (repro.distributed.async_train): "
                         "per-layer versioned ghost buffers, --staleness S "
                         "age bound, --refresh-frac budget")
    ap.add_argument("--refresh-frac", type=float, default=0.0,
                    help="extra per-step ghost refresh budget as a "
                         "fraction of the ghost set (--fullgraph only)")
    ap.add_argument("--update-stream", default="",
                    help="continual training: a JSONL graph-update "
                         "stream (repro.core.updates.GraphUpdateLog "
                         "format) folded into the training graph "
                         "between epochs — incremental re-shard + "
                         "delta-frontier ghost invalidation, no cold "
                         "restart (--fullgraph only)")
    ap.add_argument("--updates-per-epoch", type=int, default=0,
                    help="events folded between consecutive epochs "
                         "(0 = spread the whole stream evenly across "
                         "the run)")
    ap.add_argument("--sampler", default="neighbor",
                    choices=["neighbor", "importance", "fastgcn", "ladies",
                             "cluster", "saint"])
    ap.add_argument("--cache", default="degree",
                    choices=["none", "degree", "importance", "random"])
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--reorder", default="none",
                    choices=["none", "degree", "bfs", "rcm"],
                    help="locality-reorder the graph before anything "
                         "else touches it (survey §3.2.4: degree = "
                         "ZIPPER, bfs = GNNAdvisor/Rabbit-order "
                         "stand-in, rcm = reverse Cuthill-McKee). "
                         "Partitioners, samplers, halo layouts and "
                         "caches all operate on the packed graph; "
                         "training losses/accuracy are "
                         "relabeling-invariant")
    ap.add_argument("--use-kernel", action="store_true",
                    help="run every aggregation (the Gather hot spot) "
                         "through the differentiable fused Pallas "
                         "kernels (interpret mode off-TPU)")
    ap.add_argument("--wire-codec", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="communication-plane wire codec "
                         "(repro.core.comm) for every remote payload: "
                         "ghost refreshes (--fullgraph) and remote "
                         "feature fetches (--minibatch).  fp32 is "
                         "bit-exact; int8 cuts bytes ~4x with "
                         "error-feedback residuals")
    ap.add_argument("--metrics-out", default="",
                    help="enable telemetry and write the Prometheus "
                         "text-format exposition here on exit "
                         "(repro.core.telemetry)")
    ap.add_argument("--trace-out", default="",
                    help="enable telemetry and write the JSONL span "
                         "trace here on exit")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def resolve_edge_cut(g, n_dev: int, method: str) -> str:
    """EASE-style auto selection, constrained to the edge-cut family both
    distributed paths (full-graph shards, mini-batch partitions) require."""
    if method == "auto":
        from repro.core.partitioning import select_partitioner
        method = select_partitioner(g, n_dev)
        if method == "hdrf":
            method = "ldg"
        print(f"auto-selected partitioner: {method}")
    return method


def main(argv=None):
    """Parse args, run the selected training path, and (when asked) dump
    the telemetry plane on exit — metrics as Prometheus text, spans as
    JSONL (see docs/observability.md)."""
    args = parse_args(argv)
    from repro.core import telemetry
    if args.metrics_out or args.trace_out:
        telemetry.set_enabled(True)
    try:
        return run(args)
    finally:
        if args.metrics_out:
            telemetry.get_registry().write_prometheus(args.metrics_out)
            print(f"telemetry: metrics -> {args.metrics_out}")
        if args.trace_out:
            n = telemetry.get_registry().tracer.export_jsonl(args.trace_out)
            print(f"telemetry: {n} trace events -> {args.trace_out}")


def run(args):
    """The actual training driver (all four paths); ``main`` wraps it
    with the telemetry dump."""
    if args.wire_codec != "fp32" and not (args.minibatch or args.fullgraph):
        # the synchronous full-graph modes (pull/push/stale/hysync) and
        # the single-device full-batch trainer are not on the
        # communication plane; silently ignoring the flag would make
        # their reported traffic a lie
        raise SystemExit("--wire-codec is wired through --fullgraph and "
                         "--minibatch; the synchronous full-graph modes "
                         "move raw fp32")
    if args.update_stream and not args.fullgraph:
        # continual training folds deltas through the async trainer's
        # versioned ghost state; the other paths have no incremental
        # invalidation surface and would silently train a frozen graph
        raise SystemExit("--update-stream requires --fullgraph "
                         "(continual training folds deltas through the "
                         "async trainer's versioned ghost buffers)")
    if args.devices > 1 and "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import caching as CA
    from repro.core import propagation as PR
    from repro.core import sampling as SA
    from repro.core import telemetry
    from repro.core.abstraction import DeviceGraph
    from repro.core.scheduling import PipelinedLoader
    from repro.core.sync import HaloCache, SyncPolicy
    from repro.graph import generators as G
    from repro.models.gnn import model as GM
    from repro.models.gnn.model import GNNConfig
    from repro.optim import AdamW

    rng = np.random.default_rng(args.seed)
    if args.dataset:
        from repro.graph.datasets import load
        ds = load(args.dataset, seed=args.seed)
        g = ds.graph
        feat_dim = g.features.shape[1]
    else:
        g = G.sbm(args.nodes, args.classes, p_in=0.9, p_out=0.02,
                  seed=args.seed)
        g = G.featurize(g, args.feat_dim, seed=args.seed, class_sep=1.5)
        feat_dim = args.feat_dim
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges, "
          f"{g.num_classes} classes; devices={jax.device_count()}")

    reorder_inv = None
    if args.reorder != "none":
        # pack BEFORE partitioning/sampling/halo so every downstream
        # structure keys off the packed id space; node ids round-trip
        # through (perm, inv) at the API boundary — training itself is
        # relabeling-invariant, so perm is only needed for reporting
        from repro.core.reordering import locality_report
        from repro.kernels import ops as kops
        g, perm, reorder_inv = g.reordered(args.reorder)
        rep = locality_report(g)
        e = g.edges()
        td = kops.record_tile_density(e[:, 0], e[:, 1], g.num_nodes)
        print(f"reorder={args.reorder}: gather stride "
              f"{rep['avg_gather_stride']:.1f}, reuse hit "
              f"{rep['reuse_hit_rate']:.2%}, active tiles "
              f"{td['active_tile_frac']:.2%}")

    cfg = GNNConfig(arch=args.arch, feat_dim=feat_dim,
                    hidden=args.hidden, num_classes=g.num_classes,
                    use_kernel=args.use_kernel,
                    wire_codec=args.wire_codec)
    params = GM.init_gnn(cfg, jax.random.PRNGKey(args.seed))
    opt = AdamW(lr=args.lr, weight_decay=0.0)
    ostate = opt.init(params)

    # ---- staleness-bounded asynchronous full-graph path --------------
    if args.fullgraph:
        from repro.distributed import AsyncFullGraphTrainer

        if args.arch != "gcn":
            raise SystemExit("--fullgraph implements GCN (like the "
                             "synchronous distributed full-graph mode)")
        n_dev = min(args.devices, jax.device_count())
        method = resolve_edge_cut(g, n_dev, args.partitioner)
        trainer = AsyncFullGraphTrainer(
            g, cfg, opt, n_dev, partitioner=method,
            staleness=max(args.staleness, 0),
            refresh_frac=args.refresh_frac)
        if args.update_stream:
            import math as _math

            from repro.core.updates import load_update_stream
            log = load_update_stream(args.update_stream)
            if reorder_inv is not None:
                # the stream speaks original ids; the trainer's graph is
                # packed — relabel once at the boundary (folding
                # commutes with relabeling)
                log = log.relabel(reorder_inv)
            per = args.updates_per_epoch or _math.ceil(
                log.last_seq / max(args.epochs - 1, 1))
            print(f"update stream: {log.last_seq} events from "
                  f"{args.update_stream}, folding {per}/epoch")
            loss = float("nan")
            for epoch in range(args.epochs):
                params, ostate, loss = trainer.run(params, ostate, 1)
                if trainer._update_seq < log.last_seq:
                    upto = min(trainer._update_seq + per, log.last_seq)
                    fold = trainer.fold_updates(log, upto)
                    print(f"epoch {epoch:3d} loss {float(loss):.4f} "
                          f"folded {fold['events']} events "
                          f"(touched {fold['touched_nodes']} nodes, "
                          f"invalidated {fold['invalidated_rows']} "
                          f"ghost rows)")
        else:
            params, ostate, loss = trainer.run(params, ostate, args.epochs,
                                               log_every=5)
        st = trainer.stats()
        print(f"final accuracy {trainer.accuracy(params):.3f}")
        print(f"ghost rows {st['ghost_rows']}; wire codec "
              f"{st['wire_codec']}; cross-partition "
              f"{st['bytes_per_step'] / 1024:.1f} KiB/step vs "
              f"{st['sync_bytes_per_step'] / 1024:.1f} KiB/step "
              f"synchronous ({st['comm_savings']:.0%} saved); "
              f"{st['mean_step_s'] * 1e3:.1f} ms/step")
        return float(loss)

    if not args.minibatch and (args.arch != "gcn" or args.devices <= 1):
        # generic single-device full-batch trainer (any architecture);
        # the multi-device shard_map path below is GCN-specific
        from repro.core.abstraction import DeviceGraph
        dg = DeviceGraph.from_graph(g)
        x = jnp.asarray(g.features)
        y = jnp.asarray(g.labels)
        mask = jnp.ones_like(y, jnp.float32)
        step = jax.jit(GM.make_fullgraph_train_step(cfg, opt))
        for epoch in range(args.epochs):
            params, ostate, loss = step(params, ostate, dg, x, y, mask)
            if epoch % 5 == 0 or epoch == args.epochs - 1:
                print(f"epoch {epoch:3d} loss {float(loss):.4f}")
        acc = float(GM.accuracy(GM.forward_full(cfg, params, dg, x), y))
        print(f"final accuracy {acc:.3f}")
        return float(loss)

    if not args.minibatch:
        from repro.core.sync import HysyncController

        if args.arch != "gcn":
            raise SystemExit("distributed full-graph mode implements GCN; "
                             "use --minibatch for other architectures")
        n_dev = min(args.devices, jax.device_count())
        method = resolve_edge_cut(g, n_dev, args.partitioner)
        sg = PR.shard_graph(g, n_dev, method=method)

        if args.mode == "push":
            push_arrays = PR.push_layout(sg, g)
            mesh, step = PR.make_distributed_gcn_step(
                opt, n_dev, mode="push", use_kernel=args.use_kernel)
            for epoch in range(args.epochs):
                params, ostate, loss = step(params, ostate, sg,
                                            push_arrays=push_arrays)
                if epoch % 5 == 0 or epoch == args.epochs - 1:
                    print(f"epoch {epoch:3d} loss {float(loss):.4f}")
            return float(loss)

        stale_like = args.mode in ("stale", "hysync")
        mesh, step = PR.make_distributed_gcn_step(
            opt, n_dev, mode="stale" if stale_like else "pull",
            use_kernel=args.use_kernel)
        hysync = HysyncController(stale_s=args.staleness) \
            if args.mode == "hysync" else None
        policy = SyncPolicy(mode="stale" if stale_like else "bsp",
                            staleness=args.staleness)
        halo = HaloCache(sg.x)
        for epoch in range(args.epochs):
            if hysync is not None:
                policy.staleness = hysync.staleness()
            cache_val = halo.maybe_refresh(policy, epoch, sg.x)
            params, ostate, loss = step(params, ostate, sg,
                                        halo_cache=cache_val)
            if hysync is not None:
                mode_now = hysync.observe(epoch, float(loss))
            if epoch % 5 == 0 or epoch == args.epochs - 1:
                extra = f" mode={hysync.mode}" if hysync else ""
                print(f"epoch {epoch:3d} loss {float(loss):.4f}{extra}")
        if args.mode == "stale":
            print(f"halo-exchange savings vs BSP: "
                  f"{halo.comm_savings():.0%}")
        if hysync is not None and hysync.switch_step is not None:
            print(f"hysync switched stale->bsp at epoch "
                  f"{hysync.switch_step}; savings "
                  f"{halo.comm_savings():.0%}")
        return float(loss)

    # ---- distributed mini-batch path (partition-parallel) ------------
    if args.devices > 1:
        from repro.distributed import (DistributedMinibatchSampler,
                                       HostPrefetcher, collate,
                                       make_distributed_minibatch_step)

        if args.sampler not in ("neighbor",):
            raise SystemExit("distributed mini-batch uses the padded "
                             "neighbor sampler (--sampler neighbor)")
        n_dev = min(args.devices, jax.device_count())
        method = resolve_edge_cut(g, n_dev, args.partitioner)
        dsampler = DistributedMinibatchSampler(
            g, n_dev, [5, 5], args.batch, partitioner=method,
            cache_policy=args.cache, cache_capacity=g.num_nodes // 10,
            wire_codec=args.wire_codec, seed=args.seed)
        mesh, dstep = make_distributed_minibatch_step(
            cfg, opt, n_dev, dsampler.block_shapes())

        def make_dist_batch():
            seeds = rng.choice(g.num_nodes, args.batch, replace=False)
            return collate(dsampler.sample_global(seeds), dsampler.out_deg)

        prefetch = HostPrefetcher(make_dist_batch)
        steps_per_epoch = max(1, g.num_nodes // args.batch)
        loss = None
        m_step = telemetry.histogram(
            "train_step_seconds", "wall time per executed training step",
            mode="minibatch_dist")
        for epoch in range(args.epochs):
            for _ in range(steps_per_epoch):
                arrays = next(prefetch)
                t0 = time.perf_counter()
                with telemetry.span("train.step", mode="minibatch_dist"):
                    params, ostate, loss = dstep(params, ostate, arrays)
                m_step.observe(time.perf_counter() - t0)
            # monitoring only: the ratio also covers the 1-2 batches the
            # prefetcher sampled ahead; exact byte totals come after close
            st = dsampler.stats()
            print(f"epoch {epoch:3d} loss {float(loss):.4f} "
                  f"halo_hit {st['halo_hit_ratio']:.2%}")
        prefetch.close()
        st = dsampler.stats()
        xpart_mib = st["cross_partition_bytes"] / 2**20
        print(f"cross-partition traffic {xpart_mib:.1f} MiB "
              f"(wire codec {st['wire_codec']}) over "
              f"{prefetch.produced} sampled batches "
              f"({args.epochs * steps_per_epoch} trained); halo_hit "
              f"{st['halo_hit_ratio']:.2%}; ghost fraction "
              f"{st['ghost_fraction']:.2f}; prefetch overlap "
              f"{prefetch.overlap_ratio():.0%}")
        return float(loss)

    # ---- mini-batch path ---------------------------------------------
    if args.sampler == "neighbor":
        sampler = SA.NeighborSampler(g, [5, 5], seed=args.seed)
    elif args.sampler == "importance":
        sampler = SA.ImportanceSampler(g, [5, 5], seed=args.seed)
    elif args.sampler in ("fastgcn", "ladies"):
        sampler = SA.LayerWiseSampler(g, [128, 128],
                                      dependent=args.sampler == "ladies",
                                      seed=args.seed)
    else:
        sampler = None

    cache_ids = CA.CACHE_POLICIES[args.cache](g, g.num_nodes // 10)
    store = CA.FeatureStore(g, cache_ids, codec=args.wire_codec)
    step = jax.jit(GM.make_minibatch_train_step(cfg, opt))

    def make_batch():
        seeds = rng.choice(g.num_nodes, args.batch, replace=False)
        mb = sampler.sample(seeds)
        return mb, seeds

    loader = PipelinedLoader(make_batch, depth=4, n_workers=2)
    steps_per_epoch = max(1, g.num_nodes // args.batch)
    loss = None
    m_step = telemetry.histogram(
        "train_step_seconds", "wall time per executed training step",
        mode="minibatch_single")
    for epoch in range(args.epochs):
        for _ in range(steps_per_epoch):
            mb, seeds = next(loader)
            t0 = time.perf_counter()
            with telemetry.span("train.step", mode="minibatch_single"):
                blocks = [DeviceGraph.from_block(b) for b in mb.blocks]
                # input rows travel the communication plane: cache misses
                # are byte-accounted and arrive wire-decoded (zero rows at
                # pads — pad slots never aggregate, training is unaffected)
                src = mb.blocks[0].src_nodes
                if args.wire_codec == "int8" and args.use_kernel:
                    # int8-in path: rows stay in wire format all the way
                    # into the aggregation kernel, which dequantizes per
                    # source slab — no decode round-trip (layers that
                    # project before aggregating decode on device)
                    x_in = store.fetch_masked_wire(src, src >= 0)
                else:
                    x_in = jnp.asarray(store.fetch_masked(src, src >= 0))
                y = jnp.asarray(g.labels[seeds])
                params, ostate, loss = step(params, ostate, blocks, x_in,
                                            y, jnp.ones_like(y, jnp.float32))
            m_step.observe(time.perf_counter() - t0)
        print(f"epoch {epoch:3d} loss {float(loss):.4f} "
              f"cache_hit {store.hit_ratio:.2%} "
              f"fetched {store.transferred_bytes / 2**20:.1f} MiB")
    loader.close()
    return float(loss)


if __name__ == "__main__":
    sys.exit(0 if main() is not None else 1)
