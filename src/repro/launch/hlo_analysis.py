"""HLO post-SPMD analysis helpers (import-safe: touches no jax state).

``collective_bytes`` sums the output-shape bytes of every collective op in
a compiled HLO module — the source for the roofline's collective term.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12          # TPU v5e bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)\(")
_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64)"
                       r"\[([\d,]*)\]")
_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind byte totals (shapes in post-SPMD HLO are per-device)."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(3)
        b = _shape_bytes(m.group(2))
        out[op] = out.get(op, 0) + b
        out["total"] = out.get("total", 0) + b
    return out
