"""Training driver for the assigned transformer architectures.

On this CPU container it trains REDUCED variants end-to-end (the examples
use it to train a ~100M-param model for a few hundred steps); on real
hardware the same entry point shards over the production mesh via the
dry-run's sharding rules.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
      --reduced --steps 200 --batch 16 --seq 128 [--ckpt-dir ckpts]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.base import get_config
from repro.data.pipeline import SyntheticLMDataset
from repro.models.transformer import model as M
from repro.optim import AdamW, cosine_schedule


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--vocab", type=int, default=0,
                    help="override vocab (synthetic data scales with it)")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    overrides = {}
    if args.vocab:
        overrides["vocab_size"] = args.vocab
    if args.d_model:
        overrides["d_model"] = args.d_model
        if cfg.num_heads:
            overrides["head_dim"] = args.d_model // cfg.num_heads
    if args.d_ff:
        overrides["d_ff"] = args.d_ff
    if args.layers:
        overrides["num_layers"] = args.layers
    if overrides:
        cfg = cfg.replace(**overrides)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit(
            f"{cfg.family} training uses precomputed frontend embeddings; "
            "see examples/whisper_vlm_smoke.py")

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key, max_seq=args.seq)
    n_params = M.param_count(params)
    print(f"arch={cfg.name} family={cfg.family} params={n_params:,} "
          f"devices={jax.device_count()}")

    opt = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps),
                weight_decay=0.01)
    ostate = opt.init(params)
    step_fn = jax.jit(M.make_train_step(cfg, opt, remat=False), donate_argnums=(0, 1))

    ds = SyntheticLMDataset(cfg.vocab_size, args.seq, seed=args.seed)
    it = ds.batches(args.batch)
    tokens_per_step = args.batch * args.seq

    t0 = time.time()
    for step in range(1, args.steps + 1):
        b = next(it)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, ostate, metrics = step_fn(params, ostate, batch)
        if step % args.log_every == 0 or step == 1:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            tps = step * tokens_per_step / dt
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"tok/s {tps:,.0f}", flush=True)
        if args.ckpt_dir and step % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, step,
                                   {"params": params, "opt": ostate},
                                   meta={"arch": cfg.name, "loss": loss})
            print(f"  checkpoint -> {path}")
    print(f"done in {time.time() - t0:.1f}s; final loss "
          f"{float(metrics['loss']):.4f}")
    return params


if __name__ == "__main__":
    main()
