"""Serving driver: batched greedy decoding against a KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --reduced \
      --batch 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.transformer import model as M


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("use examples/whisper_vlm_smoke.py for stub-"
                         "frontend families")
    key = jax.random.PRNGKey(args.seed)
    B, S, GEN = args.batch, args.prompt_len, args.gen
    params = M.init_params(cfg, key, max_seq=S + GEN)
    print(f"arch={cfg.name} params={M.param_count(params):,} "
          f"batch={B} prompt={S} gen={GEN}")

    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    # decode-only serving loop against a pre-sized cache (prefill is folded
    # into the loop so every position exercises decode_step)
    cache = M.init_cache(cfg, B, S + GEN)
    dstep = jax.jit(lambda p, c, b: M.decode_step(cfg, p, c, b),
                    donate_argnums=(1,))

    t0 = time.time()
    seq = np.asarray(prompts)
    logits = None
    for t in range(S):
        logits, cache = dstep(params, cache,
                              {"token": jnp.asarray(seq[:, t:t + 1]),
                               "pos": jnp.asarray(t, jnp.int32)})
    t_prefill = time.time() - t0

    t0 = time.time()
    out = []
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
    for i in range(GEN):
        out.append(np.asarray(tok))
        logits, cache = dstep(params, cache,
                              {"token": tok,
                               "pos": jnp.asarray(S + i, jnp.int32)})
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
    jax.block_until_ready(logits)
    t_gen = time.time() - t0

    gen_tokens = np.concatenate(out, axis=1)
    print(f"prefill: {B * S / t_prefill:,.0f} tok/s  "
          f"decode: {B * GEN / t_gen:,.0f} tok/s")
    print("first sequences:", gen_tokens[0, :8].tolist())
    return gen_tokens


if __name__ == "__main__":
    main()
