"""Inference-time neighbor sampling with fully static shapes.

Reuses :func:`repro.core.sampling.sample_block_padded`: the seed slot array
comes in already padded to a batcher bucket, and each layer expansion emits
a :class:`~repro.core.sampling.Block` whose shape depends only on
``(bucket, fanouts)`` — so a k-layer mini-batch for bucket B always has the
same pytree of shapes and hits one jit entry.

Two serving-specific twists vs. the training samplers:

* **determinism per node** — a node's sampled neighborhood is a pure
  function of ``(seed, layer, node)``, not of request order.  Historical
  embeddings cached for a node therefore describe exactly the neighborhood
  a recompute would use, making cache hits *exact* at staleness 0.
* **expansion masks** — the innermost expansion can be restricted to
  embedding-cache misses; hit nodes keep their slot (shape discipline) but
  get no edges and no feature fetches.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sampling import Block, MiniBatch, sample_block_padded
from repro.graph.structure import Graph


def _propagate_need(b: Block, need: np.ndarray) -> np.ndarray:
    """Push a dst-slot relevance mask through one block to its src slots:
    a src slot matters if it sits in the prefix of a needed dst (self
    features flow) or feeds a valid edge (edges only exist under
    expanded, i.e. miss-path, dst nodes)."""
    src_need = np.zeros(b.num_src, bool)
    src_need[:b.num_dst] |= need
    src_need[b.edge_src[b.edge_mask]] = True
    return src_need


class ServingSampler:
    """Fixed-shape inference-time neighbor sampler.

    Args:
        g: the served graph (``g.reverse()`` is precomputed for in-edge
            expansion).
        fanouts: per-layer fanout, innermost first — one per model layer.
        seed: base of the per-``(seed, layer, node)`` rng, so a node's
            sampled neighborhood is independent of batch composition.

    Shape convention: seeds arrive padded to a batcher bucket (``-1`` =
    empty slot); every emitted :class:`~repro.core.sampling.Block` has the
    caps declared by :meth:`block_shapes` — a pure function of
    ``(bucket, fanouts)`` — and pad slots carry no edges, so pad rows
    never aggregate into real outputs.
    """

    def __init__(self, g: Graph, fanouts: Sequence[int], *, seed: int = 0):
        self.g = g
        self.gr = g.reverse()
        self.fanouts = list(fanouts)
        self.seed = seed
        # per-(layer, node) pick memo: because a node's pick is a pure
        # function of (seed, layer, node, neighbor list), memoizing it is
        # semantically invisible — it only skips re-deriving the rng.  The
        # delta path (apply_delta) drops exactly the touched entries, so
        # untouched nodes keep their sampled neighborhoods bit-identical
        # across graph mutations (the property the cache relies on).
        self._memo: dict = {}
        self.memo_hits = 0
        self.memo_misses = 0

    def _rng_for(self, layer: int):
        def rng_for(node: int):
            return np.random.default_rng((self.seed, layer, node))
        return rng_for

    def _picker(self, layer: int):
        """Memoizing pick function for :func:`sample_block_padded`: on a
        miss it computes the identical pick the plain rng path would
        (subset of the CURRENT in-neighbor list), then caches it under
        ``(layer, node)`` until a delta touches the node."""
        fanout = self.fanouts[layer]

        def picker(node: int, nbr: np.ndarray) -> np.ndarray:
            key = (layer, node)
            pick = self._memo.get(key)
            if pick is not None:
                self.memo_hits += 1
                return pick
            self.memo_misses += 1
            if len(nbr) <= fanout:
                pick = nbr
            else:
                rng = np.random.default_rng((self.seed, layer, node))
                pick = rng.choice(nbr, fanout, replace=False)
            self._memo[key] = pick
            return pick
        return picker

    # -- delta awareness ---------------------------------------------------
    def apply_delta(self, touched: np.ndarray) -> int:
        """React to a graph mutation whose frontier is ``touched`` node
        ids: rebuild the reversed adjacency (the graph arrays were folded
        in place) and drop the memoized picks of touched nodes across all
        layers, so only they are re-sampled against the new neighbor
        lists.  Untouched nodes keep their exact previous expansion.
        Returns the number of memo entries dropped."""
        self.gr = self.g.reverse()
        dropped = 0
        for node in np.asarray(touched, np.int64):
            for layer in range(len(self.fanouts)):
                if self._memo.pop((layer, int(node)), None) is not None:
                    dropped += 1
        return dropped

    def affected_seed_mask(self, seeds: np.ndarray,
                           touched: np.ndarray) -> np.ndarray:
        """Which ``seeds`` (padded, -1 = empty) have a k-hop sampled ball
        that can intersect the ``touched`` delta frontier — the only
        seeds whose outputs may change, so the only ones a delta-aware
        caller must re-serve.  Conservative: uses the full k-hop
        neighborhood (a superset of any sampled subset)."""
        from repro.core.updates import k_hop_nodes
        ball = k_hop_nodes(self.g, np.asarray(touched, np.int64),
                           len(self.fanouts))
        hit = np.zeros(self.g.num_nodes, bool)
        hit[ball] = True
        seeds = np.asarray(seeds, np.int64)
        return (seeds >= 0) & hit[np.maximum(seeds, 0)]

    # -- shape contract ----------------------------------------------------
    def block_shapes(self, bucket: int) -> List[Tuple[int, int, int]]:
        """Declared (dst_cap, src_cap, edge_cap) per block, innermost
        first — the bucket invariant tests assert emitted blocks match."""
        caps = []
        d = bucket
        for f in reversed(self.fanouts):       # outermost first
            caps.append((d, d * (1 + f), d * f))
            d = d * (1 + f)
        caps.reverse()
        return caps

    # -- sampling ----------------------------------------------------------
    def sample_outer(self, padded_seeds: np.ndarray) -> Block:
        """The final-layer block: seeds aggregate from their sampled
        1-hop neighborhood.  Always fully expanded (the last layer is
        never served from cache — its inputs may be)."""
        layer = len(self.fanouts) - 1
        return sample_block_padded(
            self.g, self.gr, padded_seeds, self.fanouts[-1],
            self._rng_for(layer), picker=self._picker(layer))

    def sample_inner(self, dst: np.ndarray,
                     expand: Optional[np.ndarray] = None) -> List[Block]:
        """Expand the remaining ``k-1`` layers below ``dst`` (the outer
        block's src nodes), innermost first.  ``expand`` restricts the
        first expansion to cache misses; deeper layers restrict
        automatically because unexpanded nodes contribute no srcs."""
        blocks: List[Block] = []
        for layer in reversed(range(len(self.fanouts) - 1)):
            b = sample_block_padded(self.g, self.gr, dst,
                                    self.fanouts[layer],
                                    self._rng_for(layer), expand=expand,
                                    picker=self._picker(layer))
            blocks.append(b)
            if expand is not None:
                expand = _propagate_need(b, expand)
            dst = b.src_nodes
        blocks.reverse()
        return blocks

    def sample(self, padded_seeds: np.ndarray,
               expand_inner: Optional[np.ndarray] = None) -> MiniBatch:
        """Full k-layer mini-batch for one micro-batch of seed slots."""
        outer = self.sample_outer(padded_seeds)
        inner = self.sample_inner(outer.src_nodes, expand_inner)
        blocks = inner + [outer]
        return MiniBatch(blocks, np.asarray(padded_seeds, np.int64),
                         blocks[0].src_nodes)


def needed_feature_mask(blocks: List[Block], need_dst: np.ndarray) -> np.ndarray:
    """Which input-feature rows (blocks[0].src_nodes slots) are actually
    required to compute the representations of the ``need_dst``-marked dst
    slots of the OUTERMOST inner block (= embedding-cache misses).

    Walks outer→inner via :func:`_propagate_need` — the same propagation
    rule ``sample_inner`` uses to restrict expansion, so which rows are
    fetched always matches which nodes were expanded."""
    need = np.asarray(need_dst, bool)
    for b in reversed(blocks):
        assert len(need) == b.num_dst
        need = _propagate_need(b, need)
    return need
