"""Elastic replicated serving: router, autoscaler, rolling weight hot-swap.

The survey frames large-scale GNN serving as graph processing meeting
DL-systems operational machinery — replication, load balancing, elastic
scaling, and consistent model versioning.  This module is that tier on
top of the single :class:`~repro.serving.server.GNNInferenceServer` loop:

* :class:`ReplicaRouter` — admits the workload under ONE virtual clock
  and dispatches each request to a replica (``round_robin`` or
  ``least_queue``).  Replicas overlap in virtual time (each is busy for
  its measured wall compute), so N replicas multiply simulated
  throughput; the router finalizes completions, tags every response with
  the weight version that computed it, and guarantees zero drops: every
  admitted request is dispatched, every dispatched request is served
  (draining replicas serve their queues dry before removal).
* :class:`AutoScaler` — KEDA-style load controller: scale up when queue
  depth per replica exceeds ``target_queue_per_replica`` or the recent
  p99 exceeds ``slo_p99_s``, scale down after sustained idleness, with a
  cooldown between actions and ``[min_replicas, max_replicas]`` bounds.
  The signals are the same queue-depth/latency series the telemetry
  plane exposes (``serving_replica_queue_depth``,
  ``serving_request_latency_seconds{replica=...}``).
* rolling hot-swap — :meth:`ReplicaRouter.hot_swap` stages
  ``(new_params, version+1)`` and the run loop flips replicas one at a
  time, each only while idle, so every batch is computed end-to-end
  under exactly one version.  Cache consistency under the swap:

  - *shared* cache: flipped (``bump_params_version`` → invalidate all
    planes + clock tick) when the FIRST replica upgrades; replicas still
    on the old version then see it as cold and neither read nor fill it
    (the version gate in ``GNNInferenceServer.serve_batch``), so a
    new-version reader can never receive old-version rows;
  - *private* caches: each replica's cache flips with the replica.

Stop/resume rides on :mod:`repro.checkpoint`: :meth:`ReplicaRouter.save`
writes the current ``(params, version)`` atomically (crash-safe temp-dir
+ rename), and :func:`restore_params` loads the newest *complete* step —
a kill mid-save can only ever resurface the previous version.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import telemetry
from repro.core.telemetry import Histogram
from repro.graph.structure import Graph
from repro.models.gnn.model import GNNConfig
from repro.serving.cache import EmbeddingCache
from repro.serving.replica import ServingReplica
from repro.serving.request import InferenceRequest, advance_vclock
from repro.serving.server import GNNInferenceServer

__all__ = ["AutoscalePolicy", "AutoScaler", "ReplicaRouter", "RouterStats",
           "restore_params"]

ROUTER_POLICIES = ("round_robin", "least_queue")


@dataclasses.dataclass
class AutoscalePolicy:
    """Scaling thresholds (all times in *virtual* seconds).

    Scale up adds one replica when ``total_queue / n_replicas >
    target_queue_per_replica`` OR the windowed p99 exceeds ``slo_p99_s``
    (when set); scale down removes one after ``scale_down_after`` many
    consecutive low-load checks.  ``cooldown_s`` separates actions;
    ``startup_delay_s`` models a new replica's cold start (it accepts
    traffic immediately but cannot serve until the delay elapses)."""
    min_replicas: int = 1
    max_replicas: int = 8
    target_queue_per_replica: float = 8.0
    low_queue_per_replica: float = 0.5
    slo_p99_s: Optional[float] = None
    check_every_s: float = 0.02
    cooldown_s: float = 0.04
    scale_down_after: int = 3
    startup_delay_s: float = 0.0
    p99_window: int = 64


class AutoScaler:
    """Load-based replica-count controller over telemetry signals.

    :meth:`decide` consumes the fleet's current queue depths and the
    recent latency window and returns +1 (scale up), -1 (scale down), or
    0 — the router applies the action.  Decisions and their inputs are
    recorded in ``events`` for the benchmark/test assertions ("the
    autoscaler demonstrably scales up on queue depth")."""

    def __init__(self, policy: AutoscalePolicy):
        self.policy = policy
        self._last_action_s = -math.inf
        self._low_checks = 0
        self._recent: collections.deque = collections.deque(
            maxlen=policy.p99_window)
        self.events: List[dict] = []

    def observe_latency(self, latency_s: float) -> None:
        """Feed one completion latency into the p99 window."""
        self._recent.append(latency_s)

    def recent_p99(self) -> float:
        """p99 over the sliding completion window (0.0 while empty)."""
        if not self._recent:
            return 0.0
        return float(np.quantile(np.asarray(self._recent), 0.99))

    def decide(self, vnow: float, queue_depths: Sequence[int],
               n_replicas: int) -> int:
        """One control step; returns the replica-count delta."""
        p = self.policy
        if vnow - self._last_action_s < p.cooldown_s:
            return 0
        qpr = sum(queue_depths) / max(n_replicas, 1)
        p99 = self.recent_p99()
        up = qpr > p.target_queue_per_replica or (
            p.slo_p99_s is not None and p99 > p.slo_p99_s)
        if up and n_replicas < p.max_replicas:
            self._last_action_s = vnow
            self._low_checks = 0
            self.events.append({"vnow": vnow, "action": "up",
                                "queue_per_replica": qpr, "p99_s": p99,
                                "replicas": n_replicas + 1})
            return 1
        if qpr < p.low_queue_per_replica and not up:
            self._low_checks += 1
            if (self._low_checks >= p.scale_down_after
                    and n_replicas > p.min_replicas):
                self._last_action_s = vnow
                self._low_checks = 0
                self.events.append({"vnow": vnow, "action": "down",
                                    "queue_per_replica": qpr, "p99_s": p99,
                                    "replicas": n_replicas - 1})
                return -1
        else:
            self._low_checks = 0
        return 0


@dataclasses.dataclass
class RouterStats:
    """Fleet-level counters: completions, drops (structurally 0, asserted
    anyway), torn batches (> 1 weight version in one batch — structurally
    0, guarded in ``ServingReplica.try_serve``), per-version response
    counts, scale/swap event logs, and the merged latency distribution
    (always-on standalone histogram, same buckets as the per-replica
    telemetry series)."""
    served: int = 0
    batches: int = 0
    dropped: int = 0
    torn_batches: int = 0
    wall_s: float = 0.0
    dispatched: int = 0
    replicas_final: int = 0
    replicas_peak: int = 0
    hot_swaps: int = 0
    version_counts: Dict[int, int] = dataclasses.field(default_factory=dict)
    scale_events: List[dict] = dataclasses.field(default_factory=list)
    swap_events: List[dict] = dataclasses.field(default_factory=list)
    latency_hist: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(
            "serving_request_latency_seconds",
            buckets=telemetry.DEFAULT_TIME_BUCKETS))

    @property
    def throughput_rps(self) -> float:
        """Completions per wall second (0.0, never NaN, on a zero wall)."""
        if not (self.wall_s > 0.0) or not math.isfinite(self.wall_s):
            return 0.0
        return self.served / self.wall_s

    def latency_quantile(self, q: float) -> float:
        """Merged-fleet latency quantile (0.0 on an empty histogram)."""
        v = self.latency_hist.quantile(q)
        return v if math.isfinite(v) else 0.0

    def summary(self) -> dict:
        return {
            "served": self.served,
            "batches": self.batches,
            "dropped": self.dropped,
            "torn_batches": self.torn_batches,
            "throughput_rps": self.throughput_rps,
            "p50_ms": self.latency_quantile(0.50) * 1e3,
            "p99_ms": self.latency_quantile(0.99) * 1e3,
            "replicas_final": self.replicas_final,
            "replicas_peak": self.replicas_peak,
            "hot_swaps": self.hot_swaps,
            "version_counts": {str(k): v
                               for k, v in sorted(self.version_counts.items())},
            "scale_events": len(self.scale_events),
        }


class ReplicaRouter:
    """Elastic multi-replica serving front end (one virtual clock).

    Args:
        g, cfg, params: served graph, model config, initial weights
            (version 0).
        n_replicas: initial fleet size.
        policy: dispatch policy — ``"round_robin"`` (rotate over active
            replicas) or ``"least_queue"`` (shortest queue wins, ties to
            the earlier-started batch / lower id).
        shared_cache: one :class:`EmbeddingCache` read and filled by all
            replicas (hits compound across the fleet) vs one private
            cache per replica (isolation; a new replica starts cold).
        cache_policy / cache_capacity / max_staleness / fanouts /
            buckets / max_wait_s / seed: forwarded to each replica's
            :class:`GNNInferenceServer`.
        autoscale: an :class:`AutoscalePolicy` to enable elastic scaling
            (``None`` = fixed fleet).

    :meth:`run` serves a workload to completion and returns
    :class:`RouterStats`; :meth:`hot_swap` stages a rolling weight
    upgrade the run loop applies replica-by-replica.
    """

    def __init__(self, g: Graph, cfg: GNNConfig, params, *,
                 n_replicas: int = 2,
                 policy: str = "least_queue",
                 shared_cache: bool = True,
                 cache_policy: str = "degree",
                 cache_capacity: Optional[int] = None,
                 max_staleness: int = 0,
                 fanouts: Sequence[int] = (5, 5),
                 buckets: Sequence[int] = (1, 4, 16, 64),
                 max_wait_s: float = 0.002,
                 seed: int = 0,
                 autoscale: Optional[AutoscalePolicy] = None):
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"choose from {ROUTER_POLICIES}")
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.g = g
        self.cfg = cfg
        self.policy = policy
        self.params = params
        self.version = 0
        self._server_kw = dict(
            fanouts=tuple(fanouts), buckets=tuple(buckets),
            cache_policy=cache_policy, cache_capacity=cache_capacity,
            max_staleness=max_staleness, max_wait_s=max_wait_s, seed=seed)
        self.shared_cache: Optional[EmbeddingCache] = None
        if shared_cache and cache_policy != "none":
            self.shared_cache = EmbeddingCache(
                g, [cfg.hidden], policy=cache_policy,
                capacity=cache_capacity, max_staleness=max_staleness,
                codec=cfg.wire_codec)
        self.autoscaler = AutoScaler(autoscale) if autoscale else None
        self._forward = None          # first replica's jit, then shared
        self._next_rid = 0
        self.replicas: List[ServingReplica] = []
        self._rr_next = 0             # round-robin cursor
        # pending rolling upgrade: (params, version, set of flipped rids)
        self._rollout: Optional[Tuple[object, int, set]] = None
        # last GraphUpdateLog sequence folded into the (shared) graph
        self._update_seq = 0
        self.stats = RouterStats()
        self._m_replicas = telemetry.gauge(
            "serving_replicas", "active replicas in the serving fleet")
        self._m_version = telemetry.gauge(
            "serving_params_version", "weight version at the router")
        self._m_dispatch: Dict[int, telemetry.Counter] = {}
        self._m_scale = {
            d: telemetry.counter("serving_scale_events_total",
                                 "autoscaler actions applied", direction=d)
            for d in ("up", "down")}
        self._m_swaps = telemetry.counter(
            "serving_hot_swaps_total", "completed rolling weight upgrades")
        for _ in range(n_replicas):
            self._add_replica(warm=True, reset_cache_stats=False)
        # one post-warmup reset per cache wipes compile-time traffic
        for cache in self._caches():
            cache.reset_stats()
        self._m_replicas.set(len(self.replicas))
        self._m_version.set(self.version)

    # -- fleet management --------------------------------------------------
    def _caches(self) -> List[EmbeddingCache]:
        if self.shared_cache is not None:
            return [self.shared_cache]
        return [r.server.cache for r in self.replicas]

    def _add_replica(self, *, warm: bool, reset_cache_stats: bool,
                     startup_until: float = 0.0) -> ServingReplica:
        rid = self._next_rid
        self._next_rid += 1
        srv = GNNInferenceServer(
            self.g, self.cfg, self.params, cache=self.shared_cache,
            params_version=self.version, forward_fn=self._forward,
            **self._server_kw)
        if self._forward is None:
            self._forward = srv._forward
        rep = ServingReplica(rid, srv)
        rep.busy_until = startup_until
        self.replicas.append(rep)
        if warm:
            rep.warmup(reset_cache_stats=reset_cache_stats)
        self.stats.replicas_peak = max(self.stats.replicas_peak,
                                       len(self.replicas))
        self._m_replicas.set(len(self.replicas))
        return rep

    def _active(self) -> List[ServingReplica]:
        """Replicas eligible for new traffic (not draining)."""
        return [r for r in self.replicas if not r.draining]

    # -- dispatch ----------------------------------------------------------
    def _dispatch(self, req: InferenceRequest) -> None:
        active = self._active()
        assert active, "router invariant: at least one active replica"
        if self.policy == "round_robin":
            rep = active[self._rr_next % len(active)]
            self._rr_next += 1
        else:                          # least_queue
            rep = min(active,
                      key=lambda r: (r.queue_depth(), r.busy_until, r.rid))
        rep.dispatch(req)
        self.stats.dispatched += 1
        m = self._m_dispatch.get(rep.rid)
        if m is None:
            m = self._m_dispatch[rep.rid] = telemetry.counter(
                "serving_router_dispatch_total",
                "requests dispatched to replicas by the router",
                replica=str(rep.rid), policy=self.policy)
        m.inc()

    # -- rolling weight hot-swap -------------------------------------------
    def hot_swap(self, new_params, *, version: Optional[int] = None) -> int:
        """Stage a rolling upgrade to ``new_params``; returns the new
        version number.  The run loop flips replicas one at a time (each
        only while idle); call between runs or let ``hot_swap_every``
        trigger it mid-run.  Only one rollout may be in flight."""
        if self._rollout is not None:
            raise RuntimeError("a rolling upgrade is already in flight")
        v = self.version + 1 if version is None else version
        if v <= self.version:
            raise ValueError(f"version must grow: {self.version} -> {v}")
        self._rollout = (new_params, v, set())
        return v

    def _progress_rollout(self, vnow: float) -> None:
        """Advance the staged upgrade by at most ONE replica — genuinely
        rolling: the rest of the fleet keeps serving (on whichever
        version each is on) while one idle replica flips.  A replica
        mid-batch is skipped and flips on a later pass — its in-flight
        batch completes on the version it started on.  The shared cache
        flips with the FIRST replica; old-version replicas then bypass
        it entirely until their own flip."""
        if self._rollout is None:
            return
        params, v, flipped = self._rollout
        for rep in self.replicas:
            if rep.version >= v or not rep.idle(vnow):
                continue
            if self.shared_cache is not None and not flipped:
                self.shared_cache.bump_params_version(v)
            rep.swap(params, v)
            flipped.add(rep.rid)
            self.stats.swap_events.append(
                {"vnow": vnow, "replica": rep.rid, "version": v})
            break                      # one replica per pass
        # complete when every *current* replica serves v (replicas flipped
        # then drained/removed don't count; ones added mid-rollout must
        # still flip)
        if all(r.version >= v for r in self.replicas):
            self.params = params
            self.version = v
            self._rollout = None
            self.stats.hot_swaps += 1
            self._m_swaps.inc()
            self._m_version.set(v)

    # -- dynamic graphs ----------------------------------------------------
    def apply_graph_update(self, log, upto_seq: Optional[int] = None) -> dict:
        """Fold pending update-log events into the fleet's shared graph
        and invalidate every replica's dependent state: the graph arrays
        mutate IN PLACE exactly once (all replicas serve the same
        ``Graph`` object), each replica's sampler drops its touched
        memoized picks and rebuilds its reversed adjacency, and every
        cache — shared or per-replica — surgically invalidates the
        (L-1)-hop delta frontier.  Idempotent per sequence number; called
        between batches by the run loop (replicas are only ever flipped
        or invalidated while idle in virtual time)."""
        from repro.core.updates import fold_in_place
        upto = log.last_seq if upto_seq is None else upto_seq
        if upto <= self._update_seq:
            return {"events": 0, "touched_nodes": 0,
                    "invalidated_rows": 0, "upto_seq": self._update_seq}
        hops = len(self._server_kw["fanouts"]) - 1
        delta, frontier = fold_in_place(
            self.g, log, self._update_seq, upto, hops=hops)
        for rep in self.replicas:
            rep.server.sampler.apply_delta(delta.nodes)
            rep.server._update_seq = upto
        n_inv = sum(c.invalidate_rows(frontier) for c in self._caches())
        self._update_seq = upto
        return {"events": delta.n_events,
                "touched_nodes": int(len(delta.nodes)),
                "invalidated_rows": n_inv,
                "upto_seq": upto}

    # -- autoscaling -------------------------------------------------------
    def _apply_autoscale(self, vnow: float) -> None:
        sc = self.autoscaler
        delta = sc.decide(vnow, [r.queue_depth() for r in self._active()],
                          len(self._active()))
        if delta > 0:
            # a private cache is brand new (safe to scrub its warmup
            # noise); a shared one carries fleet accounting — never reset
            self._add_replica(
                warm=True, reset_cache_stats=self.shared_cache is None,
                startup_until=vnow + sc.policy.startup_delay_s)
            self.stats.scale_events.append(sc.events[-1])
            self._m_scale["up"].inc()
        elif delta < 0:
            # drain the active replica with the least work outstanding;
            # it serves its queue dry, then the run loop removes it
            victim = min(self._active(),
                         key=lambda r: (r.queue_depth(), -r.rid))
            victim.draining = True
            self.stats.scale_events.append(sc.events[-1])
            self._m_scale["down"].inc()

    def _reap_drained(self, vnow: float) -> None:
        """Remove draining replicas whose queues are dry and whose last
        batch has completed — their requests were all served, so removal
        can never drop work."""
        keep = [r for r in self.replicas
                if not (r.draining and r.queue_depth() == 0 and r.idle(vnow))]
        if len(keep) != len(self.replicas):
            self.replicas = keep
            self._m_replicas.set(len(keep))

    # -- the serve loop ----------------------------------------------------
    def run(self, workload: List[InferenceRequest], *,
            tick_every_s: float = 0.0,
            hot_swap_every: int = 0,
            new_params_fn: Optional[Callable[[int], object]] = None,
            update_log=None, update_every: int = 0,
            update_chunk: int = 0) -> RouterStats:
        """Serve ``workload`` to completion across the fleet.

        ``tick_every_s`` ages the caches on the shared virtual clock
        (feature-refresh epochs, as in the single server).
        ``hot_swap_every=K`` stages a rolling upgrade after every K
        completions — ``new_params_fn(version)`` supplies the weights
        (defaults to re-shipping the current ones, which still exercises
        the full version-flip machinery).  ``update_log`` streams graph
        mutations: after every ``update_every`` completions the next
        ``update_chunk`` pending events (0 = all pending) are folded via
        :meth:`apply_graph_update` — replicas invalidate mid-run, without
        a restart.  Returns the router stats; zero drops is asserted,
        not hoped for."""
        workload = sorted(workload, key=lambda r: r.arrival_s)
        vnow = 0.0
        i = 0
        served_at_last_swap = 0
        next_update = (update_every if update_log is not None
                       and update_every > 0 else math.inf)
        next_tick = tick_every_s if tick_every_s > 0 else math.inf
        sc = self.autoscaler
        next_check = sc.policy.check_every_s if sc else math.inf
        t_start = time.perf_counter()
        while i < len(workload) or any(r.queue_depth()
                                       for r in self.replicas):
            while vnow >= next_tick:
                for cache in self._caches():
                    cache.tick()
                next_tick += tick_every_s
            while i < len(workload) and workload[i].arrival_s <= vnow:
                self._dispatch(workload[i])
                i += 1
            drained = i >= len(workload)
            self._progress_rollout(vnow)
            if sc and vnow >= next_check:
                self._apply_autoscale(vnow)
                next_check = vnow + sc.policy.check_every_s
            progressed = False
            for rep in list(self.replicas):
                if not rep.idle(vnow):
                    continue
                out = rep.try_serve(vnow, force=drained)
                if out is None:
                    continue
                progressed = True
                mb, done = out
                versions = {r.params_version for r in mb.requests}
                if len(versions) > 1:
                    self.stats.torn_batches += 1
                for r in mb.requests:
                    self.stats.latency_hist.observe(r.latency_s)
                    self.stats.version_counts[r.params_version] = \
                        self.stats.version_counts.get(r.params_version, 0) + 1
                    if sc:
                        sc.observe_latency(r.latency_s)
                self.stats.served += len(mb.requests)
                self.stats.batches += 1
                if (hot_swap_every > 0 and self._rollout is None
                        and self.stats.served - served_at_last_swap
                        >= hot_swap_every):
                    self.hot_swap(new_params_fn(self.version + 1)
                                  if new_params_fn else self.params)
                    served_at_last_swap = self.stats.served
                if self.stats.served >= next_update:
                    upto = (None if update_chunk <= 0 else
                            min(self._update_seq + update_chunk,
                                update_log.last_seq))
                    self.apply_graph_update(update_log, upto)
                    next_update += update_every
            self._reap_drained(vnow)
            if progressed:
                continue
            # advance the virtual clock to the next event: an arrival, a
            # replica's in-flight completion, a head-of-line max-wait
            # deadline, a cache tick, or an autoscaler check — never
            # straight to the next arrival (queued work would stall)
            events = []
            if i < len(workload):
                events.append(workload[i].arrival_s)
            for rep in self.replicas:
                if rep.busy_until > vnow:
                    # a busy replica serves no earlier than its in-flight
                    # completion — an already-expired head-of-line
                    # deadline on its queue is NOT an event (it would pin
                    # the clock and spin the loop)
                    events.append(rep.busy_until)
                    continue
                oldest = rep.queue.oldest_arrival()
                if oldest is not None:
                    events.append(oldest + rep.server.batcher.max_wait_s)
            if next_tick != math.inf:
                events.append(next_tick)
            if sc and (i < len(workload)
                       or any(r.queue_depth() for r in self.replicas)):
                events.append(next_check)
            if not events:
                break
            # strict one-ulp progress (see request.advance_vclock: landing
            # exactly on fl(oldest + max_wait) would livelock a replica)
            vnow = advance_vclock(vnow, min(events))
        # finish any staged upgrade now that the fleet is idle (every
        # in-flight batch completed at its own version; one replica flips
        # per pass, so loop the rollout dry)
        v_end = max([vnow] + [r.busy_until for r in self.replicas])
        while self._rollout is not None:
            self._progress_rollout(v_end)
        if update_log is not None and update_log.last_seq > self._update_seq:
            # drain the stream: the fleet must finish caught up with every
            # event published before the run ended
            self.apply_graph_update(update_log)
        self._reap_drained(math.inf)
        self.stats.wall_s += time.perf_counter() - t_start
        self.stats.replicas_final = len(self.replicas)
        self.stats.dropped = (self.stats.dispatched - self.stats.served)
        assert self.stats.dropped == 0, (
            f"router dropped {self.stats.dropped} requests")
        return self.stats

    # -- stop/resume -------------------------------------------------------
    def save(self, directory: str) -> str:
        """Checkpoint the fleet's current weights + version atomically
        (crash-safe: see :mod:`repro.checkpoint.io`); the step number IS
        the params version, so resume restores the newest complete
        version."""
        return save_checkpoint(directory, self.version,
                               {"params": self.params},
                               meta={"params_version": self.version,
                                     "policy": self.policy,
                                     "n_replicas": len(self.replicas)})

    def summary(self) -> dict:
        out = self.stats.summary()
        out["policy"] = self.policy
        out["shared_cache"] = self.shared_cache is not None
        out["params_version"] = self.version
        # cache stats: the shared cache's, or the per-replica merge
        caches = self._caches()
        if caches:
            hits = sum(c.hits for c in caches)
            misses = sum(c.misses for c in caches)
            out["embedding_hit_ratio"] = (
                hits / (hits + misses) if hits + misses else 0.0)
            out["feature_bytes"] = sum(c.features.transferred_bytes
                                       for c in caches)
            out["fill_bytes"] = sum(
                sum(t.total_bytes for t in c.fill.values()) for c in caches)
            out["wire_bytes"] = out["feature_bytes"] + out["fill_bytes"]
        out["replicas"] = [r.summary() for r in self.replicas]
        return out


def restore_params(directory: str, template) -> Tuple[object, int]:
    """Resume helper: load the newest *complete* checkpoint under
    ``directory`` into ``template``'s structure and return
    ``(params, params_version)``.  Partial steps (kill mid-save) are
    never candidates — ``latest_step`` skips them."""
    tree, manifest = load_checkpoint(directory, {"params": template})
    return tree["params"], int(manifest["meta"]["params_version"])
