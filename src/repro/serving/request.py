"""Request objects and admission queue for the GNN inference server.

Arrival times are *virtual* seconds: workloads are generated with explicit
arrival stamps and the server advances a virtual clock by the measured
compute time of each batch, so latency distributions are reproducible and
the simulation never sleeps.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, List, Optional

import numpy as np


def advance_vclock(vnow: float, nxt: float) -> float:
    """Advance a virtual clock toward the next event with STRICT progress.

    Returns ``nxt`` when it lies strictly ahead of ``vnow``; otherwise
    marches ``vnow`` one ulp forward.  The one-ulp step is load-bearing:
    landing exactly on ``fl(oldest + max_wait)`` can leave the recomputed
    head-of-line wait ``vnow - oldest`` one rounding error SHORT of
    ``max_wait_s``, so the batcher keeps refusing to emit and a plain
    ``max(vnow, nxt)`` pins the clock forever at 100% CPU — the PR 8
    livelock.  Marching one ulp flips the comparison within a few
    iterations.  Every serve/fleet loop must advance its clock through
    this helper (statically enforced by lint rule RL003,
    ``python -m repro.analysis``).
    """
    return nxt if nxt > vnow else math.nextafter(vnow, math.inf)


@dataclasses.dataclass
class InferenceRequest:
    """One per-node prediction request.  ``params_version`` is stamped at
    completion with the single weight version that computed the response
    (-1 = not yet served) — the end-to-end consistency tag the rolling
    hot-swap tests assert on."""
    req_id: int
    node_id: int
    arrival_s: float
    done_s: float = -1.0
    logits: Optional[np.ndarray] = None
    params_version: int = -1

    @property
    def latency_s(self) -> float:
        return self.done_s - self.arrival_s if self.done_s >= 0 else -1.0


class RequestQueue:
    """FIFO admission queue (oldest first — the batcher's wait policy keys
    off the head-of-line request)."""

    def __init__(self):
        self._q: Deque[InferenceRequest] = collections.deque()

    def push(self, req: InferenceRequest) -> None:
        self._q.append(req)

    def pop_up_to(self, n: int) -> List[InferenceRequest]:
        out = []
        while self._q and len(out) < n:
            out.append(self._q.popleft())
        return out

    def oldest_arrival(self) -> Optional[float]:
        return self._q[0].arrival_s if self._q else None

    def __len__(self) -> int:
        return len(self._q)


def poisson_workload(num_requests: int, node_ids: np.ndarray, rate_rps: float,
                     *, seed: int = 0, zipf_a: float = 1.5) -> List[InferenceRequest]:
    """Poisson arrivals over a Zipf-skewed node popularity distribution —
    the 'heavy traffic from millions of users' regime where a small hot
    set of vertices absorbs most requests (what makes caching pay)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate_rps, 1e-9), num_requests)
    arrivals = np.cumsum(gaps)
    # bounded Zipf over exactly len(node_ids) ranks (clipping rng.zipf's
    # unbounded tail would pile its mass onto one arbitrary node)
    p = np.arange(1, len(node_ids) + 1, dtype=np.float64) ** -zipf_a
    ranks = rng.choice(len(node_ids), num_requests, p=p / p.sum())
    # map popularity rank -> node id via a fixed permutation
    perm = rng.permutation(len(node_ids))
    nodes = np.asarray(node_ids)[perm[ranks]]
    return [InferenceRequest(i, int(nodes[i]), float(arrivals[i]))
            for i in range(num_requests)]
