"""Layered historical-embedding cache for online GNN inference.

GNNAutoScale / VR-GCN idea (survey §3.2.4) applied at serving time: keep
the *layer outputs* ("historical embeddings") of hot vertices so a request
whose neighborhood is cached skips the entire sub-tree expansion below that
layer — neighbor sampling, feature fetches and aggregation all disappear
for hit nodes.

Consistency model (implemented by the shared
:class:`repro.core.caching.VersionClock` / ``VersionedBuffer`` pair — the
same staleness substrate the training-side
:class:`repro.core.halo.HaloExchange` uses):

* a global integer **version clock** advances on :meth:`tick` (one tick ≈
  one feature/model refresh epoch);
* an entry written at clock ``t`` has staleness ``clock - t``; entries with
  staleness > ``max_staleness`` are misses (bounded-staleness reads);
* :meth:`invalidate` drops entries for nodes whose input features changed,
  so staleness-0 reads are always exact.

Feature traffic accounting rides on :class:`repro.core.caching.FeatureStore`
(the repo's existing byte-accounting substrate): the cache owns the store
and exposes combined hit/byte numbers.  Both the feature pulls and the
cache-*fill* payloads (freshly computed embedding rows shipped into the
cache) travel through the unified communication plane
(:mod:`repro.core.comm`), so a ``bf16``/``int8`` wire codec compresses —
and byte-accounts — every remote row the server moves.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import telemetry
from repro.core.caching import (CACHE_POLICIES, NEVER, FeatureStore,
                                VersionClock, VersionedBuffer)
from repro.core.comm import Transport, WireCodec
from repro.graph.structure import Graph

__all__ = ["EmbeddingCache", "NEVER"]


class EmbeddingCache:
    """Bounded-staleness historical-embedding cache for serving.

    Args:
        g: the served graph (features may be mutated via
           :meth:`update_features`).
        layer_dims: width of each cached plane — one per cached layer
            output (the server caches the final-layer input, so one plane
            of width ``hidden``).
        policy: admission policy name from
            :data:`repro.core.caching.CACHE_POLICIES`.
        capacity: admitted-node budget; ``None`` = whole graph, ``0`` is
            honored as "admit nothing".
        max_staleness: entries older than this many clock ticks are misses.
        feature_capacity: budget of the input-feature
            :class:`FeatureStore` layer (defaults to ``capacity``).
        codec: wire codec for remote payloads — both the feature pulls
            and the cache-fill rows written via :meth:`store` (which are
            stored *as decoded*, so hits serve exactly what crossed the
            wire).  ``fp32`` (default) is bit-exact with the pre-codec
            behavior.

    Shape conventions: every lookup/store is *slot-aligned* over a padded
    id vector (``-1`` = empty slot).  Padded slots are neither hits nor
    misses and are never written, so batch shapes stay static.
    """

    def __init__(self, g: Graph, layer_dims: Sequence[int], *,
                 policy: str = "degree", capacity: Optional[int] = None,
                 max_staleness: int = 0,
                 feature_capacity: Optional[int] = None,
                 codec: Union[str, WireCodec] = "fp32"):
        self.g = g
        self.max_staleness = max_staleness
        self.vclock = VersionClock()
        n = g.num_nodes
        # None = unbounded (whole graph); 0 is honored as "admit nothing"
        capacity = n if capacity is None else capacity
        admit_ids = CACHE_POLICIES[policy](g, capacity)
        # memory is bounded by the ADMITTED set, not the graph: planes hold
        # one row per admitted node plus a sacrificial row (index ``rows-1``)
        # that absorbs reads for non-admitted ids and is never written
        self.slot = np.full(n, -1, np.int64)
        self.slot[admit_ids] = np.arange(len(admit_ids))
        rows = len(admit_ids) + 1
        self.planes: Dict[int, VersionedBuffer] = {
            l: VersionedBuffer(self.vclock, rows, d)
            for l, d in enumerate(layer_dims)}
        # cache fills are remote transfers too: one channel per plane,
        # error-feedback residuals keyed by cache slot; all planes share
        # the "serving.fill" telemetry path
        self.fill: Dict[int, Transport] = {
            l: Transport(codec, n_rows=rows, path="serving.fill")
            for l in range(len(layer_dims))}
        # input-feature cache (PaGraph/AliGraph layer of the hierarchy)
        if feature_capacity is None:
            feature_capacity = capacity
        self.features = FeatureStore(
            g, CACHE_POLICIES[policy](g, feature_capacity), codec=codec,
            path="serving.features")
        self.hits = 0
        self.misses = 0
        # rows dropped by incremental (delta-driven) invalidation — the
        # counter the dynamic-graph bench compares against full flushes
        self.invalidated_rows = 0
        # model-weight version whose outputs the planes currently hold.
        # Readers on a different params version must treat the cache as
        # cold (see GNNInferenceServer.serve_batch) — mixing embeddings
        # produced by two weight versions inside one batch is the
        # "version-torn" hazard rolling hot-swap exists to prevent.
        self.params_version = 0
        self._m_hits = telemetry.counter(
            "cache_lookups_total", cache="serving.embedding", result="hit")
        self._m_misses = telemetry.counter(
            "cache_lookups_total", cache="serving.embedding", result="miss")
        self._m_invalidated = telemetry.counter(
            "cache_invalidated_rows_total",
            "embedding rows dropped by incremental (delta-driven) "
            "invalidation", cache="serving.embedding")

    @property
    def clock(self) -> int:
        """Current value of the shared version clock."""
        return self.vclock.now

    def bump_params_version(self, version: int) -> None:
        """Atomically flip the cache to a new model-weight version: every
        plane is invalidated wholesale (embeddings computed under the old
        weights are wrong at any staleness) and the version clock ticks
        once, all before ``params_version`` is published — so no reader
        can ever pair new-version freshness with old-version rows.
        Idempotent per version; rejects going backwards."""
        if version == self.params_version:
            return
        if version < self.params_version:
            raise ValueError(
                f"params version must be monotone: have "
                f"{self.params_version}, got {version}")
        for plane in self.planes.values():
            plane.invalidate_all()
        self.vclock.tick()
        self.params_version = version

    # -- embedding plane ---------------------------------------------------
    def lookup(self, layer: int, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Slot-aligned bounded-staleness read.

        Args:
            layer: cached plane index.
            ids: ``(B,)`` node ids, ``-1`` = padded slot.

        Returns:
            ``(values, fresh)`` — ``values`` is ``(B, dim)`` (garbage rows
            where not fresh), ``fresh`` marks slots served from cache
            within the staleness bound.  Padded slots are neither hits nor
            misses.
        """
        ids = np.asarray(ids)
        valid = ids >= 0
        plane = self.planes[layer]
        slot = self.slot[np.maximum(ids, 0)]
        row = np.where(slot >= 0, slot, plane.rows - 1)
        fresh = valid & plane.fresh_mask(self.max_staleness, row)
        self.hits += int(fresh.sum())
        self.misses += int((valid & ~fresh).sum())
        self._m_hits.inc(int(fresh.sum()))
        self._m_misses.inc(int((valid & ~fresh).sum()))
        return plane.values[row], fresh

    def store(self, layer: int, ids: np.ndarray, values: np.ndarray,
              mask: np.ndarray) -> None:
        """Write freshly computed rows for admitted nodes (slot-aligned;
        ``mask`` selects which slots to write).  Non-admitted and padded
        slots are silently skipped.  The written rows are a cache-*fill*
        transfer: they cross the communication plane (codec-encoded,
        byte-accounted) and the plane stores the decoded wire values."""
        ids = np.asarray(ids)
        write = np.asarray(mask, bool) & (ids >= 0)
        write &= self.slot[np.maximum(ids, 0)] >= 0
        rows = self.slot[ids[write]]
        vals = self.fill[layer].send(np.asarray(values)[write],
                                     row_ids=rows)
        self.planes[layer].write(rows, vals)

    # -- consistency -------------------------------------------------------
    def tick(self, n: int = 1) -> None:
        """Advance the version clock (a feature/model refresh epoch)."""
        self.vclock.tick(n)

    def invalidate(self, ids: np.ndarray) -> None:
        """Drop entries for nodes whose input features changed — their
        historical embeddings are wrong at any staleness."""
        ids = np.asarray(ids)
        rows = self.slot[ids[ids >= 0]]
        rows = rows[rows >= 0]
        for plane in self.planes.values():
            plane.invalidate(rows)

    def invalidate_rows(self, node_ids: np.ndarray, *,
                        tick: bool = True) -> int:
        """Incremental (delta-driven) invalidation: age exactly the rows
        of ``node_ids`` to ``NEVER`` across every plane — untouched rows
        keep their versions and stay servable within the staleness
        bound.  This is the surgical alternative to
        :meth:`bump_params_version`'s all-or-nothing flush: a graph
        delta only poisons the frontier it reaches, so only that
        frontier pays a recompute.

        ``tick`` (default) advances the shared clock once — a delta fold
        is a refresh epoch, so the write that re-fills an invalidated
        row is stamped strictly after the invalidation (the ordering the
        "never serve pre-invalidation values" property asserts).

        Returns the number of admitted cache rows invalidated (ids
        outside the admitted set cost nothing and count nothing).
        """
        ids = np.asarray(node_ids, np.int64)
        ids = ids[(ids >= 0) & (ids < len(self.slot))]
        rows = np.unique(self.slot[ids])
        rows = rows[rows >= 0]
        for plane in self.planes.values():
            plane.invalidate(rows)
        n = int(len(rows))
        self.invalidated_rows += n
        self._m_invalidated.inc(n)
        if tick:
            self.vclock.tick()
        return n

    def update_features(self, ids: np.ndarray, rows: np.ndarray) -> None:
        """Feature update path: mutate the store and invalidate dependents.
        (1-hop dependents would need graph traversal; serving treats a
        feature epoch as a tick, which ages ALL entries — the per-node
        invalidation here handles the updated nodes exactly.)"""
        self.g.features[ids] = rows
        self.invalidate(ids)
        self.tick()

    # -- stats -------------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the embedding hit/miss counters, the feature layer's
        stats, and every cache-fill transport — with the matching
        telemetry series reset in lockstep.  The one warmup-exclusion
        entry point: callers must use this instead of assigning
        ``cache.hits``/``cache.features.hits`` (cached values and
        error-feedback residuals are kept — they are state, not
        accounting)."""
        self.hits = 0
        self.misses = 0
        self.invalidated_rows = 0
        self._m_hits.reset()
        self._m_misses.reset()
        self._m_invalidated.reset()
        self.features.reset_stats()
        for t in self.fill.values():
            t.reset_counters()

    @property
    def hit_ratio(self) -> float:
        """Fraction of non-padded lookups served within the bound."""
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def stats(self) -> dict:
        """Combined embedding + feature-layer counters for summaries."""
        fill_bytes = sum(t.total_bytes for t in self.fill.values())
        return {
            "embedding_hit_ratio": self.hit_ratio,
            "embedding_hits": self.hits,
            "embedding_misses": self.misses,
            "invalidated_rows": self.invalidated_rows,
            "feature_hit_ratio": self.features.hit_ratio,
            "feature_bytes": self.features.transferred_bytes,
            "fill_bytes": fill_bytes,
            "wire_bytes": self.features.transferred_bytes + fill_bytes,
            "wire_codec": self.features.codec.name,
            "clock": self.clock,
        }
