"""The GNN inference server: admit → micro-batch → sample → cache → forward.

Control flow per micro-batch (bucket B, L layers):

1. the batcher pads B seed slots (-1 = empty) — one of the declared
   bucket shapes;
2. the outer (final-layer) block is always sampled fresh;
3. historical embeddings for the outer block's src slots are looked up in
   the :class:`EmbeddingCache`; only *misses* are expanded further down
   and only miss-path input features are fetched (zero rows elsewhere —
   shapes stay static);
4. one jitted forward per (bucket, arch) computes the miss rows, splices
   cached rows in, applies the final layer, and returns fresh rows for
   write-back.

The clock is virtual: requests carry synthetic arrival stamps and the
server advances time by the measured wall-clock compute of each batch, so
p50/p99 include queueing delay and the run is reproducible.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abstraction import DeviceGraph
from repro.graph.structure import Graph
from repro.models.gnn import model as GM
from repro.models.gnn.model import GNNConfig
from repro.serving.batcher import BucketedBatcher, MicroBatch
from repro.serving.cache import EmbeddingCache
from repro.serving.request import InferenceRequest, RequestQueue
from repro.serving.sampler import ServingSampler, needed_feature_mask


@dataclasses.dataclass
class ServeStats:
    """Serve-loop counters: requests served, batches formed, wall time,
    per-request latencies (virtual-clock seconds), and the set of jitted
    shapes (``len(jit_shapes)`` bounds recompilation —
    ≤ one entry per declared bucket)."""
    served: int = 0
    batches: int = 0
    wall_s: float = 0.0
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    jit_shapes: set = dataclasses.field(default_factory=set)

    @property
    def throughput_rps(self) -> float:
        return self.served / self.wall_s if self.wall_s > 0 else 0.0

    def latency_quantile(self, q: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.quantile(np.asarray(self.latencies_s), q))

    def summary(self) -> dict:
        return {
            "served": self.served,
            "batches": self.batches,
            "throughput_rps": self.throughput_rps,
            "p50_ms": self.latency_quantile(0.50) * 1e3,
            "p99_ms": self.latency_quantile(0.99) * 1e3,
            "jit_entries": len(self.jit_shapes),
        }


class GNNInferenceServer:
    """The online GNN inference server: admit → micro-batch → sample →
    cache → forward (see module docstring for the per-batch control flow).

    Args:
        g: served graph (features required).
        cfg: model config (any sampled arch with ``num_layers >= 2``;
            appnp is full-graph and rejected).
        params: trained parameters for ``cfg``.
        fanouts: per-layer sampling fanouts (one per model layer).
        buckets: declared batch-size vocabulary (static shapes — at most
            one jit entry per bucket, asserted via ``jit_entries``).
        cache_policy / cache_capacity / max_staleness: admission policy,
            budget, and staleness bound of the historical-embedding
            :class:`EmbeddingCache` (``"none"`` disables write-back).
        max_wait_s: head-of-line batching deadline.
        seed: sampling determinism base.

    :meth:`run` serves a workload under a virtual clock (arrival stamps +
    measured compute), so p50/p99 include queueing delay and runs are
    reproducible; :meth:`summary` merges latency, cache, and pad stats.
    """

    def __init__(self, g: Graph, cfg: GNNConfig, params, *,
                 fanouts: Sequence[int] = (5, 5),
                 buckets: Sequence[int] = (1, 4, 16, 64),
                 cache_policy: str = "degree",
                 cache_capacity: Optional[int] = None,
                 max_staleness: int = 0,
                 max_wait_s: float = 0.002,
                 seed: int = 0):
        if cfg.arch == "appnp":
            raise ValueError("appnp serves full-graph; use a sampled arch")
        if len(fanouts) != cfg.num_layers:
            raise ValueError("need one fanout per layer")
        if cfg.num_layers < 2:
            raise ValueError("serving path assumes >= 2 layers (the "
                             "historical plane caches the final-layer input)")
        self.g = g
        self.cfg = cfg
        self.params = params
        self.sampler = ServingSampler(g, fanouts, seed=seed)
        self.batcher = BucketedBatcher(buckets, max_wait_s=max_wait_s)
        self.use_cache = cache_policy != "none"
        # one cached plane: the (post-relu) hidden state entering the
        # final layer — dimension ``hidden`` for every arch in the zoo.
        # cfg.wire_codec selects the communication-plane wire format for
        # feature pulls AND cache fills (fp32 = bit-exact default).
        self.cache = EmbeddingCache(
            g, [cfg.hidden], policy=cache_policy, capacity=cache_capacity,
            max_staleness=max_staleness, codec=cfg.wire_codec)
        self._forward = jax.jit(
            lambda p, inner, outer, x, ch, fm: GM.forward_blocks_cached(
                cfg, p, inner, outer, x, ch, fm))
        self.stats = ServeStats()

    # -- one micro-batch ---------------------------------------------------
    def serve_batch(self, mb: MicroBatch) -> np.ndarray:
        """Returns (bucket, num_classes) logits (padded slots garbage)."""
        outer_b = self.sampler.sample_outer(mb.node_ids)
        ids1 = outer_b.src_nodes
        cached_h, fresh = self.cache.lookup(0, ids1)
        miss = (ids1 >= 0) & ~fresh
        inner_bs = self.sampler.sample_inner(ids1, expand=miss)
        need = needed_feature_mask(inner_bs, miss)
        x_in = self.cache.features.fetch_masked(inner_bs[0].src_nodes, need)

        inner_dev = [DeviceGraph.from_block(b) for b in inner_bs]
        outer_dev = DeviceGraph.from_block(outer_b)
        shape_key = (mb.bucket,
                     tuple((b.num_dst, b.num_src, len(b.edge_mask))
                           for b in inner_bs + [outer_b]))
        self.stats.jit_shapes.add(shape_key)

        logits, h_fresh = self._forward(
            self.params, inner_dev, outer_dev, jnp.asarray(x_in),
            jnp.asarray(cached_h), jnp.asarray(fresh))
        if self.use_cache:
            self.cache.store(0, ids1, np.asarray(h_fresh), miss)
        return np.asarray(logits)

    def warmup(self, node_id: int = 0) -> None:
        """Compile every declared bucket once (excluded from stats)."""
        for b in self.batcher.buckets:
            ids = np.full((b,), -1, np.int64)
            ids[0] = node_id
            self.serve_batch(MicroBatch([], ids, b, 0.0))
        # warmup traffic must not pollute serving stats (counters AND the
        # communication-plane byte accounting)
        self.cache.hits = self.cache.misses = 0
        self.cache.features.hits = self.cache.features.misses = 0
        self.cache.features.transport.reset_counters()
        for t in self.cache.fill.values():
            t.reset_counters()

    # -- the serve loop ----------------------------------------------------
    def run(self, workload: List[InferenceRequest], *,
            tick_every_s: float = 0.0) -> ServeStats:
        """Serve a workload to completion.  ``tick_every_s`` simulates
        periodic feature-refresh epochs: every interval of virtual time the
        cache's version clock advances, aging historical embeddings — the
        staleness bound then decides whether they can still be served."""
        workload = sorted(workload, key=lambda r: r.arrival_s)
        queue = RequestQueue()
        vnow = 0.0
        next_tick = tick_every_s if tick_every_s > 0 else float("inf")
        i = 0
        t_start = time.perf_counter()
        while i < len(workload) or len(queue):
            while vnow >= next_tick:
                self.cache.tick()
                next_tick += tick_every_s
            while i < len(workload) and workload[i].arrival_s <= vnow:
                queue.push(workload[i])
                i += 1
            drained = i >= len(workload)
            mb = self.batcher.form(queue, vnow, force=drained)
            if mb is None:
                # jump to the next event: an arrival, the head-of-line
                # request's max_wait deadline, or a cache-clock tick —
                # NOT straight to the next arrival, which would make
                # queued requests wait a full inter-arrival gap
                events = []
                if i < len(workload):
                    events.append(workload[i].arrival_s)
                oldest = queue.oldest_arrival()
                if oldest is not None:
                    events.append(oldest + self.batcher.max_wait_s)
                if next_tick != float("inf"):
                    events.append(next_tick)
                vnow = max(vnow, min(events))
                continue
            t0 = time.perf_counter()
            logits = self.serve_batch(mb)
            vnow += time.perf_counter() - t0
            for j, r in enumerate(mb.requests):
                r.logits = logits[mb.slots[j]]
                r.done_s = vnow
                self.stats.latencies_s.append(r.latency_s)
            self.stats.served += len(mb.requests)
            self.stats.batches += 1
        self.stats.wall_s += time.perf_counter() - t_start
        return self.stats

    def summary(self) -> dict:
        out = self.stats.summary()
        out.update(self.cache.stats())
        out["pad_overhead"] = self.batcher.pad_overhead
        return out
