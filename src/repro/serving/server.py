"""The GNN inference server: admit → micro-batch → sample → cache → forward.

Control flow per micro-batch (bucket B, L layers):

1. the batcher pads B seed slots (-1 = empty) — one of the declared
   bucket shapes;
2. the outer (final-layer) block is always sampled fresh;
3. historical embeddings for the outer block's src slots are looked up in
   the :class:`EmbeddingCache`; only *misses* are expanded further down
   and only miss-path input features are fetched (zero rows elsewhere —
   shapes stay static);
4. one jitted forward per (bucket, arch) computes the miss rows, splices
   cached rows in, applies the final layer, and returns fresh rows for
   write-back.

The clock is virtual: requests carry synthetic arrival stamps and the
server advances time by the measured wall-clock compute of each batch, so
p50/p99 include queueing delay and the run is reproducible.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry
from repro.core.abstraction import DeviceGraph
from repro.core.telemetry import Histogram
from repro.graph.structure import Graph
from repro.models.gnn import model as GM
from repro.models.gnn.model import GNNConfig
from repro.serving.batcher import BucketedBatcher, MicroBatch
from repro.serving.cache import EmbeddingCache
from repro.serving.request import (InferenceRequest, RequestQueue,
                                   advance_vclock)
from repro.serving.sampler import ServingSampler, needed_feature_mask


def _latency_hist() -> Histogram:
    """Standalone (always-on) latency histogram backing ``ServeStats`` —
    p50/p99 must work whether or not global telemetry is enabled, so this
    one is not attached to the registry."""
    return Histogram("serving_request_latency_seconds",
                     buckets=telemetry.DEFAULT_TIME_BUCKETS)


@dataclasses.dataclass
class ServeStats:
    """Serve-loop counters: requests served, batches formed, wall time,
    per-request latency distribution (virtual-clock seconds, a telemetry
    :class:`~repro.core.telemetry.Histogram` — the one quantile
    implementation in the repo), and the set of jitted shapes
    (``len(jit_shapes)`` bounds recompilation — ≤ one entry per declared
    bucket)."""
    served: int = 0
    batches: int = 0
    wall_s: float = 0.0
    latency_hist: Histogram = dataclasses.field(default_factory=_latency_hist)
    jit_shapes: set = dataclasses.field(default_factory=set)

    @property
    def throughput_rps(self) -> float:
        """Served requests per second of elapsed time; 0.0 (never NaN/inf,
        never a raise) when no time has elapsed — a zero-elapsed run with
        served requests is degenerate, not infinitely fast."""
        if not (self.wall_s > 0.0) or not math.isfinite(self.wall_s):
            return 0.0
        return self.served / self.wall_s

    @property
    def latencies_s(self) -> List[float]:
        """Recorded per-request latencies in observation order (a uniform
        subsample once the histogram's reservoir saturates)."""
        return [float(v) for v in self.latency_hist.samples]

    def latency_quantile(self, q: float) -> float:
        """Latency quantile (numpy-style interpolation, via the shared
        telemetry histogram); 0.0 on an empty histogram — an unserved
        stats object reports zero latency, it does not raise."""
        v = self.latency_hist.quantile(q)
        return v if math.isfinite(v) else 0.0

    def summary(self) -> dict:
        return {
            "served": self.served,
            "batches": self.batches,
            "throughput_rps": self.throughput_rps,
            "p50_ms": self.latency_quantile(0.50) * 1e3,
            "p99_ms": self.latency_quantile(0.99) * 1e3,
            "jit_entries": len(self.jit_shapes),
        }


class GNNInferenceServer:
    """The online GNN inference server: admit → micro-batch → sample →
    cache → forward (see module docstring for the per-batch control flow).

    Args:
        g: served graph (features required).
        cfg: model config (any sampled arch with ``num_layers >= 2``;
            appnp is full-graph and rejected).
        params: trained parameters for ``cfg``.
        fanouts: per-layer sampling fanouts (one per model layer).
        buckets: declared batch-size vocabulary (static shapes — at most
            one jit entry per bucket, asserted via ``jit_entries``).
        cache_policy / cache_capacity / max_staleness: admission policy,
            budget, and staleness bound of the historical-embedding
            :class:`EmbeddingCache` (``"none"`` disables write-back).
        cache: inject an externally owned :class:`EmbeddingCache` instead
            of building a private one — the replicated serving tier's
            *shared-cache* mode, where N replicas read and fill one
            cache (``cache_policy``/``cache_capacity`` are then ignored).
        max_wait_s: head-of-line batching deadline.
        seed: sampling determinism base.
        params_version: integer weight version served; :meth:`swap_params`
            flips ``(params, params_version)`` atomically between batches
            and the cache is only consulted while its ``params_version``
            matches — one batch can never mix two weight versions.

    :meth:`run` serves a workload under a virtual clock (arrival stamps +
    measured compute), so p50/p99 include queueing delay and runs are
    reproducible; :meth:`summary` merges latency, cache, and pad stats.
    """

    def __init__(self, g: Graph, cfg: GNNConfig, params, *,
                 fanouts: Sequence[int] = (5, 5),
                 buckets: Sequence[int] = (1, 4, 16, 64),
                 cache_policy: str = "degree",
                 cache_capacity: Optional[int] = None,
                 max_staleness: int = 0,
                 cache: Optional[EmbeddingCache] = None,
                 max_wait_s: float = 0.002,
                 seed: int = 0,
                 params_version: int = 0,
                 forward_fn=None):
        if cfg.arch == "appnp":
            raise ValueError("appnp serves full-graph; use a sampled arch")
        if len(fanouts) != cfg.num_layers:
            raise ValueError("need one fanout per layer")
        if cfg.num_layers < 2:
            raise ValueError("serving path assumes >= 2 layers (the "
                             "historical plane caches the final-layer input)")
        self.g = g
        self.cfg = cfg
        self.params = params
        self.params_version = params_version
        self.sampler = ServingSampler(g, fanouts, seed=seed)
        self.batcher = BucketedBatcher(buckets, max_wait_s=max_wait_s)
        # one cached plane: the (post-relu) hidden state entering the
        # final layer — dimension ``hidden`` for every arch in the zoo.
        # cfg.wire_codec selects the communication-plane wire format for
        # feature pulls AND cache fills (fp32 = bit-exact default).
        if cache is not None:
            if cache.planes[0].values.shape[1] != cfg.hidden:
                raise ValueError("injected cache plane width != cfg.hidden")
            self.use_cache = True
            self.owns_cache = False
            self.cache = cache
        else:
            self.use_cache = cache_policy != "none"
            self.owns_cache = True
            self.cache = EmbeddingCache(
                g, [cfg.hidden], policy=cache_policy,
                capacity=cache_capacity, max_staleness=max_staleness,
                codec=cfg.wire_codec)
            self.cache.params_version = params_version
        # replicas of one deployment share a single jitted forward
        # (forward_fn=) so N replicas compile each bucket once, not N times
        self._forward = forward_fn if forward_fn is not None else jax.jit(
            lambda p, inner, outer, x, ch, fm: GM.forward_blocks_cached(
                cfg, p, inner, outer, x, ch, fm))
        self.stats = ServeStats()
        # telemetry plane (no-ops unless repro.core.telemetry is enabled)
        self._m_queue = telemetry.gauge(
            "serving_queue_depth", "admitted requests waiting to batch")
        self._m_occupancy = telemetry.histogram(
            "serving_batch_occupancy", "real requests per formed batch",
            buckets=telemetry.DEFAULT_COUNT_BUCKETS)
        self._m_latency = telemetry.histogram(
            "serving_request_latency_seconds",
            "request latency, virtual-clock seconds (queueing + compute)")
        self._m_served = telemetry.counter(
            "serving_requests_total", "requests served to completion")
        self._m_batches = telemetry.counter(
            "serving_batches_total", "micro-batches computed")
        # virtual clock: _vnow advances by the measured wall compute of
        # each batch (see run()); between updates, virtual time flows at
        # wall rate from the anchor — which is what lets tracer spans
        # carry simulated timestamps consistent with reported p50/p99
        self._vnow = 0.0
        self._vanchor = time.perf_counter()
        # last GraphUpdateLog sequence number folded into self.g — the
        # cursor apply_graph_update() advances (monotone, idempotent)
        self._update_seq = 0

    def _virtual_now(self) -> float:
        """Current virtual-clock reading (the span clock): the last
        run-loop virtual time plus wall progress since its anchor."""
        return self._vnow + (time.perf_counter() - self._vanchor)

    def swap_params(self, params, version: int) -> None:
        """Atomically flip this server to new weights.  Called only
        between batches (the replica router guarantees the replica is
        idle), so every batch — including ones whose requests were queued
        before the flip — is computed end-to-end under exactly one
        ``(params, params_version, cache state)``.  A privately owned
        cache is flipped in the same breath; a shared cache is flipped
        once by whoever owns the rollout (see ``ReplicaRouter``)."""
        if version < self.params_version:
            raise ValueError(
                f"params version must be monotone: have "
                f"{self.params_version}, got {version}")
        self.params = params
        self.params_version = version
        if self.owns_cache:
            self.cache.bump_params_version(version)

    # -- dynamic graphs ----------------------------------------------------
    def apply_graph_update(self, log, upto_seq: Optional[int] = None, *,
                           flush: bool = False) -> dict:
        """Fold pending :class:`repro.core.updates.GraphUpdateLog` events
        into the served graph IN PLACE and incrementally invalidate every
        dependent state:

        * the sampler drops memoized picks of touched nodes and rebuilds
          its reversed adjacency (untouched nodes keep their exact
          previous expansion);
        * the embedding cache surgically invalidates the (L-1)-hop
          frontier of the delta — the cached plane is the FINAL-layer
          input, which depends on a node's (L-1)-hop sampled ball, so any
          node whose ball the delta can reach is aged to ``NEVER`` while
          everything else stays hot.

        The frontier is the union of pre- and post-mutation adjacency
        (a removed edge poisons the neighborhoods it used to feed).
        Idempotent per sequence number: re-applying an already-folded
        prefix is a no-op.  Called only between batches (same contract as
        :meth:`swap_params`).

        ``flush=True`` is the rebuild-on-schedule BASELINE the dynamic
        bench compares against: instead of the surgical frontier, every
        admitted cache row is invalidated on every fold — including folds
        with zero pending events, since a system without delta tracking
        cannot know nothing changed."""
        from repro.core.updates import fold_in_place
        upto = log.last_seq if upto_seq is None else upto_seq
        if upto <= self._update_seq:
            n_inv = (self.cache.invalidate_rows(np.arange(self.g.num_nodes))
                     if flush and self.use_cache else 0)
            return {"events": 0, "touched_nodes": 0,
                    "invalidated_rows": n_inv, "upto_seq": self._update_seq}
        hops = len(self.sampler.fanouts) - 1
        delta, frontier = fold_in_place(
            self.g, log, self._update_seq, upto, hops=hops)
        self.sampler.apply_delta(delta.nodes)
        if not self.use_cache:
            n_inv = 0
        elif flush:
            n_inv = self.cache.invalidate_rows(np.arange(self.g.num_nodes))
        else:
            n_inv = self.cache.invalidate_rows(frontier)
        self._update_seq = upto
        return {"events": delta.n_events,
                "touched_nodes": int(len(delta.nodes)),
                "invalidated_rows": n_inv,
                "upto_seq": upto}

    # -- one micro-batch ---------------------------------------------------
    def serve_batch(self, mb: MicroBatch) -> np.ndarray:
        """Returns (bucket, num_classes) logits (padded slots garbage)."""
        vclock = self._virtual_now
        # the cache is readable only while it holds THIS weight version's
        # embeddings — mid-rollout, a replica still on the old weights
        # sees a flipped shared cache as cold (and must not fill it, or a
        # new-version replica would read old-version rows: a torn batch)
        cache_ok = (self.use_cache
                    and self.cache.params_version == self.params_version)
        with telemetry.span("serve.batch", clock=vclock, bucket=mb.bucket):
            with telemetry.span("serve.sample", clock=vclock):
                outer_b = self.sampler.sample_outer(mb.node_ids)
                ids1 = outer_b.src_nodes
                if cache_ok:
                    cached_h, fresh = self.cache.lookup(0, ids1)
                else:
                    cached_h = np.zeros((len(ids1), self.cfg.hidden),
                                        np.float32)
                    fresh = np.zeros(len(ids1), bool)
                miss = (ids1 >= 0) & ~fresh
                inner_bs = self.sampler.sample_inner(ids1, expand=miss)
                need = needed_feature_mask(inner_bs, miss)
                x_in = self.cache.features.fetch_masked(
                    inner_bs[0].src_nodes, need)

            inner_dev = [DeviceGraph.from_block(b) for b in inner_bs]
            outer_dev = DeviceGraph.from_block(outer_b)
            shape_key = (mb.bucket,
                         tuple((b.num_dst, b.num_src, len(b.edge_mask))
                               for b in inner_bs + [outer_b]))
            self.stats.jit_shapes.add(shape_key)

            with telemetry.span("serve.forward", clock=vclock):
                logits, h_fresh = self._forward(
                    self.params, inner_dev, outer_dev, jnp.asarray(x_in),
                    jnp.asarray(cached_h), jnp.asarray(fresh))
                logits = np.asarray(logits)
            if cache_ok:
                self.cache.store(0, ids1, np.asarray(h_fresh), miss)
        return logits

    def warmup(self, node_id: int = 0, *,
               reset_cache_stats: bool = True) -> None:
        """Compile every declared bucket once (excluded from stats).
        ``reset_cache_stats=False`` keeps the cache counters — replicas
        warmed mid-run against a *shared* cache must not wipe the
        fleet's accumulated accounting."""
        for b in self.batcher.buckets:
            ids = np.full((b,), -1, np.int64)
            ids[0] = node_id
            self.serve_batch(MicroBatch([], ids, b, 0.0))
        # warmup traffic must not pollute serving stats: the caches own
        # their counters (and the matching telemetry series), so reset
        # through them instead of poking their attributes
        if reset_cache_stats:
            self.cache.reset_stats()

    # -- the serve loop ----------------------------------------------------
    def run(self, workload: List[InferenceRequest], *,
            tick_every_s: float = 0.0,
            update_log=None, update_every: int = 0,
            update_chunk: int = 0) -> ServeStats:
        """Serve a workload to completion.  ``tick_every_s`` simulates
        periodic feature-refresh epochs: every interval of virtual time the
        cache's version clock advances, aging historical embeddings — the
        staleness bound then decides whether they can still be served.

        ``update_log`` streams live graph mutations into the run: after
        every ``update_every`` completed requests the next ``update_chunk``
        pending events (0 = all pending) are folded via
        :meth:`apply_graph_update` — between batches, so no batch ever
        straddles a mutation."""
        workload = sorted(workload, key=lambda r: r.arrival_s)
        queue = RequestQueue()
        vnow = 0.0
        next_tick = tick_every_s if tick_every_s > 0 else float("inf")
        next_update = (update_every if update_log is not None
                       and update_every > 0 else float("inf"))
        i = 0
        t_start = time.perf_counter()
        while i < len(workload) or len(queue):
            while vnow >= next_tick:
                self.cache.tick()
                next_tick += tick_every_s
            while i < len(workload) and workload[i].arrival_s <= vnow:
                queue.push(workload[i])
                i += 1
            drained = i >= len(workload)
            self._m_queue.set(len(queue))
            mb = self.batcher.form(queue, vnow, force=drained)
            if mb is None:
                # jump to the next event: an arrival, the head-of-line
                # request's max_wait deadline, or a cache-clock tick —
                # NOT straight to the next arrival, which would make
                # queued requests wait a full inter-arrival gap
                events = []
                if i < len(workload):
                    events.append(workload[i].arrival_s)
                oldest = queue.oldest_arrival()
                if oldest is not None:
                    events.append(oldest + self.batcher.max_wait_s)
                if next_tick != float("inf"):
                    events.append(next_tick)
                # strict one-ulp progress (see request.advance_vclock:
                # landing exactly on fl(oldest + max_wait) would livelock)
                vnow = advance_vclock(vnow, min(events))
                continue
            # anchor the virtual clock: during this batch's compute,
            # virtual time = vnow + wall elapsed (exactly how vnow itself
            # advances below), so spans inside serve_batch land on the
            # same simulated axis as the reported latencies
            self._vnow, self._vanchor = vnow, time.perf_counter()
            t0 = time.perf_counter()
            logits = self.serve_batch(mb)
            vnow += time.perf_counter() - t0
            self._vnow = vnow
            self._m_occupancy.observe(len(mb.requests))
            for j, r in enumerate(mb.requests):
                r.logits = logits[mb.slots[j]]
                r.done_s = vnow
                r.params_version = self.params_version
                self.stats.latency_hist.observe(r.latency_s)
                self._m_latency.observe(r.latency_s)
            self._m_served.inc(len(mb.requests))
            self._m_batches.inc()
            self.stats.served += len(mb.requests)
            self.stats.batches += 1
            if self.stats.served >= next_update:
                upto = (None if update_chunk <= 0 else
                        min(self._update_seq + update_chunk,
                            update_log.last_seq))
                self.apply_graph_update(update_log, upto)
                next_update += update_every
        if update_log is not None and update_log.last_seq > self._update_seq:
            # drain the stream: a run must leave the served graph caught
            # up with every event published before it finished
            self.apply_graph_update(update_log)
        self.stats.wall_s += time.perf_counter() - t_start
        return self.stats

    def summary(self) -> dict:
        out = self.stats.summary()
        out.update(self.cache.stats())
        out["pad_overhead"] = self.batcher.pad_overhead
        return out
