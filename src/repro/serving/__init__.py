"""Online GNN inference serving (survey §3.2.2 / §3.2.4 applied at
inference time).

The subsystem turns the repo's offline training machinery into an online
server:

* :mod:`repro.serving.request`  — request objects, FIFO queue, synthetic
  arrival processes.
* :mod:`repro.serving.batcher`  — dynamic micro-batcher that pads every
  batch to one of a small set of declared bucket sizes (static shapes →
  bounded jit cache).
* :mod:`repro.serving.sampler`  — fixed-shape inference-time neighbor
  sampling built on :func:`repro.core.sampling.sample_block_padded`.
* :mod:`repro.serving.cache`    — layered historical-embedding cache
  (GNNAutoScale-style) with staleness bounds, built on
  :class:`repro.core.caching.FeatureStore`.
* :mod:`repro.serving.server`   — the serve loop: admit → batch → sample
  → fetch/cache → forward → account latency.
* :mod:`repro.serving.replica`  — one replica: private queue + batcher +
  compute path, scheduled by the router.
* :mod:`repro.serving.router`   — the elastic replicated tier: dispatch
  policies, load-based autoscaling, rolling weight hot-swap under the
  shared version clock, crash-safe stop/resume.
"""
from repro.serving.batcher import BucketedBatcher, MicroBatch
from repro.serving.cache import EmbeddingCache
from repro.serving.replica import ServingReplica
from repro.serving.request import (InferenceRequest, RequestQueue,
                                   poisson_workload)
from repro.serving.router import (AutoscalePolicy, AutoScaler,
                                  ReplicaRouter, RouterStats,
                                  restore_params)
from repro.serving.sampler import ServingSampler
from repro.serving.server import GNNInferenceServer, ServeStats

__all__ = [
    "BucketedBatcher", "MicroBatch", "EmbeddingCache", "InferenceRequest",
    "RequestQueue", "poisson_workload", "ServingSampler",
    "GNNInferenceServer", "ServeStats", "ServingReplica", "AutoscalePolicy",
    "AutoScaler", "ReplicaRouter", "RouterStats", "restore_params",
]
