"""One serving replica: a `GNNInferenceServer` wrapped for router use.

The replicated serving tier (survey §3.2.2 — replication + load balancing
as the DL-serving lineage's answer to heavy traffic) splits the single
server's run loop in two: the :class:`~repro.serving.router.ReplicaRouter`
owns admission, dispatch, autoscaling, and the virtual clock, while each
:class:`ServingReplica` owns one private request queue, one batcher, and
one compute path (a full :class:`~repro.serving.server.GNNInferenceServer`
minus its run loop).

Replica lifecycle:

* ``ACTIVE``   — receives dispatched requests, forms and serves batches;
* ``DRAINING`` — scale-down target: receives nothing new, serves its
  queue dry, then is removed (zero dropped requests by construction);
* removed     — gone from the router's replica list.

Virtual-time semantics: a replica that starts a batch at virtual time
``t`` is busy until ``t + wall_compute`` (``busy_until``); replicas
overlap in virtual time even though the host executes them serially —
which is exactly how N replicas multiply simulated throughput.

Weight hot-swap happens *between* batches only (:meth:`swap` delegates to
``GNNInferenceServer.swap_params`` while idle), so every batch — and
therefore every request — is computed under exactly one
``(params, params_version, cache)`` and stamped with that version.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import numpy as np

from repro.core import telemetry
from repro.serving.batcher import MicroBatch
from repro.serving.request import RequestQueue
from repro.serving.server import GNNInferenceServer

__all__ = ["ServingReplica"]


class ServingReplica:
    """One replica: private queue + batcher + compute, router-scheduled.

    Args:
        rid: replica id (stable across the run; telemetry label).
        server: the wrapped single-node server.  Its ``run`` loop is
            never used — the router drives :meth:`serve` directly.

    The ``replica=<rid>`` telemetry series (``serving_requests_total``,
    ``serving_batches_total``, ``serving_request_latency_seconds``,
    ``serving_replica_queue_depth``) are this class's; the router adds
    the fleet-level ones (replica count, dispatch, scale/swap events).
    """

    def __init__(self, rid: int, server: GNNInferenceServer):
        self.rid = rid
        self.server = server
        self.queue = RequestQueue()
        # virtual time at which the in-flight batch (if any) completes
        self.busy_until = 0.0
        self.draining = False
        self.served = 0
        self.batches = 0
        lbl = str(rid)
        self._m_served = telemetry.counter(
            "serving_requests_total", "requests served to completion",
            replica=lbl)
        self._m_batches = telemetry.counter(
            "serving_batches_total", "micro-batches computed", replica=lbl)
        self._m_latency = telemetry.histogram(
            "serving_request_latency_seconds",
            "request latency, virtual-clock seconds (queueing + compute)",
            replica=lbl)
        self._m_queue = telemetry.gauge(
            "serving_replica_queue_depth",
            "requests queued at this replica", replica=lbl)

    # -- state -------------------------------------------------------------
    @property
    def version(self) -> int:
        """Weight version this replica currently serves."""
        return self.server.params_version

    def idle(self, vnow: float) -> bool:
        """True when no batch is in flight at virtual time ``vnow``."""
        return self.busy_until <= vnow

    def queue_depth(self) -> int:
        return len(self.queue)

    def dispatch(self, req) -> None:
        """Router handoff: enqueue one admitted request."""
        self.queue.push(req)
        self._m_queue.set(len(self.queue))

    # -- weight hot-swap ---------------------------------------------------
    def swap(self, params, version: int) -> None:
        """Flip to new weights; caller (the router's rolling upgrade)
        guarantees the replica is idle, so no in-flight batch can
        straddle the flip."""
        self.server.swap_params(params, version)

    # -- compute -----------------------------------------------------------
    def try_serve(self, vnow: float, *,
                  force: bool = False) -> Optional[Tuple[MicroBatch, float]]:
        """Form one batch from this replica's queue (per the batcher's
        emission policy; ``force`` drains at end of workload) and compute
        it.  Returns ``(batch, done_vtime)`` or ``None`` if no batch
        formed.  Completions are finalized here: each request gets its
        logits, completion stamp ``done_vtime = vnow + wall_compute``,
        and the single weight version that computed it."""
        srv = self.server
        mb = srv.batcher.form(self.queue, vnow, force=force)
        if mb is None:
            return None
        v0 = srv.params_version
        # anchor the server's virtual clock so spans inside serve_batch
        # land on the simulated axis (same contract as the single-server
        # run loop)
        srv._vnow, srv._vanchor = vnow, time.perf_counter()
        t0 = time.perf_counter()
        logits = srv.serve_batch(mb)
        dt = time.perf_counter() - t0
        assert srv.params_version == v0, "params swapped mid-batch"
        done = vnow + dt
        self.busy_until = done
        for j, r in enumerate(mb.requests):
            r.logits = logits[mb.slots[j]]
            r.done_s = done
            r.params_version = v0
            srv.stats.latency_hist.observe(r.latency_s)
            self._m_latency.observe(r.latency_s)
        n = len(mb.requests)
        self.served += n
        self.batches += 1
        srv.stats.served += n
        srv.stats.batches += 1
        self._m_served.inc(n)
        self._m_batches.inc()
        self._m_queue.set(len(self.queue))
        return mb, done

    def warmup(self, *, reset_cache_stats: bool = True) -> None:
        """Compile every declared bucket (wall time only — virtual cold
        start is the router's ``startup_delay_s``).  Replicas added
        mid-run pass ``reset_cache_stats=False`` so warming up against a
        *shared* cache cannot wipe the fleet's accumulated accounting."""
        self.server.warmup(reset_cache_stats=reset_cache_stats)

    def summary(self) -> dict:
        return {
            "replica": self.rid,
            "served": self.served,
            "batches": self.batches,
            "version": self.version,
            "draining": self.draining,
        }
