"""Dynamic micro-batcher with a fixed bucket-shape vocabulary.

TPU/XLA discipline (same as ``core/sampling``): every batch must be one of
a small declared set of padded sizes so each (bucket, arch) pair compiles
exactly once and every later batch hits that jit cache entry.  The batcher
trades a little padding waste for zero recompilation — the classic serving
bucketing policy (e.g. TF-Serving / NVIDIA Triton shape buckets).

Emission policy:
* emit as soon as a full largest-bucket batch is pending (throughput), or
* when the head-of-line request has waited ``max_wait_s`` (latency), or
* when ``force`` is set (drain at end of workload).
The bucket chosen is the smallest declared size that fits the pending
requests (capped at the largest bucket).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.request import InferenceRequest, RequestQueue

PAD_ID = -1


@dataclasses.dataclass
class MicroBatch:
    """One padded inference batch: ``node_ids`` is ``(bucket,)`` with
    UNIQUE real ids as a prefix and ``PAD_ID`` (-1) pads; ``slots[j]``
    maps request ``j`` to its id slot (duplicate requests for one node
    share a slot).  Pad slots never sample, fetch, or aggregate — they
    only keep the shape static."""
    requests: List[InferenceRequest]
    node_ids: np.ndarray        # (bucket,) int64, UNIQUE ids, PAD_ID pads
    bucket: int
    formed_s: float
    # slot index into node_ids per request — duplicate requests for the
    # same node share one slot (dedup batching)
    slots: List[int] = dataclasses.field(default_factory=list)

    @property
    def pad_mask(self) -> np.ndarray:
        return self.node_ids >= 0

    @property
    def fill(self) -> float:
        return int(self.pad_mask.sum()) / self.bucket


class BucketedBatcher:
    """Dynamic micro-batcher over a declared bucket-size vocabulary.

    Args:
        buckets: allowed padded batch sizes (sorted, deduped); every
            emitted :class:`MicroBatch` has ``bucket ∈ buckets``, so the
            downstream jit cache holds at most ``len(buckets)`` entries
            per arch.
        max_wait_s: head-of-line latency bound — a queued request never
            waits longer than this for a batch to form (the serve loop's
            virtual clock honors it as an event deadline).

    ``form`` returns ``None`` when no emission rule fires; ``pad_overhead``
    reports the fraction of emitted slots that were padding.
    """

    def __init__(self, buckets: Sequence[int] = (1, 2, 4, 8, 16, 32, 64),
                 *, max_wait_s: float = 0.002):
        if not buckets:
            raise ValueError("need at least one bucket size")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.max_wait_s = max_wait_s
        self.emitted = 0
        self.padded_slots = 0
        self.real_slots = 0

    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    def bucket_for(self, n: int) -> int:
        """Smallest declared bucket that holds ``n`` (capped at largest)."""
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_bucket

    def should_emit(self, queue: RequestQueue, now: float,
                    force: bool = False) -> bool:
        if len(queue) == 0:
            return False
        if force or len(queue) >= self.max_bucket:
            return True
        oldest = queue.oldest_arrival()
        return oldest is not None and (now - oldest) >= self.max_wait_s

    def form(self, queue: RequestQueue, now: float,
             force: bool = False) -> Optional[MicroBatch]:
        if not self.should_emit(queue, now, force):
            return None
        reqs = queue.pop_up_to(self.max_bucket)
        # dedup: requests for the same node share one slot (the sampler
        # requires unique dst ids, and one prediction serves them all)
        slot_of = {}
        for r in reqs:
            slot_of.setdefault(r.node_id, len(slot_of))
        bucket = self.bucket_for(len(slot_of))
        ids = np.full((bucket,), PAD_ID, np.int64)
        for nid, slot in slot_of.items():
            ids[slot] = nid
        self.emitted += 1
        self.real_slots += len(slot_of)
        self.padded_slots += bucket - len(slot_of)
        return MicroBatch(reqs, ids, bucket, now,
                          slots=[slot_of[r.node_id] for r in reqs])

    @property
    def pad_overhead(self) -> float:
        tot = self.real_slots + self.padded_slots
        return self.padded_slots / tot if tot else 0.0
