"""Parallelism strategies (survey §3.2.5 / §2.3.1, Tables 2 & 7).

GNN side:
* :func:`p3_layer1` + :func:`make_p3_train_step` — P³'s push-pull hybrid
  [Gandhi & Iyer, OSDI'21]: layer 1 runs *model-parallel over the feature
  dimension* (features never cross the network; only the (N, hidden)
  partial activations are reduce-scattered), deeper layers run data-parallel
  pull.  The survey singles this out (§3.2.5, §4.2).

Transformer side:
* :func:`moe_expert_parallel` — explicit shard_map expert parallelism:
  experts sharded over ``model``; activations replicated over ``model``
  (they already are, post attention), each shard computes only its local
  experts on the tokens routed to them (gather dispatch, real FLOPs only),
  and a single ``psum`` over ``model`` combines.  This is the beyond-
  baseline replacement for the GShard one-hot dispatch in
  ``models/transformer/moe.py`` (§Perf hillclimb #1).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch import sharding as shd

AXIS = "g"


# ===========================================================================
# P3 push-pull hybrid parallelism (GNN, full graph)
# ===========================================================================

def p3_layer1(x_fshard, w1_fshard, edge_src, edge_dst, edge_mask, coef,
              n_pad: int, n_local: int):
    """Runs inside shard_map over axis "g".

    x_fshard:  (N_pad, F/n) — every vertex, a slice of the feature dim
    w1_fshard: (F/n, H)     — matching input-dim slice of W1
    Aggregation is fully local (all vertices present); the partial
    (N_pad, H) activations are psum_scatter'd onto vertex owners.
    """
    feat = jnp.take(x_fshard, edge_src, axis=0)
    feat = feat * (coef * edge_mask)[:, None]
    agg = jax.ops.segment_sum(feat, edge_dst, n_pad)        # (N_pad, F/n)
    h_partial = agg @ w1_fshard                             # (N_pad, H)
    # Forward-pass sharding primitive; layer-1 grads stay UN-psummed on
    # purpose (see make_p3_train_step).
    # repro-lint: disable=RL001 -- psum_scatter transpose is all_gather, no double reduction
    return jax.lax.psum_scatter(h_partial, AXIS, scatter_dimension=0,
                                tiled=True)                 # (N_loc, H)


def make_p3_train_step(optimizer, n_dev: int, n_layers: int = 2):
    """Distributed GCN with P3 hybrid parallelism (2-layer reference).

    Inputs (see propagation.ShardedGraph):
      x_f:   (N_pad, F) sharded over the FEATURE dim (model parallel)
      edges: full edge list, replicated (global src, global dst)
      deeper layers: data-parallel pull over vertex shards.
    """
    devs = np.array(jax.devices()[:n_dev])
    mesh = Mesh(devs, (AXIS,))

    def step(params, opt_state, x_f, edge_src, edge_dst, edge_mask, coef,
             labels, lmask):
        n_pad = x_f.shape[0]
        n_local = n_pad // n_dev
        # psum the (parameter-free) count OUTSIDE the differentiated
        # function: under check_rep=False a psum inside loss_fn transposes
        # to a second psum, scaling every gradient by n_dev (the PR 2
        # double-psum class, masked by Adam scale-invariance — see
        # propagation.py; statically enforced by lint rule RL001)
        cnt = jax.lax.psum(jnp.sum(lmask), AXIS)

        def loss_fn(p):
            h = p3_layer1(x_f, p[0]["w"], edge_src, edge_dst, edge_mask,
                          coef, n_pad, n_local) + p[0]["b"]
            h = jax.nn.relu(h)
            for i in range(1, n_layers):
                h_all = jax.lax.all_gather(h @ p[i]["w"], AXIS, tiled=True)
                feat = jnp.take(h_all, edge_src, axis=0)
                feat = feat * (coef * edge_mask)[:, None]
                agg_full = jax.ops.segment_sum(feat, edge_dst, n_pad)
                idx = jax.lax.axis_index(AXIS)
                agg = jax.lax.dynamic_slice_in_dim(
                    agg_full, idx * n_local, n_local, axis=0)
                h = agg + p[i]["b"]
                if i + 1 < n_layers:
                    h = jax.nn.relu(h)
            logz = jax.nn.logsumexp(h, axis=-1)
            gold = jnp.take_along_axis(h, labels[:, None], axis=-1)[:, 0]
            local = jnp.sum((logz - gold) * lmask)
            return local / jnp.maximum(cnt, 1.0)

        local_loss, grads = jax.value_and_grad(loss_fn)(params)
        loss = jax.lax.psum(local_loss, AXIS)
        # replicated params: each device's grad is its local contribution
        # -> SUM across devices.  The feature-sharded layer-1 weight's
        # grad is already complete for its own shard (autodiff through
        # psum_scatter delivers the full cotangent) -> keep as is.
        summed = jax.tree.map(lambda g_: jax.lax.psum(g_, AXIS), grads)
        summed[0]["w"] = grads[0]["w"]
        params, opt_state = optimizer.apply(params, summed, opt_state)
        return params, opt_state, loss

    rep = P()
    pspec = [{"w": P(AXIS, None) if i == 0 else rep, "b": rep}
             for i in range(n_layers)]
    ospec = [{"w": P(AXIS, None) if i == 0 else rep, "b": rep}
             for i in range(n_layers)]
    opt_spec = {"m": pspec, "v": pspec, "step": rep}  # moments mirror params
    smapped = shard_map(
        step, mesh=mesh,
        in_specs=(pspec, opt_spec, P(None, AXIS), rep, rep, rep, rep,
                  P(AXIS), P(AXIS)),
        out_specs=(ospec, opt_spec, rep),
        check_rep=False)
    return mesh, smapped


# ===========================================================================
# expert parallelism via shard_map (transformer MoE hillclimb)
# ===========================================================================

def _local_expert_compute(cfg, x_loc, router, w_gate, w_in, w_out,
                          capacity_factor: float):
    """Inside shard_map: x_loc (T_loc, D) replicated over model; expert
    weights are the LOCAL slice (E_loc, D, F).  Gather-dispatch (no one-hot
    einsums) + psum over 'model' by the caller."""
    E = cfg.num_experts
    k = cfg.experts_per_token
    m_idx = jax.lax.axis_index("model")
    E_loc = w_in.shape[0]
    T = x_loc.shape[0]
    C = max(1, int(np.ceil(T * k / E * capacity_factor)))

    logits = jnp.einsum("td,de->te", x_loc.astype(jnp.float32), router)
    gates = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(gates, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)

    flat_e = idx.reshape(T * k)
    local_e = flat_e - m_idx * E_loc
    is_local = (local_e >= 0) & (local_e < E_loc)

    # position within each local expert queue (cumsum over flat order)
    onehot = jax.nn.one_hot(jnp.where(is_local, local_e, E_loc), E_loc + 1,
                            dtype=jnp.int32)[:, :E_loc]       # (T*k, E_loc)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_of = jnp.sum(pos * onehot, axis=-1)
    keep = is_local & (pos_of < C)
    slot = jnp.where(keep, local_e * C + pos_of, E_loc * C)

    src = jnp.full((E_loc * C + 1,), T, jnp.int32)
    src = src.at[slot].set(jnp.arange(T * k, dtype=jnp.int32) // k)
    src = src[:E_loc * C]
    x_pad = jnp.concatenate([x_loc, jnp.zeros((1, x_loc.shape[1]),
                                              x_loc.dtype)])
    xe = jnp.take(x_pad, src, axis=0).reshape(E_loc, C, -1)

    h = jnp.einsum("ecd,edf->ecf", xe, w_in)
    hg = jnp.einsum("ecd,edf->ecf", xe, w_gate)
    act = jax.nn.silu if cfg.act == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    ye = jnp.einsum("ecf,efd->ecd", act(hg) * h, w_out)

    ye_flat = jnp.concatenate([ye.reshape(E_loc * C, -1),
                               jnp.zeros((1, ye.shape[-1]), ye.dtype)])
    contrib = jnp.take(ye_flat, jnp.minimum(slot, E_loc * C), axis=0)
    wk = (w.reshape(T * k) * keep).astype(contrib.dtype)
    y = jnp.sum((contrib * wk[:, None]).reshape(T, k, -1), axis=1)
    return y  # partial: only local experts' contributions


def moe_expert_parallel(cfg, p, x, *, capacity_factor: float = 1.25):
    """Drop-in replacement for moe.moe_block using explicit shard_map EP.

    Requires active ShardingRules (shd context).  Falls back to the
    gathered single-device path when no rules are installed (smoke tests).
    """
    rules = shd._ACTIVE.get()
    if rules is None:
        from repro.models.transformer.moe import moe_block_gathered
        return moe_block_gathered(cfg, p, x,
                                  capacity_factor=capacity_factor)

    mesh = rules.mesh
    B, S, D = x.shape
    batch_ax = rules.batch_axis

    def inner(x_in, router, w_gate, w_in, w_out):
        T_loc = x_in.shape[0] * x_in.shape[1]
        y = _local_expert_compute(cfg, x_in.reshape(T_loc, D), router,
                                  w_gate, w_in, w_out, capacity_factor)
        y = jax.lax.psum(y, "model")
        return y.reshape(x_in.shape)

    xspec = P(batch_ax, None, None)
    out = shard_map(
        inner, mesh=mesh,
        in_specs=(xspec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=xspec,
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_in"], p["w_out"])

    if cfg.num_shared_experts:
        from repro.models.transformer import layers as L
        out = out + L.mlp(cfg, x, p["shared"])
    return out
