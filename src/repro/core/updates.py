"""Streaming graph updates: the append-only edge/node update log.

The survey's dynamic-GNN-systems lineage (temporal/evolving-graph systems,
§3.3) treats a mutating graph as a *stream of updates* folded into an
otherwise-static snapshot: edges appear and disappear, node features
drift, and every derived structure — historical-embedding caches, halo
ghost buffers, sampled neighborhoods — must be invalidated *incrementally*
(only where the delta actually reaches) instead of rebuilt cold.

This module is the substrate all of that keys off:

* :class:`GraphUpdateLog` — an append-only log of
  ``add_edge`` / ``remove_edge`` / ``update_features`` events with
  monotone sequence numbers, each stamped with the shared
  :class:`~repro.core.caching.VersionClock` at append time (the same
  clock the staleness-bounded caches age against);
* :meth:`GraphUpdateLog.apply` — fold a seq range of events into a
  :class:`~repro.graph.structure.Graph` and return a NEW snapshot.
  Because :func:`~repro.graph.structure.from_edges` stable-sorts by
  source, applying ``[0, s1]`` then ``(s1, s2]`` is *bitwise identical*
  to applying ``[0, s2]`` in one shot — the composition property the
  hypothesis suite asserts and the delta-vs-rebuild equivalence tests
  build on;
* :meth:`GraphUpdateLog.delta` — the touched node/edge sets of a seq
  range, the seed of every incremental-invalidation frontier;
* :func:`k_hop_nodes` / :func:`fold_in_place` — frontier expansion and
  the in-place fold that lets every holder of a shared ``Graph`` object
  (samplers, feature stores, caches, trainers) observe the post-update
  structure without re-plumbing references.

Telemetry: every appended event counts into
``graph_updates_total{kind}``; :meth:`GraphUpdateLog.reset_stats`
resets the series and the instance counters in lockstep (the PR-6
warmup-reset rule every accounted subsystem follows).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterator, List, Optional

import numpy as np

from repro.core import telemetry
from repro.core.caching import VersionClock
from repro.graph.structure import Graph, from_edges

__all__ = ["GraphUpdate", "UpdateDelta", "GraphUpdateLog", "k_hop_nodes",
           "fold_in_place", "load_update_stream", "synthesize_updates",
           "UPDATE_KINDS"]

UPDATE_KINDS = ("add_edge", "remove_edge", "update_features")


@dataclasses.dataclass(frozen=True)
class GraphUpdate:
    """One immutable event of the update stream.

    Attributes:
        seq: monotone 1-based sequence number (``seq=0`` is reserved for
            "the base graph, nothing applied").
        kind: one of :data:`UPDATE_KINDS`.
        u: source node (``add_edge``/``remove_edge``) or the updated node
            (``update_features``).
        v: destination node of an edge event; ``-1`` for feature events.
        x: replacement feature row for ``update_features``; ``None``
            otherwise.
        clock: value of the shared version clock when the event was
            appended — the tick invalidations of this event are ordered
            against.
    """
    seq: int
    kind: str
    u: int
    v: int = -1
    x: Optional[np.ndarray] = None
    clock: int = 0


@dataclasses.dataclass(frozen=True)
class UpdateDelta:
    """Touched sets of a seq range ``(from_seq, to_seq]``.

    Attributes:
        from_seq / to_seq: the half-open range the delta covers.
        nodes: sorted unique node ids touched — both endpoints of every
            edge event plus the node of every feature event.
        edges: ``(K, 2)`` ``[u, v]`` pairs of the edge events (adds and
            removes alike; duplicates preserved in stream order).
        n_events: number of events in the range.
    """
    from_seq: int
    to_seq: int
    nodes: np.ndarray
    edges: np.ndarray
    n_events: int


class GraphUpdateLog:
    """Append-only streaming edge/node update log.

    Args:
        clock: share an existing :class:`~repro.core.caching.VersionClock`
            (e.g. a serving cache's) so event stamps are ordered against
            the same staleness epochs; default: a private clock at 0.

    Events get monotone sequence numbers starting at 1; ``apply(g, s)``
    folds events ``1..s`` into ``g`` and ``apply(g1, s2, from_seq=s1)``
    continues from an earlier snapshot — bitwise identical to the
    one-shot fold (see module docstring).  ``remove_edge`` removes ALL
    stored copies of ``(u, v)`` present at its point in the stream and
    is a no-op when the edge is absent (lenient, so replaying a stream
    against divergent snapshots cannot raise mid-fold).
    """

    def __init__(self, *, clock: Optional[VersionClock] = None):
        self.clock = clock if clock is not None else VersionClock()
        self.events: List[GraphUpdate] = []
        self.counts = {k: 0 for k in UPDATE_KINDS}
        self._m = {k: telemetry.counter(
            "graph_updates_total", "graph update events appended to the "
            "streaming update log", kind=k) for k in UPDATE_KINDS}

    # -- append ------------------------------------------------------------
    def _append(self, kind: str, u: int, v: int,
                x: Optional[np.ndarray]) -> GraphUpdate:
        ev = GraphUpdate(seq=len(self.events) + 1, kind=kind, u=int(u),
                         v=int(v), x=x, clock=self.clock.now)
        self.events.append(ev)
        self.counts[kind] += 1
        self._m[kind].inc()
        return ev

    def add_edge(self, u: int, v: int) -> GraphUpdate:
        """Append an ``add_edge`` event for the directed edge ``u -> v``
        (undirected graphs append both directions as two events)."""
        return self._append("add_edge", u, v, None)

    def remove_edge(self, u: int, v: int) -> GraphUpdate:
        """Append a ``remove_edge`` event: at apply time every stored copy
        of ``u -> v`` present at this point in the stream is dropped."""
        return self._append("remove_edge", u, v, None)

    def update_features(self, node: int, x: np.ndarray) -> GraphUpdate:
        """Append an ``update_features`` event replacing ``node``'s
        feature row with ``x`` at apply time."""
        return self._append("update_features", node, -1,
                            np.asarray(x, np.float32))

    @property
    def last_seq(self) -> int:
        """Highest appended sequence number (0 on an empty log)."""
        return len(self.events)

    def relabel(self, inv: np.ndarray) -> "GraphUpdateLog":
        """New log with every event's node ids mapped through ``inv``
        (``inv[old_id] = new_id``) — the adapter that lets an
        original-id update stream fold into a locality-packed graph
        (``Graph.reordered``): folding commutes with relabeling, so
        ``fold(packed, log.relabel(inv))`` is the relabeling of
        ``fold(g, log)`` under the same permutation (the
        fold-then-reorder regression in ``tests/test_dynamic_graph.py``).
        Seq numbers, clock stamps, and counts are preserved; telemetry
        counters are NOT re-incremented (relabeled events are not new
        events)."""
        inv = np.asarray(inv)
        out = GraphUpdateLog(clock=self.clock)
        for ev in self.events:
            out.events.append(dataclasses.replace(
                ev, u=int(inv[ev.u]),
                v=int(inv[ev.v]) if ev.v >= 0 else -1))
            out.counts[ev.kind] += 1
        return out

    def events_between(self, from_seq: int,
                       to_seq: int) -> Iterator[GraphUpdate]:
        """Iterate events with ``from_seq < seq <= to_seq`` in order."""
        if not 0 <= from_seq <= to_seq <= self.last_seq:
            raise ValueError(
                f"bad seq range ({from_seq}, {to_seq}] for a log of "
                f"{self.last_seq} events")
        return iter(self.events[from_seq:to_seq])

    # -- fold --------------------------------------------------------------
    def apply(self, g: Graph, upto_seq: Optional[int] = None, *,
              from_seq: int = 0) -> Graph:
        """Fold events ``(from_seq, upto_seq]`` into ``g`` and return a
        new :class:`~repro.graph.structure.Graph` snapshot (``g`` itself
        is never mutated; labels are shared, features are copied when
        present).

        ``upto_seq=None`` means "everything appended so far".  Passing a
        snapshot produced by an earlier ``apply(g, s1)`` with
        ``from_seq=s1`` continues the fold — and yields a CSR bitwise
        identical to the one-shot ``apply(g, s2)``, because
        :func:`~repro.graph.structure.from_edges` stable-sorts by source
        (appends keep their relative order inside each source row, and
        removal commutes with a stable sort).
        """
        upto = self.last_seq if upto_seq is None else upto_seq
        n = g.num_nodes
        edges = [(int(s), int(d)) for s, d in g.edges()]
        feats = None if g.features is None else np.array(g.features)
        for ev in self.events_between(from_seq, upto):
            if not (0 <= ev.u < n and (ev.v < n)):
                raise ValueError(f"event seq={ev.seq} touches node out of "
                                 f"range for a {n}-node graph")
            if ev.kind == "add_edge":
                if ev.v < 0:
                    raise ValueError(f"event seq={ev.seq}: bad dst {ev.v}")
                edges.append((ev.u, ev.v))
            elif ev.kind == "remove_edge":
                edges = [e for e in edges if e != (ev.u, ev.v)]
            else:                                  # update_features
                if feats is None:
                    raise ValueError("update_features on a featureless "
                                     "graph")
                x = np.asarray(ev.x, feats.dtype)
                if x.shape != feats.shape[1:]:
                    raise ValueError(
                        f"event seq={ev.seq}: update_features payload has "
                        f"shape {x.shape} but the graph's feature rows are "
                        f"{feats.shape[1:]} — the stream was recorded "
                        f"against a different featurization")
                feats[ev.u] = x
        e = (np.asarray(edges, np.int64).reshape(-1, 2)
             if edges else np.zeros((0, 2), np.int64))
        return from_edges(n, e, features=feats, labels=g.labels,
                          num_classes=g.num_classes)

    def delta(self, from_seq: int,
              to_seq: Optional[int] = None) -> UpdateDelta:
        """Touched node/edge sets of ``(from_seq, to_seq]`` — the seed of
        every incremental-invalidation frontier.  Union over sub-ranges
        is a superset of (in fact equal to) the full range's sets."""
        to = self.last_seq if to_seq is None else to_seq
        nodes, edges, k = [], [], 0
        for ev in self.events_between(from_seq, to):
            k += 1
            if ev.kind == "update_features":
                nodes.append(ev.u)
            else:
                nodes.extend((ev.u, ev.v))
                edges.append((ev.u, ev.v))
        return UpdateDelta(
            from_seq=from_seq, to_seq=to,
            nodes=np.unique(np.asarray(nodes, np.int64)),
            edges=(np.asarray(edges, np.int64).reshape(-1, 2)
                   if edges else np.zeros((0, 2), np.int64)),
            n_events=k)

    # -- persistence -------------------------------------------------------
    def to_jsonl(self, path: str) -> int:
        """Write the stream as JSONL (one event per line; the
        ``--update-stream`` wire format) and return the event count."""
        with open(path, "w") as f:
            for ev in self.events:
                rec = {"kind": ev.kind, "u": ev.u}
                if ev.kind == "update_features":
                    rec["x"] = [float(v) for v in ev.x]
                else:
                    rec["v"] = ev.v
                f.write(json.dumps(rec) + "\n")
        return len(self.events)

    # -- accounting --------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the per-kind event counters and their
        ``graph_updates_total`` telemetry series in lockstep (events
        themselves are state, not accounting, and are kept)."""
        for k in UPDATE_KINDS:
            self.counts[k] = 0
            self._m[k].reset()

    def stats(self) -> dict:
        """Per-kind event counts plus the log's seq horizon."""
        out = {f"events_{k}": v for k, v in self.counts.items()}
        out["last_seq"] = self.last_seq
        return out


def load_update_stream(path: str, *,
                       clock: Optional[VersionClock] = None
                       ) -> GraphUpdateLog:
    """Load a JSONL update stream (see :meth:`GraphUpdateLog.to_jsonl`)
    into a fresh :class:`GraphUpdateLog` stamped on ``clock``."""
    log = GraphUpdateLog(clock=clock)
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec["kind"]
            if kind == "add_edge":
                log.add_edge(rec["u"], rec["v"])
            elif kind == "remove_edge":
                log.remove_edge(rec["u"], rec["v"])
            elif kind == "update_features":
                log.update_features(rec["u"], np.asarray(rec["x"],
                                                         np.float32))
            else:
                raise ValueError(f"unknown update kind {kind!r}")
    return log


def k_hop_nodes(g: Graph, nodes: np.ndarray, hops: int) -> np.ndarray:
    """Nodes within ``hops`` edge traversals of ``nodes``, following BOTH
    edge directions (conservative: a superset of any pull- or
    push-direction reachability, so invalidating this set is always
    safe).  Returns sorted unique node ids including the seeds."""
    touched = np.zeros(g.num_nodes, bool)
    touched[np.asarray(nodes, np.int64)] = True
    if hops > 0 and g.num_edges:
        e = g.edges()
        for _ in range(hops):
            before = int(touched.sum())
            touched[e[touched[e[:, 0]], 1]] = True
            touched[e[touched[e[:, 1]], 0]] = True
            if int(touched.sum()) == before:
                break
    return np.flatnonzero(touched)


def fold_in_place(g: Graph, log: GraphUpdateLog, from_seq: int,
                  upto_seq: Optional[int] = None, *,
                  hops: int = 0) -> tuple:
    """Fold ``(from_seq, upto_seq]`` into ``g`` BY MUTATION and return
    ``(delta, frontier)``.

    The shared ``Graph`` object's CSR arrays and feature matrix are
    replaced in place, so every holder of the same object — samplers,
    feature stores, caches, trainers — observes the post-update graph
    without any reference re-plumbing (feature reads are live by
    construction; structural readers must still be told via their
    ``apply_delta``-style hooks).

    ``frontier`` is the sorted union of the ``hops``-hop neighborhoods of
    the touched nodes on the PRE-update and POST-update graphs — the set
    of nodes whose k-hop computation tree can differ, i.e. exactly what
    an embedding cache must invalidate for delta == rebuild to hold.
    """
    upto = log.last_seq if upto_seq is None else upto_seq
    delta = log.delta(from_seq, upto)
    pre = (k_hop_nodes(g, delta.nodes, hops) if len(delta.nodes)
           else np.zeros(0, np.int64))
    new_g = log.apply(g, upto, from_seq=from_seq)
    g.row_ptr = new_g.row_ptr
    g.col_idx = new_g.col_idx
    if new_g.features is not None:
        g.features = new_g.features
    post = (k_hop_nodes(g, delta.nodes, hops) if len(delta.nodes)
            else np.zeros(0, np.int64))
    return delta, np.union1d(pre, post)


def synthesize_updates(g: Graph, n_events: int, *, seed: int = 0,
                       feature_frac: float = 0.5,
                       log: Optional[GraphUpdateLog] = None
                       ) -> GraphUpdateLog:
    """Generate a deterministic synthetic update stream against ``g``:
    ``feature_frac`` of the events perturb a random node's feature row,
    the rest alternate edge additions (random non-self pairs) and
    removals of edges present in ``g`` — the stream the dynamic bench
    and dev-smoke stage replay.  Appends into ``log`` when given."""
    rng = np.random.default_rng(seed)
    out = log if log is not None else GraphUpdateLog()
    e = g.edges()
    for i in range(n_events):
        if g.features is not None and rng.random() < feature_frac:
            node = int(rng.integers(g.num_nodes))
            row = g.features[node] + rng.normal(
                scale=0.1, size=g.features.shape[1]).astype(np.float32)
            out.update_features(node, row)
        elif i % 2 == 0 or not len(e):
            u = int(rng.integers(g.num_nodes))
            v = int(rng.integers(g.num_nodes))
            if u == v:
                v = (v + 1) % g.num_nodes
            out.add_edge(u, v)
        else:
            u, v = (int(x) for x in e[rng.integers(len(e))])
            out.remove_edge(u, v)
    return out
