"""Scheduling strategies (survey §3.2.8, Table 8).

* :class:`PipelinedLoader` — AGL-style: the sampling/preprocessing stage
  runs in worker threads in parallel with model computation; after a few
  iterations training time ≈ model-compute time.
* :class:`WorkStealingPool` — GraphTheta-style work stealing over sampling
  tasks (threads steal from a shared deque).
* :func:`cost_balanced_assignment` — FlexGraph-style: assign partitions to
  workers by predicted computation cost (here: edges + vertices weighted),
  minimizing the max-load plan.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Callable, Iterable, List, Sequence

import numpy as np


class PipelinedLoader:
    """Prefetching iterator: ``sample_fn()`` runs in ``n_workers`` threads
    while the consumer trains (AGL §3.2.8: 'schedules the two stages in
    parallel')."""

    def __init__(self, sample_fn: Callable[[], object], *, depth: int = 4,
                 n_workers: int = 1):
        self.sample_fn = sample_fn
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.stop = threading.Event()
        self.idle_s = 0.0
        self.workers = [threading.Thread(target=self._work, daemon=True)
                        for _ in range(n_workers)]
        for w in self.workers:
            w.start()

    def _work(self):
        while not self.stop.is_set():
            item = self.sample_fn()
            while not self.stop.is_set():
                try:
                    self.q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self):
        return self

    def __next__(self):
        t0 = time.perf_counter()
        item = self.q.get()
        self.idle_s += time.perf_counter() - t0
        return item

    def close(self):
        """Stop and JOIN the workers: after close() returns no worker is
        mid-``sample_fn``, so any state the sampler mutates (e.g. traffic
        counters) is quiescent and safe to read exactly."""
        self.stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        for w in self.workers:
            w.join()


class WorkStealingPool:
    """Static task assignment + stealing: each worker owns a deque; when
    empty it steals from the back of the longest remaining queue."""

    def __init__(self, tasks_per_worker: Sequence[List[Callable]]):
        self.deques = [collections.deque(t) for t in tasks_per_worker]
        self.lock = threading.Lock()
        self.stolen = 0
        self.done = 0

    def _take(self, wid: int):
        with self.lock:
            if self.deques[wid]:
                return self.deques[wid].popleft(), False
            victim = max(range(len(self.deques)),
                         key=lambda i: len(self.deques[i]))
            if self.deques[victim]:
                return self.deques[victim].pop(), True
        return None, False

    def run(self) -> dict:
        results = []

        def worker(wid):
            while True:
                task, was_stolen = self._take(wid)
                if task is None:
                    return
                r = task()
                with self.lock:
                    results.append(r)
                    self.done += 1
                    if was_stolen:
                        self.stolen += 1

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(self.deques))]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return {"wall_s": time.perf_counter() - t0, "stolen": self.stolen,
                "done": self.done, "results": results}


def cost_balanced_assignment(part_costs: np.ndarray, n_workers: int) -> np.ndarray:
    """FlexGraph-style LPT (longest-processing-time) assignment of partition
    costs to workers; returns worker id per partition."""
    order = np.argsort(-part_costs)
    load = np.zeros(n_workers)
    assign = np.zeros(len(part_costs), np.int32)
    for p in order:
        w = int(np.argmin(load))
        assign[p] = w
        load[w] += part_costs[p]
    return assign


def predict_partition_cost(num_vertices: np.ndarray, num_edges: np.ndarray,
                           feat_dim: int, hidden: int) -> np.ndarray:
    """FlexGraph's per-partition GNN cost model: vertex term (dense matmul)
    + edge term (aggregation traffic)."""
    return (num_vertices * feat_dim * hidden + num_edges * feat_dim
            ).astype(np.float64)
