"""Parameter coordination (survey §3.2.9 / §2.3.3).

* ``decentralized`` — all-reduce (``lax.pmean``) of gradients, every
  replica applies the update (MALT/CROSSBOW/DistGNN lineage).  This is the
  TPU-native path.
* ``parameter_server`` — emulation of the centralized scheme (DistBelief /
  AGL): gradients are *gathered* to the root slice, the root applies the
  update, parameters are *broadcast* back.  On an all-reduce-optimal torus
  this moves more bytes than the decentralized scheme — the experiment in
  benchmarks/bench_coordination.py quantifies exactly that (the survey's
  "single point of failure / bottleneck" claim, §2.3.3).

Both are expressed inside shard_map over axis "g".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

AXIS = "g"


def allreduce_update(optimizer, params, grads, opt_state):
    """Decentralized: pmean grads, everyone updates (identical replicas)."""
    grads = jax.tree.map(lambda g: jax.lax.pmean(g, AXIS), grads)
    return optimizer.apply(params, grads, opt_state)


def parameter_server_update(optimizer, params, grads, opt_state):
    """Centralized PS emulation: all_gather grads to every device (the
    gather-to-root traffic), root computes the update, broadcast via
    masked psum (the broadcast traffic)."""
    idx = jax.lax.axis_index(AXIS)

    # gather: root receives every worker's gradient (others' copies are the
    # emulation artifact of SPMD — traffic matches PS ingest)
    gathered = jax.tree.map(lambda g: jax.lax.all_gather(g, AXIS), grads)
    mean_g = jax.tree.map(lambda g: jnp.mean(g, axis=0), gathered)

    new_params, new_opt = optimizer.apply(params, mean_g, opt_state)

    # root broadcasts: zero out non-root contributions and psum
    is_root = (idx == 0).astype(jnp.float32)

    def bcast(x):
        return jax.lax.psum(x * is_root.astype(x.dtype), AXIS)

    new_params = jax.tree.map(bcast, new_params)
    new_opt = jax.tree.map(
        lambda x: bcast(x) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, new_opt)
    return new_params, new_opt


COORDINATORS = {
    "decentralized": allreduce_update,
    "parameter_server": parameter_server_update,
}
