"""Sampling strategies for mini-batch GNN training (survey §3.2.2, Table 4).

All samplers are host-side (numpy) and deterministic under a seed, mirroring
the surveyed systems where sampling workers run on CPU (DistDGL, AGL).
They emit fixed-shape, padded :class:`Block`s so every mini-batch hits the
same jit cache entry (a TPU adaptation: the surveyed GPU systems use ragged
buffers; XLA wants static shapes — recorded in DESIGN.md).

A k-layer mini-batch is a list of ``Block``s, innermost first:
block[i] maps features over layer i: dst nodes aggregate from src nodes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.graph.structure import Graph


@dataclasses.dataclass
class Block:
    """Bipartite computation block (DGL 'nodeflow' style), padded.

    src_nodes: (S,) global ids of source nodes (padded with -1)
    dst_nodes: (D,) global ids of destination nodes (padded with -1)
    edge_src:  (E,) local src index per edge (padded 0)
    edge_dst:  (E,) local dst index per edge (padded 0)
    edge_mask: (E,) validity
    NOTE: dst nodes are ALWAYS a prefix of src nodes (self features flow).
    """
    src_nodes: np.ndarray
    dst_nodes: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray

    @property
    def num_src(self) -> int:
        return len(self.src_nodes)

    @property
    def num_dst(self) -> int:
        return len(self.dst_nodes)


@dataclasses.dataclass
class MiniBatch:
    blocks: List[Block]          # innermost (layer-0) first
    seeds: np.ndarray            # (B,) target nodes (== blocks[-1].dst_nodes)
    input_nodes: np.ndarray      # == blocks[0].src_nodes


def _pad_to(a: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full((n,), fill, a.dtype)
    out[:len(a)] = a[:n]
    return out


def _build_block(g: Graph, dst: np.ndarray, src_extra: np.ndarray,
                 edges: np.ndarray, src_cap: int, edge_cap: int) -> Block:
    """edges: (E,2) [src_global, dst_global]; src = dst ∪ extra (dst prefix)."""
    src = np.concatenate([dst, np.setdiff1d(src_extra, dst)])
    src = src[:src_cap]
    lookup_src = {v: i for i, v in enumerate(src)}
    lookup_dst = {v: i for i, v in enumerate(dst)}
    es, ed, keep = [], [], []
    for s, d in edges:
        si = lookup_src.get(s)
        di = lookup_dst.get(d)
        if si is not None and di is not None:
            es.append(si)
            ed.append(di)
    es = np.asarray(es[:edge_cap], np.int32)
    ed = np.asarray(ed[:edge_cap], np.int32)
    mask = np.zeros(edge_cap, bool)
    mask[:len(es)] = True
    return Block(
        src_nodes=_pad_to(src.astype(np.int64), src_cap, -1),
        dst_nodes=dst.astype(np.int64),
        edge_src=_pad_to(es, edge_cap, 0),
        edge_dst=_pad_to(ed, edge_cap, 0),
        edge_mask=mask,
    )


def sample_block_padded(g: Graph, gr: Graph, dst: np.ndarray, fanout: int,
                        rng_for, *, expand: np.ndarray = None,
                        picker=None) -> Block:
    """One fixed-shape layer expansion (the serving-path primitive).

    Unlike the training samplers above, ``dst`` here is a PADDED id array
    (-1 marks an empty slot) and the emitted block's shapes depend only on
    ``(len(dst), fanout)``: src_cap = D*(1+fanout), edge_cap = D*fanout.
    Every batch drawn from the same bucket therefore hits the same jit
    cache entry.

    ``rng_for(node)`` must return a Generator for that node so a node's
    sampled neighborhood is stable across requests (cache consistency).
    ``expand`` (bool, aligned with ``dst``) restricts which dst nodes get
    edges — serving skips expansion for embedding-cache hits.
    ``picker(node, nbr)``, when given, replaces the per-node rng pick
    entirely (the delta-aware samplers memoize picks through it; any
    picker must stay a pure function of ``(node, nbr)`` to preserve the
    determinism contract).
    """
    dst = np.asarray(dst, np.int64)
    dcap = len(dst)
    valid = dst >= 0
    real = dst[valid]
    if len(np.unique(real)) != len(real):
        # _build_block's slot lookup maps each id to ONE slot; duplicate
        # dst ids would leave the other slots silently edge-less
        raise ValueError("padded dst ids must be unique (dedup upstream)")
    if expand is not None:
        valid = valid & expand
    edges, srcs = [], []
    for d in dst[valid]:
        nbr = gr.neighbors(int(d))
        if len(nbr) == 0:
            continue
        if picker is not None:
            pick = picker(int(d), nbr)
        else:
            rng = rng_for(int(d))
            pick = nbr if len(nbr) <= fanout else rng.choice(
                nbr, fanout, replace=False)
        for s in pick:
            edges.append((int(s), int(d)))
        srcs.append(np.asarray(pick, np.int64))
    src_extra = (np.unique(np.concatenate(srcs))
                 if srcs else np.zeros(0, np.int64))
    return _build_block(
        g, dst, src_extra,
        np.asarray(edges, np.int64).reshape(-1, 2),
        dcap * (1 + fanout), dcap * fanout)


# ===========================================================================
# neighbor sampling (GraphSAGE)
# ===========================================================================

class NeighborSampler:
    """Fixed-fanout neighbor sampling [GraphSAGE, Hamilton+ 2017].

    For each layer (outermost last) sample ``fanout`` in-neighbors per dst
    node (with replacement if deg < fanout; missing → dropped via mask)."""

    name = "neighbor"

    def __init__(self, g: Graph, fanouts: Sequence[int], *, seed: int = 0):
        self.g = g
        self.gr = g.reverse()      # need in-neighbors
        self.fanouts = list(fanouts)
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> MiniBatch:
        seeds = np.asarray(seeds, np.int64)
        blocks: List[Block] = []
        dst = seeds
        for layer in reversed(range(len(self.fanouts))):
            f = self.fanouts[layer]
            srcs, edges = [], []
            for d in dst:
                nbr = self.gr.neighbors(d)   # in-neighbors of d
                if len(nbr) == 0:
                    continue
                pick = nbr if len(nbr) <= f else self.rng.choice(
                    nbr, f, replace=False)
                for s in pick:
                    edges.append((s, d))
                srcs.append(pick)
            src_extra = (np.unique(np.concatenate(srcs))
                         if srcs else np.zeros(0, np.int64))
            src_cap = len(dst) + len(dst) * f
            blocks.append(_build_block(
                self.g, dst, src_extra,
                np.asarray(edges, np.int64).reshape(-1, 2),
                src_cap, len(dst) * f))
            dst = blocks[-1].src_nodes[blocks[-1].src_nodes >= 0]
        blocks.reverse()
        return MiniBatch(blocks, seeds, blocks[0].src_nodes)


# ===========================================================================
# importance / layer-wise sampling (PinSage / FastGCN / LADIES)
# ===========================================================================

class ImportanceSampler(NeighborSampler):
    """PinSage-style: score neighbors by short-random-walk visit counts and
    keep the top-``fanout`` instead of a uniform pick."""

    name = "importance"

    def __init__(self, g: Graph, fanouts, *, walk_len: int = 2,
                 n_walks: int = 8, seed: int = 0):
        super().__init__(g, fanouts, seed=seed)
        self.walk_len = walk_len
        self.n_walks = n_walks

    def _walk_scores(self, d: int) -> tuple:
        counts: dict = {}
        for _ in range(self.n_walks):
            v = d
            for _ in range(self.walk_len):
                nbr = self.gr.neighbors(v)
                if len(nbr) == 0:
                    break
                v = int(self.rng.choice(nbr))
                counts[v] = counts.get(v, 0) + 1
        return counts

    def sample(self, seeds: np.ndarray) -> MiniBatch:
        seeds = np.asarray(seeds, np.int64)
        blocks: List[Block] = []
        dst = seeds
        for layer in reversed(range(len(self.fanouts))):
            f = self.fanouts[layer]
            edges = []
            for d in dst:
                scores = self._walk_scores(int(d))
                top = sorted(scores, key=scores.get, reverse=True)[:f]
                for s in top:
                    edges.append((s, d))
            e = np.asarray(edges, np.int64).reshape(-1, 2)
            src_extra = np.unique(e[:, 0]) if len(e) else np.zeros(0, np.int64)
            blocks.append(_build_block(self.g, dst, src_extra, e,
                                       len(dst) * (1 + f), len(dst) * f))
            dst = blocks[-1].src_nodes[blocks[-1].src_nodes >= 0]
        blocks.reverse()
        return MiniBatch(blocks, seeds, blocks[0].src_nodes)


class LayerWiseSampler:
    """FastGCN [Chen+ 2018] (``dependent=False``) and LADIES [Zou+ 2019]
    (``dependent=True``): sample a fixed node budget per layer with
    probability ∝ (in-)degree; LADIES restricts candidates to the union of
    neighbors of the previous layer (layer-dependent)."""

    def __init__(self, g: Graph, layer_sizes: Sequence[int], *,
                 dependent: bool = True, seed: int = 0):
        self.g = g
        self.gr = g.reverse()
        self.layer_sizes = list(layer_sizes)
        self.dependent = dependent
        self.rng = np.random.default_rng(seed)
        deg = g.in_degree().astype(np.float64) + 1.0
        self.prob = deg / deg.sum()
        self.name = "ladies" if dependent else "fastgcn"

    def sample(self, seeds: np.ndarray) -> MiniBatch:
        seeds = np.asarray(seeds, np.int64)
        blocks: List[Block] = []
        dst = seeds
        for layer in reversed(range(len(self.layer_sizes))):
            budget = self.layer_sizes[layer]
            if self.dependent:
                cand = np.unique(np.concatenate(
                    [self.gr.neighbors(d) for d in dst]
                    + [np.zeros(0, np.int64)]))
            else:
                cand = np.arange(self.g.num_nodes)
            if len(cand) == 0:
                cand = dst
            p = self.prob[cand]
            p = p / p.sum()
            n_pick = min(budget, len(cand))
            picked = self.rng.choice(cand, n_pick, replace=False, p=p)
            # connect: edges from picked -> dst that exist in g
            edges = []
            pick_set = set(picked.tolist())
            for d in dst:
                for s in self.gr.neighbors(d):
                    if int(s) in pick_set:
                        edges.append((int(s), int(d)))
            e = np.asarray(edges, np.int64).reshape(-1, 2)
            blocks.append(_build_block(
                self.g, dst, picked, e, len(dst) + budget,
                max(len(e), 1)))
            dst = blocks[-1].src_nodes[blocks[-1].src_nodes >= 0]
        blocks.reverse()
        return MiniBatch(blocks, seeds, blocks[0].src_nodes)


# ===========================================================================
# subgraph sampling (ClusterGCN / GraphSAINT)
# ===========================================================================

def bfs_clusters(g: Graph, n_clusters: int, *, seed: int = 0) -> np.ndarray:
    """Cheap METIS stand-in: multi-source BFS growth from random centers
    (balanced-ish, locality-preserving).  Returns (N,) cluster ids."""
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    centers = rng.choice(n, n_clusters, replace=False)
    assign = -np.ones(n, np.int64)
    frontier = [[c] for c in centers]
    assign[centers] = np.arange(n_clusters)
    active = True
    while active:
        active = False
        for cid in range(n_clusters):
            nxt = []
            for v in frontier[cid]:
                for u in g.neighbors(v):
                    if assign[u] < 0:
                        assign[u] = cid
                        nxt.append(int(u))
            frontier[cid] = nxt
            active = active or bool(nxt)
    unassigned = np.flatnonzero(assign < 0)
    assign[unassigned] = rng.integers(0, n_clusters, len(unassigned))
    return assign


class ClusterSampler:
    """ClusterGCN [Chiang+ 2019]: mini-batch = union of q random clusters;
    training runs on the induced subgraph."""

    name = "cluster"

    def __init__(self, g: Graph, n_clusters: int, clusters_per_batch: int,
                 *, seed: int = 0):
        self.g = g
        self.assign = bfs_clusters(g, n_clusters, seed=seed)
        self.q = clusters_per_batch
        self.n_clusters = n_clusters
        self.rng = np.random.default_rng(seed + 1)

    def sample_subgraph(self):
        cids = self.rng.choice(self.n_clusters, self.q, replace=False)
        nodes = np.flatnonzero(np.isin(self.assign, cids))
        return nodes, self.g.subgraph(nodes)


class SaintRWSampler:
    """GraphSAINT [Zeng+ 2019] random-walk sampler: roots + fixed-length
    walks induce the subgraph; builds a full GCN per subgraph."""

    name = "saint_rw"

    def __init__(self, g: Graph, n_roots: int, walk_len: int, *,
                 seed: int = 0):
        self.g = g
        self.n_roots = n_roots
        self.walk_len = walk_len
        self.rng = np.random.default_rng(seed)

    def sample_subgraph(self):
        roots = self.rng.choice(self.g.num_nodes, self.n_roots, replace=False)
        nodes = set(roots.tolist())
        for r in roots:
            v = int(r)
            for _ in range(self.walk_len):
                nbr = self.g.neighbors(v)
                if len(nbr) == 0:
                    break
                v = int(self.rng.choice(nbr))
                nodes.add(v)
        nodes = np.asarray(sorted(nodes), np.int64)
        return nodes, self.g.subgraph(nodes)


def neighborhood_growth(g: Graph, seeds: np.ndarray, hops: int) -> List[int]:
    """|k-hop neighborhood| per hop — quantifies the 'neighborhood
    explosion' the survey motivates sampling with (§3.2.2)."""
    cur = set(np.asarray(seeds).tolist())
    sizes = [len(cur)]
    gr = g.reverse()
    for _ in range(hops):
        nxt = set(cur)
        for v in cur:
            nxt.update(gr.neighbors(v).tolist())
        cur = nxt
        sizes.append(len(cur))
    return sizes
