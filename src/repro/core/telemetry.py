"""Unified telemetry plane: metrics registry, span tracing, Prometheus text.

The survey compares distributed GNN systems on communication volume,
staleness, cache effectiveness, and per-stage latency — exactly the
quantities this repo computes but historically scattered across ad-hoc
counters (``Transport.payload_bytes``, ``EmbeddingCache.hits``,
``ServeStats`` latency lists).  This module is the one place those
numbers flow through:

* :class:`MetricsRegistry` — process-local registry of :class:`Counter`,
  :class:`Gauge`, and :class:`Histogram` metrics keyed by
  ``(name, labels)``.  Asking twice for the same key returns the same
  instance, so independent subsystems (e.g. every
  :class:`~repro.core.comm.Transport` on one path) aggregate into one
  series.  The whole plane sits behind a global enable flag: a record
  against a disabled registry costs one attribute read and one branch.
* :class:`Histogram` — fixed log-spaced buckets for Prometheus
  exposition *plus* the raw samples, so :meth:`Histogram.quantile` is
  exact (``numpy``-style linear interpolation, property-tested against
  ``numpy.percentile``).
* :class:`Tracer` — a lightweight span tracer:
  ``with span("serve.batch"):`` nests via a thread-local stack and each
  span may carry its own clock (``clock=``), which is how serving's
  *virtual* clock produces spans in simulated time.  Export is JSONL,
  one event per line (schema in ``docs/observability.md``).
* :meth:`MetricsRegistry.to_prometheus` — Prometheus text-format
  exposition (``# HELP`` / ``# TYPE`` + cumulative ``_bucket``/``_sum``/
  ``_count`` series); :func:`parse_prometheus` is the matching
  stdlib-only validator the smoke stages use.
* :meth:`MetricsRegistry.snapshot` — a plain-dict view for benchmarks
  and SLO assertions (``BENCH_serving.json``).

Instrumented producers: the communication plane
(:class:`~repro.core.comm.Transport` per-(path, codec) byte/row/send
counters), caching (:class:`~repro.core.caching.FeatureStore` and
:class:`~repro.serving.cache.EmbeddingCache` hit/miss counters), halos
(:class:`~repro.core.halo.HaloExchange` refresh rows, ghost-age
histogram, staleness-violation guard), serving
(:class:`~repro.serving.server.GNNInferenceServer` queue depth, batch
occupancy, latency histograms, virtual-clock spans), training step-time
histograms and prefetcher stall time, and kernel dispatch counters
(:mod:`repro.kernels.ops`).  Enable with ``--metrics-out`` /
``--trace-out`` on ``launch/{train_gnn,serve_gnn}.py`` or
:func:`set_enabled`.
"""
from __future__ import annotations

import json
import math
import re
import threading
import time
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")
# Prometheus exposition lines: `name{label="v",...} value` (labels optional)
_PROM_SAMPLE_RE = re.compile(
    r'([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+([^\s]+)$')
_PROM_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def log_buckets(lo: float, hi: float, per_decade: int = 4) -> Tuple[float, ...]:
    """Fixed log-spaced bucket upper bounds covering ``[lo, hi]`` with
    ``per_decade`` buckets per decade — the one bucket-layout generator,
    so every histogram in the repo is comparable."""
    if not (lo > 0 and hi > lo and per_decade >= 1):
        raise ValueError("need 0 < lo < hi and per_decade >= 1")
    n = int(math.ceil(math.log10(hi / lo) * per_decade)) + 1
    return tuple(lo * 10 ** (i / per_decade) for i in range(n))


# seconds: 10 µs .. 100 s, 4/decade — covers batch compute through epochs
DEFAULT_TIME_BUCKETS = log_buckets(1e-5, 1e2, 4)
# dimensionless small ints (ages, depths, occupancies): 1 .. 1e4
DEFAULT_COUNT_BUCKETS = log_buckets(1.0, 1e4, 4)


class _Metric:
    """Base: a named, labeled series owned by (at most) one registry.

    ``registry=None`` builds a *standalone* always-on metric (e.g. the
    :class:`~repro.serving.server.ServeStats` latency histogram, which
    must record regardless of the global telemetry flag); a
    registry-owned metric records only while the registry is enabled —
    the one branch per record the module docstring promises.
    """

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None,
                 registry: Optional["MetricsRegistry"] = None):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = help
        self.labels: Dict[str, str] = {k: str(v)
                                       for k, v in (labels or {}).items()}
        self._reg = registry

    @property
    def _on(self) -> bool:
        reg = self._reg
        return reg is None or reg.enabled


class Counter(_Metric):
    """Monotonically increasing count (bytes, rows, hits, dispatches)."""

    kind = "counter"

    def __init__(self, name: str, help: str = "", labels=None, registry=None):
        super().__init__(name, help, labels, registry)
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        """Add ``amount`` (must be >= 0) to the count; no-op while the
        owning registry is disabled."""
        if not self._on:
            return
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        self.value += amount

    def reset(self) -> None:
        """Zero the count (warmup exclusion; see ``Transport.reset_counters``)."""
        self.value = 0.0


class Gauge(_Metric):
    """A value that can go up and down (queue depth, modeled bytes/call)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "", labels=None, registry=None):
        super().__init__(name, help, labels, registry)
        self.value = 0.0

    def set(self, value: float) -> None:
        """Set the gauge; no-op while the owning registry is disabled."""
        if self._on:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        """Add ``amount`` (may be negative)."""
        if self._on:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        """Subtract ``amount``."""
        self.inc(-amount)

    def reset(self) -> None:
        """Zero the gauge."""
        self.value = 0.0


class Histogram(_Metric):
    """Distribution metric: fixed log-spaced buckets + bounded-memory
    exact-then-estimated quantiles.

    Bucket counts feed the Prometheus exposition (cumulative ``_bucket``
    series with ``+Inf``) and are always exact.  Raw samples are kept
    alongside in a *bounded reservoir* of ``max_samples`` float32 values
    (Vitter's Algorithm R, fixed-seed rng for reproducibility): while the
    observation count is at or below the cap, :meth:`quantile`
    interpolates exactly like ``numpy.percentile`` (linear); past the
    cap, every past observation has equal probability of occupying a
    reservoir slot and :meth:`quantile` is an unbiased *estimate* over
    that uniform subsample (``saturated`` reports which regime the
    histogram is in).  ``sum``/``count`` and the bucket counts stay exact
    regardless — only the raw-sample memory is bounded, fixing the
    unbounded growth the pre-reservoir implementation had under
    sustained serving traffic.
    """

    kind = "histogram"

    # default raw-sample cap: 64Ki float32 = 256 KiB per series, far above
    # this repo's test/bench run lengths (those stay exact) and a hard
    # bound under production-length traffic
    DEFAULT_MAX_SAMPLES = 65536

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                 labels=None, registry=None,
                 max_samples: int = DEFAULT_MAX_SAMPLES):
        super().__init__(name, help, labels, registry)
        b = tuple(sorted(float(x) for x in buckets))
        if not b or any(x <= 0 for x in b):
            raise ValueError("buckets must be positive and non-empty")
        if max_samples < 1:
            raise ValueError("max_samples must be >= 1")
        self.buckets = b
        self.bucket_counts = np.zeros(len(b), np.int64)
        self.sum = 0.0
        self.max_samples = int(max_samples)
        self._samples: List[np.ndarray] = []
        self._n_samples = 0              # rows held across self._samples
        self._rng = np.random.default_rng(0)
        self.count = 0

    @property
    def saturated(self) -> bool:
        """True once the reservoir has been capped — quantiles are
        estimates over a uniform subsample from here on."""
        return self.count > self.max_samples

    def _reservoir_insert(self, v: np.ndarray, start_t: int) -> None:
        """Algorithm R: fold new values into the full reservoir.
        ``start_t`` is the 1-based observation index of ``v[0]``."""
        res = self.samples                     # consolidates to one array
        t = start_t + np.arange(len(v))        # observation index of each
        j = (self._rng.random(len(v)) * t).astype(np.int64)
        keep = j < self.max_samples
        # later duplicates of one slot overwrite earlier ones — the same
        # outcome as processing the stream one element at a time
        res[j[keep]] = v[keep]

    def _record(self, v: np.ndarray) -> None:
        """Shared bucket/sum/reservoir update for one batch of values."""
        self.sum += float(v.sum())
        idx = np.searchsorted(self.buckets, v, side="left")
        np.add.at(self.bucket_counts, idx[idx < len(self.buckets)], 1)
        room = self.max_samples - self._n_samples
        head, tail = v[:room], v[room:]
        if len(head):
            self._samples.append(head.astype(np.float32))
            self._n_samples += len(head)
        if len(tail):
            self._reservoir_insert(tail.astype(np.float32),
                                   self.count + len(head) + 1)
        self.count += len(v)

    def observe(self, value: float) -> None:
        """Record one sample; no-op while the owning registry is disabled."""
        if not self._on:
            return
        self._record(np.array([value], np.float64))

    def observe_batch(self, values: np.ndarray) -> None:
        """Vectorized :meth:`observe` for per-row quantities (e.g. the
        ghost-age distribution of a whole refresh plan in one call)."""
        if not self._on:
            return
        v = np.asarray(values, np.float64).ravel()
        if len(v):
            self._record(v)

    @property
    def samples(self) -> np.ndarray:
        """The retained raw samples (float32).  Below the reservoir cap
        this is every observation in observation order; above it, a
        uniform ``max_samples``-sized subsample of the stream."""
        if not self._samples:
            return np.zeros(0, np.float32)
        if len(self._samples) > 1:
            self._samples = [np.concatenate(self._samples)]
        return self._samples[0]

    def quantile(self, q: float) -> float:
        """``q``-quantile of the retained samples (numpy linear
        interpolation; 0.0 when empty).  Exact until the reservoir
        saturates (``count > max_samples``), an unbiased estimate after."""
        s = self.samples
        return float(np.quantile(s, q)) if len(s) else 0.0

    def cumulative_buckets(self) -> List[Tuple[float, int]]:
        """Prometheus-style cumulative ``(le, count)`` pairs ending with
        ``(+inf, count)``."""
        cum = np.cumsum(self.bucket_counts)
        out = [(le, int(c)) for le, c in zip(self.buckets, cum)]
        out.append((math.inf, self.count))
        return out

    def reset(self) -> None:
        """Drop all samples and bucket counts (the reservoir cap and rng
        state survive — a reset histogram starts a fresh exact regime)."""
        self.bucket_counts[:] = 0
        self.sum = 0.0
        self.count = 0
        self._samples = []
        self._n_samples = 0


class SpanError(RuntimeError):
    """Raised on malformed tracer usage (exit without matching enter)."""


class Tracer:
    """Nesting span tracer with pluggable clocks and JSONL export.

    ``with tracer.span("serve.batch", bucket=16):`` records one event on
    exit: ``{seq, name, ts, dur, depth, parent, attrs}`` where ``ts`` is
    the span's start on its clock, ``depth`` the nesting level (0 = root)
    and ``parent`` the enclosing span's name (``None`` at the root).  The
    stack is thread-local, so prefetcher-thread spans nest independently
    of the main thread's.

    Clocks: the default is ``time.perf_counter`` (wall).  A span may
    override with ``clock=``, which is how serving traces in *virtual*
    time — the server passes a callable that maps wall progress onto its
    simulated clock, so queueing delay and compute show up on the same
    axis as the reported p50/p99 (see
    ``GNNInferenceServer._virtual_now``).

    Recording is gated on the owning registry's enable flag (one branch
    per span); a disabled tracer's ``span`` still yields, costing only
    the context-manager machinery.
    """

    def __init__(self, registry: Optional["MetricsRegistry"] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self._reg = registry
        self.clock = clock
        self.events: List[dict] = []
        self._local = threading.local()
        self._seq = 0

    @property
    def _on(self) -> bool:
        reg = self._reg
        return reg is None or reg.enabled

    def _stack(self) -> List[str]:
        if not hasattr(self._local, "stack"):
            self._local.stack = []
        return self._local.stack

    @contextmanager
    def span(self, name: str, clock: Optional[Callable[[], float]] = None,
             **attrs):
        """Context manager recording one span event on exit (see class
        docstring for the event schema)."""
        if not self._on:
            yield
            return
        clk = clock or self.clock
        stack = self._stack()
        parent = stack[-1] if stack else None
        depth = len(stack)
        stack.append(name)
        t0 = clk()
        try:
            yield
        finally:
            dur = clk() - t0
            popped = stack.pop()
            if popped != name:
                raise SpanError(f"span stack corrupted: popped {popped!r}, "
                                f"expected {name!r}")
            self.events.append({
                "seq": self._seq, "name": name, "ts": t0, "dur": dur,
                "depth": depth, "parent": parent,
                "attrs": {k: v for k, v in attrs.items()},
            })
            self._seq += 1

    def export_jsonl(self, path: str) -> int:
        """Write one JSON object per event line; returns the event count."""
        with open(path, "w", encoding="utf-8") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")
        return len(self.events)

    def reset(self) -> None:
        """Drop recorded events (the per-thread stacks survive — resetting
        mid-span keeps nesting coherent for later events)."""
        self.events = []
        self._seq = 0


class MetricsRegistry:
    """Process-local registry: the one place every subsystem's counters,
    gauges, histograms, and spans live.

    ``counter/gauge/histogram(name, **labels)`` get-or-create: the same
    ``(name, labels)`` key always returns the same instance, so two
    :class:`~repro.core.comm.Transport` objects on the same path
    aggregate into one series — the behavior the cross-Transport
    aggregation test pins.  A name must keep one metric kind across all
    label sets.

    ``enabled=False`` makes every record on every owned metric (and every
    span of the owned :class:`Tracer`) a single-branch no-op; flip it
    with :func:`set_enabled` (module level) or ``registry.enabled``.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: Dict[Tuple[str, LabelKey], _Metric] = {}
        self._kinds: Dict[str, str] = {}
        self._help: Dict[str, str] = {}
        self._lock = threading.Lock()
        self.tracer = Tracer(registry=self)

    # -- get-or-create -----------------------------------------------------
    def _get(self, cls, name: str, help: str, labels: Dict[str, str],
             **kwargs) -> _Metric:
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                if self._kinds.setdefault(name, cls.kind) != cls.kind:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{self._kinds[name]}, not {cls.kind}")
                m = cls(name, help, labels=labels, registry=self, **kwargs)
                self._metrics[key] = m
                if help:
                    self._help.setdefault(name, help)
            return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        """Get-or-create the :class:`Counter` for ``(name, labels)``."""
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        """Get-or-create the :class:`Gauge` for ``(name, labels)``."""
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                  max_samples: int = Histogram.DEFAULT_MAX_SAMPLES,
                  **labels) -> Histogram:
        """Get-or-create the :class:`Histogram` for ``(name, labels)``
        (``buckets``/``max_samples`` apply only on first creation)."""
        return self._get(Histogram, name, help, labels, buckets=buckets,
                         max_samples=max_samples)

    def span(self, name: str, clock=None, **attrs):
        """Shorthand for ``registry.tracer.span(...)``."""
        return self.tracer.span(name, clock=clock, **attrs)

    # -- reads -------------------------------------------------------------
    def collect(self) -> List[_Metric]:
        """All registered metrics, sorted by ``(name, labels)``."""
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def value(self, name: str, **labels) -> float:
        """Value of one counter/gauge series (0.0 if never registered)."""
        m = self._metrics.get((name, _label_key(labels)))
        return float(m.value) if m is not None else 0.0

    def total(self, name: str, **label_filter) -> float:
        """Sum of every counter/gauge series named ``name`` whose labels
        contain ``label_filter`` — e.g. ``total("comm_bytes_total",
        path="serving.features")`` sums payload and header kinds."""
        want = set(_label_key(label_filter))
        return float(sum(
            m.value for m in self.collect()
            if m.name == name and not isinstance(m, Histogram)
            and want <= set(_label_key(m.labels))))

    def get_histogram(self, name: str, **labels) -> Optional[Histogram]:
        """The histogram for ``(name, labels)`` or ``None``."""
        m = self._metrics.get((name, _label_key(labels)))
        return m if isinstance(m, Histogram) else None

    def snapshot(self) -> dict:
        """Plain-dict view of every series — counters/gauges as values,
        histograms as ``{count, sum, p50, p99}`` — keyed by name then by
        a ``k=v,...`` label string (``""`` for unlabeled)."""
        out: Dict[str, dict] = {}
        for m in self.collect():
            lk = ",".join(f"{k}={v}" for k, v in _label_key(m.labels))
            entry = out.setdefault(m.name, {"kind": m.kind, "series": {}})
            if isinstance(m, Histogram):
                entry["series"][lk] = {
                    "count": m.count, "sum": m.sum,
                    "p50": m.quantile(0.50), "p99": m.quantile(0.99)}
            else:
                entry["series"][lk] = m.value
        return out

    # -- exposition --------------------------------------------------------
    @staticmethod
    def _fmt_labels(labels: Dict[str, str], extra: str = "") -> str:
        parts = [f'{k}="{v}"' for k, v in _label_key(labels)]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def to_prometheus(self) -> str:
        """Prometheus text-format exposition of every registered series."""
        by_name: Dict[str, List[_Metric]] = {}
        for m in self.collect():
            by_name.setdefault(m.name, []).append(m)
        lines: List[str] = []
        for name in sorted(by_name):
            ms = by_name[name]
            help_ = self._help.get(name) or ms[0].help
            if help_:
                lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {ms[0].kind}")
            for m in ms:
                if isinstance(m, Histogram):
                    for le, c in m.cumulative_buckets():
                        le_s = "+Inf" if math.isinf(le) else repr(le)
                        lab = self._fmt_labels(m.labels,
                                               'le="%s"' % le_s)
                        lines.append(f"{name}_bucket{lab} {c}")
                    lab = self._fmt_labels(m.labels)
                    lines.append(f"{name}_sum{lab} {repr(m.sum)}")
                    lines.append(f"{name}_count{lab} {m.count}")
                else:
                    v = m.value
                    v_s = repr(v) if v != int(v) else str(int(v))
                    lines.append(f"{name}{self._fmt_labels(m.labels)} {v_s}")
        return "\n".join(lines) + ("\n" if lines else "")

    def write_prometheus(self, path: str) -> None:
        """Write :meth:`to_prometheus` output to ``path``."""
        with open(path, "w", encoding="utf-8") as f:
            f.write(self.to_prometheus())

    def reset(self) -> None:
        """Zero every metric and drop trace events (metric identities and
        bucket layouts survive — warmup exclusion, not teardown)."""
        for m in self.collect():
            m.reset()
        self.tracer.reset()


# ---------------------------------------------------------------------------
# the module-level default registry (the instrumented hot paths' sink)
# ---------------------------------------------------------------------------

# Disabled by default: an uninstrumented run pays one branch per record.
# Launchers enable it when --metrics-out/--trace-out is passed; tests and
# benches via set_enabled(True).
_REGISTRY = MetricsRegistry(enabled=False)


def get_registry() -> MetricsRegistry:
    """The process-wide default registry every instrumented path records
    into."""
    return _REGISTRY


def set_enabled(on: bool) -> bool:
    """Flip the global telemetry flag; returns the previous value."""
    prev = _REGISTRY.enabled
    _REGISTRY.enabled = bool(on)
    return prev


def enabled() -> bool:
    """Whether the default registry is recording."""
    return _REGISTRY.enabled


def counter(name: str, help: str = "", **labels) -> Counter:
    """``get_registry().counter(...)``."""
    return _REGISTRY.counter(name, help, **labels)


def gauge(name: str, help: str = "", **labels) -> Gauge:
    """``get_registry().gauge(...)``."""
    return _REGISTRY.gauge(name, help, **labels)


def histogram(name: str, help: str = "",
              buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
              **labels) -> Histogram:
    """``get_registry().histogram(...)``."""
    return _REGISTRY.histogram(name, help, buckets=buckets, **labels)


def span(name: str, clock=None, **attrs):
    """``get_registry().span(...)``."""
    return _REGISTRY.span(name, clock=clock, **attrs)


# ---------------------------------------------------------------------------
# exposition validation (stdlib-only; the obs smoke + tests use this)
# ---------------------------------------------------------------------------

def parse_prometheus(text: str) -> Dict[str, Dict[LabelKey, float]]:
    """Parse (and validate) Prometheus text format back into
    ``{series_name: {label_key: value}}``; raises ``ValueError`` on any
    malformed line.  ``series_name`` includes the ``_bucket``/``_sum``/
    ``_count`` suffixes of histogram series."""
    out: Dict[str, Dict[LabelKey, float]] = {}
    typed: Dict[str, str] = {}
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in ("counter", "gauge",
                                                   "histogram"):
                raise ValueError(f"line {i}: malformed TYPE: {line!r}")
            typed[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            if not line.startswith("# HELP "):
                raise ValueError(f"line {i}: unknown comment: {line!r}")
            continue
        m = _PROM_SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {i}: malformed sample: {line!r}")
        name, labels_s, value_s = m.groups()
        try:
            value = float(value_s.replace("+Inf", "inf"))
        except ValueError:
            raise ValueError(f"line {i}: bad value {value_s!r}")
        labels: Dict[str, str] = {}
        if labels_s:
            body = labels_s[1:-1]
            if body and not re.match(
                    r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'
                    r'(,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*$', body):
                raise ValueError(f"line {i}: malformed labels {labels_s!r}")
            labels = dict(_PROM_LABEL_RE.findall(body))
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        if base not in typed and name not in typed:
            raise ValueError(f"line {i}: sample {name!r} has no TYPE line")
        out.setdefault(name, {})[_label_key(labels)] = value
    return out


def validate_trace_jsonl(path: str) -> int:
    """Validate a trace file written by :meth:`Tracer.export_jsonl`:
    every line is a JSON object with the span schema, ``seq`` is dense
    ascending, and depths are sane.  Returns the event count."""
    n = 0
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f):
            ev = json.loads(line)
            for k in ("seq", "name", "ts", "dur", "depth", "parent",
                      "attrs"):
                if k not in ev:
                    raise ValueError(f"event {i}: missing key {k!r}")
            if ev["seq"] != i:
                raise ValueError(f"event {i}: seq {ev['seq']} not dense")
            if ev["dur"] < 0 or ev["depth"] < 0:
                raise ValueError(f"event {i}: negative dur/depth")
            if ev["depth"] == 0 and ev["parent"] is not None:
                raise ValueError(f"event {i}: root span with parent")
            if ev["depth"] > 0 and ev["parent"] is None:
                raise ValueError(f"event {i}: nested span without parent")
            n += 1
    return n
