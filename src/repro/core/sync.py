"""Synchronization modes (survey §3.2.7 / §2.2.4 / §2.3.2).

JAX SPMD programs are bulk-synchronous by construction, so the BSP mode is
the native execution.  The *effects* of the asynchronous modes the survey
catalogues are reproduced faithfully at the algorithm level:

* ``bsp``      — every step synchronizes all halos (Pregel §2.2.4).
* ``stale``    — DistGNN's delayed-partial-aggregate mode: the first-layer
  halo exchange reuses a cached feature snapshot refreshed every
  ``staleness`` steps, overlapping "communication" with computation and
  cutting per-step collective volume (§3.2.7: "the zero-/delayed-
  communication strategies are fastest with slight accuracy fluctuation").
* ``bounded``  — Dorylus/SSP-style bounded staleness: refresh when the
  step counter since last refresh reaches s (same mechanism, s > 1).

True fire-and-forget asynchrony (GraphLab) does not transfer to the TPU
SPMD model — documented in DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np


@dataclasses.dataclass
class SyncPolicy:
    mode: str = "bsp"            # bsp | stale | bounded
    staleness: int = 1           # refresh period for stale/bounded

    def needs_refresh(self, step: int) -> bool:
        if self.mode == "bsp":
            return True
        return step % max(self.staleness, 1) == 0


class HysyncController:
    """Hysync-style automatic mode switching [Xie+ 2015, §2.2.4]: monitor
    per-step progress (loss delta per unit comm) and switch between
    synchronous (staleness=1) and delayed (staleness=s) execution when the
    current mode's efficiency drops.

    Heuristic: stale mode wins while convergence is comm-bound (early,
    large loss deltas); switch to BSP when loss improvements per step fall
    below ``switch_threshold`` of the initial rate (fine-tuning phase needs
    fresh halos)."""

    def __init__(self, stale_s: int = 4, switch_threshold: float = 0.05):
        self.stale_s = stale_s
        self.threshold = switch_threshold
        self.mode = "stale"
        self.init_delta = None
        self.prev_loss = None
        self.switch_step = None

    def staleness(self) -> int:
        return self.stale_s if self.mode == "stale" else 1

    def observe(self, step: int, loss: float) -> str:
        if self.prev_loss is not None:
            delta = self.prev_loss - loss
            if self.init_delta is None and delta > 0:
                self.init_delta = delta
            if (self.mode == "stale" and self.init_delta
                    and delta < self.threshold * self.init_delta):
                self.mode = "bsp"
                self.switch_step = step
        self.prev_loss = loss
        return self.mode


class HaloCache:
    """Carries the stale full-feature snapshot between steps (host side —
    the device arrays are donated through the jitted step)."""

    def __init__(self, x_full):
        self.value = x_full
        self.last_refresh = 0
        self.refreshes = 0
        self.steps = 0

    def maybe_refresh(self, policy: SyncPolicy, step: int, fresh_value):
        self.steps += 1
        if policy.needs_refresh(step):
            self.value = fresh_value
            self.last_refresh = step
            self.refreshes += 1
        return self.value

    def comm_savings(self) -> float:
        """Fraction of halo exchanges skipped vs BSP."""
        if self.steps == 0:
            return 0.0
        return 1.0 - self.refreshes / self.steps
