"""Feature caching / inter-process communication policies (survey §3.2.4,
Table 6) plus the shared bounded-staleness version clock.

The surveyed systems cut host→device (PaGraph) or remote-machine (AliGraph)
feature traffic by caching features of vertices likely to be touched:

* :func:`degree_cache` — PaGraph: pre-sort by out-degree, fill the cache
  top-down ("a higher out-degree vertex is an in-neighbor of more nodes,
  hence sampled more often").
* :func:`importance_cache` — AliGraph: cache vertices whose importance
  (k-hop in/out-neighbor ratio) exceeds a threshold.
* :func:`no_cache` — baseline.

``FeatureStore`` plays the role of DistDGL's KVStore: a global store that
serves features and counts the bytes that would cross the interconnect —
the quantity the caching claims in EXPERIMENTS.md §Paper-validation are
measured on.  Remote rows travel through one
:class:`repro.core.comm.Transport` (the unified communication plane), so
the wire format — and therefore both the returned values and the byte
accounting — follows the selected :class:`~repro.core.comm.WireCodec`
(``fp32`` identity by default; ``bf16``/``int8`` compress).

:class:`VersionClock` / :class:`VersionedBuffer` are the *one* staleness
implementation in the repo: the serving
:class:`~repro.serving.cache.EmbeddingCache` (GNNAutoScale historical
embeddings at inference time) and the training
:class:`~repro.core.halo.HaloExchange` (staleness-bounded asynchronous
full-graph halos) both read and write through them, so "an entry written
at clock ``v`` may be served while ``clock - v <= max_staleness``" means
exactly the same thing on both paths.
"""
from __future__ import annotations

from typing import Iterable, Optional, Union

import numpy as np

# HEADER_BYTES is canonically defined by the communication plane
# (re-exported here for the subsystems that historically imported it
# from caching)
from repro.core import telemetry
from repro.core.comm import (HEADER_BYTES, QuantizedRows, Transport,
                             WireCodec)
from repro.graph.structure import Graph

# sentinel version for "never written"; large-negative (not int64 min) so
# computing ``clock - NEVER`` cannot overflow int64
NEVER = -(2 ** 62)


class VersionClock:
    """A global integer clock shared by every staleness-bounded buffer.

    One :meth:`tick` ≈ one refresh epoch (a serving feature/model refresh,
    or one asynchronous full-graph training step).  Buffers attached to
    the same clock age together — the property the cross-subsystem
    staleness tests key off.
    """

    def __init__(self) -> None:
        self.now = 0

    def tick(self, n: int = 1) -> None:
        """Advance the clock by ``n`` epochs (``n >= 1``)."""
        self.now += int(n)


class VersionedBuffer:
    """One plane of values with a per-row version under a shared clock.

    Args:
        clock: the shared :class:`VersionClock` this plane ages against.
        rows:  number of value rows (fixed; shapes never change).
        dim:   feature width of each row.
        dtype: row dtype (default float32).

    Invariants:
        * a row written at clock ``v`` has age ``clock.now - v``;
        * :meth:`fresh_mask` marks rows with ``age <= max_staleness`` —
          never-written rows (version ``NEVER``) are never fresh;
        * :meth:`write` stamps rows with the *current* clock value.
    """

    def __init__(self, clock: VersionClock, rows: int, dim: int,
                 dtype=np.float32) -> None:
        self.clock = clock
        self.values = np.zeros((rows, dim), dtype)
        self.version = np.full(rows, NEVER, np.int64)

    @property
    def rows(self) -> int:
        return len(self.version)

    def age(self, rows: Optional[np.ndarray] = None) -> np.ndarray:
        """Per-row staleness ``clock.now - version`` (huge for never-written
        rows).  ``rows`` selects a subset; default is every row."""
        v = self.version if rows is None else self.version[rows]
        return self.clock.now - v

    def fresh_mask(self, max_staleness: int,
                   rows: Optional[np.ndarray] = None) -> np.ndarray:
        """Bounded-staleness read predicate: True where the row may be
        served without violating the bound."""
        return self.age(rows) <= max_staleness

    def write(self, rows: np.ndarray, values: np.ndarray) -> None:
        """Store ``values`` at ``rows`` and stamp them with the current
        clock (``rows`` may be an index array or a boolean mask)."""
        self.values[rows] = values
        self.version[rows] = self.clock.now

    def invalidate(self, rows: np.ndarray) -> None:
        """Mark rows never-written: they fail every staleness bound until
        the next :meth:`write` (inputs changed ⇒ history is wrong at any
        staleness)."""
        self.version[rows] = NEVER

    def invalidate_all(self) -> None:
        """Mark the whole plane never-written — the producing model (or
        feature epoch) changed wholesale, so every row's history is wrong
        at any staleness (rolling weight hot-swap uses this to flip a
        serving cache to a new params version atomically)."""
        self.version[:] = NEVER


class FeatureStore:
    """Global feature server + device-side cache with traffic accounting.

    Args:
        g: graph whose ``features`` are served (``(N, F)`` float32; a
            feature-less graph serves row ids instead).
        cache_ids: node ids admitted to the device-side cache (hits are
            free; misses are charged ``bytes_per_row`` each plus one
            ``HEADER_BYTES`` envelope per fetch call that moves rows).
        codec: wire codec for remote rows (``fp32`` default is bit-exact
            and keeps the historical raw-float accounting; ``bf16`` /
            ``int8`` shrink ``bytes_per_row`` and return the receiver's
            decoded view of every miss row).
        path: telemetry label for this store's transfer path — names
            both its :class:`~repro.core.comm.Transport` channel
            (``comm_*`` series) and its
            ``cache_lookups_total{cache=<path>,result=hit|miss}``
            counters in :mod:`repro.core.telemetry`.

    Shape convention: :meth:`fetch_masked` is slot-aligned over padded id
    vectors (``-1`` = pad slot) and returns zero rows at unneeded slots,
    so batch shapes stay static and pad rows can never aggregate.
    """

    def __init__(self, g: Graph, cache_ids: np.ndarray, *,
                 codec: Union[str, WireCodec] = "fp32",
                 path: str = "features"):
        self.g = g
        self.cached = np.zeros(g.num_nodes, bool)
        self.cached[cache_ids] = True
        self.transport = Transport(codec, n_rows=g.num_nodes, path=path)
        self.codec = self.transport.codec
        self.bytes_per_row = (
            self.codec.wire_bytes_per_row(g.features.shape[1])
            if g.features is not None else 4)
        self.hits = 0
        self.misses = 0
        self._m_hits = telemetry.counter(
            "cache_lookups_total", "cache lookups by result",
            cache=path, result="hit")
        self._m_misses = telemetry.counter(
            "cache_lookups_total", cache=path, result="miss")

    @property
    def requests(self) -> int:
        """Remote pull RPCs actually issued (one envelope each)."""
        return self.transport.requests

    def _pull_remote(self, rows: np.ndarray,
                     ids: np.ndarray) -> np.ndarray:
        """Ship miss rows through the communication plane: accounts one
        RPC (payload + header) and returns the wire-decoded rows."""
        return self.transport.send(rows, row_ids=ids)

    def fetch(self, ids: np.ndarray) -> np.ndarray:
        """Fetch feature rows for ``ids`` (pads dropped); cache misses
        cross the wire (codec-encoded + accounted), hits are local."""
        ids = np.asarray(ids)
        ids = ids[ids >= 0]
        hit = self.cached[ids]
        self.hits += int(hit.sum())
        self._m_hits.inc(int(hit.sum()))
        miss = ~hit
        miss_rows = int(miss.sum())
        self.misses += miss_rows
        self._m_misses.inc(miss_rows)
        if self.g.features is None:
            if miss_rows:
                self.transport.account_opaque(miss_rows, 4)
            return ids
        out = self.g.features[ids]          # fancy indexing: fresh copy
        if miss_rows:
            out[miss] = self._pull_remote(out[miss], ids[miss])
        return out

    def _local_rows_mask(self, safe_ids: np.ndarray,
                         needed: np.ndarray) -> np.ndarray:
        """Hook: needed rows served from local memory — no cache lookup,
        no traffic.  The base store owns nothing locally; the distributed
        ``PartitionFeatureStore`` overrides this with partition ownership."""
        return np.zeros(len(safe_ids), bool)

    def fetch_masked(self, ids: np.ndarray, needed: np.ndarray) -> np.ndarray:
        """Slot-aligned fetch for padded serving batches: ``ids`` may
        contain -1 pads and ``needed`` marks the slots whose features are
        actually required (the rest return zero rows, keeping the batch
        shape static).  Only needed non-local rows count toward traffic,
        and a call whose mask selects no rows (or only local/cache hits)
        issues no remote request — it adds 0 bytes, not a header."""
        ids = np.asarray(ids)
        needed = np.asarray(needed, bool) & (ids >= 0)
        safe = np.maximum(ids, 0)
        remote = needed & ~self._local_rows_mask(safe, needed)
        hit = self.cached[safe] & remote
        self.hits += int(hit.sum())
        self._m_hits.inc(int(hit.sum()))
        miss = remote & ~hit
        miss_rows = int(miss.sum())
        self.misses += miss_rows
        self._m_misses.inc(miss_rows)
        if self.g.features is None:
            if miss_rows:
                self.transport.account_opaque(miss_rows, 4)
            return safe
        out = np.zeros((len(ids), self.g.features.shape[1]),
                       self.g.features.dtype)
        out[needed] = self.g.features[safe[needed]]
        if miss_rows:
            out[miss] = self._pull_remote(out[miss], safe[miss])
        return out

    def fetch_masked_wire(self, ids: np.ndarray,
                          needed: np.ndarray) -> QuantizedRows:
        """:meth:`fetch_masked` in the int8 wire format: identical slot
        alignment, hit/miss accounting, and traffic charges, but the
        result stays quantized (:class:`QuantizedRows`) so the caller
        can feed the int8-in/fp32-accumulate kernel directly.

        Miss rows arrive via :meth:`Transport.send_wire` (charged, with
        error feedback); local/hit rows are encoded in place — they
        never cross the wire, so they cost nothing, but the batch is
        uniformly quantized (each row within the codec's scale/2 error
        bound of its fp32 value).  Unneeded/pad slots carry
        ``q = mn = scale = 0`` and dequantize to exact zero rows,
        matching :meth:`fetch_masked`.  Requires the int8 codec."""
        if self.codec.name != "int8":
            raise ValueError(
                f"fetch_masked_wire requires the int8 codec (store has "
                f"{self.codec.name!r})")
        if self.g.features is None:
            raise ValueError("fetch_masked_wire needs a feature matrix")
        ids = np.asarray(ids)
        needed = np.asarray(needed, bool) & (ids >= 0)
        safe = np.maximum(ids, 0)
        remote = needed & ~self._local_rows_mask(safe, needed)
        hit = self.cached[safe] & remote
        self.hits += int(hit.sum())
        self._m_hits.inc(int(hit.sum()))
        miss = remote & ~hit
        miss_rows = int(miss.sum())
        self.misses += miss_rows
        self._m_misses.inc(miss_rows)
        F = self.g.features.shape[1]
        q = np.zeros((len(ids), F), np.uint8)
        mn = np.zeros((len(ids), 1), np.float32)
        scale = np.zeros((len(ids), 1), np.float32)
        local = needed & ~miss
        if int(local.sum()):
            enc = self.codec.encode(
                np.asarray(self.g.features[safe[local]], np.float32))
            q[local], mn[local], scale[local] = enc.data
        if miss_rows:
            wire = self.transport.send_wire(
                np.asarray(self.g.features[safe[miss]], np.float32),
                row_ids=safe[miss])
            q[miss], mn[miss], scale[miss] = wire.q, wire.mn, wire.scale
        return QuantizedRows(q, mn, scale)

    def reset_stats(self) -> None:
        """Zero hit/miss counters and the transport's traffic counters
        (error-feedback residuals are kept).  The telemetry series are
        reset in lockstep so exposed metrics keep matching these
        counters — the warmup-exclusion entry point (callers must not
        poke ``hits``/``misses`` directly)."""
        self.hits = 0
        self.misses = 0
        self._m_hits.reset()
        self._m_misses.reset()
        self.transport.reset_counters()

    @property
    def hit_ratio(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    @property
    def transferred_bytes(self) -> int:
        """Bytes the communication plane moved: miss-row payloads at the
        codec's wire size plus one ``HEADER_BYTES`` envelope per RPC."""
        return self.transport.total_bytes


def no_cache(g: Graph, capacity: int) -> np.ndarray:
    """Baseline policy: admit nothing (every remote row is traffic)."""
    return np.zeros(0, np.int64)


def degree_cache(g: Graph, capacity: int) -> np.ndarray:
    """PaGraph policy: top-``capacity`` vertices by out-degree."""
    order = np.argsort(-g.out_degree(), kind="stable")
    return order[:capacity]


def importance_cache(g: Graph, capacity: int, *, hops: int = 1) -> np.ndarray:
    """AliGraph policy: importance = in-neighbor count / out-neighbor count
    (vertices whose neighbors are needed by many, cheap to keep)."""
    imp = (g.in_degree() + 1.0) / (g.out_degree() + 1.0)
    # AliGraph caches the *out-neighbors of important vertices*; rank
    # vertices by combined score so the budget holds the hot set.
    score = imp * np.maximum(g.out_degree(), 1)
    order = np.argsort(-score, kind="stable")
    return order[:capacity]


def random_cache(g: Graph, capacity: int, *, seed: int = 0) -> np.ndarray:
    """Uniform-random admission — the control the policy claims are
    measured against."""
    rng = np.random.default_rng(seed)
    return rng.choice(g.num_nodes, min(capacity, g.num_nodes), replace=False)


CACHE_POLICIES = {
    "none": no_cache,
    "degree": degree_cache,      # PaGraph
    "importance": importance_cache,  # AliGraph
    "random": random_cache,
}


def measure_cache(g: Graph, policy: str, capacity: int,
                  batches: Iterable[np.ndarray]) -> dict:
    """Replay input-node id streams from a sampler against a cache policy."""
    ids = CACHE_POLICIES[policy](g, capacity)
    store = FeatureStore(g, ids)
    for b in batches:
        store.fetch(b)
    return {"policy": policy, "capacity": capacity,
            "hit_ratio": store.hit_ratio,
            "transferred_mb": store.transferred_bytes / 2**20}
