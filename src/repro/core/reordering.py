"""Vertex reordering (survey §3.2.4: GNNAdvisor's neighbor grouping via
Rabbit-order-style community locality; ZIPPER's degree sorting).

Reordering assigns consecutive ids to vertices that share neighbors so the
aggregation phase's gathers hit nearby rows (L1/VMEM locality).  We provide
two policies plus a locality metric so the benefit is measurable on any
graph + access trace.
"""
from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph, from_edges


def degree_sort_order(g: Graph) -> np.ndarray:
    """ZIPPER's heuristic: sort vertices by descending out-degree.
    Returns perm with perm[new_id] = old_id."""
    return np.argsort(-g.out_degree(), kind="stable")


def bfs_locality_order(g: Graph, *, seed: int = 0) -> np.ndarray:
    """Rabbit-order stand-in: BFS from a max-degree root groups
    communities contiguously (GNNAdvisor's 'neighbor groups get
    consecutive ids')."""
    n = g.num_nodes
    visited = np.zeros(n, bool)
    order = []
    deg = g.out_degree()
    roots = np.argsort(-deg, kind="stable")
    for root in roots:
        if visited[root]:
            continue
        queue = [int(root)]
        visited[root] = True
        while queue:
            v = queue.pop(0)
            order.append(v)
            for u in g.neighbors(v):
                if not visited[u]:
                    visited[u] = True
                    queue.append(int(u))
    return np.asarray(order, np.int64)


def apply_order(g: Graph, perm: np.ndarray) -> Graph:
    """Relabel the graph: new id i = old id perm[i]."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    e = g.edges()
    g2 = from_edges(g.num_nodes,
                    np.stack([inv[e[:, 0]], inv[e[:, 1]]], axis=1),
                    features=None if g.features is None
                    else g.features[perm],
                    labels=None if g.labels is None else g.labels[perm],
                    num_classes=g.num_classes)
    return g2


def edge_locality(g: Graph, *, window: int = 128) -> float:
    """Fraction of edges whose endpoints fall within a ``window``-row id
    band — a proxy for cache-line/VMEM-tile co-residency during gathers."""
    e = g.edges()
    if len(e) == 0:
        return 0.0
    return float(np.mean(np.abs(e[:, 0] - e[:, 1]) < window))


REORDERINGS = {
    "identity": lambda g: np.arange(g.num_nodes),
    "degree": degree_sort_order,
    "bfs_locality": bfs_locality_order,
}
