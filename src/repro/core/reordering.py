"""Vertex reordering (survey §3.2.4: GNNAdvisor's neighbor grouping via
Rabbit-order-style community locality; ZIPPER's degree sorting; classic
reverse Cuthill–McKee bandwidth reduction).

Reordering assigns consecutive ids to vertices that share neighbors so the
aggregation phase's gathers hit nearby rows (L1/VMEM locality).  Three
policies are provided plus pure-numpy locality metrics so the benefit is
measurable on any graph + access trace:

* :func:`degree_sort_order` — ZIPPER: descending out-degree.
* :func:`bfs_locality_order` — Rabbit-order stand-in: BFS from max-degree
  roots groups communities contiguously (deque frontier, O(N + E)).
* :func:`rcm_order` — reverse Cuthill–McKee on the symmetrized adjacency:
  minimizes edge bandwidth ``|src - dst|``, which maps directly onto the
  blocked kernels' tile density (edges concentrate near the diagonal, so
  fewer (node-tile, edge-tile) pairs are active).

Every policy is deterministic: ties break by ascending node id through
stable sorts, so the same graph always packs the same way — the property
the fold-then-reorder dynamic-graph regression and the distributed
equivalence tests rely on.

:func:`reorder_graph` is the first-class transform behind
``Graph.reordered(policy)`` and the launchers' ``--reorder`` flag: it
returns ``(packed_graph, perm, inv)`` with ``perm[new_id] = old_id`` and
``inv[old_id] = new_id``, so callers map external ids in via ``inv`` and
report results back in original ids via ``perm``.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.graph.structure import Graph, from_edges


def identity_order(g: Graph) -> np.ndarray:
    """The no-op policy: perm[new_id] = new_id."""
    return np.arange(g.num_nodes, dtype=np.int64)


def degree_sort_order(g: Graph) -> np.ndarray:
    """ZIPPER's heuristic: sort vertices by descending out-degree.
    Returns perm with perm[new_id] = old_id (ties: ascending node id —
    ``argsort(kind="stable")`` keeps the original order of equal keys)."""
    return np.argsort(-g.out_degree(), kind="stable")


def bfs_locality_order(g: Graph, *, seed: int = 0) -> np.ndarray:
    """Rabbit-order stand-in: BFS from a max-degree root groups
    communities contiguously (GNNAdvisor's 'neighbor groups get
    consecutive ids').

    The frontier is a :class:`collections.deque` — ``popleft`` is O(1),
    so the whole traversal is O(N + E) (the previous ``list.pop(0)``
    frontier made it O(N²) on long BFS levels).  Deterministic: roots by
    (descending degree, ascending id); neighbors enqueue in CSR
    (ascending id) order.
    """
    n = g.num_nodes
    visited = np.zeros(n, bool)
    order = []
    deg = g.out_degree()
    roots = np.argsort(-deg, kind="stable")
    for root in roots:
        if visited[root]:
            continue
        queue = deque([int(root)])
        visited[root] = True
        while queue:
            v = queue.popleft()
            order.append(v)
            for u in g.neighbors(v):
                if not visited[u]:
                    visited[u] = True
                    queue.append(int(u))
    return np.asarray(order, np.int64)


def rcm_order(g: Graph) -> np.ndarray:
    """Reverse Cuthill–McKee on the symmetrized adjacency.

    Classic bandwidth-reduction ordering: BFS from a minimum-degree root,
    visiting each vertex's unvisited neighbors in ascending-degree order
    (ties: ascending id), then reverse.  Low bandwidth means edge
    endpoints land in the same or adjacent id tiles — exactly what the
    blocked one-hot-matmul kernels want (see
    :func:`repro.kernels.segment_sum.edge_tile_density`).
    """
    n = g.num_nodes
    e = g.edges()
    adj = from_edges(n, np.concatenate([e, e[:, [1, 0]]], axis=0))
    deg = adj.out_degree()
    visited = np.zeros(n, bool)
    order = []
    roots = np.argsort(deg, kind="stable")       # min-degree roots first
    for root in roots:
        if visited[root]:
            continue
        queue = deque([int(root)])
        visited[root] = True
        while queue:
            v = queue.popleft()
            order.append(v)
            nb = np.unique(adj.neighbors(v))
            nb = nb[~visited[nb]]
            nb = nb[np.argsort(deg[nb], kind="stable")]
            visited[nb] = True
            queue.extend(int(u) for u in nb)
    return np.asarray(order[::-1], np.int64)


def apply_order(g: Graph, perm: np.ndarray) -> Graph:
    """Relabel the graph: new id i = old id perm[i].  Features, labels and
    CSR structure are permuted consistently (edges re-sorted by new src
    id via the stable ``from_edges`` build)."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    e = g.edges()
    g2 = from_edges(g.num_nodes,
                    np.stack([inv[e[:, 0]], inv[e[:, 1]]], axis=1),
                    features=None if g.features is None
                    else g.features[perm],
                    labels=None if g.labels is None else g.labels[perm],
                    num_classes=g.num_classes)
    return g2


def reorder_graph(g: Graph, policy: str = "bfs"):
    """Apply a reordering policy end-to-end.

    Returns ``(packed, perm, inv)``: ``packed`` is the relabeled graph,
    ``perm[new_id] = old_id`` and ``inv[old_id] = new_id`` (mutual
    inverses — ``perm[inv] == arange(n)``).  Callers translate external
    node ids into the packed space with ``inv`` and report packed results
    in original ids with ``perm``; ``policy="none"`` returns the graph
    unchanged with identity maps, so call sites need no special-casing.
    """
    if policy not in REORDER_POLICIES:
        raise KeyError(f"unknown reorder policy {policy!r}; "
                       f"choose from {sorted(REORDER_POLICIES)}")
    perm = REORDER_POLICIES[policy](g)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    if policy == "none":
        return g, perm, inv
    return apply_order(g, perm), perm, inv


# ---------------------------------------------------------------------------
# locality metrics (pure numpy — the measurable half of the claim)
# ---------------------------------------------------------------------------

def edge_locality(g: Graph, *, window: int = 128) -> float:
    """Fraction of edges whose endpoints fall within a ``window``-row id
    band — a proxy for cache-line/VMEM-tile co-residency during gathers."""
    e = g.edges()
    if len(e) == 0:
        return 0.0
    return float(np.mean(np.abs(e[:, 0] - e[:, 1]) < window))


def avg_gather_stride(g: Graph) -> float:
    """Mean absolute id step between consecutively touched rows as the
    aggregation walks the edge list in CSR order — the source stream is
    the gather side, the destination stream the scatter side; both are
    averaged.  0 on an edgeless graph; lower is better (sequential access
    has stride ≈ 0, random access ≈ N/3)."""
    e = g.edges()
    if len(e) < 2:
        return 0.0
    return float((np.mean(np.abs(np.diff(e[:, 0])))
                  + np.mean(np.abs(np.diff(e[:, 1])))) / 2.0)


def reuse_distance_hit_rate(g: Graph, *, window: int = 1024) -> float:
    """Fraction of destination-row accesses whose previous access to the
    same row happened within the last ``window`` accesses — an LRU-style
    reuse-distance proxy for how often the scatter target is still
    cache/VMEM resident.  First-ever accesses count as misses; an
    edgeless graph scores 0."""
    dst = g.edges()[:, 1] if g.num_edges else np.zeros(0, np.int64)
    if len(dst) == 0:
        return 0.0
    pos = np.arange(len(dst))
    order = np.lexsort((pos, dst))
    sd, sp = dst[order], pos[order]
    same = sd[1:] == sd[:-1]
    gaps = sp[1:] - sp[:-1]
    hits = int(np.sum(same & (gaps <= window)))
    return hits / len(dst)


def locality_report(g: Graph, *, window: int = 128,
                    reuse_window: int = 1024) -> dict:
    """All locality metrics in one dict (what the launchers surface into
    telemetry under ``--reorder`` and the bench writes per policy)."""
    return {
        "edge_locality": edge_locality(g, window=window),
        "avg_gather_stride": avg_gather_stride(g),
        "reuse_hit_rate": reuse_distance_hit_rate(g, window=reuse_window),
    }


REORDER_POLICIES = {
    "none": identity_order,
    "degree": degree_sort_order,     # ZIPPER
    "bfs": bfs_locality_order,       # GNNAdvisor / Rabbit-order stand-in
    "rcm": rcm_order,                # reverse Cuthill–McKee
}

# legacy aliases (bench_caching + older tests predate the launcher flag)
REORDERINGS = {
    "identity": identity_order,
    "degree": degree_sort_order,
    "bfs_locality": bfs_locality_order,
}
