"""Ownership + halo (ghost) vertex layout for partition-aware training.

The surveyed distributed mini-batch systems (DistDGL, PaGraph, DistGNN —
§3.2.1/§3.2.4) split a graph with an edge-cut partitioner and then give
each partition two vertex sets:

* **owned** — vertices the partition is responsible for (its seeds, its
  labels, its slice of the feature matrix);
* **halo** (ghost) — remote endpoints of cut edges: the vertices whose
  features/embeddings must be fetched from other partitions to aggregate
  onto owned destinations.

This module computes both from any :class:`EdgeCutPartition`, plus
fixed-shape exchange index arrays (every partition's halo list padded to
one common cap) so a halo feature exchange is a single static-shape
gather per partition — the jit-stable layout the shard_map training step
and the halo FeatureStore cache both key off.

:class:`HaloExchange` layers *versioned per-layer ghost buffers* on top of
a :class:`HaloLayout`: the historical-embedding idea (GNNAutoScale /
PipeGCN / DistGNN's delayed aggregates, survey §3.2.7) applied to
full-graph training.  Each layer's ghost activations live in a
:class:`~repro.core.caching.VersionedBuffer` under the shared
:class:`~repro.core.caching.VersionClock`; a refresh *plan* per step picks
which ghost rows are exchanged synchronously (every row whose staleness
would exceed the bound, plus a budgeted fraction of the oldest rest) and
charges exactly those rows as cross-partition traffic — priced at the
wire size of the exchange's :class:`~repro.core.comm.WireCodec`, the
unified communication plane every transfer path shares.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import telemetry
from repro.core.caching import NEVER, VersionClock, VersionedBuffer
from repro.core.comm import HEADER_BYTES, WireCodec, resolve_codec
from repro.core.partitioning import EdgeCutPartition
from repro.graph.structure import Graph


@dataclasses.dataclass
class HaloLayout:
    """Per-partition ownership + ghost layout of an edge-cut partition.

    ``owner`` maps every vertex to its partition; per partition ``p``,
    ``owned[p]`` are its vertices and ``halo[p] = halo_in[p] ∪
    halo_out[p]`` its ghosts (remote endpoints of cut edges, split by
    fetch direction).  ``halo_idx``/``halo_mask`` are the fixed-shape
    exchange indices: every partition's ghost list padded to one common
    ``halo_cap`` (``-1`` pads, mask marks validity) so a halo exchange is
    one static-shape gather per partition — pad slots stay zero and never
    alias a real vertex.
    """
    n_parts: int
    owner: np.ndarray            # (N,) vertex -> owning partition
    owned: List[np.ndarray]      # per-partition owned vertex ids (sorted)
    halo_in: List[np.ndarray]    # remote in-neighbors of owned vertices
    halo_out: List[np.ndarray]   # remote out-neighbors of owned vertices
    halo: List[np.ndarray]       # ghost set = halo_in ∪ halo_out (sorted)
    halo_idx: np.ndarray         # (P, H_cap) global ids, -1 pad
    halo_mask: np.ndarray        # (P, H_cap) slot validity

    @property
    def halo_cap(self) -> int:
        return self.halo_idx.shape[1]

    def ghost_fraction(self) -> float:
        """Mean #ghost copies per partition / N — the replication overhead
        an edge-cut pays (survey §3.2.1)."""
        n = len(self.owner)
        return float(np.mean([len(h) for h in self.halo]) / max(n, 1))

    # -- fixed-shape exchange ----------------------------------------------
    def gather_halo(self, feats: np.ndarray) -> np.ndarray:
        """Pull each partition's halo feature rows into a (P, H_cap, F)
        buffer (pad slots zero).  Shape depends only on the layout, never
        on which partition is gathering."""
        out = np.zeros((self.n_parts, self.halo_cap, feats.shape[1]),
                       feats.dtype)
        out[self.halo_mask] = feats[self.halo_idx[self.halo_mask]]
        return out

    def scatter_halo(self, gathered: np.ndarray,
                     num_features: int) -> np.ndarray:
        """Inverse routing: write exchanged rows back to a global (N, F)
        buffer.  Round-trips exactly: scatter(gather(x)) restores x on
        every halo vertex (partitions holding the same ghost write
        identical rows)."""
        buf = np.zeros((len(self.owner), num_features), gathered.dtype)
        buf[self.halo_idx[self.halo_mask]] = gathered[self.halo_mask]
        return buf

    def exchange_bytes(self, bytes_per_row: int) -> int:
        """Bytes one full (uncached) halo exchange moves across partitions."""
        return int(sum(len(h) for h in self.halo)) * bytes_per_row


def build_halo(g: Graph, part: EdgeCutPartition) -> HaloLayout:
    """Classify every edge endpoint as owned-or-ghost per partition.

    For partition ``p``: a cut edge ``(u, v)`` with ``owner(v) == p``
    contributes ``u`` to ``halo_in[p]`` (needed to aggregate onto owned
    destinations, the pull direction); ``owner(u) == p`` contributes ``v``
    to ``halo_out[p]`` (push direction).  The ghost set is the union, so
    every endpoint of every edge touching ``p`` is owned or halo — the
    invariant the property tests assert.
    """
    owner = np.asarray(part.assignment)
    e = g.edges()
    src_o = owner[e[:, 0]]
    dst_o = owner[e[:, 1]]
    cut = src_o != dst_o
    owned, halo_in, halo_out, halo = [], [], [], []
    for p in range(part.n_parts):
        owned.append(np.flatnonzero(owner == p).astype(np.int64))
        hi = np.unique(e[cut & (dst_o == p), 0])
        ho = np.unique(e[cut & (src_o == p), 1])
        halo_in.append(hi)
        halo_out.append(ho)
        halo.append(np.union1d(hi, ho))
    cap = max(1, max((len(h) for h in halo), default=1))
    halo_idx = np.full((part.n_parts, cap), -1, np.int64)
    halo_mask = np.zeros((part.n_parts, cap), bool)
    for p, h in enumerate(halo):
        halo_idx[p, :len(h)] = h
        halo_mask[p, :len(h)] = True
    return HaloLayout(part.n_parts, owner, owned, halo_in, halo_out, halo,
                      halo_idx, halo_mask)


# ---------------------------------------------------------------------------
# versioned ghost buffers: staleness-bounded asynchronous halo exchange
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RefreshPlan:
    """One training step's ghost-refresh decision.

    Attributes:
        step:  the clock value this plan serves (the step index).
        masks: per-layer ``(n_rows,)`` bool — rows exchanged *synchronously*
               this step (they carry current-step values and gradients);
               every other ghost row is served stale from its buffer.
        rows_moved:   Σ over layers of refreshed ghost *copies* (a row
               ghosted by k partitions is sent k times).
        payload_bytes: rows_moved × the active codec's per-row wire size
               (``WireCodec.wire_bytes_per_row``, so compression shows up
               directly in every plan's estimate).
        header_bytes:  one per-RPC header per (partition, layer) that pulls
               at least one refreshed row this step.
    """
    step: int
    masks: List[np.ndarray]
    rows_moved: int
    payload_bytes: int
    header_bytes: int

    @property
    def bytes(self) -> int:
        """Total cross-partition bytes this plan moves."""
        return self.payload_bytes + self.header_bytes


class HaloExchange:
    """Versioned per-layer ghost activation buffers over a halo layout.

    The asynchronous full-graph step (``repro.distributed.async_train``)
    computes each layer with *historical* activations for ghost vertices:
    layer ``l``'s buffer holds a stale copy of the global layer-``l``
    output, refreshed row-by-row under a staleness bound.  This class owns
    those buffers, the shared version clock, the per-step refresh policy,
    and the traffic accounting.

    Refresh policy at step ``t`` (:meth:`plan_refresh`):

    * **must-refresh** — every ghost row whose age ``t - version`` exceeds
      ``max_staleness`` (so a stale read NEVER exceeds the bound; with
      ``max_staleness=0`` every ghost refreshes every step, degrading to
      the synchronous halo exchange);
    * **budget** — plus the oldest ``refresh_frac`` fraction of the
      remaining ghost rows, spreading refreshes so staleness (and per-step
      traffic) stays smooth instead of expiring in bursts.

    Only the *pull-direction* ghosts (``halo_in``: remote sources of edges
    into owned destinations) are buffered and charged — those are the rows
    a pull aggregation actually reads.  Rows that are nobody's ghost are
    never refreshed and never read remotely.

    Args:
        layout: ownership/ghost sets from :func:`build_halo`.
        layer_dims: widths of the buffered layer outputs, *innermost
            first* — for an L-layer GCN these are the inputs of layers
            ``1..L-1``, i.e. ``[hidden] * (L-1)``.
        max_staleness: bound ``S``; a stale read is at most ``S`` steps old.
        refresh_frac: extra per-step refresh budget as a fraction of the
            ghost set (``0.0`` = only must-refresh rows).
        relabel: optional old→new vertex id map (e.g.
            ``ShardedGraph.perm``) when buffers live in a relabeled/padded
            id space; ``n_rows`` then gives the padded row count.
        n_rows: buffer row count (default: number of vertices in
            ``layout``).
        codec: wire codec name or :class:`~repro.core.comm.WireCodec`
            for refresh payloads.  Plans charge each refreshed ghost copy
            at ``codec.wire_bytes_per_row(dim)`` (fp32 → the historical
            ``4 × dim``), and the buffers are expected to hold the
            codec-*decoded* values (the jitted step applies
            ``codec.jax_qdq`` before :meth:`write_planes` stores them).
        clock: share an existing :class:`VersionClock` (e.g. with a
            serving cache); default: a private clock starting at 0.
    """

    def __init__(self, layout: HaloLayout, layer_dims: Sequence[int], *,
                 max_staleness: int = 0, refresh_frac: float = 0.0,
                 relabel: Optional[np.ndarray] = None,
                 n_rows: Optional[int] = None,
                 codec: "str | WireCodec" = "fp32",
                 clock: Optional[VersionClock] = None):
        if max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        if not 0.0 <= refresh_frac <= 1.0:
            raise ValueError("refresh_frac must be in [0, 1]")
        self.layout = layout
        self.max_staleness = max_staleness
        self.refresh_frac = refresh_frac
        self.codec = resolve_codec(codec)
        self.layer_dims = list(layer_dims)
        # per-layer wire size of one refreshed ghost row (codec-aware —
        # what RefreshPlan estimates and the bytes/step benches report)
        self.row_wire_bytes = [self.codec.wire_bytes_per_row(d)
                               for d in self.layer_dims]
        n = n_rows if n_rows is not None else len(layout.owner)
        if relabel is None:
            relabel = np.arange(len(layout.owner), dtype=np.int64)
        # pull-direction ghost membership: member[p, r] ⇔ row r must be
        # replicated at partition p for its aggregations
        self.member = np.zeros((layout.n_parts, n), bool)
        for p in range(layout.n_parts):
            self.member[p, relabel[layout.halo_in[p]]] = True
        self.copies = self.member.sum(0).astype(np.int64)   # (n_rows,)
        self.ghost_rows = self.copies > 0
        self.n_ghost = int(self.ghost_rows.sum())
        self.clock = clock if clock is not None else VersionClock()
        self.buffers = [VersionedBuffer(self.clock, n, d)
                        for d in self.layer_dims]
        # lifetime accounting (plans may be generated ahead of execution;
        # the trainer sums CONSUMED plans for exact per-step reporting)
        self.steps_planned = 0
        self.total_bytes = 0
        self.total_rows = 0
        # telemetry: the halo path has no Transport (its traffic is priced
        # analytically per plan), so it feeds the shared comm_* series
        # directly, plus its own refresh/age/violation series
        lab = dict(path="halo", codec=self.codec.name)
        self._m_payload = telemetry.counter("comm_bytes_total",
                                            kind="payload", **lab)
        self._m_header = telemetry.counter("comm_bytes_total",
                                           kind="header", **lab)
        self._m_rows = telemetry.counter("comm_rows_total", **lab)
        self._m_refresh = telemetry.counter(
            "halo_refresh_rows_total",
            "ghost copies refreshed synchronously (all layers)")
        self._m_age = telemetry.histogram(
            "halo_ghost_age", "age (steps) refreshed ghost rows reached "
            "before refresh (first fills excluded)",
            buckets=telemetry.DEFAULT_COUNT_BUCKETS)
        self._m_viol = telemetry.counter(
            "halo_staleness_violations_total",
            "ghost rows left older than the bound after planning "
            "(structurally 0 — a nonzero value is a bug)")
        # graph-delta invalidations: per-layer ghost rows forced into the
        # next plan's must-refresh set regardless of the staleness bound
        self.delta_rows = 0
        self._m_delta = telemetry.counter(
            "delta_refresh_rows_total",
            "ghost buffer rows (per layer) force-refreshed because a "
            "graph delta touched their owners")

    # -- graph-delta invalidation ------------------------------------------
    def invalidate_rows(self, rows: np.ndarray) -> int:
        """Delta-aware invalidation: mark the given buffer rows (relabeled
        id space) never-written in EVERY layer buffer, so the next
        :meth:`plan_refresh` force-refreshes them regardless of the
        staleness bound ``S`` — a ghost whose owner a graph delta touched
        must never be served from history, however young.

        Rows outside the ghost set are ignored (they are nobody's ghost;
        nothing reads them remotely).  Invalidated rows land in the
        *must* set of the next plan, so the structural
        ``halo_staleness_violations_total == 0`` guarantee is preserved,
        and their refresh is excluded from the age histogram exactly
        like first fills (version ``NEVER`` carries no meaningful age).

        Returns the number of (row, layer) buffer entries invalidated,
        also counted into ``delta_refresh_rows_total``.
        """
        rows = np.asarray(rows, np.int64)
        n = len(self.copies)
        m = np.zeros(n, bool)
        m[rows[(rows >= 0) & (rows < n)]] = True
        m &= self.ghost_rows
        for buf in self.buffers:
            buf.invalidate(m)
        cnt = int(m.sum()) * len(self.buffers)
        self.delta_rows += cnt
        self._m_delta.inc(cnt)
        return cnt

    # -- refresh planning --------------------------------------------------
    def plan_refresh(self) -> RefreshPlan:
        """Decide (and account) this step's synchronous refresh set, stamp
        the refreshed rows at the current clock, and advance the clock.

        Returns the :class:`RefreshPlan` whose masks the jitted step
        consumes; the fresh values themselves are stored afterwards via
        :meth:`write_planes` (the split is what lets a host thread plan
        step ``t+1`` while the device still computes step ``t``).

        Guarantee: every ghost row NOT in the mask satisfies
        ``age <= max_staleness`` at this step — the bounded-staleness
        property the hypothesis tests assert.
        """
        now = self.clock.now
        budget = int(self.refresh_frac * self.n_ghost)
        masks, rows_moved, payload, headers = [], 0, 0, 0
        for buf, row_bytes in zip(self.buffers, self.row_wire_bytes):
            age = buf.age()
            must = self.ghost_rows & (age > self.max_staleness)
            mask = must.copy()
            extra = budget      # budget is per layer, on top of must rows
            if extra > 0:
                rest = self.ghost_rows & ~must
                idx = np.flatnonzero(rest)
                if len(idx):
                    oldest = idx[np.argsort(-age[idx], kind="stable")]
                    mask[oldest[:extra]] = True
            # telemetry: the age each refreshed row reached (first fills
            # from NEVER have no meaningful age) + the structural guard
            # that planning left no ghost row over the bound
            seen = mask & (buf.version != NEVER)
            self._m_age.observe_batch(age[seen])
            self._m_viol.inc(int((self.ghost_rows & ~mask
                                  & (age > self.max_staleness)).sum()))
            buf.version[mask] = now          # values arrive in write_planes
            masks.append(mask)
            rows_moved += int(self.copies[mask].sum())
            payload += int(self.copies[mask].sum()) * row_bytes
            headers += HEADER_BYTES * int(
                (self.member[:, mask].any(axis=1)).sum())
        self.clock.tick()
        self.steps_planned += 1
        self.total_rows += rows_moved
        self.total_bytes += payload + headers
        self._m_payload.inc(payload)
        self._m_header.inc(headers)
        self._m_rows.inc(rows_moved)
        self._m_refresh.inc(rows_moved)
        return RefreshPlan(now, masks, rows_moved, payload, headers)

    def write_planes(self, plan: RefreshPlan,
                     planes: Sequence[np.ndarray]) -> None:
        """Store the step's freshly computed global layer outputs into the
        buffers, but only at the rows ``plan`` refreshed (everything else
        keeps its historical value and version).

        ``planes`` must already carry the *wire* values: under a lossy
        codec the jitted step returns codec-decoded planes (it applies
        ``codec.jax_qdq`` + error feedback in
        :func:`repro.models.gnn.model.forward_stale`), so the buffers —
        and every subsequent stale read — see exactly what crossed the
        interconnect."""
        for buf, mask, plane in zip(self.buffers, plan.masks, planes):
            buf.values[mask] = np.asarray(plane)[mask]

    # -- views -------------------------------------------------------------
    def ghost_planes(self) -> List[np.ndarray]:
        """Current (stale) per-layer global activation planes, the arrays
        the jitted step reads for non-refreshed ghost rows."""
        return [buf.values for buf in self.buffers]

    def sync_bytes_per_step(self) -> int:
        """Traffic a fully synchronous exchange (S=0, every ghost copy,
        every layer, every step) would move *under the active codec* —
        the baseline the staleness savings are measured against."""
        per_layer_rows = int(self.copies.sum())
        payload = sum(per_layer_rows * rb for rb in self.row_wire_bytes)
        headers = HEADER_BYTES * len(self.layer_dims) * int(
            (self.member.any(axis=1)).sum())
        return payload + headers

    def stats(self) -> dict:
        """Lifetime planning totals (may run ahead of executed steps when
        plans are prefetched; exact consumed numbers live in the trainer)."""
        steps = max(self.steps_planned, 1)
        return {
            "staleness": self.max_staleness,
            "refresh_frac": self.refresh_frac,
            "wire_codec": self.codec.name,
            "ghost_rows": self.n_ghost,
            "steps_planned": self.steps_planned,
            "refreshed_rows_total": self.total_rows,
            "delta_refresh_rows": self.delta_rows,
            "bytes_total": self.total_bytes,
            "bytes_per_step": self.total_bytes / steps,
            "sync_bytes_per_step": self.sync_bytes_per_step(),
        }
