"""Ownership + halo (ghost) vertex layout for partition-aware training.

The surveyed distributed mini-batch systems (DistDGL, PaGraph, DistGNN —
§3.2.1/§3.2.4) split a graph with an edge-cut partitioner and then give
each partition two vertex sets:

* **owned** — vertices the partition is responsible for (its seeds, its
  labels, its slice of the feature matrix);
* **halo** (ghost) — remote endpoints of cut edges: the vertices whose
  features/embeddings must be fetched from other partitions to aggregate
  onto owned destinations.

This module computes both from any :class:`EdgeCutPartition`, plus
fixed-shape exchange index arrays (every partition's halo list padded to
one common cap) so a halo feature exchange is a single static-shape
gather per partition — the jit-stable layout the shard_map training step
and the halo FeatureStore cache both key off.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.partitioning import EdgeCutPartition
from repro.graph.structure import Graph


@dataclasses.dataclass
class HaloLayout:
    n_parts: int
    owner: np.ndarray            # (N,) vertex -> owning partition
    owned: List[np.ndarray]      # per-partition owned vertex ids (sorted)
    halo_in: List[np.ndarray]    # remote in-neighbors of owned vertices
    halo_out: List[np.ndarray]   # remote out-neighbors of owned vertices
    halo: List[np.ndarray]       # ghost set = halo_in ∪ halo_out (sorted)
    halo_idx: np.ndarray         # (P, H_cap) global ids, -1 pad
    halo_mask: np.ndarray        # (P, H_cap) slot validity

    @property
    def halo_cap(self) -> int:
        return self.halo_idx.shape[1]

    def ghost_fraction(self) -> float:
        """Mean #ghost copies per partition / N — the replication overhead
        an edge-cut pays (survey §3.2.1)."""
        n = len(self.owner)
        return float(np.mean([len(h) for h in self.halo]) / max(n, 1))

    # -- fixed-shape exchange ----------------------------------------------
    def gather_halo(self, feats: np.ndarray) -> np.ndarray:
        """Pull each partition's halo feature rows into a (P, H_cap, F)
        buffer (pad slots zero).  Shape depends only on the layout, never
        on which partition is gathering."""
        out = np.zeros((self.n_parts, self.halo_cap, feats.shape[1]),
                       feats.dtype)
        out[self.halo_mask] = feats[self.halo_idx[self.halo_mask]]
        return out

    def scatter_halo(self, gathered: np.ndarray,
                     num_features: int) -> np.ndarray:
        """Inverse routing: write exchanged rows back to a global (N, F)
        buffer.  Round-trips exactly: scatter(gather(x)) restores x on
        every halo vertex (partitions holding the same ghost write
        identical rows)."""
        buf = np.zeros((len(self.owner), num_features), gathered.dtype)
        buf[self.halo_idx[self.halo_mask]] = gathered[self.halo_mask]
        return buf

    def exchange_bytes(self, bytes_per_row: int) -> int:
        """Bytes one full (uncached) halo exchange moves across partitions."""
        return int(sum(len(h) for h in self.halo)) * bytes_per_row


def build_halo(g: Graph, part: EdgeCutPartition) -> HaloLayout:
    """Classify every edge endpoint as owned-or-ghost per partition.

    For partition ``p``: a cut edge ``(u, v)`` with ``owner(v) == p``
    contributes ``u`` to ``halo_in[p]`` (needed to aggregate onto owned
    destinations, the pull direction); ``owner(u) == p`` contributes ``v``
    to ``halo_out[p]`` (push direction).  The ghost set is the union, so
    every endpoint of every edge touching ``p`` is owned or halo — the
    invariant the property tests assert.
    """
    owner = np.asarray(part.assignment)
    e = g.edges()
    src_o = owner[e[:, 0]]
    dst_o = owner[e[:, 1]]
    cut = src_o != dst_o
    owned, halo_in, halo_out, halo = [], [], [], []
    for p in range(part.n_parts):
        owned.append(np.flatnonzero(owner == p).astype(np.int64))
        hi = np.unique(e[cut & (dst_o == p), 0])
        ho = np.unique(e[cut & (src_o == p), 1])
        halo_in.append(hi)
        halo_out.append(ho)
        halo.append(np.union1d(hi, ho))
    cap = max(1, max((len(h) for h in halo), default=1))
    halo_idx = np.full((part.n_parts, cap), -1, np.int64)
    halo_mask = np.zeros((part.n_parts, cap), bool)
    for p, h in enumerate(halo):
        halo_idx[p, :len(h)] = h
        halo_mask[p, :len(h)] = True
    return HaloLayout(part.n_parts, owner, owned, halo_in, halo_out, halo,
                      halo_idx, halo_mask)
