"""Programming abstractions for GNNs (survey §3.2.3, Table 5).

Two abstractions are provided:

* **SAGA-NN** (NeuGraph): a GNN layer is Scatter → ApplyEdge → Gather →
  ApplyVertex.  Scatter/Gather are system-provided (gather of source
  features onto edges / segment reduction onto destinations); ApplyEdge and
  ApplyVertex are user-defined tensor functions.
* a **message-passing base class** (DGL/PyG style) implemented on top of
  SAGA-NN, used by the model zoo (GCN/SAGE/GAT/GIN).

TPU adaptation (DESIGN.md §2): edges are padded fixed-shape arrays and the
Gather step is a dense segment reduction (`jax.ops.segment_sum` — oracle
path) or the Pallas-blocked `repro.kernels.segment_sum` kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import QuantizedRows
from repro.core.sampling import Block
from repro.graph.structure import Graph


@dataclasses.dataclass
class DeviceGraph:
    """Padded edge-list graph on device.

    For bipartite blocks ``num_dst != num_src`` and destination nodes are a
    prefix of source nodes."""
    edge_src: jax.Array        # (E,) int32 — index into src features
    edge_dst: jax.Array        # (E,) int32 — index into dst features
    edge_mask: jax.Array       # (E,) bool
    num_src: int
    num_dst: int
    in_deg: jax.Array          # (num_dst,) float32 (masked in-degree)
    out_deg: jax.Array         # (num_src,) float32

    @staticmethod
    def from_graph(g: Graph) -> "DeviceGraph":
        e = g.edges()
        n = g.num_nodes
        src = jnp.asarray(e[:, 0], jnp.int32)
        dst = jnp.asarray(e[:, 1], jnp.int32)
        mask = jnp.ones((len(e),), bool)
        indeg = jnp.asarray(np.maximum(g.in_degree(), 1), jnp.float32)
        outdeg = jnp.asarray(np.maximum(g.out_degree(), 1), jnp.float32)
        return DeviceGraph(src, dst, mask, n, n, indeg, outdeg)

    @staticmethod
    def from_block(b: Block) -> "DeviceGraph":
        es = jnp.asarray(b.edge_src, jnp.int32)
        ed = jnp.asarray(b.edge_dst, jnp.int32)
        m = jnp.asarray(b.edge_mask)
        indeg = jnp.zeros((b.num_dst,), jnp.float32).at[ed].add(
            m.astype(jnp.float32))
        indeg = jnp.maximum(indeg, 1.0)
        outdeg = jnp.zeros((b.num_src,), jnp.float32).at[es].add(
            m.astype(jnp.float32))
        return DeviceGraph(es, ed, m, b.num_src, b.num_dst, indeg,
                           jnp.maximum(outdeg, 1.0))


jax.tree_util.register_dataclass(
    DeviceGraph,
    data_fields=["edge_src", "edge_dst", "edge_mask", "in_deg", "out_deg"],
    meta_fields=["num_src", "num_dst"])


# ---------------------------------------------------------------------------
# segment reductions (the Gather step)
# ---------------------------------------------------------------------------

def segment_sum(msgs, seg_ids, num_segments, *, use_kernel: bool = False):
    """Gather-step segment reduction: ``jax.ops.segment_sum`` oracle or
    the differentiable blocked Pallas kernel (``use_kernel=True``)."""
    if use_kernel:
        from repro.kernels import ops as kops
        if msgs.ndim == 1:          # e.g. per-edge scalars/logits
            return kops.segment_sum(msgs[:, None], seg_ids,
                                    num_segments)[:, 0]
        return kops.segment_sum(msgs, seg_ids, num_segments)
    return jax.ops.segment_sum(msgs, seg_ids, num_segments)


def gather_scale_segment_sum(h, edge_src, edge_dst, coef, num_dst, *,
                             use_kernel: bool = False):
    """Fused Scatter -> ApplyEdge(scale) -> Gather:
    ``out[d] = sum_{e: edge_dst[e]=d} coef[e] * h[edge_src[e]]``.

    ``coef`` is the per-edge coefficient with the validity mask folded in
    (masked/pad edges carry 0).  With ``use_kernel=True`` this runs as
    ONE Pallas kernel that never materializes the (E, F) message tensor
    in HBM (see :mod:`repro.kernels.segment_sum`); the reference path
    spells out the same computation in XLA ops.
    """
    if isinstance(h, QuantizedRows):
        # int8-in path: wire-format rows aggregate without a decode
        # round-trip on the kernel path; the reference path decodes
        # first (same math the kernel performs per source slab)
        if use_kernel:
            from repro.kernels import ops as kops
            return kops.gather_scale_segment_sum_q(
                jnp.asarray(h.q), jnp.asarray(h.mn),
                jnp.asarray(h.scale), edge_src, edge_dst, coef, num_dst)
        h = jnp.asarray(h.dequantize())
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.gather_scale_segment_sum(h, edge_src, edge_dst,
                                             coef, num_dst)
    msgs = jnp.take(h, edge_src, axis=0) * coef[:, None]
    return jax.ops.segment_sum(msgs, edge_dst, num_dst)


def segment_mean(msgs, seg_ids, num_segments, deg, *,
                 use_kernel: bool = False):
    """Degree-normalized segment reduction (``use_kernel`` forwarded to
    the underlying :func:`segment_sum`)."""
    s = segment_sum(msgs, seg_ids, num_segments, use_kernel=use_kernel)
    return s / deg[:, None]


def segment_max(msgs, seg_ids, num_segments):
    # no Pallas counterpart: max has no MXU-friendly one-hot form and is
    # never the hot path (GAT uses it once for numerical stability)
    return jax.ops.segment_max(msgs, seg_ids, num_segments,
                               indices_are_sorted=False)


def segment_softmax(logits, seg_ids, num_segments, mask, *,
                    use_kernel: bool = False):
    """Per-destination softmax over incoming edges (GAT).

    ``use_kernel`` reaches the denominator's :func:`segment_sum` too, so
    a kernel-mode GAT runs every reduction through the Pallas path (the
    max for numerical stability stays ``jax.ops.segment_max``).
    """
    neg = jnp.asarray(-1e30, logits.dtype)
    logits = jnp.where(mask[:, None] if logits.ndim > 1 else mask,
                       logits, neg)
    mx = segment_max(logits, seg_ids, num_segments)
    ex = jnp.exp(logits - mx[seg_ids])
    ex = ex * (mask[:, None] if logits.ndim > 1 else mask)
    den = segment_sum(ex, seg_ids, num_segments, use_kernel=use_kernel)
    return ex / (den[seg_ids] + 1e-9)


# ---------------------------------------------------------------------------
# SAGA-NN
# ---------------------------------------------------------------------------

def saga_layer(g: DeviceGraph,
               x_src: jax.Array,
               x_dst: jax.Array,
               *,
               apply_edge: Callable,
               gather: str = "sum",
               apply_vertex: Callable,
               edge_data: Optional[jax.Array] = None,
               use_kernel: bool = False) -> jax.Array:
    """One SAGA-NN step.

    scatter:      src features -> edges (system)
    apply_edge:   (src_feat_on_edge, dst_feat_on_edge, edge_data) -> msgs
    gather:       segment reduce msgs onto destinations (system)
    apply_vertex: (aggregated, x_dst) -> new dst features
    """
    feat_e = jnp.take(x_src, g.edge_src, axis=0)              # Scatter
    dst_e = jnp.take(x_dst, g.edge_dst, axis=0)
    msgs = apply_edge(feat_e, dst_e, edge_data)               # ApplyEdge
    msgs = msgs * g.edge_mask[:, None].astype(msgs.dtype)
    if gather == "sum":                                        # Gather
        agg = segment_sum(msgs, g.edge_dst, g.num_dst,
                          use_kernel=use_kernel)
    elif gather == "mean":
        agg = segment_mean(msgs, g.edge_dst, g.num_dst, g.in_deg,
                           use_kernel=use_kernel)
    elif gather == "max":
        agg = segment_max(msgs, g.edge_dst, g.num_dst)
        agg = jnp.where(jnp.isfinite(agg), agg, 0.0)
    else:
        raise ValueError(gather)
    return apply_vertex(agg, x_dst)                            # ApplyVertex


class MessagePassing:
    """DGL/PyG-style base class on top of SAGA-NN.  Subclasses override
    ``message``/``aggregate``/``update`` and provide ``init``."""

    aggregate = "sum"

    def message(self, p, src_feat, dst_feat, edge_data):
        return src_feat

    def update(self, p, agg, self_feat):
        raise NotImplementedError

    def __call__(self, p, g: DeviceGraph, x_src, x_dst=None, *,
                 use_kernel=False):
        if isinstance(x_src, QuantizedRows):
            # generic layers scatter fp32 rows onto edges; only layers
            # that aggregate before projecting (SAGE) consume the wire
            # format directly
            x_src = jnp.asarray(x_src.dequantize())
        if x_dst is None:
            x_dst = x_src[:g.num_dst]
        return saga_layer(
            g, x_src, x_dst,
            apply_edge=lambda s, d, e: self.message(p, s, d, e),
            gather=self.aggregate,
            apply_vertex=lambda a, h: self.update(p, a, h),
            use_kernel=use_kernel)
