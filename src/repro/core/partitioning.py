"""Graph partitioning strategies (survey §3.2.1 / §2.2.2, Tables 1 & 3).

All partitioners are host-side (numpy) preprocessing, as in the surveyed
systems.  Edge-cut partitioners return a vertex→partition assignment;
vertex-cut partitioners return an edge→partition assignment (vertices are
replicated); the 2D grid partitioner returns per-edge block coordinates.

Quality metrics (§3.2.1): replication factor, edge-cut fraction, balance.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.graph.structure import Graph


@dataclasses.dataclass
class EdgeCutPartition:
    assignment: np.ndarray       # (N,) vertex -> partition
    n_parts: int

    def edge_cut_fraction(self, g: Graph) -> float:
        e = g.edges()
        return float(np.mean(self.assignment[e[:, 0]]
                             != self.assignment[e[:, 1]]))

    def balance(self) -> float:
        sizes = np.bincount(self.assignment, minlength=self.n_parts)
        return float(sizes.max() / max(sizes.mean(), 1e-9))

    def replication_factor(self, g: Graph) -> float:
        """#(vertex, partition) pairs that must hold the vertex (owner +
        ghost copies for cut edges) / N."""
        e = g.edges()
        pairs = np.concatenate([
            np.stack([e[:, 0], self.assignment[e[:, 1]]], 1),
            np.stack([e[:, 1], self.assignment[e[:, 0]]], 1),
            np.stack([np.arange(g.num_nodes), self.assignment], 1),
        ])
        uniq = np.unique(pairs, axis=0)
        return float(len(uniq) / g.num_nodes)


@dataclasses.dataclass
class VertexCutPartition:
    edge_assignment: np.ndarray  # (E,) edge -> partition
    n_parts: int
    _edges: np.ndarray           # (E, 2)

    def replication_factor(self, g: Graph) -> float:
        pairs = np.concatenate([
            np.stack([self._edges[:, 0], self.edge_assignment], 1),
            np.stack([self._edges[:, 1], self.edge_assignment], 1)])
        uniq = np.unique(pairs, axis=0)
        return float(len(uniq) / g.num_nodes)

    def balance(self) -> float:
        sizes = np.bincount(self.edge_assignment, minlength=self.n_parts)
        return float(sizes.max() / max(sizes.mean(), 1e-9))


# ===========================================================================
# edge-cut family
# ===========================================================================

def hash_partition(g: Graph, n_parts: int) -> EdgeCutPartition:
    """Pregel/P3: partition(v) = hash(v) mod N — minimal preprocessing."""
    # splitmix-style integer hash for dispersion
    v = np.arange(g.num_nodes, dtype=np.uint64)
    v = (v ^ (v >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    v = (v ^ (v >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    v = v ^ (v >> np.uint64(31))
    return EdgeCutPartition((v % np.uint64(n_parts)).astype(np.int32),
                            n_parts)


def ldg_partition(g: Graph, n_parts: int, *, slack: float = 1.1,
                  seed: int = 0) -> EdgeCutPartition:
    """Linear Deterministic Greedy [Stanton & Kliot 2012]: stream vertices;
    assign to the partition with most neighbors, damped by a capacity
    penalty (1 - size/capacity)."""
    n = g.num_nodes
    cap = slack * n / n_parts
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    assign = -np.ones(n, np.int32)
    sizes = np.zeros(n_parts, np.int64)
    for v in order:
        nbrs = g.neighbors(v)
        placed = assign[nbrs]
        placed = placed[placed >= 0]
        score = np.bincount(placed, minlength=n_parts).astype(np.float64)
        score *= np.maximum(0.0, 1.0 - sizes / cap)
        # tie-break: least-loaded
        best = np.flatnonzero(score == score.max())
        p = best[np.argmin(sizes[best])]
        assign[v] = p
        sizes[p] += 1
    return EdgeCutPartition(assign, n_parts)


def fennel_partition(g: Graph, n_parts: int, *, gamma: float = 1.5,
                     seed: int = 0) -> EdgeCutPartition:
    """FENNEL [Tsourakakis+ 2014]: score = |N(v) ∩ P| - α·γ·|P|^(γ-1)."""
    n, m = g.num_nodes, g.num_edges
    alpha = np.sqrt(n_parts) * m / (n ** gamma)
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    assign = -np.ones(n, np.int32)
    sizes = np.zeros(n_parts, np.float64)
    for v in order:
        nbrs = g.neighbors(v)
        placed = assign[nbrs]
        placed = placed[placed >= 0]
        nb = np.bincount(placed, minlength=n_parts).astype(np.float64)
        score = nb - alpha * gamma * np.power(sizes, gamma - 1)
        p = int(np.argmax(score))
        assign[v] = p
        sizes[p] += 1
    return EdgeCutPartition(assign, n_parts)


# ===========================================================================
# vertex-cut family
# ===========================================================================

def hdrf_partition(g: Graph, n_parts: int, *, lam: float = 1.0,
                   seed: int = 0) -> VertexCutPartition:
    """HDRF [Petroni+ 2015]: stream edges; replicate High-Degree vertices
    first; balance via a load term."""
    edges = g.edges()
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(edges))
    deg = g.out_degree() + g.in_degree()
    replicas = [set() for _ in range(g.num_nodes)]  # partitions holding v
    load = np.zeros(n_parts, np.float64)
    assign = np.zeros(len(edges), np.int32)
    eps = 1e-9
    for ei in order:
        u, v = edges[ei]
        du, dv = deg[u] + eps, deg[v] + eps
        theta_u = du / (du + dv)
        theta_v = 1 - theta_u
        maxload = load.max() + eps
        minload = load.min()
        scores = np.zeros(n_parts)
        for p in range(n_parts):
            g_u = (1 + (1 - theta_u)) if p in replicas[u] else 0.0
            g_v = (1 + (1 - theta_v)) if p in replicas[v] else 0.0
            bal = lam * (maxload - load[p]) / (eps + maxload - minload)
            scores[p] = g_u + g_v + bal
        p = int(np.argmax(scores))
        assign[ei] = p
        replicas[u].add(p)
        replicas[v].add(p)
        load[p] += 1
    out = np.zeros(len(edges), np.int32)
    out[order] = assign[order]
    assign_final = assign
    return VertexCutPartition(assign_final, n_parts, edges)


def grid_vertex_cut(g: Graph, n_parts: int) -> VertexCutPartition:
    """2D grid edge placement (GridGraph/NeuGraph/ZIPPER): edge (u, v) goes
    to block (chunk(u), chunk(v)) arranged on a √P x √P grid."""
    p_side = int(np.sqrt(n_parts))
    assert p_side * p_side == n_parts, "grid partitioner needs square P"
    edges = g.edges()
    n = g.num_nodes
    cu = (edges[:, 0] * p_side // n).astype(np.int64)
    cv = (edges[:, 1] * p_side // n).astype(np.int64)
    return VertexCutPartition((cu * p_side + cv).astype(np.int32), n_parts,
                              edges)


def two_phase_partition(g: Graph, n_parts: int, *, seed: int = 0
                        ) -> VertexCutPartition:
    """2PS [Mayer+ 2020]: phase 1 gathers clustering information (cheap
    label-propagation communities); phase 2 streams edges and scores
    partitions by cluster affinity + degree + load (HDRF-style)."""
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    # phase 1: a few label-propagation rounds
    labels = np.arange(n)
    for _ in range(3):
        order = rng.permutation(n)
        for v in order:
            nbr = g.neighbors(v)
            if len(nbr) == 0:
                continue
            counts = np.bincount(labels[nbr])
            labels[v] = int(np.argmax(counts))
    _, labels = np.unique(labels, return_inverse=True)
    cluster_part = labels % n_parts          # cluster -> home partition

    # phase 2: stream edges with cluster-affinity scoring under a hard
    # capacity bound (keeps balance even when affinity is sticky)
    edges = g.edges()
    order = rng.permutation(len(edges))
    load = np.zeros(n_parts)
    cap = 1.1 * len(edges) / n_parts
    replicas = [set() for _ in range(n)]
    assign = np.zeros(len(edges), np.int32)
    eps = 1e-9
    for ei in order:
        u, v = edges[ei]
        scores = np.zeros(n_parts)
        maxload = load.max() + eps
        minload = load.min()
        for p in range(n_parts):
            if load[p] >= cap:
                scores[p] = -np.inf
                continue
            s = 0.0
            if p in replicas[u]:
                s += 1.0
            if p in replicas[v]:
                s += 1.0
            if cluster_part[labels[u]] == p:
                s += 0.5
            if cluster_part[labels[v]] == p:
                s += 0.5
            s += 2.0 * (maxload - load[p]) / (eps + maxload - minload)
            scores[p] = s
        p = int(np.argmax(scores))
        assign[ei] = p
        replicas[u].add(p)
        replicas[v].add(p)
        load[p] += 1
    return VertexCutPartition(assign, n_parts, edges)


# ===========================================================================
# hybrid (PowerLyra)
# ===========================================================================

def hybrid_partition(g: Graph, n_parts: int, *, degree_threshold: int = 32,
                     seed: int = 0) -> VertexCutPartition:
    """PowerLyra hybrid-cut: low-degree (in-degree <= θ) vertices keep all
    their in-edges on hash(dst) (edge-cut-like locality); high-degree
    vertices get their in-edges spread by hash(src) (vertex-cut)."""
    edges = g.edges()
    indeg = g.in_degree()
    hp = hash_partition(g, n_parts).assignment

    dst_low = indeg[edges[:, 1]] <= degree_threshold
    assign = np.where(dst_low, hp[edges[:, 1]], hp[edges[:, 0]])
    return VertexCutPartition(assign.astype(np.int32), n_parts, edges)


# ===========================================================================
# registry & dispatch
# ===========================================================================

PARTITIONERS = {
    "hash": hash_partition,
    "ldg": ldg_partition,
    "fennel": fennel_partition,
    "hdrf": hdrf_partition,
    "grid": grid_vertex_cut,
    "hybrid": hybrid_partition,
    "2ps": two_phase_partition,
}


def select_partitioner(g: Graph, n_parts: int, *,
                       latency_budget_s: float = 1.0) -> str:
    """EASE-style automatic selection [Merkel+ 2023, §2.2.2]: predict the
    best strategy from cheap graph statistics instead of running all.

    Heuristic model (validated in tests/benchmarks):
      - heavy-tailed degree distribution  -> vertex-cut (hdrf)
      - uniform degrees + time budget     -> locality streaming (ldg)
      - tight latency budget / huge graph -> hash
    """
    deg = g.out_degree().astype(np.float64)
    mean = max(deg.mean(), 1e-9)
    cv = deg.std() / mean                        # coefficient of variation
    # streaming partitioners cost ~O(N * n_parts) python-side here;
    # calibrate a crude throughput constant
    est_stream_s = g.num_nodes * n_parts * 2e-6
    if est_stream_s > latency_budget_s:
        return "hash"
    if cv > 0.8:                                 # power-law-ish
        return "hdrf"
    return "ldg"


def partition(g: Graph, n_parts: int, method: str = "hash", **kw):
    return PARTITIONERS[method](g, n_parts, **kw)


def contiguousize(g: Graph, part: EdgeCutPartition):
    """Relabel vertices so each partition's vertices are contiguous and
    equally padded — the device-ready layout for shard_map training.

    Returns (perm (N,), counts (P,)) with perm[new_id] = old_id.
    """
    order = np.argsort(part.assignment, kind="stable")
    counts = np.bincount(part.assignment, minlength=part.n_parts)
    return order, counts
