"""Distributed full-graph message propagation (survey §3.2.6 / §2.2.5).

The survey's push/pull taxonomy maps onto SPMD collectives exactly:

* **pull** (GAS/GraphLab/DGL): each device *pulls* the current features of
  all source vertices — ``all_gather`` over the graph axis, then a local
  gather + segment-reduce onto its own destinations.
* **push** (Pregel/NeuGraph): each device computes its local sources'
  contributions to *every* destination and *pushes* partial aggregates —
  a local segment-reduce into a full-size buffer followed by
  ``psum_scatter`` (reduce-scatter) onto the destination owners.

Both compute the same aggregation; they differ in where the reduction
happens and what crosses the wire (features vs partial aggregates) — the
trade-off the survey highlights.  DistGNN's delayed-aggregate mode (§3.2.7)
is the pull variant with a stale feature cache refreshed every ``s`` steps.

Everything here runs under ``shard_map`` over mesh axis ``"g"``; vertices
are range-partitioned after a partitioner-driven relabel (partitioning.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import partitioning as part_mod
from repro.core.abstraction import DeviceGraph, gather_scale_segment_sum
from repro.graph.structure import Graph

AXIS = "g"


@dataclasses.dataclass
class ShardedGraph:
    """Host-prepared, device-shardable graph layout.

    Arrays are concatenated per-device segments (axis 0 shards over "g"):
      edge_src_g:  (n_dev * E_loc,) GLOBAL src id         (pull layout)
      edge_dst_l:  (n_dev * E_loc,) LOCAL dst id
      edge_mask:   (n_dev * E_loc,)
      x:           (N_pad, F) permuted features
      labels/mask: (N_pad,)
      in_deg:      (N_pad,) global in-degree (clamped >= 1)
      out_deg:     (N_pad,)
    """
    n_dev: int
    n_local: int
    e_local: int
    perm: np.ndarray
    edge_src_g: jax.Array
    edge_dst_l: jax.Array
    edge_mask: jax.Array
    x: jax.Array
    labels: jax.Array
    label_mask: jax.Array
    in_deg: jax.Array
    out_deg: jax.Array


def shard_graph(g: Graph, n_dev: int, *, method: str = "hash",
                feat: Optional[np.ndarray] = None) -> ShardedGraph:
    """Partition with the chosen edge-cut strategy, relabel vertices to
    contiguous per-device ranges, pad, and build the pull edge layout."""
    p = part_mod.partition(g, n_dev, method)
    assert isinstance(p, part_mod.EdgeCutPartition), \
        "distributed full-graph training uses edge-cut partitioners"
    order, counts = part_mod.contiguousize(g, p)  # order[new] = old
    n_local = int(np.ceil(counts.max() / 1)) if n_dev == 1 else int(
        np.ceil(g.num_nodes / n_dev))
    n_local = max(n_local, int(counts.max()))
    n_pad = n_local * n_dev

    # new id layout: device d owns [d*n_local, d*n_local + counts[d])
    new_of_old = np.full(g.num_nodes, -1, np.int64)
    off = 0
    starts = np.zeros(n_dev, np.int64)
    for d in range(n_dev):
        starts[d] = d * n_local
    pos = starts.copy()
    for new_seq, old in enumerate(order):
        d = p.assignment[old]
        new_of_old[old] = pos[d]
        pos[d] += 1

    e = g.edges()
    src_new = new_of_old[e[:, 0]]
    dst_new = new_of_old[e[:, 1]]
    dst_dev = dst_new // n_local

    # group edges by destination owner, pad each device to e_local
    e_local = 0
    groups = []
    for d in range(n_dev):
        sel = dst_dev == d
        groups.append((src_new[sel], dst_new[sel] - d * n_local))
        e_local = max(e_local, int(sel.sum()))
    e_local = max(e_local, 1)
    es = np.zeros((n_dev, e_local), np.int32)
    ed = np.zeros((n_dev, e_local), np.int32)
    em = np.zeros((n_dev, e_local), bool)
    for d, (s_, d_) in enumerate(groups):
        k = len(s_)
        es[d, :k] = s_
        ed[d, :k] = d_
        em[d, :k] = True

    feats = g.features if feat is None else feat
    F = feats.shape[1]
    x = np.zeros((n_pad, F), np.float32)
    labels = np.zeros((n_pad,), np.int32)
    lmask = np.zeros((n_pad,), np.float32)
    x[new_of_old] = feats
    if g.labels is not None:
        labels[new_of_old] = g.labels
        lmask[new_of_old] = 1.0
    indeg = np.ones((n_pad,), np.float32)
    outdeg = np.ones((n_pad,), np.float32)
    indeg[new_of_old] = np.maximum(g.in_degree(), 1)
    outdeg[new_of_old] = np.maximum(g.out_degree(), 1)

    return ShardedGraph(
        n_dev=n_dev, n_local=n_local, e_local=e_local, perm=new_of_old,
        edge_src_g=jnp.asarray(es.reshape(-1)),
        edge_dst_l=jnp.asarray(ed.reshape(-1)),
        edge_mask=jnp.asarray(em.reshape(-1)),
        x=jnp.asarray(x), labels=jnp.asarray(labels),
        label_mask=jnp.asarray(lmask),
        in_deg=jnp.asarray(indeg), out_deg=jnp.asarray(outdeg))


# ---------------------------------------------------------------------------
# pull / push aggregation primitives (inside shard_map)
# ---------------------------------------------------------------------------

def pull_aggregate(h_loc, edge_src_g, edge_dst_l, edge_mask, n_local,
                   *, coef_e=None, use_kernel=False):
    """All-gather features, local segment-sum onto owned destinations.

    Args (inside shard_map over ``"g"``): ``h_loc`` ``(n_local, F)`` owned
    rows; ``edge_src_g`` global src ids / ``edge_dst_l`` local dst ids /
    ``edge_mask`` validity for this device's ``(E_loc,)`` edge slice;
    ``coef_e`` optional per-edge coefficient.  Returns ``(n_local, F)``
    aggregates; masked (pad) edges contribute zero, so pad rows never
    aggregate.  ``use_kernel=True`` runs gather+scale+reduce as one fused
    Pallas kernel (no (E, F) message tensor in HBM)."""
    h_all = jax.lax.all_gather(h_loc, AXIS, tiled=True)     # (N_pad, F)
    coef = edge_mask.astype(h_all.dtype)
    if coef_e is not None:
        coef = coef * coef_e
    return gather_scale_segment_sum(h_all, edge_src_g, edge_dst_l, coef,
                                    n_local, use_kernel=use_kernel)


def push_aggregate(h_loc, edge_src_l, edge_dst_g, edge_mask, n_pad,
                   *, coef_e=None, use_kernel=False):
    """Local partial aggregates for ALL destinations, reduce-scatter.

    Args mirror :func:`pull_aggregate` with the dual layout: ``edge_src_l``
    local src ids, ``edge_dst_g`` global dst ids, ``n_pad`` the padded
    global row count.  Returns this device's ``(n_local, F)`` slice of the
    psum_scattered aggregate; masked edges contribute zero."""
    coef = edge_mask.astype(h_loc.dtype)
    if coef_e is not None:
        coef = coef * coef_e
    partial = gather_scale_segment_sum(h_loc, edge_src_l, edge_dst_g,
                                       coef, n_pad,
                                       use_kernel=use_kernel)
    # Forward-pass sharding primitive, not the PR 2 class: unlike psum,
    # differentiating through psum_scatter inserts no second reduction.
    # repro-lint: disable=RL001 -- psum_scatter transpose is all_gather, no double reduction
    return jax.lax.psum_scatter(partial, AXIS, scatter_dimension=0,
                                tiled=True)                 # (N_loc, F)


def push_layout(sg: ShardedGraph, g: Graph) -> dict:
    """Re-group the edge list by SOURCE owner (push layout)."""
    e = g.edges()
    src_new = sg.perm[e[:, 0]]
    dst_new = sg.perm[e[:, 1]]
    src_dev = src_new // sg.n_local
    groups = []
    e_local = 1
    for d in range(sg.n_dev):
        sel = src_dev == d
        groups.append((src_new[sel] - d * sg.n_local, dst_new[sel]))
        e_local = max(e_local, int(sel.sum()))
    es = np.zeros((sg.n_dev, e_local), np.int32)
    ed = np.zeros((sg.n_dev, e_local), np.int32)
    em = np.zeros((sg.n_dev, e_local), bool)
    for d, (s_, d_) in enumerate(groups):
        k = len(s_)
        es[d, :k] = s_
        ed[d, :k] = d_
        em[d, :k] = True
    return {"edge_src_l": jnp.asarray(es.reshape(-1)),
            "edge_dst_g": jnp.asarray(ed.reshape(-1)),
            "edge_mask": jnp.asarray(em.reshape(-1))}


# ---------------------------------------------------------------------------
# distributed GCN training step (pull | push | stale-pull)
# ---------------------------------------------------------------------------

def gcn_forward_local(params, h_loc, sg_local, *, mode, halo_cache=None,
                      use_kernel=False):
    """Runs inside shard_map.  ``sg_local`` holds per-device edge slices and
    degree vectors; GCN normalization 1/sqrt(d_out d_in) per edge.
    ``use_kernel`` routes each layer's aggregation through the fused
    Pallas gather-scale-segment-sum kernel."""
    (es, ed, em, indeg_l, outdeg_all, n_local) = sg_local
    h = h_loc
    n_layers = len(params)
    for i, p in enumerate(params):
        hw = h @ p["w"]
        if mode == "pull":
            h_all = jax.lax.all_gather(hw, AXIS, tiled=True)
        elif mode == "stale" and halo_cache is not None and i == 0:
            # DistGNN-style: first-layer halo uses the cached (stale)
            # features; deeper layers still synchronize.
            h_all = halo_cache @ p["w"]
        else:
            h_all = jax.lax.all_gather(hw, AXIS, tiled=True)
        coef = (jax.lax.rsqrt(jnp.take(outdeg_all, es))
                * jax.lax.rsqrt(jnp.take(indeg_l, ed)))
        agg = gather_scale_segment_sum(h_all, es, ed, coef * em, n_local,
                                       use_kernel=use_kernel)
        h = agg + p["b"]
        if i + 1 < n_layers:
            h = jax.nn.relu(h)
    return h


def gcn_forward_push(params, h_loc, push_arrays, outdeg_all, indeg_l,
                     n_local, n_dev, *, use_kernel=False):
    """Push-mode GCN forward (Pregel/NeuGraph): each device computes its
    LOCAL sources' contributions for every destination and reduce-scatters
    partial aggregates."""
    es_l, ed_g, em = push_arrays
    idx = jax.lax.axis_index(AXIS)
    h = h_loc
    n_layers = len(params)
    n_pad = n_local * n_dev
    for i, p in enumerate(params):
        hw = h @ p["w"]
        # per-edge GCN normalization with LOCAL source / GLOBAL dest degree
        outdeg_l = jax.lax.dynamic_slice_in_dim(
            outdeg_all, idx * n_local, n_local, axis=0)
        indeg_all = jax.lax.all_gather(indeg_l, AXIS, tiled=True)
        coef = (jax.lax.rsqrt(jnp.take(outdeg_l, es_l))
                * jax.lax.rsqrt(jnp.take(indeg_all, ed_g)))
        h = push_aggregate(hw, es_l, ed_g, em, n_pad, coef_e=coef,
                           use_kernel=use_kernel) + p["b"]
        if i + 1 < n_layers:
            h = jax.nn.relu(h)
    return h


def make_distributed_gcn_step(optimizer, n_dev: int, *, mode: str = "pull",
                              use_kernel: bool = False):
    """Returns (mesh, train_step) for full-graph distributed GCN.

    mode: "pull" (all-gather features), "stale" (DistGNN delayed halos) or
    "push" (reduce-scatter partial aggregates; requires push-layout edges
    passed via ``train_step(..., push_arrays=...)``).  ``use_kernel``
    runs every layer's aggregation through the differentiable Pallas
    kernels — fused while the (all-gathered) source slab fits VMEM
    (``repro.kernels.segment_sum.fused_fits``), else the unfused blocked
    kernel, dispatched automatically; the gradient-equivalence matrix in
    ``tests/kernel_train_check.py`` proves the kernel path matches this
    reference to <= 1e-5 per parameter.

    train_step(params, opt_state, sg_arrays...) -> (params, opt_state, loss)
    with all graph arrays sharded over axis "g".  Gradients are psum'd
    (decentralized all-reduce coordination; see coordination.py for the
    parameter-server emulation).
    """
    devs = np.array(jax.devices()[:n_dev])
    mesh = Mesh(devs, (AXIS,))

    if mode == "push":
        def pstep(params, opt_state, x, es_l, ed_g, em, indeg, outdeg,
                  labels, lmask):
            n_local = x.shape[0]
            # psum the (parameter-free) count OUTSIDE the differentiated
            # function: under check_rep=False a psum inside loss_fn
            # transposes to another psum, scaling gradients by n_dev
            # (masked by Adam scale-invariance + clipping, caught by the
            # gradient-equivalence matrix in tests/distributed_train_check)
            cnt = jnp.maximum(jax.lax.psum(jnp.sum(lmask), AXIS), 1.0)

            def loss_fn(p):
                h = gcn_forward_push(p, x, (es_l, ed_g, em), outdeg,
                                     indeg, n_local, n_dev,
                                     use_kernel=use_kernel)
                logz = jax.nn.logsumexp(h, axis=-1)
                gold = jnp.take_along_axis(h, labels[:, None],
                                           axis=-1)[:, 0]
                return jnp.sum((logz - gold) * lmask) / cnt

            local_loss, grads = jax.value_and_grad(loss_fn)(params)
            loss = jax.lax.psum(local_loss, AXIS)
            grads = jax.tree.map(lambda g_: jax.lax.psum(g_, AXIS), grads)
            params, opt_state = optimizer.apply(params, grads, opt_state)
            return params, opt_state, loss

        rep = P()
        shard = P(AXIS)
        smapped = shard_map(
            pstep, mesh=mesh,
            in_specs=(rep, rep, shard, shard, shard, shard, shard, rep,
                      shard, shard),
            out_specs=(rep, rep, rep), check_rep=False)

        def train_step(params, opt_state, sg: ShardedGraph, *,
                       push_arrays: dict, halo_cache=None):
            return jax.jit(smapped)(
                params, opt_state, sg.x, push_arrays["edge_src_l"],
                push_arrays["edge_dst_g"], push_arrays["edge_mask"],
                sg.in_deg, sg.out_deg, sg.labels, sg.label_mask)

        return mesh, train_step

    def step(params, opt_state, x, es, ed, em, indeg, outdeg, labels, lmask,
             halo_cache):
        n_local = x.shape[0]
        indeg_l = indeg
        outdeg_all = outdeg  # replicated (N_pad,)
        # count psum'd outside the VJP (see pstep: psum-in-loss_fn would
        # scale gradients by n_dev under check_rep=False)
        cnt = jnp.maximum(jax.lax.psum(jnp.sum(lmask), AXIS), 1.0)

        def loss_fn(p):
            h = gcn_forward_local(
                p, x, (es, ed, em, indeg_l, outdeg_all, n_local),
                mode=mode, halo_cache=halo_cache, use_kernel=use_kernel)
            logz = jax.nn.logsumexp(h, axis=-1)
            gold = jnp.take_along_axis(h, labels[:, None], axis=-1)[:, 0]
            return jnp.sum((logz - gold) * lmask) / cnt

        local_loss, grads = jax.value_and_grad(loss_fn)(params)
        loss = jax.lax.psum(local_loss, AXIS)
        # each device's grad covers only its local psum contribution, so
        # the decentralized combine is a SUM (all-reduce), not a mean
        grads = jax.tree.map(lambda g_: jax.lax.psum(g_, AXIS), grads)
        params, opt_state = optimizer.apply(params, grads, opt_state)
        return params, opt_state, loss

    pspec = P()
    shard = P(AXIS)
    smapped = shard_map(
        step, mesh=mesh,
        in_specs=(pspec, pspec, shard, shard, shard, shard, shard, pspec,
                  shard, shard, pspec),
        out_specs=(pspec, pspec, pspec),
        check_rep=False)

    def train_step(params, opt_state, sg: ShardedGraph, halo_cache=None):
        if halo_cache is None:
            halo_cache = sg.x  # full (replicated) feature matrix
        return jax.jit(smapped)(
            params, opt_state, sg.x, sg.edge_src_g, sg.edge_dst_l,
            sg.edge_mask, sg.in_deg, sg.out_deg, sg.labels, sg.label_mask,
            halo_cache)

    return mesh, train_step
