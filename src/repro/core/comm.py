"""Unified communication plane: wire codecs + byte-accounted transport.

The survey's communication-reduction chapter observes that transfer
volume — ghost activations, remote feature rows, cache fills — is the
dominant scaling bottleneck of distributed GNN systems, and that the
systems which beat it (Dorylus' quantized lambda traffic, SANCUS'
bounded-error broadcast avoidance) all compress the wire format while
bounding the induced error.  This module is the repo's one implementation
of that idea: every remote byte in all three system families flows
through it.

* :class:`WireCodec` — pluggable payload encodings with a per-row wire
  size, host (numpy) encode/decode, and a jit-safe
  :meth:`~WireCodec.jax_qdq` for quantization *inside* a jitted step:

  - ``fp32``: identity; bit-exact with the pre-codec behavior.
  - ``bf16``: round-to-nearest-even truncation, 2 bytes/element.
  - ``int8``: per-row affine quantization (row min + 255 steps), 1
    byte/element + 8 bytes/row of scale/offset metadata, with optional
    **error-feedback** residuals on the sender so the bias of repeated
    sends of the same row averages out (the SANCUS-style bounded-error
    argument: the running mean of decoded sends converges to the truth).

* :class:`Transport` — one sender↔receiver channel: frames each send as
  ``[HEADER_BYTES envelope][n_rows × wire_bytes_per_row]``, owns the
  error-feedback residual state, and accounts payload/header bytes,
  rows, and RPCs.  A send that moves zero rows costs zero bytes (no
  envelope) — the invariant the ``fetch_masked`` regression tests pin.

Consumers: :class:`repro.core.halo.HaloExchange` (ghost-plane refresh
accounting + in-step qdq via :func:`repro.models.gnn.model.forward_stale`),
:class:`repro.core.caching.FeatureStore` /
:class:`repro.distributed.sampler.PartitionFeatureStore` (remote feature
fetches), and :class:`repro.serving.cache.EmbeddingCache` (cache-fill
payloads).  Select with ``--wire-codec {fp32,bf16,int8}`` on
``launch/train_gnn.py`` and ``launch/serve_gnn.py``, or
``GNNConfig.wire_codec``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Union

import numpy as np

from repro.core import telemetry


class QuantizedRows(NamedTuple):
    """An int8 wire batch kept in its wire format: ``q`` (n, F) uint8
    codes with per-row affine metadata ``mn``/``scale`` (n, 1) float32;
    row i dequantizes to ``mn[i] + q[i] * scale[i]``.

    This is the type the int8-in/fp32-accumulate kernel path consumes
    directly (:func:`repro.kernels.segment_sum.gather_scale_segment_sum_q_pallas`)
    — :meth:`FeatureStore.fetch_masked_wire` hands fetched rows to the
    aggregation without a decode round-trip.  Fields may be numpy or jax
    arrays; as a NamedTuple it is automatically a jax pytree.
    """
    q: "np.ndarray"
    mn: "np.ndarray"
    scale: "np.ndarray"

    @property
    def num_rows(self) -> int:
        return self.q.shape[0]

    def rows(self, index) -> "QuantizedRows":
        """Row-sliced view (same wire format)."""
        return QuantizedRows(self.q[index], self.mn[index],
                             self.scale[index])

    def dequantize(self):
        """The receiver's float32 view — identical math to
        :meth:`Int8Codec.decode` (``mn + q * scale``)."""
        return (self.mn + self.q.astype("float32") * self.scale
                ).astype("float32")

# per-RPC envelope cost of one remote transfer (DistDGL KVStore-style
# request header: keys, shard route, lengths) — charged once per send
# that actually moves rows, never for sends fully served locally.  This
# is the ONE definition; `core.caching` and `core.halo` import it.
HEADER_BYTES = 64

# int8 per-row affine metadata: row offset (min) + quantization step
# (scale), one float32 each
INT8_ROW_META_BYTES = 8


# ---------------------------------------------------------------------------
# bfloat16 emulation (numpy has no native bf16)
# ---------------------------------------------------------------------------

def _bf16_bits(x: np.ndarray) -> np.ndarray:
    """float32 -> bfloat16 bit pattern (uint16), round-to-nearest-even —
    matches jnp's ``astype(bfloat16)`` on finite values."""
    b = np.ascontiguousarray(x, np.float32).view(np.uint32)
    rounded = b + np.uint32(0x7FFF) + ((b >> np.uint32(16)) & np.uint32(1))
    return (rounded >> np.uint32(16)).astype(np.uint16)


def _bf16_value(bits: np.ndarray) -> np.ndarray:
    """bfloat16 bit pattern (uint16) -> float32 value."""
    return (bits.astype(np.uint32) << np.uint32(16)).view(np.float32)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WirePayload:
    """One encoded message body: opaque per-codec arrays + its wire size.

    ``data`` is codec-specific (quantized values, row metadata); only
    ``nbytes`` (payload bytes on the wire, excluding the per-RPC header)
    and ``n_rows`` are interpreted by :class:`Transport`.
    """
    codec: str
    n_rows: int
    nbytes: int
    data: tuple


class WireCodec:
    """A wire encoding for float32 row batches.

    Subclasses define ``name``, :meth:`wire_bytes_per_row`,
    :meth:`encode` / :meth:`decode` (host-side, numpy), and
    :meth:`jax_qdq` (the jit-safe quantize-dequantize used inside
    ``forward_stale``).  ``identity`` marks the lossless fp32 codec so
    hot paths can skip encode/decode entirely and stay bit-exact;
    ``error_feedback`` marks codecs whose senders should keep residuals.
    """

    name: str = "abstract"
    identity: bool = False
    error_feedback: bool = False

    def wire_bytes_per_row(self, dim: int) -> int:
        """Payload bytes one ``dim``-wide row occupies on the wire
        (excluding the per-RPC :data:`HEADER_BYTES` envelope)."""
        raise NotImplementedError

    def encode(self, rows: np.ndarray) -> WirePayload:
        """Encode ``(n, dim)`` float rows into a wire payload."""
        raise NotImplementedError

    def decode(self, payload: WirePayload) -> np.ndarray:
        """Decode a payload back to ``(n, dim)`` float rows (what the
        receiver sees; lossy codecs do not round-trip exactly)."""
        raise NotImplementedError

    def qdq(self, rows: np.ndarray) -> np.ndarray:
        """Host-side quantize→dequantize: the receiver's view of ``rows``."""
        return self.decode(self.encode(rows))

    def jax_qdq(self, x):
        """Jit-safe quantize→dequantize (``jnp`` in, ``jnp`` out) for
        applying the wire loss inside a compiled step."""
        raise NotImplementedError


class Fp32Codec(WireCodec):
    """Identity codec: 4 bytes/element, bit-exact — today's raw-fp32 wire
    format, kept as the behavior-preserving default."""

    name = "fp32"
    identity = True

    def wire_bytes_per_row(self, dim: int) -> int:
        """4 bytes per element, no row metadata."""
        return 4 * dim

    def encode(self, rows: np.ndarray) -> WirePayload:
        """Pass-through (the payload carries the rows verbatim)."""
        rows = np.asarray(rows)
        return WirePayload(self.name, len(rows),
                           self.wire_bytes_per_row(rows.shape[1])
                           * len(rows), (rows,))

    def decode(self, payload: WirePayload) -> np.ndarray:
        """Pass-through."""
        return payload.data[0]

    def qdq(self, rows: np.ndarray) -> np.ndarray:
        """Identity (no copy): fp32 is lossless."""
        return np.asarray(rows)

    def jax_qdq(self, x):
        """Identity."""
        return x


class Bf16Codec(WireCodec):
    """Truncating bfloat16 codec: 2 bytes/element, relative error
    ≤ 2⁻⁸ per element (8-bit mantissa), no per-row metadata."""

    name = "bf16"

    def wire_bytes_per_row(self, dim: int) -> int:
        """2 bytes per element, no row metadata."""
        return 2 * dim

    def encode(self, rows: np.ndarray) -> WirePayload:
        """Round-to-nearest-even each float32 to its top 16 bits."""
        rows = np.asarray(rows, np.float32)
        return WirePayload(self.name, len(rows),
                           self.wire_bytes_per_row(rows.shape[1])
                           * len(rows), (_bf16_bits(rows),))

    def decode(self, payload: WirePayload) -> np.ndarray:
        """Re-widen the 16-bit pattern to float32."""
        return _bf16_value(payload.data[0])

    def jax_qdq(self, x):
        """Round-trip through ``jnp.bfloat16`` (round-to-nearest-even)."""
        import jax.numpy as jnp
        return x.astype(jnp.bfloat16).astype(jnp.float32)


class Int8Codec(WireCodec):
    """Per-row affine uint8 quantization with sender-side error feedback.

    Each row is encoded as ``q = round((x - min) / scale)`` with
    ``scale = (max - min) / 255`` — 1 byte/element plus
    :data:`INT8_ROW_META_BYTES` of float32 ``(min, scale)`` metadata.
    The per-element error is bounded by ``scale / 2`` (half a
    quantization step, property-tested in ``tests/test_comm.py``).

    ``error_feedback = True``: a :class:`Transport` (or the in-step
    residual carried by ``forward_stale``) adds the previous send's
    quantization error to the next send of the same row before encoding,
    so the running mean of decoded sends converges to the true value —
    repeated ghost refreshes accumulate no bias.
    """

    name = "int8"
    error_feedback = True

    def wire_bytes_per_row(self, dim: int) -> int:
        """1 byte per element + per-row (min, scale) metadata."""
        return dim + INT8_ROW_META_BYTES

    def encode(self, rows: np.ndarray) -> WirePayload:
        """Quantize each row against its own float32 (min, scale)."""
        rows = np.asarray(rows)
        n, dim = rows.shape
        if n == 0:
            return WirePayload(self.name, 0, 0,
                               (np.zeros((0, dim), np.uint8),
                                np.zeros((0, 1), np.float32),
                                np.zeros((0, 1), np.float32)))
        # metadata is float32 on the wire; quantize against the rounded
        # values so the scale/2 error bound holds for what was sent
        mn = rows.min(axis=1, keepdims=True).astype(np.float32)
        mx = rows.max(axis=1, keepdims=True).astype(np.float32)
        scale = ((mx.astype(np.float64) - mn) / 255.0).astype(np.float32)
        safe = np.where(scale > 0, scale, 1.0).astype(np.float64)
        q = np.rint((rows.astype(np.float64) - mn) / safe)
        q = np.clip(np.where(scale > 0, q, 0.0), 0, 255).astype(np.uint8)
        return WirePayload(self.name, n,
                           n * self.wire_bytes_per_row(dim),
                           (q, mn, scale))

    def decode(self, payload: WirePayload) -> np.ndarray:
        """``min + q * scale`` in float64, emitted as float32."""
        q, mn, scale = payload.data
        return (mn.astype(np.float64)
                + q.astype(np.float64) * scale.astype(np.float64)
                ).astype(np.float32)

    def jax_qdq(self, x):
        """Jit-safe per-row affine quantize→dequantize (no error
        feedback here — the caller carries residual state)."""
        import jax.numpy as jnp
        mn = jnp.min(x, axis=-1, keepdims=True)
        mx = jnp.max(x, axis=-1, keepdims=True)
        scale = (mx - mn) / 255.0
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round((x - mn) / safe), 0.0, 255.0)
        return jnp.where(scale > 0, mn + q * scale, mn)


CODECS: Dict[str, WireCodec] = {
    c.name: c for c in (Fp32Codec(), Bf16Codec(), Int8Codec())
}


def resolve_codec(codec: Union[str, WireCodec, None]) -> WireCodec:
    """Normalize a codec name / instance / ``None`` (→ fp32) to a
    :class:`WireCodec`, raising ``KeyError`` on unknown names."""
    if codec is None:
        return CODECS["fp32"]
    if isinstance(codec, WireCodec):
        return codec
    if codec not in CODECS:
        raise KeyError(f"unknown wire codec {codec!r}; "
                       f"choose from {sorted(CODECS)}")
    return CODECS[codec]


# ---------------------------------------------------------------------------
# transport: framing + accounting + error-feedback state
# ---------------------------------------------------------------------------

class ResidualStore:
    """Lazily grown per-row error-feedback state for a sender.

    Only rows that have actually crossed the wire get a residual row —
    a partition's remote-fetch path touches its halo set, a small
    fraction of a big graph, so a dense ``(num_nodes, dim)`` value
    buffer would dwarf the feature matrix itself.  The id→slot map is a
    dense int32 vector (4 bytes per id — negligible), keeping gather and
    scatter fully vectorized on the fetch hot path; residuals are
    bounded by half a quantization step, so float32 values are plenty.
    """

    def __init__(self, n_rows: int, dim: int):
        self.dim = dim
        self._slot = np.full(n_rows, -1, np.int32)
        self._used = 0
        self._buf = np.zeros((16, dim), np.float32)

    def gather(self, row_ids: np.ndarray) -> np.ndarray:
        """Current residual rows for ``row_ids`` (zeros if never sent)."""
        slots = self._slot[np.asarray(row_ids)]
        out = np.zeros((len(slots), self.dim), np.float32)
        known = slots >= 0
        out[known] = self._buf[slots[known]]
        return out

    def scatter(self, row_ids: np.ndarray, values: np.ndarray) -> None:
        """Store updated residual rows (allocating slots on first send)."""
        row_ids = np.asarray(row_ids)
        fresh = np.unique(row_ids[self._slot[row_ids] < 0])
        if len(fresh):
            self._slot[fresh] = self._used + np.arange(len(fresh),
                                                       dtype=np.int32)
            self._used += len(fresh)
            while self._used > len(self._buf):
                self._buf = np.concatenate(
                    [self._buf, np.zeros_like(self._buf)])
        self._buf[self._slot[row_ids]] = values.astype(np.float32)


class Transport:
    """One byte-accounted sender→receiver channel over a wire codec.

    Every remote transfer in the repo is a :meth:`send`: the payload is
    encoded, charged as ``n_rows × wire_bytes_per_row + HEADER_BYTES``
    (one envelope per RPC that moves rows — a zero-row send is free and
    unframed), decoded, and the receiver's view returned.  For
    error-feedback codecs constructed with ``n_rows``, the channel keeps
    one residual row per sender-side row id (grown lazily, only for rows
    that actually cross the wire): ``send(x)`` transmits ``Q(x + r)``
    and stores ``r' = (x + r) - decode(Q(x + r))``, so repeated sends of
    a row are unbiased on average.

    Args:
        codec: wire codec name or instance.
        n_rows: sender-side row-id space for error-feedback residuals
            (``None`` = stateless sends, residuals disabled; the value
            bounds nothing — residual rows are allocated per *touched*
            id via :class:`ResidualStore`).
        path: telemetry label naming the transfer path this channel
            serves (``"serving.features"``, ``"minibatch.features"``,
            ``"serving.fill"``, ...).  Every send is mirrored into the
            process telemetry plane (:mod:`repro.core.telemetry`) as
            ``comm_bytes_total{path,codec,kind=payload|header}`` /
            ``comm_rows_total`` / ``comm_sends_total`` — transports
            sharing a path aggregate into the same series.
    """

    def __init__(self, codec: Union[str, WireCodec] = "fp32", *,
                 n_rows: Optional[int] = None, path: str = "default"):
        self.codec = resolve_codec(codec)
        self.path = path
        self._n_rows = n_rows if n_rows else 0
        self._ef_enabled = bool(n_rows) and self.codec.error_feedback
        self.residuals: Optional[ResidualStore] = None    # lazy, per dim
        self.payload_bytes = 0
        self.header_bytes = 0
        self.rows_sent = 0
        self.requests = 0
        lab = dict(path=path, codec=self.codec.name)
        self._m_payload = telemetry.counter(
            "comm_bytes_total", "bytes moved by the communication plane",
            kind="payload", **lab)
        self._m_header = telemetry.counter(
            "comm_bytes_total", kind="header", **lab)
        self._m_rows = telemetry.counter(
            "comm_rows_total", "rows moved by the communication plane",
            **lab)
        self._m_sends = telemetry.counter(
            "comm_sends_total", "RPCs issued by the communication plane",
            **lab)

    def _record(self, payload: int, n_rows: int) -> None:
        """Mirror one accounted send into the telemetry plane."""
        self._m_payload.inc(payload)
        self._m_header.inc(HEADER_BYTES)
        self._m_rows.inc(n_rows)
        self._m_sends.inc()

    @property
    def total_bytes(self) -> int:
        """Payload + per-RPC envelope bytes moved so far."""
        return self.payload_bytes + self.header_bytes

    def _residuals_for(self, dim: int) -> Optional[ResidualStore]:
        if not self._ef_enabled:
            return None
        if self.residuals is None or self.residuals.dim != dim:
            self.residuals = ResidualStore(self._n_rows, dim)
        return self.residuals

    def send(self, rows: np.ndarray,
             row_ids: Optional[np.ndarray] = None) -> np.ndarray:
        """One RPC: encode → account → decode; returns the receiver's
        float32 view of ``rows``.  ``row_ids`` keys the error-feedback
        residuals (ignored for stateless codecs/transports).  A zero-row
        send returns immediately and charges nothing."""
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2:
            raise ValueError(f"send expects (n, dim) rows, got {rows.shape}")
        n, dim = rows.shape
        if n == 0:
            return rows
        if self.codec.identity:
            # fast path: fp32 is the wire format — account the send and
            # hand the rows through untouched (zero copies on the
            # default-codec hot paths)
            payload = n * self.codec.wire_bytes_per_row(dim)
            self.payload_bytes += payload
            self.header_bytes += HEADER_BYTES
            self.rows_sent += n
            self.requests += 1
            self._record(payload, n)
            return rows
        res = self._residuals_for(dim)
        if res is not None and row_ids is not None:
            row_ids = np.asarray(row_ids)
            pre = rows.astype(np.float64) + res.gather(row_ids)
            payload = self.codec.encode(pre)
            out = self.codec.decode(payload)
            res.scatter(row_ids, pre - out)
            out = out.astype(np.float32)
        else:
            payload = self.codec.encode(rows)
            out = self.codec.decode(payload).astype(np.float32)
        self.payload_bytes += payload.nbytes
        self.header_bytes += HEADER_BYTES
        self.rows_sent += n
        self.requests += 1
        self._record(payload.nbytes, n)
        return out

    def send_wire(self, rows: np.ndarray,
                  row_ids: Optional[np.ndarray] = None) -> QuantizedRows:
        """One RPC that hands the receiver the *wire format* instead of
        the decoded view: identical accounting and error-feedback
        residual updates to :meth:`send`, but the int8 payload is
        returned as :class:`QuantizedRows` so the receiver can feed it
        straight into the int8-in/fp32-accumulate kernel — no decode
        round-trip through an HBM-resident fp32 feature matrix.

        Only meaningful for the ``int8`` codec (the one wire format the
        kernel consumes); other codecs raise."""
        if self.codec.name != "int8":
            raise ValueError(
                f"send_wire requires the int8 codec (got "
                f"{self.codec.name!r}); use send() for decoded rows")
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2:
            raise ValueError(f"send_wire expects (n, dim) rows, got "
                             f"{rows.shape}")
        n, dim = rows.shape
        if n == 0:
            return QuantizedRows(np.zeros((0, dim), np.uint8),
                                 np.zeros((0, 1), np.float32),
                                 np.zeros((0, 1), np.float32))
        res = self._residuals_for(dim)
        if res is not None and row_ids is not None:
            row_ids = np.asarray(row_ids)
            pre = rows.astype(np.float64) + res.gather(row_ids)
            payload = self.codec.encode(pre)
            res.scatter(row_ids, pre - self.codec.decode(payload))
        else:
            payload = self.codec.encode(rows)
        self.payload_bytes += payload.nbytes
        self.header_bytes += HEADER_BYTES
        self.rows_sent += n
        self.requests += 1
        self._record(payload.nbytes, n)
        q, mn, scale = payload.data
        return QuantizedRows(q, mn, scale)

    def account_opaque(self, n_rows: int, bytes_per_row: int) -> None:
        """Charge a send whose payload is not float rows (e.g. raw node
        ids on a feature-less graph): same framing, no codec."""
        if n_rows <= 0:
            return
        self.payload_bytes += n_rows * bytes_per_row
        self.header_bytes += HEADER_BYTES
        self.rows_sent += n_rows
        self.requests += 1
        self._record(n_rows * bytes_per_row, n_rows)

    def reset_counters(self) -> None:
        """Zero the traffic counters (error-feedback residuals are kept —
        they are sender state, not accounting).  Used to exclude warmup
        traffic from reported stats.  The channel's telemetry series are
        reset too so the exposed ``comm_*`` numbers keep matching the
        instance counters (note: transports sharing a ``path`` share the
        series, so a reset excludes *everyone's* pre-reset traffic — in
        practice same-path transports are reset together, e.g. serving
        warmup)."""
        self.payload_bytes = 0
        self.header_bytes = 0
        self.rows_sent = 0
        self.requests = 0
        for m in (self._m_payload, self._m_header, self._m_rows,
                  self._m_sends):
            m.reset()

    def stats(self) -> dict:
        """Lifetime channel counters for summaries."""
        return {
            "wire_codec": self.codec.name,
            "payload_bytes": self.payload_bytes,
            "header_bytes": self.header_bytes,
            "total_bytes": self.total_bytes,
            "rows_sent": self.rows_sent,
            "requests": self.requests,
        }
