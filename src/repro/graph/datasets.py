"""Named synthetic dataset registry (survey Table 9 stand-ins).

No external downloads are available in this container, so each registry
entry is a deterministic synthetic graph whose *shape class* matches a
dataset family from the survey's Table 9 (size, density, degree skew,
task) — enough to exercise every system path at the right regime.

Each entry returns a featurized Graph plus train/val/test node masks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.graph import generators as G
from repro.graph.structure import Graph


@dataclasses.dataclass
class Dataset:
    name: str
    graph: Graph
    train_mask: np.ndarray
    val_mask: np.ndarray
    test_mask: np.ndarray
    task: str                      # vertex | edge


def _splits(n: int, rng, train=0.6, val=0.2):
    order = rng.permutation(n)
    tr = np.zeros(n, bool)
    va = np.zeros(n, bool)
    te = np.zeros(n, bool)
    a, b = int(n * train), int(n * (train + val))
    tr[order[:a]] = True
    va[order[a:b]] = True
    te[order[b:]] = True
    return tr, va, te


def _make(name: str, g: Graph, seed: int, task="vertex") -> Dataset:
    rng = np.random.default_rng(seed + 1000)
    tr, va, te = _splits(g.num_nodes, rng)
    return Dataset(name, g, tr, va, te, task)


def citeseer_like(seed: int = 0) -> Dataset:
    """~3k nodes, ~1.4 avg degree, 6 classes (citation-graph regime)."""
    g = G.sbm(3300, 6, p_in=0.15, p_out=0.002, seed=seed)
    g = G.featurize(g, 64, seed=seed, class_sep=1.2)
    return _make("citeseer-like", g, seed)


def pubmed_like(seed: int = 0) -> Dataset:
    """~20k nodes, low density, 3 classes."""
    g = G.sbm(19_700, 3, p_in=0.05, p_out=0.001, seed=seed)
    g = G.featurize(g, 128, seed=seed, class_sep=1.0)
    return _make("pubmed-like", g, seed)


def reddit_like(seed: int = 0, scale: float = 0.02) -> Dataset:
    """Power-law community graph (Reddit regime, scaled by ``scale`` so it
    runs on CPU: default ~4.7k nodes, heavy-tailed degrees)."""
    n = int(233_000 * scale)
    g = G.barabasi_albert(n, 8, seed=seed)
    g = G.featurize(g, 64, seed=seed, num_classes=16, class_sep=1.0)
    return _make("reddit-like", g, seed)


def livejournal_like(seed: int = 0, scale: float = 0.002) -> Dataset:
    """Large sparse social graph (LiveJournal regime, scaled)."""
    n = int(4_847_000 * scale)
    g = G.barabasi_albert(n, 7, seed=seed)
    g = G.featurize(g, 32, seed=seed, num_classes=8)
    return _make("livejournal-like", g, seed, task="edge")


DATASETS = {
    "citeseer-like": citeseer_like,
    "pubmed-like": pubmed_like,
    "reddit-like": reddit_like,
    "livejournal-like": livejournal_like,
}


def load(name: str, **kw) -> Dataset:
    return DATASETS[name](**kw)
