"""Synthetic graph generators (deterministic, numpy-only, fast at 1e5+ nodes).

These supply the survey-claim experiments: power-law graphs for the
vertex-cut/replication-factor claims (PowerGraph/PowerLyra), community
graphs for ClusterGCN-style sampling, grids for 2D partitioning.
"""
from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph, from_edges, make_undirected


def erdos_renyi(n: int, avg_degree: float, *, seed: int = 0,
                directed: bool = True) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    e = np.stack([src[keep], dst[keep]], axis=1)
    if directed:
        return from_edges(n, e)
    return make_undirected(n, e)


def barabasi_albert(n: int, m: int, *, seed: int = 0) -> Graph:
    """Power-law (preferential attachment) graph — 'natural graph' with
    skewed degree distribution (PowerGraph's motivating case)."""
    rng = np.random.default_rng(seed)
    targets = list(range(m))
    repeated: list = list(range(m))
    edges = []
    for v in range(m, n):
        # preferential attachment: sample from the degree-weighted pool
        idx = rng.integers(0, len(repeated), m)
        chosen = np.unique(np.asarray([repeated[i] for i in idx]))
        for t in chosen:
            edges.append((v, t))
        repeated.extend(chosen.tolist())
        repeated.extend([v] * len(chosen))
    return make_undirected(n, np.asarray(edges, np.int64))


def sbm(n: int, n_blocks: int, p_in: float, p_out: float, *,
        seed: int = 0) -> Graph:
    """Stochastic block model with planted communities; labels = block id."""
    rng = np.random.default_rng(seed)
    block = rng.integers(0, n_blocks, n)
    # expected edges: sample pairs then filter by block-dependent prob
    m = int(n * (p_in + p_out) * 40)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    same = block[src] == block[dst]
    prob = np.where(same, p_in, p_out)
    keep = (rng.random(m) < prob) & (src != dst)
    g = make_undirected(n, np.stack([src[keep], dst[keep]], 1))
    g.labels = block.astype(np.int32)
    g.num_classes = n_blocks
    return g


def grid2d(rows: int, cols: int) -> Graph:
    idx = np.arange(rows * cols).reshape(rows, cols)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))
    return make_undirected(rows * cols, np.concatenate(e, 0))


def featurize(g: Graph, feat_dim: int, *, seed: int = 0,
              num_classes: int = 0, class_sep: float = 2.0) -> Graph:
    """Attach Gaussian class-clustered features (and labels if absent) so
    node classification is learnable — the synthetic stand-in for
    CORA/Reddit-style datasets (survey Table 9)."""
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    if g.labels is None:
        if num_classes <= 0:
            num_classes = 8
        g.labels = rng.integers(0, num_classes, n).astype(np.int32)
        g.num_classes = num_classes
    k = g.num_classes
    centers = rng.normal(0, class_sep, (k, feat_dim))
    g.features = (centers[g.labels]
                  + rng.normal(0, 1.0, (n, feat_dim))).astype(np.float32)
    return g
